#!/usr/bin/env python3
"""Perf-trajectory gate: diff BENCH_*.json outputs against the committed
baseline (BENCH_baseline.json) and fail on regression.

The benches already hard-gate their own targets (they exit non-zero when a
target is missed); this comparator adds the *trajectory* check on top:
every gated metric must stay within 10% of its baseline value, so a PR
that keeps a bench barely above its floor while eroding a 10x win into a
4x win still fails CI.

Baseline entries are machine-independent ratios (allocation/copy/message
reductions, speedups, byte counts), never wall-clock times, so the check
is stable across runners. Each entry:

    {"file": "BENCH_alloc.json", "path": "reduction_x_at_batch8",
     "direction": "higher", "value": 10.0}

`direction: "higher"` means bigger is better (regression = current <
0.9 * baseline); `"lower"` means smaller is better (regression = current >
1.1 * baseline, so a 0.0 baseline tolerates exactly 0.0).

A baseline entry whose BENCH file was not produced by this run is skipped
with a note (CI's bench steps each emit a subset); a produced file missing
the metric's path is a hard failure (schema drift must be loud). Any
`"target_met": false` anywhere in a produced file also fails.

Usage: python3 scripts/check_bench.py [--baseline PATH] [--dir DIR]
Only the standard library is used.
"""

import argparse
import glob
import json
import os
import sys

TOLERANCE = 0.10


def lookup(doc, dotted):
    """Resolve 'a.b.c' in nested dicts; None when any hop is missing."""
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def failed_target_flags(doc, prefix=""):
    """All paths in `doc` where a `target_met` flag is false."""
    bad = []
    if isinstance(doc, dict):
        for key, val in doc.items():
            path = f"{prefix}{key}"
            if key == "target_met" and val is False:
                bad.append(path)
            bad.extend(failed_target_flags(val, path + "."))
    elif isinstance(doc, list):
        for i, val in enumerate(doc):
            bad.extend(failed_target_flags(val, f"{prefix}{i}."))
    return bad


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--dir", default=".", help="directory holding BENCH_*.json outputs")
    args = ap.parse_args()

    with open(args.baseline, encoding="utf-8") as fh:
        baseline = json.load(fh)

    docs = {}
    failures = []
    checked = 0
    skipped = []

    for entry in baseline["metrics"]:
        fname, path = entry["file"], entry["path"]
        fpath = os.path.join(args.dir, fname)
        if fname not in docs:
            if not os.path.exists(fpath):
                docs[fname] = None
            else:
                with open(fpath, encoding="utf-8") as fh:
                    docs[fname] = json.load(fh)
        doc = docs[fname]
        if doc is None:
            skipped.append(f"{fname}:{path}")
            continue
        current = lookup(doc, path)
        if not isinstance(current, (int, float)) or isinstance(current, bool):
            failures.append(f"{fname}:{path} missing or non-numeric (schema drift?)")
            continue
        base, direction = float(entry["value"]), entry["direction"]
        if direction == "higher":
            ok = current >= base * (1.0 - TOLERANCE)
            bound = f">= {base * (1.0 - TOLERANCE):.4g}"
        elif direction == "lower":
            ok = current <= base * (1.0 + TOLERANCE)
            bound = f"<= {base * (1.0 + TOLERANCE):.4g}"
        else:
            failures.append(f"{fname}:{path} has unknown direction {direction!r}")
            continue
        checked += 1
        verdict = "ok" if ok else "REGRESSED"
        print(f"{verdict:>9}  {fname}:{path} = {current:.4g} (baseline {base:.4g}, want {bound})")
        if not ok:
            failures.append(f"{fname}:{path} = {current:.4g} vs baseline {base:.4g} ({bound})")

    # every produced BENCH file (baseline-listed or not) must have all its
    # own gates green
    for fpath in sorted(glob.glob(os.path.join(args.dir, "BENCH_*.json"))):
        fname = os.path.basename(fpath)
        if fname == os.path.basename(args.baseline):
            continue
        if docs.get(fname) is None:
            with open(fpath, encoding="utf-8") as fh:
                docs[fname] = json.load(fh)
        for flag in failed_target_flags(docs[fname]):
            failures.append(f"{fname}:{flag} is false (bench-local gate missed)")

    if skipped:
        print(f"skipped {len(skipped)} baseline metrics (bench not run): {', '.join(skipped)}")
    if checked == 0:
        print("error: no BENCH_*.json outputs matched the baseline — did the benches run?")
        return 1
    if failures:
        print(f"\nperf trajectory check FAILED ({len(failures)} regression(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nperf trajectory check passed: {checked} gated metrics within {TOLERANCE:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
