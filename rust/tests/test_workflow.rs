//! Integration: the full five-kernel PAL workflow over synthetic kernels
//! (no artifacts needed — the HLO path is covered by test_e2e.rs).

use std::sync::Arc;
use std::time::Duration;

use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::{CommitteeStdUtils, SelectAllUtils};
use pal::coordinator::workflow::Workflow;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::sim::workload::{SyntheticGenerator, SyntheticModel, SyntheticOracle};

fn setting(gene: usize, pred: usize, orcl: usize, ml: usize) -> AlSetting {
    AlSetting {
        result_dir: format!("/tmp/pal-test-{gene}-{pred}-{orcl}-{ml}"),
        gene_process: gene,
        pred_process: pred,
        orcl_process: orcl,
        ml_process: ml,
        retrain_size: 4,
        stop: StopCriteria {
            max_iterations: Some(40),
            max_labels: None,
            max_wall: Some(Duration::from_secs(30)),
            ..Default::default()
        },
        ..Default::default()
    }
}

fn synthetic_kernels(s: &AlSetting, threshold: f32) -> KernelSet {
    let generators = (0..s.gene_process)
        .map(|i| {
            let seed = i as u64;
            Box::new(move || {
                Box::new(SyntheticGenerator::new(4, Duration::ZERO, u64::MAX, seed))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..s.orcl_process)
        .map(|_| {
            Box::new(|| {
                Box::new(SyntheticOracle { label_cost: Duration::from_millis(1), out_dim: 4 })
                    as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, replica: usize| {
        let mut m =
            SyntheticModel::new(4, 4, Duration::ZERO, Duration::from_micros(200), 64, mode);
        // diversify members so the committee has nonzero std
        let w: Vec<f32> = (0..16).map(|k| ((k + replica * 7) % 5) as f32 * 0.1).collect();
        m.update(&w);
        Box::new(m) as Box<dyn Model>
    });
    let utils =
        Arc::new(move || Box::new(CommitteeStdUtils::new(threshold, 8)) as Box<dyn Utils>);
    KernelSet { generators, oracles, model, utils }
}

#[test]
fn full_workflow_runs_and_stops() {
    let s = setting(6, 3, 2, 3);
    let mut kernels = synthetic_kernels(&s, 0.01);
    // pace the exchange loop (2 ms/step) so labeling + retraining overlap
    // the run instead of racing the 40-iteration bound
    kernels.generators = (0..6usize)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(
                    4,
                    Duration::from_millis(2),
                    u64::MAX,
                    i as u64,
                )) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let report = Workflow::new(s).run(kernels).unwrap();
    assert_eq!(report.al_iterations, 40);
    assert!(report.oracle_labels > 0, "uncertain committee should trigger labeling");
    assert!(report.retrain_rounds > 0, "labels should trigger retraining");
    assert!(report.wall < Duration::from_secs(30));
    // every generator stepped every iteration (lockstep loop)
    let gen_steps = report.sum_counter("generator", "steps");
    assert!(gen_steps >= 40 * 6, "generators stepped {gen_steps}");
}

#[test]
fn max_labels_stops_the_run() {
    let mut s = setting(4, 2, 2, 2);
    s.stop.max_iterations = None;
    s.stop.max_labels = Some(5);
    let kernels = synthetic_kernels(&s, 0.0); // everything uncertain
    let report = Workflow::new(s).run(kernels).unwrap();
    assert!(report.oracle_labels >= 5, "labels {}", report.oracle_labels);
    assert!(report.oracle_labels < 200, "should stop promptly after 5");
}

#[test]
fn inference_only_mode_runs_without_oracle_and_training() {
    // §2.5: oracle and training kernels can be disabled
    let s = setting(5, 2, 0, 0);
    let kernels = synthetic_kernels(&s, 0.01);
    let report = Workflow::new(s).run(kernels).unwrap();
    assert_eq!(report.al_iterations, 40);
    assert_eq!(report.oracle_labels, 0);
    assert_eq!(report.retrain_rounds, 0);
}

#[test]
fn generator_stop_signal_shuts_down_workflow() {
    let mut s = setting(3, 2, 1, 2);
    s.stop.max_iterations = None; // only the generator can stop the run
    s.stop.max_wall = Some(Duration::from_secs(20));
    let generators = (0..3usize)
        .map(|i| {
            Box::new(move || {
                // generator 0 signals stop after 10 steps
                let max = if i == 0 { 10 } else { u64::MAX };
                Box::new(SyntheticGenerator::new(4, Duration::ZERO, max, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let mut kernels = synthetic_kernels(&s, 0.5);
    kernels.generators = generators;
    let report = Workflow::new(s).run(kernels).unwrap();
    assert!(report.al_iterations >= 9 && report.al_iterations < 1000,
        "iterations {}", report.al_iterations);
}

#[test]
fn no_sample_lost_between_oracle_and_training() {
    // conservation: labels produced == datapoints delivered to each trainer
    // (within one retrain_size of in-flight buffering at shutdown)
    let mut s = setting(4, 2, 2, 2);
    s.retrain_size = 3;
    s.stop.max_iterations = Some(30);
    let kernels = synthetic_kernels(&s, 0.0);
    let report = Workflow::new(s.clone()).run(kernels).unwrap();
    let labels = report.oracle_labels;
    // each trainer receives the same broadcast batches
    for t in report.kernel("training") {
        let got = t.counter("datapoints");
        assert!(
            got <= labels && got + (s.retrain_size as u64) + 3 >= labels / 1, // got in [labels - buffered, labels]
            "trainer {} got {got} of {labels} labels",
            t.rank
        );
    }
}

#[test]
fn weight_updates_reach_predictors() {
    let s = setting(4, 2, 2, 2);
    let kernels = synthetic_kernels(&s, 0.0);
    let report = Workflow::new(s).run(kernels).unwrap();
    let updates = report.sum_counter("prediction", "weight_updates");
    assert!(updates >= 2, "predictors saw {updates} weight updates");
}

#[test]
fn dynamic_oracle_list_rescoring_runs() {
    let mut s = setting(4, 2, 1, 2);
    s.dynamic_oracle_list = true;
    s.retrain_size = 2;
    // run until enough labels accumulated that at least one retraining
    // finished while the oracle buffer was non-empty
    s.stop.max_iterations = None;
    s.stop.max_labels = Some(12);
    let mut kernels = synthetic_kernels(&s, 0.0);
    kernels.oracles = (0..1)
        .map(|_| {
            Box::new(|| {
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(5),
                    out_dim: 4,
                }) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let report = Workflow::new(s).run(kernels).unwrap();
    // the manager should have attempted at least one rescoring round
    let manager = &report.kernel("manager")[0];
    let adjustments = manager.counter("adjustments") + manager.counter("adjust_timeouts");
    assert!(adjustments > 0, "dynamic oracle list never exercised: {:?}", manager.counters);
}

#[test]
fn select_all_utils_labels_at_full_rate() {
    let mut s = setting(3, 1, 3, 1);
    s.stop.max_iterations = Some(10);
    s.retrain_size = 100; // never flush; isolate labeling
    let mut kernels = synthetic_kernels(&s, 0.0);
    // pace the exchange loop so the (fast) oracles keep up — otherwise the
    // run shuts down with the selection buffer still queued, which is
    // correct PAL semantics but not what this test measures
    kernels.generators = (0..3usize)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(
                    4,
                    Duration::from_millis(4),
                    u64::MAX,
                    i as u64,
                )) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    kernels.utils = Arc::new(|| Box::new(SelectAllUtils { max_per_iter: 3 }) as Box<dyn Utils>);
    let report = Workflow::new(s).run(kernels).unwrap();
    // 10 iterations × 3 selected, minus in-flight at shutdown
    assert!(report.oracle_labels >= 15, "labels {}", report.oracle_labels);
}

#[test]
fn comm_latency_slows_but_does_not_break() {
    let mut s = setting(3, 2, 1, 2);
    s.comm_latency = Duration::from_millis(2);
    s.stop.max_iterations = Some(10);
    let kernels = synthetic_kernels(&s, 0.1);
    let report = Workflow::new(s).run(kernels).unwrap();
    assert_eq!(report.al_iterations, 10);
    // each iteration pays ≥ 2 latency hops on the gen→pred→gen path
    assert!(report.wall >= Duration::from_millis(10 * 4));
}
