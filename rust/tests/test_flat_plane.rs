//! Allocation-count regression for the flat data plane.
//!
//! Pins the PR-3 acceptance criterion: decoding a `PredictBatch[Result]`
//! frame and running the committee reductions over it performs **zero
//! per-row heap allocations** — the allocation count of the hot region is a
//! small constant, independent of the batch size.
//!
//! This file installs a counting global allocator and therefore contains
//! exactly ONE `#[test]`: the default test harness runs tests of a binary
//! concurrently, and any sibling test's allocations would pollute the
//! counters. Result-equivalence properties live in `test_props.rs`; this
//! binary only counts. The *training*-plane allocation bounds (label
//! decode → `add_trainingset_batch`, weight-payload fan-out) live in the
//! sibling single-test binary `test_flat_train.rs` for the same reason.

use pal::bench_util::alloc::{alloc_count, CountingAlloc};
use pal::comm::protocol::{
    decode_predict_batch_result, decode_predict_batch_result_rows, encode_predict_batch_result,
};
use pal::coordinator::selection::{committee_std, committee_std_batch, committee_std_check_batch};
use pal::data::batch::{Batch, BatchView};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

const MODELS: usize = 3;
const WIDTH: usize = 16;

/// Committee result frames (one per member) for `rows` items of `WIDTH`.
fn frames(rows: usize) -> Vec<Vec<f32>> {
    (0..MODELS)
        .map(|m| {
            let items: Vec<Vec<f32>> = (0..rows)
                .map(|i| (0..WIDTH).map(|k| ((m * 31 + i * 7 + k) % 13) as f32 * 0.1).collect())
                .collect();
            encode_predict_batch_result(1, &items)
        })
        .collect()
}

/// Allocations for one flat decode → `committee_std` pass over `rows` items.
fn flat_decode_std_allocs(frames: &[Vec<f32>]) -> u64 {
    let before = alloc_count();
    let views: [BatchView<'_>; MODELS] = [
        decode_predict_batch_result_rows(&frames[0]).unwrap().1,
        decode_predict_batch_result_rows(&frames[1]).unwrap().1,
        decode_predict_batch_result_rows(&frames[2]).unwrap().1,
    ];
    let stds = committee_std_batch(&views);
    std::hint::black_box(&stds);
    let delta = alloc_count() - before;
    drop(stds);
    delta
}

/// Allocations for one nested decode → `committee_std` pass (the baseline
/// this PR replaces).
fn nested_decode_std_allocs(frames: &[Vec<f32>]) -> u64 {
    let before = alloc_count();
    let preds: Vec<Vec<Vec<f32>>> = frames
        .iter()
        .map(|f| decode_predict_batch_result(f).unwrap().1)
        .collect();
    let stds = committee_std(&preds);
    std::hint::black_box(&stds);
    let delta = alloc_count() - before;
    drop((stds, preds));
    delta
}

/// Allocations for one full flat `prediction_check` (std + mean + top-k)
/// with nothing selected, so the candidate list stays empty and the region
/// is strictly batch-size-independent.
fn flat_check_allocs(frames: &[Vec<f32>], inputs: &Batch) -> u64 {
    let before = alloc_count();
    let views: [BatchView<'_>; MODELS] = [
        decode_predict_batch_result_rows(&frames[0]).unwrap().1,
        decode_predict_batch_result_rows(&frames[1]).unwrap().1,
        decode_predict_batch_result_rows(&frames[2]).unwrap().1,
    ];
    let out = committee_std_check_batch(&inputs.view(), &views, f32::MAX, 8);
    std::hint::black_box(&out);
    let delta = alloc_count() - before;
    drop(out);
    delta
}

#[test]
fn flat_decode_and_reduce_allocate_nothing_per_row() {
    let small_frames = frames(8);
    let large_frames = frames(64);
    let small_inputs = Batch::from_rows(
        &(0..8).map(|i| vec![i as f32; 4]).collect::<Vec<_>>(),
    )
    .unwrap();
    let large_inputs = Batch::from_rows(
        &(0..64).map(|i| vec![i as f32; 4]).collect::<Vec<_>>(),
    )
    .unwrap();

    // warm-up: lazy one-time allocations (fmt machinery etc.) out of the way
    let _ = flat_decode_std_allocs(&small_frames);
    let _ = nested_decode_std_allocs(&small_frames);
    let _ = flat_check_allocs(&small_frames, &small_inputs);

    // --- flat decode + committee_std: constant, tiny ---
    let flat_small = flat_decode_std_allocs(&small_frames);
    let flat_large = flat_decode_std_allocs(&large_frames);
    assert!(flat_small <= 2, "flat decode+std allocated {flat_small} times (want <= 2)");
    assert_eq!(
        flat_small, flat_large,
        "flat decode+std must not allocate per row (8 rows: {flat_small}, 64 rows: {flat_large})"
    );

    // --- full flat check (std + mean + empty top-k): constant ---
    let check_small = flat_check_allocs(&small_frames, &small_inputs);
    let check_large = flat_check_allocs(&large_frames, &large_inputs);
    assert!(check_small <= 8, "flat check allocated {check_small} times (want <= 8)");
    assert_eq!(
        check_small, check_large,
        "flat check must not allocate per row (8 rows: {check_small}, 64 rows: {check_large})"
    );

    // --- >= 10x fewer allocations per item than the nested baseline at
    //     batch size 8 (the PR's acceptance criterion) ---
    let nested_small = nested_decode_std_allocs(&small_frames);
    assert!(
        nested_small >= 10 * flat_small.max(1),
        "flat path saves too little: nested {nested_small} vs flat {flat_small} allocs at batch 8"
    );
}
