//! Integration: PJRT runtime round-trip against real artifacts.
//!
//! Requires `make artifacts`. These tests validate the numerics of the AOT
//! bridge — the same checks the python suite runs in-process, but through
//! the production path: HLO text → PJRT compile → execute.

use pal::runtime::{default_artifacts_dir, Engine, Manifest, TensorIn};

/// Skip (loudly) when the full HLO execution path is unavailable — no built
/// artifacts or no linked PJRT backend. Mirrors GPU-gated suites: coverage
/// runs wherever `make artifacts` + a real backend exist.
macro_rules! require_hlo {
    () => {
        if !pal::runtime::hlo_available() {
            eprintln!("skipping: PJRT backend/artifacts unavailable in this build");
            return;
        }
    };
}

fn engine() -> Engine {
    let m = Manifest::load(default_artifacts_dir()).expect("run `make artifacts` first");
    Engine::new(m).unwrap()
}

#[test]
fn toy_init_is_deterministic_and_member_diverse() {
    require_hlo!();
    let e = engine();
    let w1 = e.call("toy_init", &[TensorIn::U32(0)]).unwrap().remove(0);
    let w2 = e.call("toy_init", &[TensorIn::U32(0)]).unwrap().remove(0);
    assert_eq!(w1, w2);
    let p = e.entry("toy_init").unwrap().meta_usize("param_size").unwrap();
    let m0 = &w1[..p];
    let m1 = &w1[p..2 * p];
    assert!(m0.iter().zip(m1).any(|(a, b)| (a - b).abs() > 1e-4), "members identical");
}

#[test]
fn toy_train_descends_and_fwd_agrees() {
    require_hlo!();
    let e = engine();
    let entry = e.entry("toy_train_t10").unwrap();
    let p = entry.meta_usize("param_size").unwrap();
    let opt_size = entry.meta_usize("opt_size").unwrap();
    let w_all = e.call("toy_init", &[TensorIn::U32(1)]).unwrap().remove(0);
    let mut w = w_all[..p].to_vec();
    let mut opt = vec![0.0f32; opt_size];
    // learn y = x on a fixed batch
    let x: Vec<f32> = (0..40).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
    let y = x.clone();
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..60 {
        let out = e
            .call(
                "toy_train_t10",
                &[TensorIn::F32(&w), TensorIn::F32(&opt), TensorIn::F32(&x), TensorIn::F32(&y)],
            )
            .unwrap();
        w = out[0].clone();
        opt = out[1].clone();
        last = out[2][0];
        first.get_or_insert(last);
    }
    assert!(
        last < first.unwrap() * 0.5,
        "training did not descend: {first:?} -> {last}"
    );

    // fwd with trained member replicated across the committee
    let members = e.entry("toy_init").unwrap().meta_usize("n_members").unwrap();
    let mut w_rep = Vec::new();
    for _ in 0..members {
        w_rep.extend_from_slice(&w);
    }
    let xb: Vec<f32> = (0..80).map(|i| ((i % 9) as f32 - 4.0) / 4.0).collect();
    let out = e.call("toy_fwd_b20", &[TensorIn::F32(&w_rep), TensorIn::F32(&xb)]).unwrap();
    let y_std = &out[2];
    // identical members → zero committee std
    assert!(y_std.iter().all(|s| s.abs() < 1e-5));
}

#[test]
fn potential_fwd_committee_has_positive_std_and_finite_forces() {
    require_hlo!();
    let e = engine();
    let entry = e.entry("potential_dimer_fwd_b8").unwrap();
    let meta_members = entry.meta_usize("n_members").unwrap();
    let p = entry.meta_usize("param_size").unwrap();
    let w = e.call("potential_dimer_init", &[TensorIn::U32(3)]).unwrap().remove(0);
    assert_eq!(w.len(), meta_members * p);
    // 8 dimer geometries at varying bond length
    let mut x = Vec::new();
    for i in 0..8 {
        x.extend_from_slice(&[0.0, 0.0, 0.0, 1.0 + 0.1 * i as f32, 0.0, 0.0]);
    }
    let g = vec![0.0f32; 8];
    let s = vec![1.0f32; 8];
    let out = e
        .call(
            "potential_dimer_fwd_b8",
            &[TensorIn::F32(&w), TensorIn::F32(&x), TensorIn::F32(&g), TensorIn::F32(&s)],
        )
        .unwrap();
    let (e_std, f_mean) = (&out[2], &out[3]);
    assert!(e_std.iter().any(|&v| v > 1e-5), "committee should disagree untrained");
    assert!(f_mean.iter().all(|v| v.is_finite()));
    // forces on a symmetric dimer point along the bond axis only
    for row in f_mean.chunks(6) {
        assert!(row[1].abs() < 1e-3 && row[2].abs() < 1e-3, "{row:?}");
    }
}

#[test]
fn potential_m1_variant_has_zero_committee_std() {
    require_hlo!();
    let e = engine();
    let p = e.entry("potential_dimer1_init").unwrap().meta_usize("param_size").unwrap();
    let w = e.call("potential_dimer1_init", &[TensorIn::U32(0)]).unwrap().remove(0);
    assert_eq!(w.len(), p);
    let mut x = Vec::new();
    for i in 0..8 {
        x.extend_from_slice(&[0.0, 0.0, 0.0, 1.2 + 0.05 * i as f32, 0.0, 0.0]);
    }
    let g = vec![0.0f32; 8];
    let s = vec![1.0f32; 8];
    let out = e
        .call(
            "potential_dimer1_fwd_b8",
            &[TensorIn::F32(&w), TensorIn::F32(&x), TensorIn::F32(&g), TensorIn::F32(&s)],
        )
        .unwrap();
    assert!(out[2].iter().all(|&v| v.abs() < 1e-6), "single member must have std 0");
}

#[test]
fn potential_train_step_descends_on_morse_labels() {
    use pal::potential::{Morse, Pes};
    require_hlo!();
    let e = engine();
    let entry = e.entry("potential_dimer1_train_t16").unwrap();
    let p = entry.meta_usize("param_size").unwrap();
    let opt_size = entry.meta_usize("opt_size").unwrap();
    let mut w = e.call("potential_dimer1_init", &[TensorIn::U32(7)]).unwrap().remove(0);
    let mut opt = vec![0.0f32; opt_size];
    assert_eq!(w.len(), p);

    // labeled batch from the analytic Morse oracle
    let pes = Morse::dimer();
    let mut x = Vec::new();
    let mut ye = Vec::new();
    let mut yf = Vec::new();
    for i in 0..16 {
        let r = 1.0 + 0.08 * i as f32;
        let geom = [0.0, 0.0, 0.0, r, 0.0, 0.0];
        x.extend_from_slice(&geom);
        ye.push(pes.energy(&geom) as f32);
        yf.extend_from_slice(&pes.forces(&geom));
    }
    let g = vec![0.0f32; 16];
    let s = vec![1.0f32; 16];
    let mut first = None;
    let mut last = f32::NAN;
    for _ in 0..80 {
        let out = e
            .call(
                "potential_dimer1_train_t16",
                &[
                    TensorIn::F32(&w),
                    TensorIn::F32(&opt),
                    TensorIn::F32(&x),
                    TensorIn::F32(&g),
                    TensorIn::F32(&s),
                    TensorIn::F32(&ye),
                    TensorIn::F32(&yf),
                ],
            )
            .unwrap();
        w = out[0].clone();
        opt = out[1].clone();
        last = out[2][0];
        first.get_or_insert(last);
    }
    assert!(
        last < first.unwrap() * 0.5,
        "potential training did not descend: {first:?} -> {last}"
    );
}

#[test]
fn euq_energy_matches_fwd_energy() {
    require_hlo!();
    let e = engine();
    let w = e.call("potential_dimer_init", &[TensorIn::U32(5)]).unwrap().remove(0);
    let mut x = Vec::new();
    for i in 0..8 {
        x.extend_from_slice(&[0.0, 0.0, 0.0, 1.3 + 0.07 * i as f32, 0.0, 0.0]);
    }
    let g = vec![0.0f32; 8];
    let s = vec![1.0f32; 8];
    let fwd = e
        .call(
            "potential_dimer_fwd_b8",
            &[TensorIn::F32(&w), TensorIn::F32(&x), TensorIn::F32(&g), TensorIn::F32(&s)],
        )
        .unwrap();
    let euq = e
        .call(
            "potential_dimer_euq_b8",
            &[TensorIn::F32(&w), TensorIn::F32(&x), TensorIn::F32(&g)],
        )
        .unwrap();
    // e_all from both paths agree: Pallas fused committee kernel == jnp path
    for (a, b) in fwd[0].iter().zip(euq[0].iter()) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn surrogate_fwd_and_train_roundtrip() {
    require_hlo!();
    let e = engine();
    let entry = e.entry("surrogate1_train_t16").unwrap();
    let opt_size = entry.meta_usize("opt_size").unwrap();
    let grid = entry.meta_usize("grid").unwrap();
    let mut w = e.call("surrogate1_init", &[TensorIn::U32(2)]).unwrap().remove(0);
    let mut opt = vec![0.0f32; opt_size];
    // toy dataset: checkerboard grids → fixed targets
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..16 {
        for k in 0..grid * grid {
            xs.push(((k + i) % 5 == 0) as u8 as f32);
        }
        ys.extend_from_slice(&[0.1 + 0.01 * i as f32, 0.02]);
    }
    let mut first = None;
    let mut last = f32::NAN;
    for _ in 0..40 {
        let out = e
            .call(
                "surrogate1_train_t16",
                &[TensorIn::F32(&w), TensorIn::F32(&opt), TensorIn::F32(&xs), TensorIn::F32(&ys)],
            )
            .unwrap();
        w = out[0].clone();
        opt = out[1].clone();
        last = out[2][0];
        first.get_or_insert(last);
    }
    assert!(last < first.unwrap(), "surrogate loss should descend");

    let xb = &xs[..8 * grid * grid];
    let out = e.call("surrogate1_fwd_b8", &[TensorIn::F32(&w), TensorIn::F32(xb)]).unwrap();
    assert_eq!(out[1].len(), 8 * 2);
    assert!(out[1].iter().all(|v| v.is_finite()));
}

#[test]
fn engine_stats_track_calls() {
    require_hlo!();
    let e = engine();
    let w = e.call("toy_init", &[TensorIn::U32(0)]).unwrap().remove(0);
    let x = vec![0.0f32; 80];
    e.call("toy_fwd_b20", &[TensorIn::F32(&w), TensorIn::F32(&x)]).unwrap();
    e.call("toy_fwd_b20", &[TensorIn::F32(&w), TensorIn::F32(&x)]).unwrap();
    let stats = e.stats();
    assert_eq!(stats["toy_fwd_b20"].calls, 2);
    assert!(e.mean_latency_ms("toy_fwd_b20").unwrap() > 0.0);
    assert!(stats["toy_init"].compile_ns > 0);
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    require_hlo!();
    let e = engine();
    let w = e.call("toy_init", &[TensorIn::U32(0)]).unwrap().remove(0);
    let short = vec![0.0f32; 10];
    assert!(e.call("toy_fwd_b20", &[TensorIn::F32(&w), TensorIn::F32(&short)]).is_err());
    assert!(e.call("toy_fwd_b20", &[TensorIn::F32(&w)]).is_err());
    assert!(e.call("nonexistent", &[]).is_err());
}
