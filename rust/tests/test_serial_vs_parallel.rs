//! Serial (Fig. 1a) vs parallel (Fig. 1b) parity + failure injection.

use std::sync::Arc;
use std::time::Duration;

use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::{CommitteeStdUtils, SelectAllUtils};
use pal::coordinator::workflow::Workflow;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::serial::SerialWorkflow;
use pal::sim::workload::{SyntheticGenerator, SyntheticModel, SyntheticOracle};

fn serial(n_iters: u64, oracle_ms: u64, train_epochs: usize, p: usize) -> SerialWorkflow {
    SerialWorkflow {
        generators: (0..4)
            .map(|i| {
                Box::new(SyntheticGenerator::new(4, Duration::ZERO, u64::MAX, i as u64))
                    as Box<dyn Generator>
            })
            .collect(),
        oracles: (0..p)
            .map(|_| {
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(oracle_ms),
                    out_dim: 4,
                }) as Box<dyn Oracle>
            })
            .collect(),
        models: (0..2)
            .map(|i| {
                let mut m = SyntheticModel::new(
                    4,
                    4,
                    Duration::ZERO,
                    Duration::from_micros(500),
                    train_epochs,
                    Mode::Train,
                );
                let w: Vec<f32> = (0..16).map(|k| ((k + i * 3) % 5) as f32 * 0.1).collect();
                m.update(&w);
                Box::new(m) as Box<dyn Model>
            })
            .collect(),
        utils: Box::new(SelectAllUtils { max_per_iter: 4 }),
        steps_per_iter: 1,
        iterations: n_iters,
    }
}

#[test]
fn serial_baseline_phases_are_sequential() {
    let mut w = serial(4, 5, 8, 2);
    let r = w.run();
    assert_eq!(r.iterations, 4);
    assert!(r.oracle_labels == 16);
    // the three phases account for (almost) all wall time — nothing overlaps
    let sum = r.gen_time + r.oracle_time + r.train_time;
    assert!(sum >= r.wall.mul_f64(0.7), "phases {sum:?} vs wall {:?}", r.wall);
}

#[test]
fn parallel_overlaps_oracle_and_training() {
    // Same cost structure run through PAL: the oracle phase (N/P · t_o) and
    // training overlap generation, so wall < serial wall on the same work.
    let oracle_ms = 10u64;
    let labels_target = 16u64;

    // serial reference
    let mut sw = serial(4, oracle_ms, 8, 2);
    let sr = sw.run();

    // parallel run with the same kernels / costs until the same label count
    let s = AlSetting {
        result_dir: "/tmp/pal-svp".into(),
        gene_process: 4,
        pred_process: 2,
        ml_process: 2,
        orcl_process: 2,
        retrain_size: 4,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(labels_target),
            max_wall: Some(Duration::from_secs(30)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..4usize)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(4, Duration::ZERO, u64::MAX, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..2usize)
        .map(|_| {
            Box::new(move || {
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(oracle_ms),
                    out_dim: 4,
                }) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(|mode: Mode, replica: usize| {
        let mut m = SyntheticModel::new(
            4,
            4,
            Duration::ZERO,
            Duration::from_micros(500),
            8,
            mode,
        );
        let w: Vec<f32> = (0..16).map(|k| ((k + replica * 3) % 5) as f32 * 0.1).collect();
        m.update(&w);
        Box::new(m) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(SelectAllUtils { max_per_iter: 4 }) as Box<dyn Utils>);
    let pr = Workflow::new(s)
        .run(KernelSet { generators, oracles, model, utils })
        .unwrap();

    assert!(pr.oracle_labels >= labels_target);
    assert_eq!(sr.oracle_labels, labels_target);
    // the parallel workflow must not be slower than serial on the same
    // labeling work (it overlaps everything else with it)
    assert!(
        pr.wall <= sr.wall + Duration::from_millis(50),
        "parallel {:?} vs serial {:?}",
        pr.wall,
        sr.wall
    );
}

#[test]
fn slow_oracle_injection_does_not_deadlock() {
    // failure injection: one oracle is 50x slower than the other — the
    // manager's first-free dispatch must route around it
    let s = AlSetting {
        result_dir: "/tmp/pal-slow-oracle".into(),
        gene_process: 3,
        pred_process: 1,
        ml_process: 0,
        orcl_process: 2,
        retrain_size: 4,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(10),
            max_wall: Some(Duration::from_secs(20)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..3usize)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(4, Duration::from_millis(1), u64::MAX, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..2usize)
        .map(|i| {
            Box::new(move || {
                let cost = if i == 0 { 500 } else { 10 };
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(cost),
                    out_dim: 4,
                }) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(|mode: Mode, _r: usize| {
        Box::new(SyntheticModel::new(4, 4, Duration::ZERO, Duration::ZERO, 1, mode))
            as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(SelectAllUtils { max_per_iter: 3 }) as Box<dyn Utils>);
    let report = Workflow::new(s)
        .run(KernelSet { generators, oracles, model, utils })
        .unwrap();
    assert!(report.oracle_labels >= 10);
    // the fast oracle must have done the bulk of the work
    let per_oracle: Vec<u64> =
        report.kernel("oracle").iter().map(|k| k.counter("labels")).collect();
    let max = *per_oracle.iter().max().unwrap();
    let min = *per_oracle.iter().min().unwrap();
    assert!(max > min, "dispatch did not route around the slow oracle: {per_oracle:?}");
}

#[test]
fn committee_disagreement_drives_selection_rate() {
    // identical members → zero std → nothing selected; diverse members →
    // selection happens. Controls that UQ gating, not noise, drives labels.
    let run = |diverse: bool| {
        let s = AlSetting {
            result_dir: "/tmp/pal-uq".into(),
            gene_process: 3,
            pred_process: 2,
            ml_process: 0,
            orcl_process: 1,
            retrain_size: 100,
            stop: StopCriteria {
                max_iterations: Some(20),
                max_labels: None,
                max_wall: Some(Duration::from_secs(10)),
                ..Default::default()
            },
            ..Default::default()
        };
        let generators = (0..3usize)
            .map(|i| {
                Box::new(move || {
                    Box::new(SyntheticGenerator::new(4, Duration::ZERO, u64::MAX, i as u64))
                        as Box<dyn Generator>
                }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
            })
            .collect();
        let oracles = (0..1usize)
            .map(|_| {
                Box::new(|| {
                    Box::new(SyntheticOracle { label_cost: Duration::ZERO, out_dim: 4 })
                        as Box<dyn Oracle>
                }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
            })
            .collect();
        let model = Arc::new(move |mode: Mode, replica: usize| {
            let mut m = SyntheticModel::new(4, 4, Duration::ZERO, Duration::ZERO, 1, mode);
            let scale = if diverse { replica as f32 + 1.0 } else { 1.0 };
            let w: Vec<f32> = (0..16).map(|k| (k % 5) as f32 * 0.1 * scale).collect();
            m.update(&w);
            Box::new(m) as Box<dyn Model>
        });
        let utils =
            Arc::new(|| Box::new(CommitteeStdUtils::new(0.05, 10)) as Box<dyn Utils>);
        Workflow::new(s)
            .run(KernelSet { generators, oracles, model, utils })
            .unwrap()
    };
    let agree = run(false);
    let disagree = run(true);
    assert_eq!(
        agree.sum_counter("exchange", "selected_for_oracle"),
        0,
        "identical committee must select nothing"
    );
    assert!(
        disagree.sum_counter("exchange", "selected_for_oracle") > 0,
        "diverse committee must select"
    );
}
