//! Allocation/byte regression for the memory plane (PR 8).
//!
//! Pins the three memory-plane claims:
//! 1. `Dataset::minibatch` is a strided gather into reused scratch —
//!    steady-state allocations are zero and independent of the rolling
//!    window size.
//! 2. The identity-keyed [`UploadCache`] stages unchanged shared weights
//!    once: a repeat `ensure` uploads zero bytes and allocates nothing.
//! 3. Labels-only oracle result frames (`TAG_ORACLE_LABELS`) carry no
//!    input bytes — the frame size is independent of the input width, and
//!    the borrowed-view decode allocates a constant count per frame.
//!
//! This file installs a counting global allocator and therefore contains
//! exactly ONE `#[test]`: the default harness runs a binary's tests
//! concurrently, and any sibling test's allocations would pollute the
//! counters (same discipline as `test_flat_plane.rs`).

use pal::bench_util::alloc::{alloc_count, CountingAlloc};
use pal::comm::bus::Payload;
use pal::comm::protocol::{
    decode_oracle_labels_views, encode_oracle_batch_result_into, encode_oracle_labels_into,
};
use pal::data::batch::RowBlock;
use pal::data::Dataset;
use pal::runtime::UploadCache;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Steady-state allocations of one `minibatch` call at `window`, measured
/// after a warmup call has sized the gather scratch.
fn minibatch_steady_allocs(window: usize) -> u64 {
    const DIM: usize = 8;
    const MB: usize = 16;
    let mut d = Dataset::new(0.0, 11).with_rolling_window(window);
    let pts: Vec<(Vec<f32>, Vec<f32>)> =
        (0..window + 16).map(|i| (vec![i as f32; DIM], vec![i as f32])).collect();
    d.add(&pts);
    std::hint::black_box(d.minibatch(MB));
    let before = alloc_count();
    for _ in 0..32 {
        std::hint::black_box(d.minibatch(MB));
    }
    alloc_count() - before
}

/// Allocations of one labels-only decode over `frame`.
fn labels_decode_allocs(frame: &[f32]) -> u64 {
    let before = alloc_count();
    let (_, rows) = decode_oracle_labels_views(frame).expect("valid labels frame");
    std::hint::black_box(&rows);
    let delta = alloc_count() - before;
    drop(rows);
    delta
}

/// A labels-only frame plus the legacy interleaved frame for the same
/// batch: `rows` inputs of `in_w` f32, one-f32 labels.
fn result_frames(rows: usize, in_w: usize) -> (Vec<f32>, Vec<f32>) {
    let inputs: Vec<Vec<f32>> = (0..rows).map(|i| vec![i as f32; in_w]).collect();
    let input_refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let mut labels = RowBlock::new();
    for i in 0..rows {
        labels.push_row(&[i as f32]);
    }
    let mut labels_frame = Vec::new();
    encode_oracle_labels_into(9, &labels, &mut labels_frame);
    let mut legacy_frame = Vec::new();
    encode_oracle_batch_result_into(9, &input_refs, &labels, &mut legacy_frame);
    (labels_frame, legacy_frame)
}

#[test]
fn memory_plane_is_copy_and_allocation_free() {
    // --- (1) minibatch: zero steady-state allocs, flat in the window ---
    let allocs_64 = minibatch_steady_allocs(64);
    let allocs_512 = minibatch_steady_allocs(512);
    assert_eq!(allocs_64, 0, "minibatch allocated {allocs_64} times at window 64 (want 0)");
    assert_eq!(
        allocs_64, allocs_512,
        "minibatch allocations must be flat in the window (64: {allocs_64}, 512: {allocs_512})"
    );

    // --- (2) upload cache: repeat ensure of the same payload stages zero
    //     bytes and allocates nothing ---
    let weights = Payload::from(vec![0.5f32; 4096]);
    let mut cache = UploadCache::new(8);
    assert!(cache.ensure(&weights, &[4096]).unwrap(), "first stage is a miss");
    let staged = cache.stats().bytes_uploaded;
    assert_eq!(staged, 4 * 4096, "miss uploads the full weight buffer");
    let before = alloc_count();
    for _ in 0..16 {
        assert!(!cache.ensure(&weights, &[4096]).unwrap(), "repeat stage must hit");
    }
    let hit_allocs = alloc_count() - before;
    assert_eq!(hit_allocs, 0, "cache hits allocated {hit_allocs} times (want 0)");
    let s = cache.stats();
    assert_eq!(s.bytes_uploaded, staged, "cache hits must upload zero bytes");
    assert_eq!(s.hits, 16);
    assert_eq!(s.bytes_reused, 16 * 4 * 4096);

    // --- (3) labels-only results: no input bytes on the wire, constant
    //     decode allocations ---
    let (labels_8_narrow, legacy_8_narrow) = result_frames(8, 8);
    let (labels_8_wide, legacy_8_wide) = result_frames(8, 512);
    assert_eq!(
        labels_8_narrow.len(),
        labels_8_wide.len(),
        "labels-only frame size must not depend on the input width"
    );
    assert!(
        legacy_8_wide.len() > legacy_8_narrow.len(),
        "legacy interleaved frame re-ships inputs, so it must grow with input width"
    );
    assert!(
        legacy_8_narrow.len() as f64 >= 1.8 * labels_8_narrow.len() as f64,
        "labels-only must cut result-frame f32s >= 1.8x even at narrow inputs \
         (legacy {}, labels-only {})",
        legacy_8_narrow.len(),
        labels_8_narrow.len()
    );
    let (labels_64, _) = result_frames(64, 8);
    let _ = labels_decode_allocs(&labels_8_narrow); // warmup
    let decode_small = labels_decode_allocs(&labels_8_narrow);
    let decode_large = labels_decode_allocs(&labels_64);
    assert!(decode_small <= 2, "labels decode allocated {decode_small} times (want <= 2)");
    assert_eq!(
        decode_small, decode_large,
        "labels decode must not allocate per row (8 rows: {decode_small}, 64: {decode_large})"
    );
}
