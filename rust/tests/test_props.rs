//! Property tests (mini-prop harness) on coordinator invariants:
//! routing order, buffer conservation, selection contracts, codec
//! round-trips, speedup-model bounds.

use pal::comm::codec;
use pal::comm::protocol;
use pal::coordinator::buffers::{OracleBuffer, TrainBuffer};
use pal::coordinator::selection::{
    committee_mean, committee_mean_batch, committee_std, committee_std_batch,
    committee_std_check, committee_std_check_batch, CommitteeStdUtils,
};
use pal::data::batch::{Batch, BatchView, DatapointBlock, RowBlock};
use pal::kernels::{Mode, Model, Utils};
use pal::prop::{forall, Gen};
use pal::sim::speedup::Workload;
use pal::sim::workload::SyntheticModel;

fn gen_preds(g: &mut Gen, models: usize, gens: usize, width: usize) -> Vec<Vec<Vec<f32>>> {
    (0..models).map(|_| g.arrays(gens, width)).collect()
}

#[test]
fn codec_roundtrip_any_shapes() {
    forall(
        200,
        |g| {
            let n = g.usize(0, 12);
            (0..n).map(|_| {
                let w = g.usize(0, 40);
                g.vec_normal(w)
            }).collect::<Vec<_>>()
        },
        |parts| {
            let packed = codec::pack_vecs(&parts);
            codec::unpack(&packed) == Some(parts)
        },
    );
}

#[test]
fn unpack_views_equivalent_to_unpack_on_roundtrips() {
    forall(
        200,
        |g| {
            let n = g.usize(0, 12);
            (0..n).map(|_| {
                let w = g.usize(0, 40);
                g.vec_normal(w)
            }).collect::<Vec<_>>()
        },
        |parts| {
            let packed = codec::pack_vecs(&parts);
            let owned = codec::unpack(&packed);
            let views = codec::unpack_views(&packed);
            match (owned, views) {
                (Some(o), Some(v)) => {
                    o == parts
                        && v.len() == o.len()
                        && v.iter().zip(&o).all(|(a, b)| *a == b.as_slice())
                }
                _ => false,
            }
        },
    );
}

/// Apply one of the malformation modes the codec must reject (or none).
fn mutate_packed(g: &mut Gen, mut packed: Vec<f32>) -> Vec<f32> {
    match g.usize(0, 3) {
        // truncation (from 1 element up to the whole payload)
        0 => {
            let cut = g.usize(1, packed.len());
            packed.truncate(packed.len() - cut);
        }
        // trailing garbage
        1 => {
            let extra = g.usize(1, 4);
            for _ in 0..extra {
                packed.push(g.f32(-2.0, 2.0));
            }
        }
        // oversized header: part count or a length >= MAX_LEN
        2 => {
            let idx = g.usize(0, 1).min(packed.len().saturating_sub(1));
            if !packed.is_empty() {
                packed[idx] = codec::MAX_LEN as f32;
            }
        }
        // untouched round-trip
        _ => {}
    }
    packed
}

#[test]
fn unpack_views_rejects_exactly_like_unpack() {
    // identical accept/reject decisions on truncated, trailing-garbage and
    // oversized-header inputs — and identical values whenever both accept
    forall(
        300,
        |g| {
            let n = g.usize(0, 8);
            let parts: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let w = g.usize(0, 12);
                    g.vec_normal(w)
                })
                .collect();
            let packed = codec::pack_vecs(&parts);
            mutate_packed(g, packed)
        },
        |mutated| {
            let owned = codec::unpack(&mutated);
            let views = codec::unpack_views(&mutated);
            match (owned, views) {
                (Some(o), Some(v)) => v.iter().zip(&o).all(|(a, b)| *a == b.as_slice()),
                (None, None) => true,
                _ => false,
            }
        },
    );
}

#[test]
fn datapoint_views_equivalent_to_owned() {
    forall(
        150,
        |g| {
            let n = g.usize(0, 10);
            let pts: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
                .map(|_| {
                    let a = g.usize(1, 16);
                    let b = g.usize(1, 6);
                    (g.vec_normal(a), g.vec_normal(b))
                })
                .collect();
            let packed = codec::pack_datapoints(&pts);
            mutate_packed(g, packed)
        },
        |mutated| {
            let owned = codec::unpack_datapoints(&mutated);
            let views = codec::unpack_datapoint_views(&mutated);
            match (owned, views) {
                (Some(o), Some(v)) => {
                    v.len() == o.len()
                        && v.iter()
                            .zip(&o)
                            .all(|((vx, vy), (ox, oy))| *vx == ox.as_slice() && *vy == oy.as_slice())
                }
                (None, None) => true,
                _ => false,
            }
        },
    );
}

#[test]
fn batch_frame_views_equivalent_to_owned() {
    forall(
        150,
        |g| {
            let id = g.rng().next_u64() & ((1u64 << 48) - 1);
            let n = g.usize(0, 8);
            let items: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let w = g.usize(0, 16);
                    g.vec_normal(w)
                })
                .collect();
            let packed = protocol::encode_predict_batch(id, &items);
            mutate_packed(g, packed)
        },
        |mutated| {
            let owned = protocol::decode_predict_batch(&mutated);
            let views = protocol::decode_predict_batch_views(&mutated);
            match (owned, views) {
                (Some((io, o)), Some((iv, v))) => {
                    io == iv && v.iter().zip(&o).all(|(a, b)| *a == b.as_slice())
                }
                (None, None) => true,
                _ => false,
            }
        },
    );
}

#[test]
fn datapoints_roundtrip_any_widths() {
    forall(
        150,
        |g| {
            let n = g.usize(0, 10);
            (0..n)
                .map(|_| {
                    let a = g.usize(1, 20);
                    let b = g.usize(1, 8);
                    (g.vec_normal(a), g.vec_normal(b))
                })
                .collect::<Vec<_>>()
        },
        |pts| {
            let packed = codec::pack_datapoints(&pts);
            codec::unpack_datapoints(&packed) == Some(pts)
        },
    );
}

#[test]
fn gen_frame_roundtrip_any_payload() {
    forall(
        200,
        |g| {
            let stop = g.bool();
            let w = g.usize(0, 60);
            (stop, g.vec_normal(w))
        },
        |(stop, data)| {
            let enc = protocol::encode_gen(stop, &data);
            let (s2, d2) = protocol::decode_gen(&enc);
            s2 == stop && d2 == data.as_slice()
        },
    );
}

#[test]
fn batch_frames_roundtrip_any_ids_and_shapes() {
    // encode→decode identity for both batch frames, across the whole
    // 48-bit id space and item lists including empty items/empty batches
    forall(
        200,
        |g| {
            let id = g.rng().next_u64() & ((1u64 << 48) - 1);
            let n = g.usize(0, 12);
            let items: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let w = g.usize(0, 40);
                    g.vec_normal(w)
                })
                .collect();
            (id, items)
        },
        |(id, items)| {
            let req = protocol::encode_predict_batch(id, &items);
            let resp = protocol::encode_predict_batch_result(id, &items);
            protocol::decode_predict_batch(&req) == Some((id, items.clone()))
                && protocol::decode_predict_batch_result(&resp) == Some((id, items))
        },
    );
}

#[test]
fn batch_frame_max_size_payload_roundtrip() {
    // one big stacked item near the id-space ceiling (property sizes stay
    // small for speed; the boundary case is pinned here)
    let id = (1u64 << 48) - 1;
    let big: Vec<f32> = (0..200_000).map(|i| (i % 977) as f32 * 0.5).collect();
    let items = vec![big, Vec::new()];
    let enc = protocol::encode_predict_batch(id, &items);
    assert_eq!(protocol::decode_predict_batch(&enc), Some((id, items)));
}

#[test]
fn batch_frames_reject_truncation_anywhere() {
    forall(
        80,
        |g| {
            let n = g.usize(1, 6);
            let w = g.size as usize + 3;
            let items: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(w)).collect();
            let cut = g.usize(0, 2);
            (items, cut)
        },
        |(items, cut)| {
            let enc = protocol::encode_predict_batch(1, &items);
            // removing trailing elements must never decode successfully
            protocol::decode_predict_batch(&enc[..enc.len().saturating_sub(cut + 1)]).is_none()
        },
    );
}

// ---------------------------------------------------------------------------
// Flat data plane: batch path ≡ nested path
// ---------------------------------------------------------------------------

#[test]
fn uniform_parse_equivalent_to_views_incl_rejections() {
    // flat parse accepts exactly the uniform subset of what the view parse
    // accepts (same values), and rejects everything else (ragged included)
    forall(
        300,
        |g| {
            let n = g.usize(0, 8);
            let uniform = g.bool();
            let w = g.usize(0, 10);
            let parts: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let len = if uniform { w } else { g.usize(0, 10) };
                    g.vec_normal(len)
                })
                .collect();
            let packed = codec::pack_vecs(&parts);
            mutate_packed(g, packed)
        },
        |mutated| {
            let views = codec::unpack_views(&mutated);
            let flat = codec::unpack_batch_view(&mutated);
            match (views, flat) {
                (Some(v), Some(b)) => {
                    b.rows() == v.len()
                        && (0..b.rows()).all(|i| b.row(i) == v[i])
                }
                (Some(v), None) => {
                    // flat may reject only ragged part lists
                    let w0 = v.first().map(|p| p.len()).unwrap_or(0);
                    v.iter().any(|p| p.len() != w0)
                }
                (None, None) => true,
                (None, Some(_)) => false,
            }
        },
    );
}

#[test]
fn batch_frame_rows_decode_equivalent_to_nested() {
    // pack → decode round-trip: the flat frame decoder agrees with the
    // nested decoder on every uniform frame, including mutated ones
    forall(
        250,
        |g| {
            let id = g.rng().next_u64() & ((1u64 << 48) - 1);
            let n = g.usize(0, 10);
            let w = g.usize(0, 12);
            let items: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(w)).collect();
            let packed = protocol::encode_predict_batch(id, &items);
            mutate_packed(g, packed)
        },
        |mutated| {
            let nested = protocol::decode_predict_batch(&mutated);
            let flat = protocol::decode_predict_batch_rows(&mutated);
            match (nested, flat) {
                (Some((ni, nv)), Some((fi, fv))) => {
                    ni == fi
                        && fv.rows() == nv.len()
                        && (0..fv.rows()).all(|i| fv.row(i) == nv[i].as_slice())
                }
                (Some((_, nv)), None) => {
                    let w0 = nv.first().map(|p| p.len()).unwrap_or(0);
                    nv.iter().any(|p| p.len() != w0)
                }
                (None, None) => true,
                (None, Some(_)) => false,
            }
        },
    );
}

#[test]
fn committee_reductions_batch_equivalent_to_nested_bitwise() {
    forall(
        200,
        |g| {
            let models = g.usize(1, 5);
            let gens = g.usize(1, 10);
            let width = g.usize(1, 6);
            gen_preds(g, models, gens, width)
        },
        |nested| {
            let batches: Vec<Batch> =
                nested.iter().map(|m| Batch::from_rows(m).unwrap()).collect();
            let views: Vec<BatchView<'_>> = batches.iter().map(|b| b.view()).collect();
            committee_std_batch(&views) == committee_std(&nested)
                && committee_mean_batch(&views).to_nested() == committee_mean(&nested)
        },
    );
}

#[test]
fn full_pack_decode_reduce_roundtrip_batch_equals_legacy() {
    // end-to-end: encode per-member result frames, decode both ways, run
    // the full committee_std_check — identical selections and checked rows
    forall(
        150,
        |g| {
            let models = g.usize(1, 4);
            let gens = g.usize(1, 8);
            let width = g.usize(1, 5);
            let inputs = g.arrays(gens, width + 1);
            let preds = gen_preds(g, models, gens, width);
            let threshold = g.f32(0.0, 0.4);
            let cap = g.usize(0, 10);
            (inputs, preds, threshold, cap)
        },
        |(inputs, preds, threshold, cap)| {
            let frames: Vec<Vec<f32>> = preds
                .iter()
                .map(|m| protocol::encode_predict_batch_result(7, m))
                .collect();
            // legacy: nested decode + nested check
            let nested: Vec<Vec<Vec<f32>>> = frames
                .iter()
                .map(|f| protocol::decode_predict_batch_result(f).unwrap().1)
                .collect();
            let (n_orcl, n_checked) = committee_std_check(&inputs, &nested, threshold, cap);
            // flat: strided decode over the frames + batch check
            let input_batch = Batch::from_rows(&inputs).unwrap();
            let views: Vec<BatchView<'_>> = frames
                .iter()
                .map(|f| protocol::decode_predict_batch_result_rows(f).unwrap().1)
                .collect();
            let (b_orcl, b_checked) =
                committee_std_check_batch(&input_batch.view(), &views, threshold, cap);
            b_orcl.to_nested() == n_orcl && b_checked.to_nested() == n_checked
        },
    );
}

#[test]
fn prediction_check_batch_shim_matches_nested_for_custom_utils() {
    // a Utils that only implements the nested hook must behave identically
    // through the batch entry point (the default shim)
    struct TakeFirst;
    impl Utils for TakeFirst {
        fn prediction_check(
            &mut self,
            list_data_to_pred: &[Vec<f32>],
            preds_per_model: &[Vec<Vec<f32>>],
        ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
            let checked = committee_mean(preds_per_model);
            (list_data_to_pred.iter().take(1).cloned().collect(), checked)
        }
    }
    forall(
        100,
        |g| {
            let gens = g.usize(1, 6);
            (g.arrays(gens, 3), gen_preds(g, 2, gens, 2))
        },
        |(inputs, preds)| {
            let mut u = TakeFirst;
            let (n_orcl, n_checked) = u.prediction_check(&inputs, &preds);
            let input_batch = Batch::from_rows(&inputs).unwrap();
            let batches: Vec<Batch> =
                preds.iter().map(|m| Batch::from_rows(m).unwrap()).collect();
            let views: Vec<BatchView<'_>> = batches.iter().map(|b| b.view()).collect();
            let (b_orcl, b_checked) = u.prediction_check_batch(&input_batch.view(), &views);
            b_orcl.to_nested() == n_orcl && b_checked.to_nested() == n_checked
        },
    );
}

// ---------------------------------------------------------------------------
// Flat training plane: block path ≡ nested datapoint path
// ---------------------------------------------------------------------------

#[test]
fn train_block_views_equivalent_to_datapoint_views() {
    // the flat train-block decoder accepts/rejects exactly like the nested
    // pair-view decoder — truncation, trailing garbage, oversized headers
    // and odd part counts included — and agrees on every value
    forall(
        300,
        |g| {
            let n = g.usize(0, 10);
            let pts: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
                .map(|_| {
                    let a = g.usize(0, 12);
                    let b = g.usize(0, 6);
                    (g.vec_normal(a), g.vec_normal(b))
                })
                .collect();
            let packed = codec::pack_datapoints(&pts);
            mutate_packed(g, packed)
        },
        |mutated| {
            let nested = codec::unpack_datapoint_views(&mutated);
            let block = codec::decode_train_block_views(&mutated);
            match (nested, block) {
                (Some(n), Some(b)) => {
                    b.len() == n.len()
                        && (0..b.len()).all(|i| b.pair(i) == n[i])
                        && b.total_input_values()
                            == n.iter().map(|(x, _)| x.len()).sum::<usize>()
                }
                (None, None) => true,
                _ => false,
            }
        },
    );
}

#[test]
fn train_block_encode_bytes_identical_to_nested_encoder() {
    // encode: DatapointBlock → wire bytes identical to pack_datapoints;
    // decode → block → re-encode is the identity on the wire
    forall(
        200,
        |g| {
            let n = g.usize(0, 10);
            (0..n)
                .map(|_| {
                    let a = g.usize(0, 14);
                    let b = g.usize(0, 5);
                    (g.vec_normal(a), g.vec_normal(b))
                })
                .collect::<Vec<_>>()
        },
        |pts| {
            let nested = codec::pack_datapoints(&pts);
            let block = DatapointBlock::from_pairs(&pts);
            let mut flat = Vec::new();
            codec::encode_train_block_into(&block, &mut flat);
            if flat != nested {
                return false;
            }
            // decode → materialize → re-encode round-trips the bytes
            let view = codec::decode_train_block_views(&nested).unwrap();
            let reblock = view.to_block();
            let mut again = Vec::new();
            codec::encode_train_block_into(&reblock, &mut again);
            again == nested && reblock.to_nested() == pts
        },
    );
}

#[test]
fn datapoint_block_equivalent_to_nested_datapoints() {
    forall(
        200,
        |g| {
            let n = g.usize(0, 12);
            (0..n)
                .map(|_| {
                    let a = g.usize(0, 10);
                    let b = g.usize(0, 4);
                    (g.vec_normal(a), g.vec_normal(b))
                })
                .collect::<Vec<_>>()
        },
        |pts| {
            let block = DatapointBlock::from_pairs(&pts);
            let view = block.view();
            block.len() == pts.len()
                && block.to_nested() == pts
                && view.to_nested() == pts
                && view.iter().zip(&pts).all(|((x, y), (px, py))| {
                    x == px.as_slice() && y == py.as_slice()
                })
        },
    );
}

#[test]
fn weight_payload_bit_equal_to_get_weight() {
    forall(
        100,
        |g| {
            let in_dim = g.usize(1, 6);
            let out_dim = g.usize(1, 4);
            let w = g.vec_normal(in_dim * out_dim);
            (in_dim, out_dim, w)
        },
        |(in_dim, out_dim, w)| {
            let mut trainer = SyntheticModel::new(
                in_dim,
                out_dim,
                std::time::Duration::ZERO,
                std::time::Duration::ZERO,
                1,
                Mode::Train,
            );
            trainer.update(&w);
            let p = trainer.get_weight_payload();
            if p.as_slice() != trainer.get_weight().as_slice() {
                return false;
            }
            // adopting the payload reproduces the weights bit-for-bit
            let mut replica = SyntheticModel::new(
                in_dim,
                out_dim,
                std::time::Duration::ZERO,
                std::time::Duration::ZERO,
                1,
                Mode::Predict,
            );
            replica.update_from(&p);
            replica.get_weight() == w
                && replica.get_weight_payload().as_slice() == p.as_slice()
        },
    );
}

#[test]
fn row_block_shared_rows_preserve_values() {
    forall(
        150,
        |g| {
            let n = g.usize(0, 10);
            (0..n)
                .map(|_| {
                    let w = g.usize(0, 8);
                    g.vec_normal(w)
                })
                .collect::<Vec<_>>()
        },
        |rows| {
            let rb = RowBlock::from_rows(&rows);
            if rb.to_nested() != rows {
                return false;
            }
            let shared = rb.into_shared();
            shared.len() == rows.len()
                && (0..shared.len()).all(|i| {
                    shared.row(i) == rows[i].as_slice()
                        && shared.row_payload(i).as_slice() == rows[i].as_slice()
                })
        },
    );
}

#[test]
fn prediction_check_returns_one_entry_per_generator() {
    // SI: "length must match the number of generators and should be sorted
    // by the rank of generator"
    forall(
        150,
        |g| {
            let models = g.usize(1, 5);
            let gens = g.usize(1, 12);
            let width = g.usize(1, 6);
            let inputs = g.arrays(gens, width + 2);
            let preds = gen_preds(g, models, gens, width);
            let threshold = g.f32(0.0, 0.5);
            let cap = g.usize(0, 15);
            (inputs, preds, threshold, cap)
        },
        |(inputs, preds, threshold, cap)| {
            let (to_orcl, checked) = committee_std_check(&inputs, &preds, threshold, cap);
            checked.len() == inputs.len() && to_orcl.len() <= cap.min(inputs.len())
        },
    );
}

#[test]
fn selected_inputs_are_actual_generator_inputs() {
    forall(
        100,
        |g| {
            let gens = g.usize(1, 10);
            let inputs = g.arrays(gens, 4);
            let preds = gen_preds(g, 3, gens, 3);
            (inputs, preds)
        },
        |(inputs, preds)| {
            let (to_orcl, _) = committee_std_check(&inputs, &preds, 0.01, 100);
            to_orcl.iter().all(|x| inputs.contains(x))
        },
    );
}

#[test]
fn selected_generators_get_zeroed_predictions_everyone_else_mean() {
    forall(
        100,
        |g| {
            let gens = g.usize(1, 8);
            let inputs = g.arrays(gens, 3);
            let preds = gen_preds(g, 4, gens, 2);
            let threshold = g.f32(0.0, 0.3);
            (inputs, preds, threshold)
        },
        |(inputs, preds, threshold)| {
            let stds = committee_std(&preds);
            let means = committee_mean(&preds);
            let (to_orcl, checked) =
                committee_std_check(&inputs, &preds, threshold, usize::MAX);
            let mut selected_count = 0;
            for gidx in 0..inputs.len() {
                let zeroed = checked[gidx].iter().all(|&v| v == 0.0);
                let was_selected = stds[gidx] > threshold;
                if was_selected {
                    selected_count += 1;
                    if !zeroed {
                        return false;
                    }
                } else if checked[gidx] != means[gidx] {
                    // unselected generators receive the untouched mean
                    return false;
                }
            }
            selected_count == to_orcl.len()
        },
    );
}

#[test]
fn adjust_output_is_submultiset_of_buffer() {
    forall(
        100,
        |g| {
            let n = g.usize(0, 10);
            let buffer = g.arrays(n, 4);
            let preds: Vec<Vec<Vec<f32>>> = (0..3).map(|_| g.arrays(n, 2)).collect();
            let threshold = g.f32(0.0, 0.4);
            (buffer, preds, threshold)
        },
        |(buffer, preds, threshold)| {
            let mut u = CommitteeStdUtils::new(threshold, usize::MAX);
            let adjusted = u.adjust_input_for_oracle(buffer.clone(), &preds);
            // every adjusted entry appears in the buffer at least as often
            adjusted.len() <= buffer.len()
                && adjusted.iter().all(|a| {
                    let in_buf = buffer.iter().filter(|b| *b == a).count();
                    let in_adj = adjusted.iter().filter(|b| *b == a).count();
                    in_adj <= in_buf
                })
        },
    );
}

#[test]
fn oracle_buffer_conserves_entries() {
    forall(
        100,
        |g| {
            let batches = g.usize(1, 6);
            let sizes: Vec<usize> = (0..batches).map(|_| g.usize(0, 8)).collect();
            let cap = g.usize(1, 24);
            (sizes, cap)
        },
        |(sizes, cap)| {
            let mut buf = OracleBuffer::new(Some(cap));
            let mut pushed = 0u64;
            for (bi, n) in sizes.iter().enumerate() {
                buf.push_all((0..*n).map(|i| vec![bi as f32, i as f32]).collect());
                pushed += *n as u64;
            }
            let mut popped = 0u64;
            while buf.pop().is_some() {
                popped += 1;
            }
            // conservation: enqueued == popped + dropped
            buf.enqueued == pushed && popped + buf.dropped == pushed
        },
    );
}

#[test]
fn train_buffer_flush_boundary() {
    forall(
        100,
        |g| {
            let threshold = g.usize(1, 10);
            let pushes = g.usize(0, 40);
            (threshold, pushes)
        },
        |(threshold, pushes)| {
            let mut buf = TrainBuffer::new(threshold);
            let mut flushed_total = 0;
            for i in 0..pushes {
                buf.push((vec![i as f32], vec![0.0]));
                if let Some(batch) = buf.flush() {
                    // flushes only at >= threshold, and take everything
                    if batch.len() < threshold {
                        return false;
                    }
                    flushed_total += batch.len();
                }
            }
            flushed_total + buf.len() == pushes
        },
    );
}

#[test]
fn speedup_bounds_hold_generally() {
    forall(
        300,
        |g| Workload {
            t_oracle: g.f64(0.001, 100.0),
            t_train: g.f64(0.001, 100.0),
            t_gen: g.f64(0.001, 100.0),
            n_samples: g.usize(1, 64) as u64,
            p_workers: g.usize(1, 64) as u64,
        },
        |w| {
            let s = w.speedup();
            // S in [1, 3]: parallel can't be slower than serial, and with 3
            // overlapping phases can't beat 3x
            s >= 1.0 - 1e-9 && s <= 3.0 + 1e-9
        },
    );
}

#[test]
fn committee_stats_model_count_invariance() {
    // replicating the same model's predictions M times gives zero std and
    // the same mean
    forall(
        100,
        |g| {
            let gens = g.usize(1, 6);
            let preds = g.arrays(gens, 3);
            let m = g.usize(1, 6);
            (preds, m)
        },
        |(preds, m)| {
            let replicated: Vec<Vec<Vec<f32>>> = (0..m).map(|_| preds.clone()).collect();
            let stds = committee_std(&replicated);
            let means = committee_mean(&replicated);
            stds.iter().all(|&s| s.abs() < 1e-6)
                && means
                    .iter()
                    .zip(&preds)
                    .all(|(a, b)| a.iter().zip(b).all(|(x, y)| (x - y).abs() < 1e-5))
        },
    );
}

// ---------------------------------------------------------------------------
// Oracle plane: frame codecs + scheduler triggers/backpressure
// ---------------------------------------------------------------------------

use pal::config::BatchSetting;
use pal::coordinator::oracle_plane::OracleScheduler;
use std::time::{Duration, Instant};

#[test]
fn oracle_batch_frame_bytes_identical_to_predict_batch() {
    // the dispatch frame reuses the PredictBatch layout byte for byte, and
    // its decoders accept exactly the same inputs
    forall(
        150,
        |g| {
            let n = g.usize(0, 8);
            let rows: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let w = g.usize(0, 12);
                    g.vec_normal(w)
                })
                .collect();
            let id = g.usize(0, 1 << 20) as u64;
            (id, rows)
        },
        |(id, rows)| {
            let rb = RowBlock::from_rows(&rows);
            let mut frame = Vec::new();
            protocol::encode_oracle_batch_block_into(id, &rb, &mut frame);
            if frame != protocol::encode_predict_batch(id, &rows) {
                return false;
            }
            match protocol::decode_oracle_batch_views(&frame) {
                Some((got_id, views)) => {
                    got_id == id
                        && views.len() == rows.len()
                        && views.iter().zip(&rows).all(|(a, b)| *a == b.as_slice())
                }
                None => false,
            }
        },
    );
}

/// `[id_hi, id_lo]` header validity, mirrored from the frame codec.
fn valid_frame_id(frame: &[f32]) -> bool {
    let (Some(&hi), Some(&lo)) = (frame.first(), frame.get(1)) else {
        return false;
    };
    hi >= 0.0
        && lo >= 0.0
        && hi.fract() == 0.0
        && lo.fract() == 0.0
        && (hi as u64) < (1 << 24)
        && (lo as u64) < (1 << 24)
}

#[test]
fn oracle_batch_result_frame_equivalent_to_legacy_per_label_wire() {
    // one result frame carries exactly the pairs the per-label path would
    // have shipped as n separate `pack(&[input, label])` messages, and its
    // packed section is byte-identical to `pack_datapoints` over them
    forall(
        150,
        |g| {
            let n = g.usize(0, 8);
            let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
                .map(|_| {
                    let xw = g.usize(0, 10);
                    let yw = g.usize(0, 6);
                    (g.vec_normal(xw), g.vec_normal(yw))
                })
                .collect();
            let id = g.usize(0, 1 << 20) as u64;
            (id, pairs)
        },
        |(id, pairs)| {
            let inputs: Vec<&[f32]> = pairs.iter().map(|(x, _)| x.as_slice()).collect();
            let labels =
                RowBlock::from_rows(&pairs.iter().map(|(_, y)| y.clone()).collect::<Vec<_>>());
            let mut frame = Vec::new();
            protocol::encode_oracle_batch_result_into(id, &inputs, &labels, &mut frame);
            // packed section == legacy datapoint bytes
            if frame[2..] != codec::pack_datapoints(&pairs)[..] {
                return false;
            }
            // decoded pairs == what n per-label messages would decode to
            let Some((got_id, view)) = protocol::decode_oracle_batch_result_views(&frame) else {
                return false;
            };
            if got_id != id || view.len() != pairs.len() {
                return false;
            }
            pairs.iter().enumerate().all(|(i, (x, y))| {
                let legacy = codec::pack(&[x.as_slice(), y.as_slice()]);
                let parts = codec::unpack_views(&legacy).unwrap();
                view.pair(i) == (parts[0], parts[1])
            })
        },
    );
}

#[test]
fn oracle_batch_result_decode_rejects_exactly_like_datapoint_views() {
    // truncation / trailing garbage / oversized headers anywhere in the
    // frame: the frame decoder accepts iff the id header is valid AND the
    // packed section passes the (already equivalence-tested) pair decoder
    forall(
        300,
        |g| {
            let n = g.usize(0, 6);
            let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
                .map(|_| {
                    let xw = g.usize(0, 8);
                    let yw = g.usize(0, 4);
                    (g.vec_normal(xw), g.vec_normal(yw))
                })
                .collect();
            let inputs: Vec<&[f32]> = pairs.iter().map(|(x, _)| x.as_slice()).collect();
            let labels =
                RowBlock::from_rows(&pairs.iter().map(|(_, y)| y.clone()).collect::<Vec<_>>());
            let mut frame = Vec::new();
            protocol::encode_oracle_batch_result_into(7, &inputs, &labels, &mut frame);
            mutate_packed(g, frame)
        },
        |mutated| {
            let got = protocol::decode_oracle_batch_result_views(&mutated);
            let expect = if valid_frame_id(&mutated) {
                codec::unpack_datapoint_views(&mutated[2..])
            } else {
                None
            };
            match (got, expect) {
                (Some((_, view)), Some(pairs)) => {
                    view.len() == pairs.len()
                        && (0..view.len()).all(|i| view.pair(i) == pairs[i])
                }
                (None, None) => true,
                _ => false,
            }
        },
    );
}

#[test]
fn oracle_scheduler_backpressure_releases_fifo_through_the_buffer() {
    // the manager's dispatch discipline end to end: queue rows in an
    // OracleBuffer, pop batches as the scheduler allows — backpressure must
    // release strictly FIFO, in max_size chunks, never exceeding
    // max_outstanding per oracle
    let mut buffer = OracleBuffer::new(None);
    let mut sched = OracleScheduler::new(
        &BatchSetting {
            max_size: 2,
            max_delay: Duration::from_secs(10),
            max_outstanding: 1,
        },
        1,
    );
    let t0 = Instant::now();
    for i in 0..6 {
        buffer.push_row(&[i as f32]);
        sched.note_enqueued(t0);
    }
    let mut served: Vec<Vec<f32>> = Vec::new();
    for _ in 0..3 {
        let d = sched.try_dispatch(buffer.len(), t0, None).expect("dispatch");
        assert_eq!(d.take, 2);
        assert_eq!(d.oracle, 0);
        for _ in 0..d.take {
            served.push(buffer.pop_row().unwrap().to_vec());
        }
        // the single oracle is saturated until this batch completes
        assert!(sched.try_dispatch(buffer.len(), t0, None).is_none(), "backpressure");
        sched.complete(d.id).unwrap();
    }
    assert_eq!(
        served,
        (0..6).map(|i| vec![i as f32]).collect::<Vec<_>>(),
        "items must leave the buffer strictly FIFO"
    );
    assert!(buffer.is_empty());
    assert!(sched.try_dispatch(0, t0, None).is_none(), "nothing left to send");
}

#[test]
fn oracle_rescore_replacements_route_through_the_next_batch() {
    // dynamic_orcale_list parity between oracle modes: after a rescore
    // replaces the buffer, the next batched dispatch carries exactly the
    // rows the per-label path would pop next, in the same order
    let mut buffer = OracleBuffer::new(None);
    let mut sched = OracleScheduler::new(
        &BatchSetting {
            max_size: 3,
            max_delay: Duration::from_secs(10),
            max_outstanding: 2,
        },
        2,
    );
    let t0 = Instant::now();
    for i in 0..4 {
        buffer.push_row(&[i as f32, 0.5]);
        sched.note_enqueued(t0);
    }
    // rescore: keep rows 3 and 1, most-uncertain first (a typical
    // adjustment) — the scheduler only resyncs its clock, the buffer is
    // the single source of row order
    let drained = buffer.drain_block();
    let mut adjusted = RowBlock::new();
    adjusted.push_row(drained.row(3));
    adjusted.push_row(drained.row(1));
    buffer.replace_block(&adjusted);
    sched.sync_queue(buffer.len(), t0);

    // per-label reference order: what pop_row would dispatch
    let want = vec![vec![3.0f32, 0.5], vec![1.0, 0.5]];
    let later = t0 + Duration::from_secs(10); // deadline trigger fires
    let d = sched.try_dispatch(buffer.len(), later, None).expect("size/deadline trigger");
    assert_eq!(d.take, 2, "deadline flushes the whole adjusted remainder");
    let got: Vec<Vec<f32>> =
        (0..d.take).map(|_| buffer.pop_row().unwrap().to_vec()).collect();
    assert_eq!(got, want, "batched dispatch must follow the rescored order");
}
