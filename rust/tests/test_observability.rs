//! Observability-plane e2e: scrape `/metrics` + `/status` from a *live*
//! run (clean and chaos), check the mid-run numbers against the final
//! [`RunReport`], verify `trace_out` produces a Chrome trace whose span
//! counts match the report's counters, and pin that enabling the registry
//! does not perturb the deterministic scenario.
//!
//! The registry, trace sink, and metrics-server bound address are
//! process-wide singletons, so every test here serializes on one lock —
//! same discipline as the telemetry lib tests.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use pal::comm::FaultPlan;
use pal::config::{
    AlSetting, BatchSetting, ExchangeMode, OracleMode, StopCriteria, Topology,
};
use pal::coordinator::selection::SelectAllUtils;
use pal::coordinator::workflow::Workflow;
use pal::json::{parse, Value};
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::sim::scenario::{self, MbWalker};
use pal::sim::workload::{SyntheticModel, SyntheticOracle};
use pal::telemetry::registry::registry;
use pal::telemetry::server::http_get;
use pal::telemetry::RunReport;

/// Wire layout shared with the chaos matrix: input `[x, y, z, g, s]`,
/// label `[e, fx, fy, fz]`.
const IN_DIM: usize = 5;
const OUT_DIM: usize = 4;

const GENS: usize = 4;
const ORACLES: usize = 4;
/// Large enough that the run stays alive for many scrape rounds (each
/// label costs ~2 ms of oracle wall time across 4 oracles).
const LABELS: u64 = 200;

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    // a poisoned lock only means an earlier test failed; the registry is
    // reset per run, so continuing is safe
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Batched flows, strict label budget, slow-ish oracles: long enough to
/// scrape mid-run, fast enough for CI.
fn live_setting() -> AlSetting {
    AlSetting {
        result_dir: "/tmp/pal-observability".into(),
        gene_process: GENS,
        pred_process: 1,
        ml_process: 0,
        orcl_process: ORACLES,
        committee_size: Some(1),
        exchange_mode: ExchangeMode::Batched,
        retrain_size: 10_000, // never flush
        strict_label_budget: true,
        seed: 23,
        batch: BatchSetting {
            max_size: GENS,
            max_delay: Duration::from_millis(2),
            max_outstanding: 2,
        },
        oracle_mode: OracleMode::Batched,
        oracle_batch: BatchSetting {
            max_size: 4,
            max_delay: Duration::from_millis(1),
            max_outstanding: 1,
        },
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(LABELS),
            min_retrain_rounds: 0,
            min_train_epochs: 0,
            max_wall: Some(Duration::from_secs(60)),
        },
        ..Default::default()
    }
}

fn live_kernels(s: &AlSetting) -> KernelSet {
    let max_sel = s.gene_process;
    let generators = (0..s.gene_process)
        .map(|i| {
            let seed = 900 + i as u64;
            Box::new(move || Box::new(MbWalker::new(seed)) as Box<dyn Generator>)
                as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..s.orcl_process)
        .map(|_| {
            Box::new(|| {
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(2),
                    out_dim: OUT_DIM,
                }) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    KernelSet {
        generators,
        oracles,
        model: Arc::new(|mode: Mode, _member: usize| {
            Box::new(SyntheticModel::new(IN_DIM, OUT_DIM, Duration::ZERO, Duration::ZERO, 8, mode))
                as Box<dyn Model>
        }),
        utils: Arc::new(move || {
            Box::new(SelectAllUtils { max_per_iter: max_sel }) as Box<dyn Utils>
        }),
    }
}

/// Wait for the run-started signal: the metrics server's bound address
/// appears in the registry once `Workflow::run_on` has it listening.
fn wait_for_server() -> std::net::SocketAddr {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        if let Some(addr) = registry().bound_addr() {
            return addr;
        }
        assert!(Instant::now() < deadline, "metrics server never came up");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Scrape `/status` until `pred(snapshot)` holds, returning that
/// snapshot. Panics if the server goes away (run over) first.
fn poll_status_until(
    addr: std::net::SocketAddr,
    what: &str,
    pred: impl Fn(&Value) -> bool,
) -> Value {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (code, body) = http_get(addr, "/status").expect("run ended before /status satisfied");
        assert_eq!(code, 200);
        let snap = parse(&body).expect("valid /status json");
        if pred(&snap) {
            return snap;
        }
        assert!(Instant::now() < deadline, "{what} never observed in /status");
        std::thread::sleep(Duration::from_millis(1));
    }
}

// ---------------------------------------------------------------------------
// Live scrape during a clean run
// ---------------------------------------------------------------------------

/// `--metrics-addr` serves `/metrics`, `/status`, and `/healthz` while the
/// workflow is in flight, with live (nonzero, monotonically growing)
/// numbers that end consistent with the final report.
#[test]
fn live_run_serves_metrics_and_status_mid_run() {
    let _g = serial();
    let mut setting = live_setting();
    setting.metrics_addr = Some("127.0.0.1:0".into());
    let kernels = live_kernels(&setting);
    let runner = std::thread::spawn(move || Workflow::new(setting).run(kernels).unwrap());

    let addr = wait_for_server();
    let (code, body) = http_get(addr, "/healthz").unwrap();
    assert_eq!((code, body.as_str()), (200, "ok\n"));

    // live progress: labels grow while the run is still in flight
    let snap = poll_status_until(addr, "first labels", |s| {
        s.path("run.labels").as_f64().unwrap_or(0.0) > 0.0
    });
    let mid_labels = snap.path("run.labels").as_f64().unwrap();
    assert!(mid_labels >= 1.0);
    // every rank row the supervisors registered is present and typed
    let ranks = snap.get("ranks").as_array().expect("ranks section");
    assert!(
        ranks.iter().any(|r| r.get("kernel").as_str() == Some("oracle")),
        "no oracle rank row in /status"
    );
    assert!(
        ranks.iter().any(|r| r.get("state").as_str() == Some("running")),
        "no running rank mid-run"
    );

    // the Prometheus rendering serves the same counters
    let (code, prom) = http_get(addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    assert!(prom.contains("pal_labels_total"), "no labels counter in /metrics");
    assert!(prom.contains("pal_oracle_rtt_ms_count"), "no rtt histogram in /metrics");
    assert!(prom.contains("pal_world_messages_total"), "no world stats in /metrics");

    let report = runner.join().unwrap();
    assert!(report.oracle_labels >= LABELS);
    // mid-run counters never exceed the final truth
    assert!(mid_labels <= report.oracle_labels as f64);
    // the server is torn down with the run
    assert_eq!(registry().bound_addr(), None);
    assert!(http_get(addr, "/healthz").is_err(), "server still up after join");
}

// ---------------------------------------------------------------------------
// Live scrape during a chaos run
// ---------------------------------------------------------------------------

/// Fault counters are visible in `/status` *before* join — an operator
/// watching the surface sees the eviction while the run is still degraded
/// but alive — and the mid-run numbers agree with the final FaultReport.
#[test]
fn chaos_run_shows_fault_counters_before_join() {
    let _g = serial();
    let mut setting = live_setting();
    setting.metrics_addr = Some("127.0.0.1:0".into());
    let victim = Topology::new(&setting).orcl_ranks()[0];
    let kernels = live_kernels(&setting);
    let plan = FaultPlan::default().kill_after_recvs(victim, 1);
    let runner =
        std::thread::spawn(move || Workflow::new(setting).with_faults(plan).run(kernels).unwrap());

    let addr = wait_for_server();
    let snap = poll_status_until(addr, "oracle eviction", |s| {
        s.path("faults.oracle_evictions").as_f64().unwrap_or(0.0) >= 1.0
    });
    let mid_evictions = snap.path("faults.oracle_evictions").as_f64().unwrap();
    let failed: Vec<f64> = snap
        .path("faults.failed_ranks")
        .as_array()
        .expect("failed_ranks")
        .iter()
        .filter_map(|v| v.as_f64())
        .collect();
    assert!(
        failed.contains(&(victim as f64)),
        "victim {victim} not in live failed_ranks {failed:?}"
    );
    // the dead endpoint is flagged on its rank row
    let ranks = snap.get("ranks").as_array().unwrap();
    assert!(
        ranks.iter().any(|r| {
            r.get("rank").as_f64() == Some(victim as f64)
                && r.get("state").as_str() == Some("failed")
        }),
        "victim rank row not marked failed"
    );

    let report = runner.join().unwrap();
    assert!(report.oracle_labels >= LABELS, "recovery failed: {}", report.oracle_labels);
    assert!(report.faults.failed_ranks.contains(&victim));
    // live counters are a prefix of the final truth
    assert!(mid_evictions >= 1.0);
    assert!(mid_evictions <= report.faults.oracle_evictions as f64);
}

// ---------------------------------------------------------------------------
// Trace recorder vs RunReport counters
// ---------------------------------------------------------------------------

fn span_count(events: &[Value], name: &str) -> u64 {
    events.iter().filter(|e| e.get("name").as_str() == Some(name)).count() as u64
}

/// `--trace-out` writes a Chrome trace-event array whose per-phase span
/// counts equal the post-mortem counters: `predict` == prediction
/// batches, `oracle_calc` == oracle batches, `retrain` == training
/// rounds, `weight_sync` == training weight syncs.
#[test]
fn trace_span_counts_match_report_counters() {
    let _g = serial();
    let path = "/tmp/pal-observability-trace.json";
    let _ = std::fs::remove_file(path);
    let mut setting = scenario::deterministic_setting(OracleMode::Batched);
    setting.trace_out = Some(path.into());
    let report: RunReport =
        Workflow::new(setting).run(scenario::deterministic_kernels()).unwrap();

    let text = std::fs::read_to_string(path).expect("trace file written");
    let events = parse(&text).expect("valid trace json");
    let events = events.as_array().expect("trace is an array").to_vec();
    assert!(!events.is_empty(), "empty trace from a full run");

    // every event is well-formed Chrome trace: complete span or instant
    for e in &events {
        let ph = e.get("ph").as_str().expect("ph");
        assert!(ph == "X" || ph == "i", "unexpected phase {ph}");
        assert!(e.get("ts").as_f64().is_some());
        assert!(e.get("tid").as_f64().is_some());
    }

    assert_eq!(
        span_count(&events, "predict"),
        report.sum_counter("prediction", "batches"),
        "predict spans vs prediction batches"
    );
    assert_eq!(
        span_count(&events, "oracle_calc"),
        report.sum_counter("oracle", "batches"),
        "oracle_calc spans vs oracle batches"
    );
    assert_eq!(
        span_count(&events, "retrain"),
        report.sum_counter("training", "rounds"),
        "retrain spans vs training rounds"
    );
    assert_eq!(
        span_count(&events, "weight_sync"),
        report.sum_counter("training", "weight_syncs"),
        "weight_sync spans vs training weight_syncs"
    );
    // the dispatch legs trace their batch lifecycles too
    assert!(span_count(&events, "oracle_batch") >= 1, "no oracle_batch lifecycle spans");
    assert!(span_count(&events, "pred_batch") >= 1, "no pred_batch lifecycle spans");
    // a clean run records no fault events
    assert_eq!(span_count(&events, "rank_down"), 0);
    assert_eq!(span_count(&events, "evict"), 0);
    let _ = std::fs::remove_file(path);
}

// ---------------------------------------------------------------------------
// Determinism with the registry enabled
// ---------------------------------------------------------------------------

/// Publishing live metrics must not perturb the run: the deterministic
/// scenario stays bit-identical with the registry enabled vs disabled.
#[test]
fn registry_enabled_run_is_bit_identical_to_disabled() {
    let _g = serial();
    registry().reset_for_run(None);
    registry().set_enabled(true);
    let observed = scenario::run_once(OracleMode::Batched);
    // the registry actually saw the run it observed
    assert!(
        registry().counter(pal::telemetry::registry::Counter::Labels) >= scenario::LABELS,
        "registry missed the run's labels"
    );
    registry().set_enabled(false);
    let plain = scenario::run_once(OracleMode::Batched);

    assert_eq!(observed.oracle_labels, plain.oracle_labels);
    assert_eq!(observed.retrain_rounds, plain.retrain_rounds);
    assert_eq!(observed.final_losses.len(), plain.final_losses.len());
    for (i, (x, y)) in observed.final_losses.iter().zip(&plain.final_losses).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "trainer {i} loss differs with registry on: {x} vs {y}"
        );
    }
}
