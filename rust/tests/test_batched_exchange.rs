//! Integration: the batched, sharded prediction Exchange
//! (`exchange_mode = Batched`) over synthetic kernels — coalescing,
//! shard routing, weight fan-out to replicas, message-count wins, and
//! variable-size-mode compatibility.

use std::sync::Arc;
use std::time::Duration;

use pal::config::{AlSetting, BatchSetting, ExchangeMode, StopCriteria};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::sim::workload::{SyntheticGenerator, SyntheticModel, SyntheticOracle};
use pal::telemetry::RunReport;

fn batched_setting(gene: usize, pred: usize, committee: usize, orcl: usize, ml: usize) -> AlSetting {
    AlSetting {
        result_dir: format!("/tmp/pal-batched-{gene}-{pred}-{committee}-{orcl}-{ml}"),
        gene_process: gene,
        pred_process: pred,
        orcl_process: orcl,
        ml_process: ml,
        committee_size: Some(committee),
        exchange_mode: ExchangeMode::Batched,
        retrain_size: 4,
        batch: BatchSetting {
            max_size: gene.max(1),
            max_delay: Duration::from_millis(1),
            max_outstanding: 2,
        },
        stop: StopCriteria {
            max_iterations: Some(40),
            max_labels: None,
            max_wall: Some(Duration::from_secs(30)),
            ..Default::default()
        },
        ..Default::default()
    }
}

fn synthetic_kernels(s: &AlSetting, threshold: f32) -> KernelSet {
    let generators = (0..s.gene_process)
        .map(|i| {
            let seed = i as u64;
            Box::new(move || {
                Box::new(SyntheticGenerator::new(4, Duration::ZERO, u64::MAX, seed))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..s.orcl_process)
        .map(|_| {
            Box::new(|| {
                Box::new(SyntheticOracle { label_cost: Duration::from_millis(1), out_dim: 4 })
                    as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, member: usize| {
        let mut m =
            SyntheticModel::new(4, 4, Duration::ZERO, Duration::from_micros(200), 16, mode);
        // diversify members (replicas of one member stay identical)
        let w: Vec<f32> = (0..16).map(|k| ((k + member * 7) % 5) as f32 * 0.1).collect();
        m.update(&w);
        Box::new(m) as Box<dyn Model>
    });
    let utils =
        Arc::new(move || Box::new(CommitteeStdUtils::new(threshold, 8)) as Box<dyn Utils>);
    KernelSet { generators, oracles, model, utils }
}

fn run(s: AlSetting, threshold: f32) -> RunReport {
    let kernels = synthetic_kernels(&s, threshold);
    Workflow::new(s).run(kernels).unwrap()
}

#[test]
fn batched_workflow_labels_and_trains() {
    let mut s = batched_setting(6, 4, 2, 2, 2);
    s.stop.max_iterations = None;
    s.stop.max_labels = Some(10);
    let report = run(s, 0.0); // everything uncertain → labeling flows
    assert!(report.oracle_labels >= 10, "labels {}", report.oracle_labels);
    assert!(report.retrain_rounds > 0, "labels should trigger retraining");
    assert!(report.sum_counter("prediction", "samples") > 0);
    assert!(report.sum_counter("exchange", "batches_dispatched") > 0);
    // every batched item came back to a generator
    let items = report.sum_counter("exchange", "batch_items");
    assert!(items > 0);
}

#[test]
fn sharded_routing_exercises_every_predictor() {
    // 4 predictors in 2 shards of 2; round-robin must spread batches so
    // every rank serves traffic
    let s = batched_setting(6, 4, 2, 0, 0);
    let report = run(s, f32::MAX);
    assert_eq!(report.al_iterations, 40);
    for p in report.kernel("prediction") {
        assert!(
            p.counter("batches") > 0,
            "predictor rank {} never served a batch",
            p.rank
        );
    }
    // generators kept stepping throughout
    assert!(report.sum_counter("generator", "steps") >= 40);
}

#[test]
fn weight_sync_reaches_replicas_in_every_shard() {
    // trainers (one per member) must push weights to the member's replica
    // in both shards, not just the paired first-shard rank
    let mut s = batched_setting(4, 4, 2, 2, 2);
    s.stop.max_iterations = None;
    s.stop.max_labels = Some(8);
    let report = run(s, 0.0);
    for p in report.kernel("prediction") {
        assert!(
            p.counter("weight_updates") >= 1,
            "prediction rank {} saw no weight sync",
            p.rank
        );
    }
}

#[test]
fn coalescing_cuts_messages_per_item_at_least_2x() {
    // same items through the same topology; only the batch size differs.
    // batch=1 models the one-request-at-a-time relay; batch=G coalesces a
    // full generator round into one shard dispatch.
    let gene = 8usize;
    let items_target = 240u64;

    let mut coalesced = batched_setting(gene, 2, 2, 0, 0);
    coalesced.batch.max_size = gene;
    coalesced.batch.max_delay = Duration::from_millis(200); // full batches
    coalesced.stop.max_iterations = Some(items_target / gene as u64);
    let rep_coalesced = run(coalesced, f32::MAX);

    let mut single = batched_setting(gene, 2, 2, 0, 0);
    single.batch.max_size = 1;
    single.stop.max_iterations = Some(items_target);
    let rep_single = run(single, f32::MAX);

    let items_c = rep_coalesced.sum_counter("exchange", "batch_items").max(1);
    let items_s = rep_single.sum_counter("exchange", "batch_items").max(1);
    let per_item_c = rep_coalesced.messages as f64 / items_c as f64;
    let per_item_s = rep_single.messages as f64 / items_s as f64;
    assert!(
        per_item_s >= 2.0 * per_item_c,
        "coalescing saved too little: {per_item_s:.2} vs {per_item_c:.2} msgs/item \
         ({items_s} vs {items_c} items)"
    );
}

#[test]
fn variable_size_mode_is_consumed_by_batched_exchange() {
    let mut s = batched_setting(4, 2, 2, 0, 0);
    s.fixed_size_data = false;
    s.stop.max_iterations = Some(20);
    let report = run(s, f32::MAX);
    assert_eq!(report.al_iterations, 20);
    let headers = report.sum_counter("exchange", "size_headers");
    assert!(headers > 0, "size headers were not consumed");
}

#[test]
fn generator_stop_signal_reaches_manager_in_batched_mode() {
    let mut s = batched_setting(3, 2, 2, 1, 2);
    s.stop.max_iterations = None; // only the generator can stop the run
    s.stop.max_wall = Some(Duration::from_secs(20));
    let mut kernels = synthetic_kernels(&s, 0.5);
    kernels.generators = (0..3usize)
        .map(|i| {
            Box::new(move || {
                // generator 0 signals stop after 10 steps
                let max = if i == 0 { 10 } else { u64::MAX };
                Box::new(SyntheticGenerator::new(4, Duration::ZERO, max, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let report = Workflow::new(s).run(kernels).unwrap();
    assert!(
        report.wall < Duration::from_secs(20),
        "stop signal did not shut the workflow down"
    );
    assert!(report.sum_counter("exchange", "stop_signals") >= 1);
}
