//! Deterministic end-to-end AL loop: full Manager + Exchange workflow on
//! the Müller–Brown potential with fixed RNG seeds, asserting the
//! oracle-label count, the retrain-round count, and the final training
//! losses are bit-stable across runs.
//!
//! Determinism is by construction, not by luck:
//!
//! * generators are fixed-seed walkers that ignore `data_to_gene`, so
//!   trajectories don't depend on when weight syncs land;
//! * selection is a pure function of the *inputs* (Müller–Brown energy
//!   threshold), not of the committee's predictions;
//! * batches are full (`batch.max_size = gene_process`, long deadline) and
//!   items are ordered by origin rank inside a batch, so batch composition
//!   is arrival-order independent;
//! * a single oracle labels in dispatch order, and the Manager's strict
//!   label budget (`strict_label_budget`) dispatches exactly
//!   `stop.max_labels` inputs — never an in-flight extra;
//! * trainers run fixed-epoch rounds (interrupts ignored), so the final
//!   loss is a pure function of the (deterministic) labeled dataset.

use std::sync::Arc;
use std::time::Duration;

use pal::comm::FaultPlan;
use pal::config::{AlSetting, BatchSetting, ExchangeMode, OracleMode, StopCriteria};
use pal::coordinator::workflow::Workflow;
use pal::data::Dataset;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::kernels::oracles::PesOracle;
use pal::potential::{MullerBrown, Pes};
use pal::rng::Rng;
use pal::sim::workload::SyntheticModel;
use pal::telemetry::RunReport;

/// Wire layout for a 1-"atom" PES with 1 global and 1 state:
/// input `[x, y, z, g, s]`, label `[e, fx, fy, fz]`.
const IN_DIM: usize = 5;
const OUT_DIM: usize = 4;

/// Fixed-seed random walker over the Müller–Brown landscape. Ignores the
/// checked predictions entirely: the trajectory is a pure function of the
/// seed, which is what makes the whole loop replayable.
struct MbWalker {
    rng: Rng,
    pos: [f32; 2],
}

impl MbWalker {
    fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let pes = MullerBrown::default();
        let x0 = pes.initial_geometry(&mut rng);
        MbWalker { rng, pos: [x0[0], x0[1]] }
    }
}

impl Generator for MbWalker {
    fn generate_new_data(&mut self, _data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>) {
        self.pos[0] += (self.rng.normal() * 0.08) as f32;
        self.pos[1] += (self.rng.normal() * 0.08) as f32;
        (false, vec![self.pos[0], self.pos[1], 0.0, 0.0, 1.0])
    }
}

/// Selection that depends only on the *input*: configurations whose
/// Müller–Brown energy exceeds `threshold` go to the oracle (high-energy =
/// poorly-sampled transition regions). The checked payloads are the
/// committee means, but nothing downstream consumes them.
struct EnergySelectUtils {
    pes: MullerBrown,
    threshold: f64,
    max_per_batch: usize,
}

impl Utils for EnergySelectUtils {
    fn prediction_check(
        &mut self,
        list_data_to_pred: &[Vec<f32>],
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let checked = pal::coordinator::selection::committee_mean(preds_per_model);
        let to_orcl: Vec<Vec<f32>> = list_data_to_pred
            .iter()
            .filter(|x| self.pes.energy(&x[..3]) > self.threshold)
            .take(self.max_per_batch)
            .cloned()
            .collect();
        (to_orcl, checked)
    }
}

/// Fixed-epoch committee member: like the synthetic model but immune to
/// retraining interrupts, so every round runs the same number of epochs.
struct FixedEpochModel(SyntheticModel);

impl Model for FixedEpochModel {
    fn predict(&mut self, list: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.0.predict(list)
    }
    fn update(&mut self, w: &[f32]) {
        self.0.update(w)
    }
    fn get_weight(&self) -> Vec<f32> {
        self.0.get_weight()
    }
    fn get_weight_size(&self) -> usize {
        self.0.get_weight_size()
    }
    fn add_trainingset(&mut self, points: &[(Vec<f32>, Vec<f32>)]) {
        self.0.add_trainingset(points)
    }
    fn retrain(&mut self, _interrupt: &mut dyn FnMut() -> bool) -> bool {
        self.0.retrain(&mut || false)
    }
    fn last_loss(&self) -> Option<f32> {
        self.0.last_loss()
    }
    fn last_round_epochs(&self) -> u64 {
        self.0.last_round_epochs()
    }
}

const GENS: usize = 4;
const MEMBERS: usize = 2;
const SHARDS: usize = 2;
const LABELS: u64 = 12;
const RETRAIN_SIZE: usize = 4;

fn deterministic_setting(oracle_mode: OracleMode) -> AlSetting {
    let flushes = LABELS / RETRAIN_SIZE as u64; // 3
    AlSetting {
        result_dir: "/tmp/pal-determinism".into(),
        gene_process: GENS,
        pred_process: MEMBERS * SHARDS,
        ml_process: MEMBERS,
        orcl_process: 1, // single oracle → labels land in dispatch order
        committee_size: Some(MEMBERS),
        exchange_mode: ExchangeMode::Batched,
        retrain_size: RETRAIN_SIZE,
        strict_label_budget: true,
        // exercise the rescore path end to end on every retrain:
        // EnergySelectUtils keeps the default (identity)
        // `adjust_input_for_oracle`, so the full drain → rescore →
        // replace → scheduler-resync round-trip runs without changing the
        // dispatch order — rescore replacements are bit-identical across
        // oracle modes by construction, and any regression that perturbs
        // the buffer or the batched scheduler clock breaks bit-stability
        dynamic_oracle_list: true,
        seed: 7,
        batch: BatchSetting {
            // full batches only: every batch holds one item per generator,
            // ordered by rank — composition is timing-independent
            max_size: GENS,
            max_delay: Duration::from_secs(10),
            max_outstanding: 2,
        },
        oracle_mode,
        oracle_batch: BatchSetting {
            // selections arrive in multiples of GENS = RETRAIN_SIZE, so the
            // size trigger always forms *full* oracle batches aligned with
            // the retrain flush boundary — batch composition (not just item
            // order) is timing-independent, and label arrival partitions
            // the train buffer exactly like the per-label path. One batch
            // in flight at a time: with 2+, two result frames could land in
            // one Manager drain and merge two retrain flushes into one,
            // making the flush partitioning timing-dependent.
            max_size: RETRAIN_SIZE,
            max_delay: Duration::from_secs(10),
            max_outstanding: 1,
        },
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(LABELS),
            // wait for every flushed batch to finish retraining (one
            // RETRAIN_DONE per trainer per flush) before shutting down
            min_retrain_rounds: flushes * MEMBERS as u64,
            min_train_epochs: 0,
            max_wall: Some(Duration::from_secs(60)),
        },
        ..Default::default()
    }
}

fn deterministic_kernels() -> KernelSet {
    let generators = (0..GENS)
        .map(|i| {
            let seed = 100 + i as u64;
            Box::new(move || Box::new(MbWalker::new(seed)) as Box<dyn Generator>)
                as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = vec![Box::new(|| {
        Box::new(PesOracle::fixed(MullerBrown::default(), 1)) as Box<dyn Oracle>
    }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>];
    let model = Arc::new(move |mode: Mode, member: usize| {
        let mut inner =
            SyntheticModel::new(IN_DIM, OUT_DIM, Duration::ZERO, Duration::ZERO, 8, mode);
        // member-specific deterministic init; replicas of a member match
        let w: Vec<f32> = (0..IN_DIM * OUT_DIM)
            .map(|k| ((k + member * 11) % 7) as f32 * 0.05)
            .collect();
        inner.update(&w);
        Box::new(FixedEpochModel(inner)) as Box<dyn Model>
    });
    let utils = Arc::new(|| {
        Box::new(EnergySelectUtils {
            pes: MullerBrown::default(),
            // far below every reachable energy → select everything, so the
            // selected sequence is exactly the generator round-robin
            threshold: -1e9,
            max_per_batch: GENS,
        }) as Box<dyn Utils>
    });
    KernelSet { generators, oracles, model, utils }
}

fn run_once(oracle_mode: OracleMode) -> RunReport {
    Workflow::new(deterministic_setting(oracle_mode))
        .run(deterministic_kernels())
        .unwrap()
}

#[test]
fn muller_brown_loop_is_bit_stable_across_runs() {
    let a = run_once(OracleMode::PerLabel);
    let b = run_once(OracleMode::PerLabel);

    // exact label budget, both runs
    assert_eq!(a.oracle_labels, LABELS, "run A labels");
    assert_eq!(b.oracle_labels, LABELS, "run B labels");

    // every flushed batch retrained on every committee member, both runs
    let expected_rounds = (LABELS / RETRAIN_SIZE as u64) * MEMBERS as u64;
    assert_eq!(a.retrain_rounds, expected_rounds, "run A rounds");
    assert_eq!(b.retrain_rounds, expected_rounds, "run B rounds");

    // final losses are bit-identical per trainer
    assert_eq!(a.final_losses.len(), MEMBERS);
    assert_eq!(b.final_losses.len(), MEMBERS);
    for (i, (x, y)) in a.final_losses.iter().zip(&b.final_losses).enumerate() {
        assert!(x.is_finite(), "trainer {i} loss not reported: {x}");
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "trainer {i} loss differs across runs: {x} vs {y}"
        );
    }
}

#[test]
fn strict_budget_never_overshoots() {
    let report = run_once(OracleMode::PerLabel);
    let manager = &report.kernel("manager")[0];
    assert_eq!(manager.counter("dispatched"), LABELS);
    assert_eq!(manager.counter("labels"), LABELS);
    assert_eq!(report.sum_counter("oracle", "labels"), LABELS);
}

/// The oracle-plane acceptance pin: labels and the training-set order —
/// and therefore every trainer's final loss, a pure function of the
/// (ordered) labeled dataset — are **bit-identical** between the batched
/// and per-label oracle modes, and the batched mode is itself bit-stable
/// across runs. The single oracle makes batch completion FIFO, so item
/// order through the train buffer matches the per-label dispatch order
/// exactly, whatever the batch boundaries.
#[test]
fn batched_oracle_mode_is_bit_identical_to_per_label() {
    let per_label = run_once(OracleMode::PerLabel);
    let batched = run_once(OracleMode::Batched);
    let batched2 = run_once(OracleMode::Batched);

    // exact label budget in both modes (item-level `dispatched` semantics)
    assert_eq!(per_label.oracle_labels, LABELS);
    assert_eq!(batched.oracle_labels, LABELS, "batched mode labels");
    let manager = &batched.kernel("manager")[0];
    assert_eq!(manager.counter("dispatched"), LABELS);
    assert_eq!(report_batches(&batched), (LABELS / RETRAIN_SIZE as u64, LABELS));
    assert_eq!(per_label.retrain_rounds, batched.retrain_rounds);

    // final losses bit-identical: per-label vs batched, and run to run
    for (i, (x, y)) in per_label.final_losses.iter().zip(&batched.final_losses).enumerate() {
        assert!(x.is_finite(), "trainer {i} loss not reported: {x}");
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "trainer {i} loss differs between oracle modes: {x} vs {y}"
        );
    }
    for (x, y) in batched.final_losses.iter().zip(&batched2.final_losses) {
        assert_eq!(x.to_bits(), y.to_bits(), "batched mode not bit-stable across runs");
    }
}

/// Committee member backed by the flat [`Dataset`]: labeled pairs go
/// through `Dataset::add` (val split + rolling window), and every retrain
/// round draws fixed-size minibatches via the strided-gather `minibatch`
/// and takes one SGD step per draw on a linear map. The final loss is a
/// pure function of the ordered labeled stream and the dataset's RNG
/// stream, so it pins the flat Dataset's draw order and window semantics
/// end to end.
struct DatasetModel {
    data: Dataset,
    w: Vec<f32>,
    loss: Option<f32>,
    epochs: u64,
}

const DS_WINDOW: usize = 8;
const DS_EPOCHS: usize = 4;
const DS_MB: usize = 2;

impl DatasetModel {
    fn new(member: usize) -> Self {
        let w = (0..IN_DIM * OUT_DIM)
            .map(|k| ((k + member * 11) % 7) as f32 * 0.05)
            .collect();
        DatasetModel {
            data: Dataset::new(0.25, 1000 + member as u64).with_rolling_window(DS_WINDOW),
            w,
            loss: None,
            epochs: 0,
        }
    }

    fn forward(&self, x: &[f32], out: &mut [f32]) {
        for (j, o) in out.iter_mut().enumerate() {
            *o = (0..IN_DIM).map(|i| x[i] * self.w[i * OUT_DIM + j]).sum();
        }
    }
}

impl Model for DatasetModel {
    fn predict(&mut self, list: &[Vec<f32>]) -> Vec<Vec<f32>> {
        list.iter()
            .map(|x| {
                let mut out = vec![0.0; OUT_DIM];
                self.forward(x, &mut out);
                out
            })
            .collect()
    }
    fn update(&mut self, w: &[f32]) {
        self.w = w.to_vec();
    }
    fn get_weight(&self) -> Vec<f32> {
        self.w.clone()
    }
    fn get_weight_size(&self) -> usize {
        IN_DIM * OUT_DIM
    }
    fn add_trainingset(&mut self, points: &[(Vec<f32>, Vec<f32>)]) {
        self.data.add(points);
    }
    fn retrain(&mut self, _interrupt: &mut dyn FnMut() -> bool) -> bool {
        if self.data.is_empty() {
            return false;
        }
        let mut loss_acc = 0.0f32;
        for _ in 0..DS_EPOCHS {
            let mut grad = [0.0f32; IN_DIM * OUT_DIM];
            {
                let (xs, ys) = self.data.minibatch(DS_MB);
                for r in 0..DS_MB {
                    let x = &xs[r * IN_DIM..(r + 1) * IN_DIM];
                    let y = &ys[r * OUT_DIM..(r + 1) * OUT_DIM];
                    for j in 0..OUT_DIM {
                        let p: f32 = (0..IN_DIM).map(|i| x[i] * self.w[i * OUT_DIM + j]).sum();
                        let e = p - y[j];
                        loss_acc += e * e;
                        for i in 0..IN_DIM {
                            grad[i * OUT_DIM + j] += e * x[i];
                        }
                    }
                }
            }
            for (wk, gk) in self.w.iter_mut().zip(grad.iter()) {
                *wk -= 1e-4 * gk;
            }
        }
        self.loss = Some(loss_acc / (DS_EPOCHS * DS_MB) as f32);
        self.epochs += DS_EPOCHS as u64;
        false
    }
    fn last_loss(&self) -> Option<f32> {
        self.loss
    }
    fn last_round_epochs(&self) -> u64 {
        DS_EPOCHS as u64
    }
}

fn dataset_kernels() -> KernelSet {
    let KernelSet { generators, oracles, utils, .. } = deterministic_kernels();
    let model = Arc::new(move |_mode: Mode, member: usize| {
        Box::new(DatasetModel::new(member)) as Box<dyn Model>
    });
    KernelSet { generators, oracles, model, utils }
}

fn run_dataset(oracle_mode: OracleMode) -> RunReport {
    Workflow::new(deterministic_setting(oracle_mode))
        .run(dataset_kernels())
        .unwrap()
}

/// The memory-plane determinism pin: routing every labeled pair through
/// the flat `Dataset` (val split, index-based rolling window, strided
/// `minibatch` gather) keeps labels and final losses **bit-identical**
/// between the per-label and batched oracle modes, and bit-stable across
/// runs. Any drift in the Dataset's RNG draw order, window eviction, or
/// gather layout shows up here as a loss mismatch.
#[test]
fn flat_dataset_model_is_bit_identical_across_oracle_modes() {
    let per_label = run_dataset(OracleMode::PerLabel);
    let batched = run_dataset(OracleMode::Batched);
    let batched2 = run_dataset(OracleMode::Batched);

    assert_eq!(per_label.oracle_labels, LABELS);
    assert_eq!(batched.oracle_labels, LABELS, "batched mode labels");
    assert_eq!(per_label.retrain_rounds, batched.retrain_rounds);

    assert_eq!(per_label.final_losses.len(), MEMBERS);
    for (i, (x, y)) in per_label.final_losses.iter().zip(&batched.final_losses).enumerate() {
        assert!(x.is_finite(), "trainer {i} loss not reported: {x}");
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "trainer {i} Dataset-backed loss differs between oracle modes: {x} vs {y}"
        );
    }
    for (x, y) in batched.final_losses.iter().zip(&batched2.final_losses) {
        assert_eq!(x.to_bits(), y.to_bits(), "Dataset-backed run not bit-stable");
    }
}

/// The fault plane's zero-cost pin: installing an *empty* `FaultPlan`
/// compiles to no per-rank fault state at all, so the run is bit-identical
/// to a plain one — same labels, same rounds, same final losses to the
/// bit — and its fault report is clean.
#[test]
fn empty_fault_plan_is_bit_identical_to_plain_run() {
    let plain = run_once(OracleMode::PerLabel);
    let planned = Workflow::new(deterministic_setting(OracleMode::PerLabel))
        .with_faults(FaultPlan::default())
        .run(deterministic_kernels())
        .unwrap();

    assert!(planned.faults.is_clean(), "{:?}", planned.faults);
    assert_eq!(plain.oracle_labels, planned.oracle_labels);
    assert_eq!(plain.retrain_rounds, planned.retrain_rounds);
    for (i, (x, y)) in plain.final_losses.iter().zip(&planned.final_losses).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "trainer {i} loss differs under an empty fault plan: {x} vs {y}"
        );
    }
}

/// `(oracle batch frames, labels they carried)` from the oracle telemetry.
fn report_batches(report: &RunReport) -> (u64, u64) {
    (
        report.sum_counter("oracle", "batches"),
        report.sum_counter("oracle", "labels"),
    )
}
