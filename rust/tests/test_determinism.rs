//! Deterministic end-to-end AL loop: full Manager + Exchange workflow on
//! the Müller–Brown potential with fixed RNG seeds, asserting the
//! oracle-label count, the retrain-round count, and the final training
//! losses are bit-stable across runs.
//!
//! Determinism is by construction, not by luck:
//!
//! * generators are fixed-seed walkers that ignore `data_to_gene`, so
//!   trajectories don't depend on when weight syncs land;
//! * selection is a pure function of the *inputs* (Müller–Brown energy
//!   threshold), not of the committee's predictions;
//! * batches are full (`batch.max_size = gene_process`, long deadline) and
//!   items are ordered by origin rank inside a batch, so batch composition
//!   is arrival-order independent;
//! * a single oracle labels in dispatch order, and the Manager's strict
//!   label budget (`strict_label_budget`) dispatches exactly
//!   `stop.max_labels` inputs — never an in-flight extra;
//! * trainers run fixed-epoch rounds (interrupts ignored), so the final
//!   loss is a pure function of the (deterministic) labeled dataset.

use std::sync::Arc;
use std::time::Duration;

use pal::config::{AlSetting, BatchSetting, ExchangeMode, StopCriteria};
use pal::coordinator::workflow::Workflow;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::kernels::oracles::PesOracle;
use pal::potential::{MullerBrown, Pes};
use pal::rng::Rng;
use pal::sim::workload::SyntheticModel;
use pal::telemetry::RunReport;

/// Wire layout for a 1-"atom" PES with 1 global and 1 state:
/// input `[x, y, z, g, s]`, label `[e, fx, fy, fz]`.
const IN_DIM: usize = 5;
const OUT_DIM: usize = 4;

/// Fixed-seed random walker over the Müller–Brown landscape. Ignores the
/// checked predictions entirely: the trajectory is a pure function of the
/// seed, which is what makes the whole loop replayable.
struct MbWalker {
    rng: Rng,
    pos: [f32; 2],
}

impl MbWalker {
    fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let pes = MullerBrown::default();
        let x0 = pes.initial_geometry(&mut rng);
        MbWalker { rng, pos: [x0[0], x0[1]] }
    }
}

impl Generator for MbWalker {
    fn generate_new_data(&mut self, _data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>) {
        self.pos[0] += (self.rng.normal() * 0.08) as f32;
        self.pos[1] += (self.rng.normal() * 0.08) as f32;
        (false, vec![self.pos[0], self.pos[1], 0.0, 0.0, 1.0])
    }
}

/// Selection that depends only on the *input*: configurations whose
/// Müller–Brown energy exceeds `threshold` go to the oracle (high-energy =
/// poorly-sampled transition regions). The checked payloads are the
/// committee means, but nothing downstream consumes them.
struct EnergySelectUtils {
    pes: MullerBrown,
    threshold: f64,
    max_per_batch: usize,
}

impl Utils for EnergySelectUtils {
    fn prediction_check(
        &mut self,
        list_data_to_pred: &[Vec<f32>],
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let checked = pal::coordinator::selection::committee_mean(preds_per_model);
        let to_orcl: Vec<Vec<f32>> = list_data_to_pred
            .iter()
            .filter(|x| self.pes.energy(&x[..3]) > self.threshold)
            .take(self.max_per_batch)
            .cloned()
            .collect();
        (to_orcl, checked)
    }
}

/// Fixed-epoch committee member: like the synthetic model but immune to
/// retraining interrupts, so every round runs the same number of epochs.
struct FixedEpochModel(SyntheticModel);

impl Model for FixedEpochModel {
    fn predict(&mut self, list: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.0.predict(list)
    }
    fn update(&mut self, w: &[f32]) {
        self.0.update(w)
    }
    fn get_weight(&self) -> Vec<f32> {
        self.0.get_weight()
    }
    fn get_weight_size(&self) -> usize {
        self.0.get_weight_size()
    }
    fn add_trainingset(&mut self, points: &[(Vec<f32>, Vec<f32>)]) {
        self.0.add_trainingset(points)
    }
    fn retrain(&mut self, _interrupt: &mut dyn FnMut() -> bool) -> bool {
        self.0.retrain(&mut || false)
    }
    fn last_loss(&self) -> Option<f32> {
        self.0.last_loss()
    }
    fn last_round_epochs(&self) -> u64 {
        self.0.last_round_epochs()
    }
}

const GENS: usize = 4;
const MEMBERS: usize = 2;
const SHARDS: usize = 2;
const LABELS: u64 = 12;
const RETRAIN_SIZE: usize = 4;

fn deterministic_setting() -> AlSetting {
    let flushes = LABELS / RETRAIN_SIZE as u64; // 3
    AlSetting {
        result_dir: "/tmp/pal-determinism".into(),
        gene_process: GENS,
        pred_process: MEMBERS * SHARDS,
        ml_process: MEMBERS,
        orcl_process: 1, // single oracle → labels land in dispatch order
        committee_size: Some(MEMBERS),
        exchange_mode: ExchangeMode::Batched,
        retrain_size: RETRAIN_SIZE,
        strict_label_budget: true,
        seed: 7,
        batch: BatchSetting {
            // full batches only: every batch holds one item per generator,
            // ordered by rank — composition is timing-independent
            max_size: GENS,
            max_delay: Duration::from_secs(10),
            max_outstanding: 2,
        },
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(LABELS),
            // wait for every flushed batch to finish retraining (one
            // RETRAIN_DONE per trainer per flush) before shutting down
            min_retrain_rounds: flushes * MEMBERS as u64,
            min_train_epochs: 0,
            max_wall: Some(Duration::from_secs(60)),
        },
        ..Default::default()
    }
}

fn deterministic_kernels() -> KernelSet {
    let generators = (0..GENS)
        .map(|i| {
            let seed = 100 + i as u64;
            Box::new(move || Box::new(MbWalker::new(seed)) as Box<dyn Generator>)
                as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = vec![Box::new(|| {
        Box::new(PesOracle::fixed(MullerBrown::default(), 1)) as Box<dyn Oracle>
    }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>];
    let model = Arc::new(move |mode: Mode, member: usize| {
        let mut inner =
            SyntheticModel::new(IN_DIM, OUT_DIM, Duration::ZERO, Duration::ZERO, 8, mode);
        // member-specific deterministic init; replicas of a member match
        let w: Vec<f32> = (0..IN_DIM * OUT_DIM)
            .map(|k| ((k + member * 11) % 7) as f32 * 0.05)
            .collect();
        inner.update(&w);
        Box::new(FixedEpochModel(inner)) as Box<dyn Model>
    });
    let utils = Arc::new(|| {
        Box::new(EnergySelectUtils {
            pes: MullerBrown::default(),
            // far below every reachable energy → select everything, so the
            // selected sequence is exactly the generator round-robin
            threshold: -1e9,
            max_per_batch: GENS,
        }) as Box<dyn Utils>
    });
    KernelSet { generators, oracles, model, utils }
}

fn run_once() -> RunReport {
    Workflow::new(deterministic_setting())
        .run(deterministic_kernels())
        .unwrap()
}

#[test]
fn muller_brown_loop_is_bit_stable_across_runs() {
    let a = run_once();
    let b = run_once();

    // exact label budget, both runs
    assert_eq!(a.oracle_labels, LABELS, "run A labels");
    assert_eq!(b.oracle_labels, LABELS, "run B labels");

    // every flushed batch retrained on every committee member, both runs
    let expected_rounds = (LABELS / RETRAIN_SIZE as u64) * MEMBERS as u64;
    assert_eq!(a.retrain_rounds, expected_rounds, "run A rounds");
    assert_eq!(b.retrain_rounds, expected_rounds, "run B rounds");

    // final losses are bit-identical per trainer
    assert_eq!(a.final_losses.len(), MEMBERS);
    assert_eq!(b.final_losses.len(), MEMBERS);
    for (i, (x, y)) in a.final_losses.iter().zip(&b.final_losses).enumerate() {
        assert!(x.is_finite(), "trainer {i} loss not reported: {x}");
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "trainer {i} loss differs across runs: {x} vs {y}"
        );
    }
}

#[test]
fn strict_budget_never_overshoots() {
    let report = run_once();
    let manager = &report.kernel("manager")[0];
    assert_eq!(manager.counter("dispatched"), LABELS);
    assert_eq!(manager.counter("labels"), LABELS);
    assert_eq!(report.sum_counter("oracle", "labels"), LABELS);
}
