//! Deterministic end-to-end AL loop: full Manager + Exchange workflow on
//! the Müller–Brown potential with fixed RNG seeds, asserting the
//! oracle-label count, the retrain-round count, and the final training
//! losses are bit-stable across runs.
//!
//! The scenario itself (walkers, selection, fixed-epoch committee, run
//! recipe, and *why* it is deterministic by construction) lives in
//! [`pal::sim::scenario`] so the transport-conformance suite can replay
//! the identical run over other backends; this file pins the baseline
//! behavior on the default `channel` transport.

use std::sync::Arc;

use pal::comm::FaultPlan;
use pal::config::OracleMode;
use pal::coordinator::workflow::Workflow;
use pal::data::Dataset;
use pal::kernels::{KernelSet, Mode, Model};
use pal::sim::scenario::{
    dataset_seed_weights, deterministic_kernels, deterministic_setting, run_once, IN_DIM, LABELS,
    MEMBERS, OUT_DIM, RETRAIN_SIZE,
};
use pal::telemetry::RunReport;

#[test]
fn muller_brown_loop_is_bit_stable_across_runs() {
    let a = run_once(OracleMode::PerLabel);
    let b = run_once(OracleMode::PerLabel);

    // exact label budget, both runs
    assert_eq!(a.oracle_labels, LABELS, "run A labels");
    assert_eq!(b.oracle_labels, LABELS, "run B labels");

    // every flushed batch retrained on every committee member, both runs
    let expected_rounds = (LABELS / RETRAIN_SIZE as u64) * MEMBERS as u64;
    assert_eq!(a.retrain_rounds, expected_rounds, "run A rounds");
    assert_eq!(b.retrain_rounds, expected_rounds, "run B rounds");

    // final losses are bit-identical per trainer
    assert_eq!(a.final_losses.len(), MEMBERS);
    assert_eq!(b.final_losses.len(), MEMBERS);
    for (i, (x, y)) in a.final_losses.iter().zip(&b.final_losses).enumerate() {
        assert!(x.is_finite(), "trainer {i} loss not reported: {x}");
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "trainer {i} loss differs across runs: {x} vs {y}"
        );
    }
}

#[test]
fn strict_budget_never_overshoots() {
    let report = run_once(OracleMode::PerLabel);
    let manager = &report.kernel("manager")[0];
    assert_eq!(manager.counter("dispatched"), LABELS);
    assert_eq!(manager.counter("labels"), LABELS);
    assert_eq!(report.sum_counter("oracle", "labels"), LABELS);
}

/// The oracle-plane acceptance pin: labels and the training-set order —
/// and therefore every trainer's final loss, a pure function of the
/// (ordered) labeled dataset — are **bit-identical** between the batched
/// and per-label oracle modes, and the batched mode is itself bit-stable
/// across runs. The single oracle makes batch completion FIFO, so item
/// order through the train buffer matches the per-label dispatch order
/// exactly, whatever the batch boundaries.
#[test]
fn batched_oracle_mode_is_bit_identical_to_per_label() {
    let per_label = run_once(OracleMode::PerLabel);
    let batched = run_once(OracleMode::Batched);
    let batched2 = run_once(OracleMode::Batched);

    // exact label budget in both modes (item-level `dispatched` semantics)
    assert_eq!(per_label.oracle_labels, LABELS);
    assert_eq!(batched.oracle_labels, LABELS, "batched mode labels");
    let manager = &batched.kernel("manager")[0];
    assert_eq!(manager.counter("dispatched"), LABELS);
    assert_eq!(report_batches(&batched), (LABELS / RETRAIN_SIZE as u64, LABELS));
    assert_eq!(per_label.retrain_rounds, batched.retrain_rounds);

    // final losses bit-identical: per-label vs batched, and run to run
    for (i, (x, y)) in per_label.final_losses.iter().zip(&batched.final_losses).enumerate() {
        assert!(x.is_finite(), "trainer {i} loss not reported: {x}");
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "trainer {i} loss differs between oracle modes: {x} vs {y}"
        );
    }
    for (x, y) in batched.final_losses.iter().zip(&batched2.final_losses) {
        assert_eq!(x.to_bits(), y.to_bits(), "batched mode not bit-stable across runs");
    }
}

/// Committee member backed by the flat [`Dataset`]: labeled pairs go
/// through `Dataset::add` (val split + rolling window), and every retrain
/// round draws fixed-size minibatches via the strided-gather `minibatch`
/// and takes one SGD step per draw on a linear map. The final loss is a
/// pure function of the ordered labeled stream and the dataset's RNG
/// stream, so it pins the flat Dataset's draw order and window semantics
/// end to end.
struct DatasetModel {
    data: Dataset,
    w: Vec<f32>,
    loss: Option<f32>,
    epochs: u64,
}

const DS_WINDOW: usize = 8;
const DS_EPOCHS: usize = 4;
const DS_MB: usize = 2;

impl DatasetModel {
    fn new(member: usize) -> Self {
        DatasetModel {
            data: Dataset::new(0.25, 1000 + member as u64).with_rolling_window(DS_WINDOW),
            w: dataset_seed_weights(member),
            loss: None,
            epochs: 0,
        }
    }

    fn forward(&self, x: &[f32], out: &mut [f32]) {
        for (j, o) in out.iter_mut().enumerate() {
            *o = (0..IN_DIM).map(|i| x[i] * self.w[i * OUT_DIM + j]).sum();
        }
    }
}

impl Model for DatasetModel {
    fn predict(&mut self, list: &[Vec<f32>]) -> Vec<Vec<f32>> {
        list.iter()
            .map(|x| {
                let mut out = vec![0.0; OUT_DIM];
                self.forward(x, &mut out);
                out
            })
            .collect()
    }
    fn update(&mut self, w: &[f32]) {
        self.w = w.to_vec();
    }
    fn get_weight(&self) -> Vec<f32> {
        self.w.clone()
    }
    fn get_weight_size(&self) -> usize {
        IN_DIM * OUT_DIM
    }
    fn add_trainingset(&mut self, points: &[(Vec<f32>, Vec<f32>)]) {
        self.data.add(points);
    }
    fn retrain(&mut self, _interrupt: &mut dyn FnMut() -> bool) -> bool {
        if self.data.is_empty() {
            return false;
        }
        let mut loss_acc = 0.0f32;
        for _ in 0..DS_EPOCHS {
            let mut grad = [0.0f32; IN_DIM * OUT_DIM];
            {
                let (xs, ys) = self.data.minibatch(DS_MB);
                for r in 0..DS_MB {
                    let x = &xs[r * IN_DIM..(r + 1) * IN_DIM];
                    let y = &ys[r * OUT_DIM..(r + 1) * OUT_DIM];
                    for j in 0..OUT_DIM {
                        let p: f32 = (0..IN_DIM).map(|i| x[i] * self.w[i * OUT_DIM + j]).sum();
                        let e = p - y[j];
                        loss_acc += e * e;
                        for i in 0..IN_DIM {
                            grad[i * OUT_DIM + j] += e * x[i];
                        }
                    }
                }
            }
            for (wk, gk) in self.w.iter_mut().zip(grad.iter()) {
                *wk -= 1e-4 * gk;
            }
        }
        self.loss = Some(loss_acc / (DS_EPOCHS * DS_MB) as f32);
        self.epochs += DS_EPOCHS as u64;
        false
    }
    fn last_loss(&self) -> Option<f32> {
        self.loss
    }
    fn last_round_epochs(&self) -> u64 {
        DS_EPOCHS as u64
    }
}

fn dataset_kernels() -> KernelSet {
    let KernelSet { generators, oracles, utils, .. } = deterministic_kernels();
    let model = Arc::new(move |_mode: Mode, member: usize| {
        Box::new(DatasetModel::new(member)) as Box<dyn Model>
    });
    KernelSet { generators, oracles, model, utils }
}

fn run_dataset(oracle_mode: OracleMode) -> RunReport {
    Workflow::new(deterministic_setting(oracle_mode))
        .run(dataset_kernels())
        .unwrap()
}

/// The memory-plane determinism pin: routing every labeled pair through
/// the flat `Dataset` (val split, index-based rolling window, strided
/// `minibatch` gather) keeps labels and final losses **bit-identical**
/// between the per-label and batched oracle modes, and bit-stable across
/// runs. Any drift in the Dataset's RNG draw order, window eviction, or
/// gather layout shows up here as a loss mismatch.
#[test]
fn flat_dataset_model_is_bit_identical_across_oracle_modes() {
    let per_label = run_dataset(OracleMode::PerLabel);
    let batched = run_dataset(OracleMode::Batched);
    let batched2 = run_dataset(OracleMode::Batched);

    assert_eq!(per_label.oracle_labels, LABELS);
    assert_eq!(batched.oracle_labels, LABELS, "batched mode labels");
    assert_eq!(per_label.retrain_rounds, batched.retrain_rounds);

    assert_eq!(per_label.final_losses.len(), MEMBERS);
    for (i, (x, y)) in per_label.final_losses.iter().zip(&batched.final_losses).enumerate() {
        assert!(x.is_finite(), "trainer {i} loss not reported: {x}");
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "trainer {i} Dataset-backed loss differs between oracle modes: {x} vs {y}"
        );
    }
    for (x, y) in batched.final_losses.iter().zip(&batched2.final_losses) {
        assert_eq!(x.to_bits(), y.to_bits(), "Dataset-backed run not bit-stable");
    }
}

/// The fault plane's zero-cost pin: installing an *empty* `FaultPlan`
/// compiles to no per-rank fault state at all, so the run is bit-identical
/// to a plain one — same labels, same rounds, same final losses to the
/// bit — and its fault report is clean.
#[test]
fn empty_fault_plan_is_bit_identical_to_plain_run() {
    let plain = run_once(OracleMode::PerLabel);
    let planned = Workflow::new(deterministic_setting(OracleMode::PerLabel))
        .with_faults(FaultPlan::default())
        .run(deterministic_kernels())
        .unwrap();

    assert!(planned.faults.is_clean(), "{:?}", planned.faults);
    assert_eq!(plain.oracle_labels, planned.oracle_labels);
    assert_eq!(plain.retrain_rounds, planned.retrain_rounds);
    for (i, (x, y)) in plain.final_losses.iter().zip(&planned.final_losses).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "trainer {i} loss differs under an empty fault plan: {x} vs {y}"
        );
    }
}

/// `(oracle batch frames, labels they carried)` from the oracle telemetry.
fn report_batches(report: &RunReport) -> (u64, u64) {
    (
        report.sum_counter("oracle", "batches"),
        report.sum_counter("oracle", "labels"),
    )
}
