//! Allocation-count regression for the oracle plane (the green-flow
//! sibling of `test_flat_plane.rs` / `test_flat_train.rs`).
//!
//! Pins this PR's acceptance criteria for batched label ingest, on the
//! exact path the Manager takes (`decode_oracle_batch_result_views` →
//! one `TrainBuffer::push_pair` per pair):
//!
//! * after one warm flush cycle (the steady state — `TrainBuffer::flush`
//!   pre-sizes the replacement staging block), ingesting a whole
//!   `OracleBatchResult` frame performs a **constant** number of
//!   allocations, independent of the batch size — zero per-label boxing
//!   between the oracle and the training buffer;
//! * the flat path allocates ≥ 8× less than the nested per-label baseline
//!   it replaces (one owned `unpack` + `(Vec, Vec)` pair per label).
//!
//! This file installs a counting global allocator and therefore contains
//! exactly ONE `#[test]`: the default test harness runs tests of a binary
//! concurrently, and any sibling test's allocations would pollute the
//! counters.

use pal::bench_util::alloc::{alloc_count, CountingAlloc};
use pal::comm::protocol::{decode_oracle_batch_result_views, encode_oracle_batch_result_into};
use pal::coordinator::buffers::TrainBuffer;
use pal::data::batch::RowBlock;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

const IN_DIM: usize = 8;
const OUT_DIM: usize = 4;

/// A `TAG_ORACLE_BATCH_RESULT` frame carrying `points` labeled samples.
fn result_frame(points: usize) -> Vec<f32> {
    let xs: Vec<Vec<f32>> = (0..points)
        .map(|i| (0..IN_DIM).map(|k| ((i * 7 + k) % 13) as f32 * 0.1).collect())
        .collect();
    let ys: Vec<Vec<f32>> = (0..points)
        .map(|i| (0..OUT_DIM).map(|k| ((i * 3 + k) % 5) as f32 * 0.2).collect())
        .collect();
    let inputs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
    let labels = RowBlock::from_rows(&ys);
    let mut frame = Vec::new();
    encode_oracle_batch_result_into(3, &inputs, &labels, &mut frame);
    frame
}

/// A `TrainBuffer` in flush steady state: one full fill-and-flush cycle of
/// `points` samples has run, so the staging block holds pre-sized backing
/// buffers for the next cycle.
fn warmed_buffer(points: usize) -> TrainBuffer {
    let mut buf = TrainBuffer::new(points);
    let frame = result_frame(points);
    let (_id, view) = decode_oracle_batch_result_views(&frame).unwrap();
    for (x, y) in view.iter() {
        buf.push_pair(x, y);
    }
    buf.flush().expect("warm cycle flushes");
    buf
}

/// Allocations for one batch-label ingest exactly as the Manager performs
/// it: borrowed-view decode of the result frame + one `push_pair` per pair
/// into the train buffer.
fn flat_ingest_allocs(frame: &[f32], buffer: &mut TrainBuffer) -> u64 {
    let before = alloc_count();
    let (_id, view) = decode_oracle_batch_result_views(frame).unwrap();
    for (x, y) in view.iter() {
        buffer.push_pair(x, y);
    }
    let delta = alloc_count() - before;
    std::hint::black_box(&view);
    delta
}

/// Allocations for the nested per-label baseline this plane replaces: one
/// owned decode + one boxed `(Vec, Vec)` pair per label.
fn nested_ingest_allocs(frame: &[f32], staging: &mut Vec<(Vec<f32>, Vec<f32>)>) -> u64 {
    use pal::comm::codec::unpack_datapoints;
    let before = alloc_count();
    // per-label wire: the packed section decodes pair by pair into owned Vecs
    let points = unpack_datapoints(&frame[2..]).unwrap();
    staging.extend(points);
    let delta = alloc_count() - before;
    std::hint::black_box(&staging);
    delta
}

#[test]
fn oracle_batch_label_ingest_allocates_constant() {
    let small = result_frame(8);
    let large = result_frame(64);

    // warm-up: lazy one-time allocations out of the way
    let _ = flat_ingest_allocs(&small, &mut warmed_buffer(64));

    // --- decode → push_pair: constant allocations, independent of batch
    // size (both buffers are in the steady state of a 64-sample flush
    // cycle, exactly like the Manager between retrain flushes) ---
    let mut buf_small = warmed_buffer(64);
    let flat_small = flat_ingest_allocs(&small, &mut buf_small);
    let mut buf_large = warmed_buffer(64);
    let flat_large = flat_ingest_allocs(&large, &mut buf_large);
    assert_eq!(buf_small.len(), 8);
    assert_eq!(buf_large.len(), 64);
    assert!(flat_small <= 4, "flat batch-label ingest allocated {flat_small} times (want <= 4)");
    assert_eq!(
        flat_small, flat_large,
        "flat batch-label ingest must not allocate per label (8 rows: {flat_small}, \
         64 rows: {flat_large})"
    );

    // --- ≥ 8× fewer allocations than the per-label nested baseline ---
    let mut nested_stage = Vec::with_capacity(64);
    let nested_large = nested_ingest_allocs(&large, &mut nested_stage);
    assert_eq!(nested_stage.len(), 64);
    assert!(
        nested_large >= 8 * flat_large.max(1),
        "flat path saves too little: nested {nested_large} vs flat {flat_large} allocs at batch 64"
    );

    // staged values are identical either way
    let staged = buf_large.flush().expect("threshold met");
    for i in 0..64 {
        let (x, y) = staged.pair(i);
        assert_eq!((x, y), (nested_stage[i].0.as_slice(), nested_stage[i].1.as_slice()));
    }
}
