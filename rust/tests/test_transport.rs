//! Cross-backend transport conformance suite.
//!
//! The bus contract — per-(src, tag) FIFO, cross-source arrival order,
//! zero-copy payload fan-out, gather deferral, dead-letter accounting —
//! is defined by the protocol layer, not by any one delivery backend, so
//! every test here runs over *both* in-process backends
//! ([`TransportKind::Channel`] and [`TransportKind::Shm`]) through the
//! identical `World`/`Endpoint` API. The strongest pin replays the full
//! deterministic Müller–Brown workflow ([`pal::sim::scenario`]) on each
//! backend and asserts labels, retrain rounds, and final losses are
//! **bit-identical**.
//!
//! The tcp backend is covered two ways: an in-process loopback world
//! (two `World`s in one process bridged by a real socket) and a
//! two-OS-process end-to-end run — the parent re-execs this test binary
//! with `PAL_TCP_FOLLOWER_ADDR` set, which turns the no-op
//! [`tcp_follower_child`] test into the oracle-hosting follower process.

use std::time::Duration;

use pal::comm::bus::{Payload, Src, World};
use pal::comm::transport::tcp::Bootstrap;
use pal::comm::{RecvError, TransportKind};
use pal::config::OracleMode;
use pal::coordinator::workflow::Workflow;
use pal::sim::scenario::{
    deterministic_kernels_without_oracles, deterministic_oracles, deterministic_setting,
    run_with_transport, LABELS, MEMBERS, RETRAIN_SIZE,
};

const IN_PROCESS: [TransportKind; 2] = [TransportKind::Channel, TransportKind::Shm];

fn world(kind: TransportKind, n: usize) -> World {
    World::with_backend(n, Duration::ZERO, kind)
}

// ---------------------------------------------------------------------------
// bus contract over every in-process backend

#[test]
fn roundtrip_and_fifo_per_src_tag() {
    for kind in IN_PROCESS {
        let mut w = world(kind, 2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        for i in 0..16 {
            assert!(a.send(1, 3, vec![i as f32]), "{kind}: send {i}");
        }
        for i in 0..16 {
            let m = b.recv_timeout(Src::Rank(0), 3, Duration::from_secs(5)).unwrap();
            assert_eq!(m.src, 0, "{kind}");
            assert_eq!(m.data, vec![i as f32], "{kind}: FIFO broken at {i}");
        }
    }
}

#[test]
fn multi_tag_recv_takes_first_available() {
    for kind in IN_PROCESS {
        let mut w = world(kind, 2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 5, vec![5.0]);
        a.send(1, 3, vec![3.0]);
        let m = b.recv_timeout_tags(Src::Rank(0), &[3, 5], Duration::from_secs(5)).unwrap();
        assert_eq!(m.tag, 5, "{kind}: arrival order across the tag set");
        let m = b.recv_timeout_tags(Src::Rank(0), &[3, 5], Duration::from_secs(5)).unwrap();
        assert_eq!(m.tag, 3, "{kind}");
        a.send(1, 9, vec![]);
        let r = b.recv_timeout_tags(Src::Rank(0), &[3, 5], Duration::from_millis(20));
        assert_eq!(r.unwrap_err(), RecvError::Timeout, "{kind}: unlisted tag matched");
    }
}

#[test]
fn recv_ready_all_preserves_cross_source_arrival_order() {
    for kind in IN_PROCESS {
        let mut w = world(kind, 3);
        let mut eps = w.endpoints();
        let e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // small gaps keep the send stamps strictly ordered, so the shm
        // backend's earliest-head selection has no exact ties to break
        e1.send(0, 9, vec![1.0]);
        std::thread::sleep(Duration::from_millis(2));
        e2.send(0, 9, vec![2.0]);
        std::thread::sleep(Duration::from_millis(2));
        e1.send(0, 9, vec![3.0]);
        std::thread::sleep(Duration::from_millis(5));
        let batch = e0.recv_ready_all(Src::Any, 9);
        let got: Vec<Vec<f32>> = batch.iter().map(|m| m.data.as_slice().to_vec()).collect();
        assert_eq!(got, vec![vec![1.0], vec![2.0], vec![3.0]], "{kind}");
        assert!(e0.recv_ready_all(Src::Any, 9).is_empty(), "{kind}: double drain");
    }
}

#[test]
fn bcast_is_zero_copy_at_8_ranks() {
    for kind in IN_PROCESS {
        let mut w = world(kind, 8);
        let stats = w.stats();
        let mut eps = w.endpoints();
        let root = eps.remove(0);
        let payload = Payload::from(vec![0.5f32; 1024]);
        let dsts: Vec<usize> = (1..8).collect();
        assert_eq!(root.bcast(&dsts, 11, &payload), 7, "{kind}: delivery shortfall");
        let mut received = Vec::new();
        for (i, e) in eps.iter_mut().enumerate() {
            let m = e.recv_timeout(Src::Rank(0), 11, Duration::from_secs(5)).unwrap();
            assert_eq!(m.data.as_slice().len(), 1024, "{kind}: rank {}", i + 1);
            assert_eq!(
                m.data.ident(),
                payload.ident(),
                "{kind}: rank {} got a different buffer — fan-out copied",
                i + 1
            );
            received.push(m);
        }
        // original + 7 received views of the same allocation, all still held
        assert_eq!(payload.shared_handles(), 8, "{kind}");
        drop(received);
        // logical traffic scales with fan-out; physical copies stay at zero
        assert_eq!(stats.messages(), 7, "{kind}");
        assert_eq!(stats.payload_clones(), 0, "{kind}: bcast materialized a buffer");
        assert_eq!(stats.bytes_copied(), 0, "{kind}: bcast copied payload bytes");
    }
}

#[test]
fn gather_defers_duplicates_without_reordering() {
    for kind in IN_PROCESS {
        let mut w = world(kind, 3);
        let mut eps = w.endpoints();
        let e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // rank 1 races two rounds ahead before rank 2 sends round 1
        e1.send(0, 9, vec![1.0]);
        e1.send(0, 9, vec![10.0]);
        e1.send(0, 9, vec![100.0]);
        e2.send(0, 9, vec![2.0]);
        let r1 = e0.gather(&[1, 2], 9, Duration::from_secs(5)).unwrap();
        assert_eq!(r1, vec![vec![1.0], vec![2.0]], "{kind}");
        e2.send(0, 9, vec![20.0]);
        let r2 = e0.gather(&[1, 2], 9, Duration::from_secs(5)).unwrap();
        assert_eq!(r2, vec![vec![10.0], vec![20.0]], "{kind}: deferred frame reordered");
        e2.send(0, 9, vec![200.0]);
        let r3 = e0.gather(&[1, 2], 9, Duration::from_secs(5)).unwrap();
        assert_eq!(r3, vec![vec![100.0], vec![200.0]], "{kind}");
    }
}

#[test]
fn self_send_is_accepted_and_dropped() {
    for kind in IN_PROCESS {
        let mut w = world(kind, 2);
        let mut a = w.endpoint(0);
        assert!(a.send(0, 4, vec![1.0]), "{kind}: self-send refused");
        assert!(a.try_recv(Src::Rank(0), 4).is_none(), "{kind}: self-send delivered");
    }
}

#[test]
fn send_to_dropped_endpoint_is_a_dead_letter() {
    for kind in IN_PROCESS {
        let mut w = world(kind, 3);
        let stats = w.stats();
        let e0 = w.endpoint(0);
        let e1 = w.endpoint(1);
        let ctrl = w.control_handle(0);
        drop(e1);
        assert!(!e0.send(1, 7, vec![1.0]), "{kind}: send to dead rank accepted");
        assert_eq!(stats.dead_letters(), 1, "{kind}");
        // the control plane counts its losses the same way
        assert!(!ctrl.send(1, 7, vec![2.0]), "{kind}");
        assert_eq!(stats.dead_letters(), 2, "{kind}");
        // an untaken rank of a live world still queues
        assert!(e0.send(2, 7, vec![3.0]), "{kind}: send to untaken rank refused");
        assert_eq!(stats.dead_letters(), 2, "{kind}");
    }
}

#[test]
fn receiver_disconnects_when_world_and_peers_are_gone() {
    for kind in IN_PROCESS {
        let mut w = world(kind, 2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 1, vec![1.0]);
        drop(a);
        drop(w);
        // queued traffic still drains before the disconnect is reported
        let m = b.recv_timeout(Src::Any, 1, Duration::from_secs(5)).unwrap();
        assert_eq!(m.data, vec![1.0], "{kind}");
        let r = b.recv_timeout(Src::Any, 1, Duration::from_secs(5));
        assert_eq!(r.unwrap_err(), RecvError::Disconnected, "{kind}");
    }
}

// ---------------------------------------------------------------------------
// the acceptance pin: whole-workflow bit-identity across backends

/// The deterministic Müller–Brown scenario run over `channel` and `shm`
/// must agree to the bit: same labels, same retrain rounds, same final
/// losses. The scenario depends only on per-(src, tag) FIFO order — never
/// on timing — so any divergence is a transport-contract violation.
#[test]
fn al_run_is_bit_identical_across_in_process_backends() {
    let channel = run_with_transport(OracleMode::PerLabel, TransportKind::Channel);
    let shm = run_with_transport(OracleMode::PerLabel, TransportKind::Shm);

    assert_eq!(channel.oracle_labels, LABELS, "channel labels");
    assert_eq!(shm.oracle_labels, LABELS, "shm labels");
    let expected_rounds = (LABELS / RETRAIN_SIZE as u64) * MEMBERS as u64;
    assert_eq!(channel.retrain_rounds, expected_rounds);
    assert_eq!(shm.retrain_rounds, expected_rounds);

    assert_eq!(channel.final_losses.len(), MEMBERS);
    assert_eq!(shm.final_losses.len(), MEMBERS);
    for (i, (c, s)) in channel.final_losses.iter().zip(&shm.final_losses).enumerate() {
        assert!(c.is_finite(), "trainer {i} loss not reported: {c}");
        assert_eq!(
            c.to_bits(),
            s.to_bits(),
            "trainer {i} loss differs between channel and shm: {c} vs {s}"
        );
    }
}

// ---------------------------------------------------------------------------
// tcp: loopback world and two-process e2e

#[test]
fn tcp_loopback_roundtrip_and_shutdown() {
    let boot = Bootstrap::bind("127.0.0.1:0").unwrap();
    let addr = boot.local_addr().unwrap().to_string();
    let follower = std::thread::spawn(move || {
        let (mut w, _monitor) =
            World::connect(&addr, 2, &[1], Duration::ZERO, Duration::from_secs(10)).unwrap();
        let mut e1 = w.endpoint(1);
        // echo: re-sending the received payload is a refcount bump locally;
        // the socket writer serializes it at the process boundary
        let m = e1.recv_timeout(Src::Rank(0), 7, Duration::from_secs(10)).unwrap();
        e1.send(0, 8, m.data);
    });
    let (mut w, monitor) = World::listen(boot, 2, &[0], Duration::ZERO).unwrap();
    let stats = w.stats();
    let mut e0 = w.endpoint(0);
    drop(w);
    assert!(e0.send(1, 7, vec![1.0, 2.0, 3.0]));
    let m = e0.recv_timeout(Src::Rank(1), 8, Duration::from_secs(10)).unwrap();
    assert_eq!(m.src, 1);
    assert_eq!(m.data, vec![1.0, 2.0, 3.0]);
    // serialization at the process boundary is the one physical copy
    assert!(stats.bytes_copied() >= 12, "socket send not charged as a copy");
    follower.join().unwrap();
    // the follower dropped its world → FIN → our reader exits → monitor
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while !monitor.all_peers_closed() {
        assert!(std::time::Instant::now() < deadline, "peer hangup never observed");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Follower half of the two-process e2e. Runs as a no-op in a normal
/// suite; when [`tcp_e2e_reaches_strict_label_budget_across_processes`]
/// re-execs this binary with `PAL_TCP_FOLLOWER_ADDR` set, it hosts the
/// scenario's oracle ranks until the leader hangs up.
#[test]
fn tcp_follower_child() {
    let Ok(addr) = std::env::var("PAL_TCP_FOLLOWER_ADDR") else {
        return;
    };
    let setting = deterministic_setting(OracleMode::PerLabel);
    Workflow::run_tcp_follower(&setting, deterministic_oracles(), &addr, Duration::from_secs(30))
        .expect("tcp follower run");
}

/// The tcp acceptance pin: the deterministic scenario, split across two
/// real OS processes (coordinators + generators + committee here, the
/// oracle in a re-exec'd child), reaches the strict label budget and
/// reproduces the in-process run bit for bit.
#[test]
fn tcp_e2e_reaches_strict_label_budget_across_processes() {
    let boot = Bootstrap::bind("127.0.0.1:0").unwrap();
    let addr = boot.local_addr().unwrap().to_string();
    let exe = std::env::current_exe().expect("test binary path");
    let mut child = std::process::Command::new(exe)
        .args(["tcp_follower_child", "--exact", "--nocapture"])
        .env("PAL_TCP_FOLLOWER_ADDR", &addr)
        .spawn()
        .expect("spawn follower process");

    let mut setting = deterministic_setting(OracleMode::PerLabel);
    setting.transport = TransportKind::Tcp;
    let report = Workflow::new(setting)
        .run_tcp_leader(deterministic_kernels_without_oracles(), boot)
        .expect("tcp leader run");

    let status = child.wait().expect("join follower process");
    assert!(status.success(), "follower process failed: {status}");

    // strict label budget across a real process boundary
    assert_eq!(report.oracle_labels, LABELS, "tcp labels");
    let expected_rounds = (LABELS / RETRAIN_SIZE as u64) * MEMBERS as u64;
    assert_eq!(report.retrain_rounds, expected_rounds, "tcp rounds");

    // and the run is the *same* run: the scenario is timing-independent,
    // so even the socket transport reproduces the losses bit for bit
    let in_process = run_with_transport(OracleMode::PerLabel, TransportKind::Channel);
    for (i, (t, c)) in report.final_losses.iter().zip(&in_process.final_losses).enumerate() {
        assert!(t.is_finite(), "trainer {i} loss not reported: {t}");
        assert_eq!(
            t.to_bits(),
            c.to_bits(),
            "trainer {i} loss differs between tcp and channel: {t} vs {c}"
        );
    }
}
