//! Allocation-count regression for the flat *training* plane (the sibling
//! of `test_flat_plane.rs`, which pins the prediction side).
//!
//! Pins this PR's acceptance criteria for the training side:
//!
//! * label decode → `add_trainingset_batch` stages rows contiguously with
//!   a **constant** number of allocations, independent of the batch size;
//! * the trainer → replica weight sync is refcount-only: exporting the
//!   weight payload costs one shared-storage materialization, and every
//!   per-replica clone + adoption (`update_from`) allocates **nothing**;
//! * the flat path allocates ≥ 8× less than the nested
//!   `unpack_datapoints` → `add_trainingset` baseline it replaces.
//!
//! This file installs a counting global allocator and therefore contains
//! exactly ONE `#[test]`: the default test harness runs tests of a binary
//! concurrently, and any sibling test's allocations would pollute the
//! counters.

use pal::bench_util::alloc::{alloc_count, CountingAlloc};
use pal::comm::codec::{decode_train_block_views, pack_datapoints, unpack_datapoints};
use pal::kernels::{Mode, Model};
use pal::sim::workload::SyntheticModel;
use std::time::Duration;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

const IN_DIM: usize = 8;
const OUT_DIM: usize = 4;

fn model(mode: Mode) -> SyntheticModel {
    SyntheticModel::new(IN_DIM, OUT_DIM, Duration::ZERO, Duration::ZERO, 1, mode)
}

/// A `TAG_TRAIN_DATA` payload carrying `points` labeled samples.
fn train_payload(points: usize) -> Vec<f32> {
    let pts: Vec<(Vec<f32>, Vec<f32>)> = (0..points)
        .map(|i| {
            let x: Vec<f32> = (0..IN_DIM).map(|k| ((i * 7 + k) % 13) as f32 * 0.1).collect();
            let y: Vec<f32> = (0..OUT_DIM).map(|k| ((i * 3 + k) % 5) as f32 * 0.2).collect();
            (x, y)
        })
        .collect();
    pack_datapoints(&pts)
}

/// Allocations for one flat label ingest: borrowed-view decode of the wire
/// payload + contiguous staging into the model's training set.
fn flat_ingest_allocs(payload: &[f32], model: &mut SyntheticModel) -> u64 {
    let before = alloc_count();
    let view = decode_train_block_views(payload).unwrap();
    model.add_trainingset_batch(&view);
    let delta = alloc_count() - before;
    std::hint::black_box(&view);
    delta
}

/// Allocations for the nested baseline this PR replaces: owned pair decode
/// + nested `add_trainingset`.
fn nested_ingest_allocs(payload: &[f32], model: &mut SyntheticModel) -> u64 {
    let before = alloc_count();
    let points = unpack_datapoints(payload).unwrap();
    model.add_trainingset(&points);
    let delta = alloc_count() - before;
    std::hint::black_box(&points);
    delta
}

#[test]
fn flat_train_plane_allocates_constant_and_weights_sync_allocation_free() {
    let small = train_payload(8);
    let large = train_payload(64);

    // warm-up: lazy one-time allocations out of the way
    let _ = flat_ingest_allocs(&small, &mut model(Mode::Train));
    let _ = nested_ingest_allocs(&small, &mut model(Mode::Train));

    // --- label decode → add_trainingset_batch: constant allocations ---
    // fresh model per measurement so internal reservations don't carry over
    let flat_small = flat_ingest_allocs(&small, &mut model(Mode::Train));
    let flat_large = flat_ingest_allocs(&large, &mut model(Mode::Train));
    assert!(flat_small <= 8, "flat label ingest allocated {flat_small} times (want <= 8)");
    assert_eq!(
        flat_small, flat_large,
        "flat label ingest must not allocate per row (8 rows: {flat_small}, 64 rows: {flat_large})"
    );

    // --- ≥ 8× fewer allocations than the nested baseline at batch 64 ---
    let nested_large = nested_ingest_allocs(&large, &mut model(Mode::Train));
    assert!(
        nested_large >= 8 * flat_large.max(1),
        "flat path saves too little: nested {nested_large} vs flat {flat_large} allocs at batch 64"
    );

    // --- weight payload round-trip: one export materialization, then the
    //     whole 8-replica fan-out + adoption allocates nothing ---
    let mut trainer = model(Mode::Train);
    let w: Vec<f32> = (0..IN_DIM * OUT_DIM).map(|i| i as f32 * 0.01).collect();
    trainer.update(&w);
    let mut replicas: Vec<SyntheticModel> = (0..8).map(|_| model(Mode::Predict)).collect();

    let before = alloc_count();
    let payload = trainer.get_weight_payload();
    let export_allocs = alloc_count() - before;
    assert!(
        export_allocs <= 2,
        "weight export allocated {export_allocs} times (want <= 2: one shared buffer)"
    );

    let before = alloc_count();
    for r in replicas.iter_mut() {
        let per_replica = payload.clone(); // what the transport does per destination
        r.update_from(&per_replica);
    }
    let fanout_allocs = alloc_count() - before;
    assert_eq!(
        fanout_allocs, 0,
        "per-replica weight sync must be refcount-only (allocated {fanout_allocs} times)"
    );
    for r in &replicas {
        assert_eq!(r.get_weight(), w, "adopted weights must be bit-identical");
    }
}
