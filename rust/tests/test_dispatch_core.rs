//! Equivalence + eviction pins for the shared dispatch core.
//!
//! Two layers of proof for this PR's refactor:
//!
//! 1. **Static-policy equivalence** — the extracted
//!    [`pal::coordinator::dispatch::DispatchCore`] behind the default
//!    static policies must be *bit-identical* to the pre-extraction
//!    schedulers. The reference implementations below are verbatim ports
//!    of the PR-5 `OracleScheduler` / `BatchScheduler` (captured from git
//!    history before the extraction), driven side-by-side with the real
//!    schedulers through seeded random op sequences: enqueues, simulated
//!    clock advances (no sleeps), dispatch attempts under random label
//!    budgets, out-of-order completions, and rescore queue resyncs. Every
//!    dispatch decision `(id, endpoint, take)`, origin-sorted batch
//!    composition, trigger timing, backpressure refusal, and in-flight
//!    count must match at every step, across a grid of batch settings and
//!    pool sizes.
//!
//!    One intentional divergence: the round-robin reference applies this
//!    PR's cursor bugfix (advance past the shard *actually chosen*, not
//!    the saturated preferred one). The buggy pre-fix sequence is pinned
//!    negatively in `exchange.rs::rr_cursor_advances_past_chosen_shard_not_preferred`.
//!
//! 2. **Eviction end-to-end** — a full Workflow run under the adaptive
//!    policy where one oracle stops replying mid-run (simulated by a
//!    per-item latency far past `sched_timeout_ms`). The health plane must
//!    evict it, requeue its in-flight inputs, and relabel them elsewhere —
//!    with a strict label budget the run can only reach `max_labels` if
//!    the requeue released the stalled batch's budget headroom, so the
//!    stop criterion itself proves zero lost labels.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use pal::config::{
    AlSetting, BatchSetting, ExchangeMode, OracleMode, SchedPolicy, SchedSetting, StopCriteria,
};
use pal::coordinator::exchange::BatchScheduler;
use pal::coordinator::oracle_plane::OracleScheduler;
use pal::coordinator::workflow::Workflow;
use pal::kernels::oracles::{LatencyOracle, PesOracle};
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::potential::{MullerBrown, Pes};
use pal::rng::Rng;
use pal::sim::workload::SyntheticModel;

// ---------------------------------------------------------------------------
// Reference: the PR-5 OracleScheduler, verbatim (pre-extraction)
// ---------------------------------------------------------------------------

struct RefOracleScheduler {
    max_size: usize,
    max_delay: Duration,
    max_outstanding: usize,
    outstanding: Vec<usize>,
    inflight: HashMap<u64, (usize, usize)>, // id -> (oracle, items)
    queued_since: Option<Instant>,
    next_id: u64,
}

impl RefOracleScheduler {
    fn new(batch: &BatchSetting, n_oracles: usize) -> Self {
        RefOracleScheduler {
            max_size: batch.max_size.max(1),
            max_delay: batch.max_delay,
            max_outstanding: batch.max_outstanding.max(1),
            outstanding: vec![0; n_oracles.max(1)],
            inflight: HashMap::new(),
            queued_since: None,
            next_id: 0,
        }
    }

    fn note_enqueued(&mut self, now: Instant) {
        if self.queued_since.is_none() {
            self.queued_since = Some(now);
        }
    }

    fn sync_queue(&mut self, queue_len: usize, now: Instant) {
        if queue_len == 0 {
            self.queued_since = None;
        } else if self.queued_since.is_none() {
            self.queued_since = Some(now);
        }
    }

    fn in_flight(&self) -> usize {
        self.outstanding.iter().sum()
    }

    fn in_flight_items(&self) -> usize {
        self.inflight.values().map(|&(_, items)| items).sum()
    }

    fn triggered(&self, queue_len: usize, now: Instant) -> bool {
        if queue_len == 0 {
            return false;
        }
        if queue_len >= self.max_size {
            return true;
        }
        self.queued_since
            .map(|t| now.duration_since(t) >= self.max_delay)
            .unwrap_or(false)
    }

    /// Old routing: global least-outstanding, then the capacity check.
    fn pick_oracle(&self) -> Option<usize> {
        let (best, &count) = self
            .outstanding
            .iter()
            .enumerate()
            .min_by_key(|&(_, &c)| c)
            .expect("at least one oracle");
        (count < self.max_outstanding).then_some(best)
    }

    fn try_dispatch(
        &mut self,
        queue_len: usize,
        now: Instant,
        budget: Option<u64>,
    ) -> Option<(u64, usize, usize)> {
        if budget == Some(0) {
            return None;
        }
        if !self.triggered(queue_len, now) {
            return None;
        }
        let oracle = self.pick_oracle()?;
        let mut take = queue_len.min(self.max_size);
        if let Some(b) = budget {
            take = take.min(b as usize);
        }
        let id = self.next_id;
        self.next_id += 1;
        self.outstanding[oracle] += 1;
        self.inflight.insert(id, (oracle, take));
        self.queued_since = if queue_len > take { Some(now) } else { None };
        Some((id, oracle, take))
    }

    fn complete(&mut self, id: u64) -> Option<(usize, usize)> {
        let (oracle, items) = self.inflight.remove(&id)?;
        self.outstanding[oracle] = self.outstanding[oracle].saturating_sub(1);
        Some((oracle, items))
    }
}

// ---------------------------------------------------------------------------
// Reference: the PR-5 BatchScheduler, verbatim except the cursor bugfix
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct RefDispatch {
    id: u64,
    shard: usize,
    origins: Vec<usize>,
    items: Vec<Vec<f32>>,
}

struct RefBatchScheduler {
    queue: VecDeque<(usize, Instant, Vec<f32>)>, // (origin, enqueued, row)
    max_size: usize,
    max_delay: Duration,
    max_outstanding: usize,
    outstanding: Vec<usize>,
    inflight: HashMap<u64, (usize, usize)>, // id -> (shard, items)
    rr_cursor: usize,
    next_id: u64,
}

impl RefBatchScheduler {
    fn new(batch: &BatchSetting, n_shards: usize) -> Self {
        RefBatchScheduler {
            queue: VecDeque::new(),
            max_size: batch.max_size.max(1),
            max_delay: batch.max_delay,
            max_outstanding: batch.max_outstanding.max(1),
            outstanding: vec![0; n_shards.max(1)],
            inflight: HashMap::new(),
            rr_cursor: 0,
            next_id: 0,
        }
    }

    fn push(&mut self, origin: usize, data: &[f32], now: Instant) {
        self.queue.push_back((origin, now, data.to_vec()));
    }

    fn queue_len(&self) -> usize {
        self.queue.len()
    }

    fn in_flight(&self) -> usize {
        self.outstanding.iter().sum()
    }

    fn triggered(&self, now: Instant) -> bool {
        if self.queue.len() >= self.max_size {
            return true;
        }
        self.queue
            .front()
            .map(|&(_, t, _)| now.duration_since(t) >= self.max_delay)
            .unwrap_or(false)
    }

    /// Old routing (round-robin preferred, least-outstanding fallback,
    /// backpressure before any cursor change) with this PR's fix applied:
    /// the cursor advances past the shard actually chosen.
    fn pick_shard(&mut self) -> Option<usize> {
        let n = self.outstanding.len();
        let preferred = self.rr_cursor % n;
        let shard = if self.outstanding[preferred] < self.max_outstanding {
            preferred
        } else {
            let (best, &count) = self
                .outstanding
                .iter()
                .enumerate()
                .min_by_key(|&(_, &c)| c)
                .expect("at least one shard");
            if count >= self.max_outstanding {
                return None;
            }
            best
        };
        self.rr_cursor = (shard + 1) % n;
        Some(shard)
    }

    fn try_dispatch(&mut self, now: Instant) -> Option<RefDispatch> {
        if !self.triggered(now) {
            return None;
        }
        let shard = self.pick_shard()?;
        let n = self.queue.len().min(self.max_size);
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| self.queue[i].0);
        let mut origins = Vec::with_capacity(n);
        let mut items = Vec::with_capacity(n);
        for &i in &order {
            origins.push(self.queue[i].0);
            items.push(self.queue[i].2.clone());
        }
        self.queue.drain(..n);
        let id = self.next_id;
        self.next_id += 1;
        self.outstanding[shard] += 1;
        self.inflight.insert(id, (shard, n));
        Some(RefDispatch { id, shard, origins, items })
    }

    fn complete(&mut self, id: u64) -> Option<(usize, usize)> {
        let (shard, items) = self.inflight.remove(&id)?;
        self.outstanding[shard] = self.outstanding[shard].saturating_sub(1);
        Some((shard, items))
    }
}

// ---------------------------------------------------------------------------
// Seeded op-sequence drivers
// ---------------------------------------------------------------------------

const STEPS: usize = 600;

fn oracle_equivalence_run(cfg: &BatchSetting, n_oracles: usize, seed: u64) {
    let mut real = OracleScheduler::new(cfg, n_oracles);
    let mut reference = RefOracleScheduler::new(cfg, n_oracles);
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let mut clock_ms: u64 = 0;
    let mut queue_len: usize = 0;
    let mut live: Vec<u64> = Vec::new();
    let ctx = format!(
        "oracle cfg (size {}, delay {:?}, outstanding {}, pool {n_oracles}, seed {seed})",
        cfg.max_size, cfg.max_delay, cfg.max_outstanding
    );

    for step in 0..STEPS {
        let now = t0 + Duration::from_millis(clock_ms);
        match rng.below(5) {
            0 => {
                queue_len += rng.below(3) + 1;
                real.note_enqueued(now);
                reference.note_enqueued(now);
            }
            1 => clock_ms += rng.below(9) as u64,
            2 => {
                let budget = match rng.below(3) {
                    0 => None,
                    _ => Some(rng.below(11) as u64),
                };
                let a = real.try_dispatch(queue_len, now, budget).map(|d| (d.id, d.oracle, d.take));
                let b = reference.try_dispatch(queue_len, now, budget);
                assert_eq!(a, b, "step {step}, {ctx}: dispatch diverged");
                if let Some((id, _, take)) = a {
                    assert!(take > 0, "step {step}, {ctx}: empty batch");
                    queue_len -= take.min(queue_len);
                    live.push(id);
                }
            }
            3 => {
                if let Some(i) = (!live.is_empty()).then(|| rng.below(live.len())) {
                    let id = live.swap_remove(i);
                    assert_eq!(
                        real.complete(id, now),
                        reference.complete(id),
                        "step {step}, {ctx}: completion diverged"
                    );
                }
            }
            _ => {
                // rescore resync: the external buffer was pruned/replaced
                queue_len = rng.below(queue_len + 1);
                real.sync_queue(queue_len, now);
                reference.sync_queue(queue_len, now);
            }
        }
        assert_eq!(real.in_flight(), reference.in_flight(), "step {step}, {ctx}");
        assert_eq!(real.in_flight_items(), reference.in_flight_items(), "step {step}, {ctx}");
    }
}

fn batch_equivalence_run(cfg: &BatchSetting, n_shards: usize, seed: u64) {
    let mut real = BatchScheduler::new(cfg, n_shards);
    let mut reference = RefBatchScheduler::new(cfg, n_shards);
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let mut clock_ms: u64 = 0;
    let mut live: Vec<u64> = Vec::new();
    let mut pushed = 0usize;
    let ctx = format!(
        "batch cfg (size {}, delay {:?}, outstanding {}, shards {n_shards}, seed {seed})",
        cfg.max_size, cfg.max_delay, cfg.max_outstanding
    );

    for step in 0..STEPS {
        let now = t0 + Duration::from_millis(clock_ms);
        match rng.below(4) {
            0 => {
                for _ in 0..rng.below(3) + 1 {
                    let origin = rng.below(4);
                    let row = [pushed as f32, origin as f32];
                    real.push(origin, &row, now);
                    reference.push(origin, &row, now);
                    pushed += 1;
                }
            }
            1 => clock_ms += rng.below(9) as u64,
            2 => {
                let a = real.try_dispatch(now);
                let b = reference.try_dispatch(now);
                match (&a, &b) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        assert_eq!((x.id, x.shard), (y.id, y.shard), "step {step}, {ctx}");
                        assert_eq!(x.origins, y.origins, "step {step}, {ctx}: origin order");
                        assert_eq!(x.items.len(), y.items.len(), "step {step}, {ctx}");
                        for i in 0..y.items.len() {
                            assert_eq!(
                                x.items.row(i),
                                y.items[i].as_slice(),
                                "step {step}, {ctx}: row {i}"
                            );
                        }
                        live.push(x.id);
                    }
                    _ => panic!("step {step}, {ctx}: dispatch diverged ({a:?} vs {b:?})"),
                }
            }
            _ => {
                if let Some(i) = (!live.is_empty()).then(|| rng.below(live.len())) {
                    let id = live.swap_remove(i);
                    assert_eq!(
                        real.complete(id, now),
                        reference.complete(id),
                        "step {step}, {ctx}: completion diverged"
                    );
                }
            }
        }
        assert_eq!(real.queue_len(), reference.queue_len(), "step {step}, {ctx}");
        assert_eq!(real.in_flight(), reference.in_flight(), "step {step}, {ctx}");
    }
}

/// (max_size, max_delay_ms, max_outstanding, pool size) grid: degenerate
/// single-endpoint pools, size- and deadline-dominated triggers, deep and
/// shallow backpressure.
const GRID: &[(usize, u64, usize, usize)] = &[
    (1, 0, 1, 1),
    (2, 5, 1, 2),
    (4, 0, 2, 3),
    (8, 5, 3, 5),
    (3, 7, 2, 2),
    (6, 2, 1, 4),
];

fn grid_setting(max_size: usize, delay_ms: u64, max_outstanding: usize) -> BatchSetting {
    BatchSetting {
        max_size,
        max_delay: Duration::from_millis(delay_ms),
        max_outstanding,
    }
}

#[test]
fn static_oracle_scheduler_is_bit_identical_to_pr5() {
    for (k, &(size, delay, outstanding, pool)) in GRID.iter().enumerate() {
        let cfg = grid_setting(size, delay, outstanding);
        for rep in 0..3u64 {
            oracle_equivalence_run(&cfg, pool, 0xD15_0000 + 31 * k as u64 + rep);
        }
    }
}

#[test]
fn static_batch_scheduler_is_bit_identical_to_pr5() {
    for (k, &(size, delay, outstanding, pool)) in GRID.iter().enumerate() {
        let cfg = grid_setting(size, delay, outstanding);
        for rep in 0..3u64 {
            batch_equivalence_run(&cfg, pool, 0xBA7C_0000 + 31 * k as u64 + rep);
        }
    }
}

// ---------------------------------------------------------------------------
// Eviction end-to-end: an oracle that stops replying mid-run
// ---------------------------------------------------------------------------

/// Wire layout for a 1-"atom" PES with 1 global and 1 state:
/// input `[x, y, z, g, s]`, label `[e, fx, fy, fz]`.
const IN_DIM: usize = 5;
const OUT_DIM: usize = 4;

const GENS: usize = 4;
const ORACLES: usize = 4;
const LABELS: u64 = 24;

/// Fixed-seed random walker (ignores checked predictions).
struct MbWalker {
    rng: Rng,
    pos: [f32; 2],
}

impl MbWalker {
    fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let pes = MullerBrown::default();
        let x0 = pes.initial_geometry(&mut rng);
        MbWalker { rng, pos: [x0[0], x0[1]] }
    }
}

impl Generator for MbWalker {
    fn generate_new_data(&mut self, _data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>) {
        self.pos[0] += (self.rng.normal() * 0.08) as f32;
        self.pos[1] += (self.rng.normal() * 0.08) as f32;
        (false, vec![self.pos[0], self.pos[1], 0.0, 0.0, 1.0])
    }
}

/// Select every input (the run is throughput-, not selection-, focused).
struct SelectAllUtils;

impl Utils for SelectAllUtils {
    fn prediction_check(
        &mut self,
        list_data_to_pred: &[Vec<f32>],
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let checked = pal::coordinator::selection::committee_mean(preds_per_model);
        (list_data_to_pred.to_vec(), checked)
    }
}

fn eviction_setting() -> AlSetting {
    AlSetting {
        result_dir: "/tmp/pal-eviction".into(),
        gene_process: GENS,
        pred_process: 1,
        ml_process: 0, // training disabled: the green flow is the subject
        orcl_process: ORACLES,
        committee_size: Some(1),
        exchange_mode: ExchangeMode::Batched,
        retrain_size: 10_000, // never flush
        strict_label_budget: true,
        seed: 11,
        batch: BatchSetting {
            max_size: GENS,
            max_delay: Duration::from_millis(2),
            max_outstanding: 2,
        },
        oracle_mode: OracleMode::Batched,
        oracle_batch: BatchSetting {
            max_size: 4,
            max_delay: Duration::from_millis(1),
            max_outstanding: 1,
        },
        sched: SchedSetting {
            policy: SchedPolicy::Adaptive,
            // evict on in-flight age; the stalled oracle sleeps far past it
            timeout: Some(Duration::from_millis(120)),
            // no timed rejoin within the test window — only a late reply
            // (proof of life) can readmit the stalled oracle
            rejoin_backoff: Duration::from_secs(120),
            ..Default::default()
        },
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(LABELS),
            min_retrain_rounds: 0,
            min_train_epochs: 0,
            max_wall: Some(Duration::from_secs(60)),
        },
        ..Default::default()
    }
}

fn eviction_kernels() -> KernelSet {
    let generators = (0..GENS)
        .map(|i| {
            let seed = 300 + i as u64;
            Box::new(move || Box::new(MbWalker::new(seed)) as Box<dyn Generator>)
                as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    // oracle 0 stalls: 400 ms per item dwarfs the 120 ms eviction timeout,
    // so its first batch times out mid-run; oracles 1-3 label instantly
    let oracles = (0..ORACLES)
        .map(|i| {
            Box::new(move || {
                let inner = PesOracle::fixed(MullerBrown::default(), 1);
                if i == 0 {
                    Box::new(LatencyOracle::new(inner, Duration::from_millis(400)))
                        as Box<dyn Oracle>
                } else {
                    Box::new(inner) as Box<dyn Oracle>
                }
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _member: usize| {
        Box::new(SyntheticModel::new(IN_DIM, OUT_DIM, Duration::ZERO, Duration::ZERO, 8, mode))
            as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(SelectAllUtils) as Box<dyn Utils>);
    KernelSet { generators, oracles, model, utils }
}

/// The acceptance pin: with a strict label budget of `LABELS`, the stalled
/// oracle's in-flight batch would strand its budget headroom forever —
/// labels would plateau below `LABELS` and the run could only end by
/// hitting `max_wall`. Reaching `max_labels` therefore proves the health
/// plane evicted the stalled oracle, requeued its in-flight inputs,
/// released their budget, and relabeled them on a live oracle: zero lost
/// labels. A late reply from the evicted oracle may add duplicate labels
/// (they were paid for), never fewer.
#[test]
fn stalled_oracle_is_evicted_and_its_labels_are_recovered() {
    let report = Workflow::new(eviction_setting()).run(eviction_kernels()).unwrap();

    assert!(
        report.oracle_labels >= LABELS,
        "labels lost to the stalled oracle: {} < {LABELS}",
        report.oracle_labels
    );
    assert!(
        report.wall < Duration::from_secs(50),
        "run only finished via max_wall ({:?}): eviction did not recover the budget",
        report.wall
    );

    let manager = &report.kernel("manager")[0];
    assert!(
        manager.counter("oracle_evictions") >= 1,
        "stalled oracle was never evicted"
    );
    assert!(
        manager.counter("requeued_inputs") >= 1,
        "evicted batch's inputs were not requeued"
    );
    // every ingested label landed in the training buffer exactly once per
    // result frame — duplicates (relabels + a late reply) allowed, losses not
    assert!(manager.counter("labels") >= LABELS);
}
