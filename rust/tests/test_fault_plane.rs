//! Chaos matrix for the fault plane: seeded [`FaultPlan`] kills of one
//! rank from every kernel class mid-run, plus a genuine (un-planned) host
//! panic, all asserting *degraded completion* — `Workflow::run` returns
//! `Ok(RunReport)` with the dead rank in `faults.failed_ranks`, and where
//! the class is redundant (oracles, prediction shards) the strict label
//! budget is still reached: the coordinators evicted the dead rank,
//! requeued its in-flight work, and relabeled/re-served it elsewhere.
//!
//! Faults are deterministic protocol-event triggers (kill on the Nth
//! arrival or after the Nth send), so each scenario perturbs the same
//! point in the message stream every run — the reproducibility test pins
//! that the same plan yields the same failed ranks and the same label
//! count twice.

use std::sync::Arc;
use std::time::Duration;

use pal::comm::FaultPlan;
use pal::config::{
    topology, AlSetting, BatchSetting, ExchangeMode, OracleMode, StopCriteria, Topology,
};
use pal::coordinator::selection::SelectAllUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::oracles::PesOracle;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::potential::{MullerBrown, Pes};
use pal::rng::Rng;
use pal::sim::workload::SyntheticModel;
use pal::telemetry::RunReport;

/// Wire layout for a 1-"atom" PES with 1 global and 1 state:
/// input `[x, y, z, g, s]`, label `[e, fx, fy, fz]`.
const IN_DIM: usize = 5;
const OUT_DIM: usize = 4;

const GENS: usize = 4;
const ORACLES: usize = 4;
const LABELS: u64 = 24;

/// Fixed-seed random walker (ignores checked predictions).
struct MbWalker {
    rng: Rng,
    pos: [f32; 2],
}

impl MbWalker {
    fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let pes = MullerBrown::default();
        let x0 = pes.initial_geometry(&mut rng);
        MbWalker { rng, pos: [x0[0], x0[1]] }
    }
}

impl Generator for MbWalker {
    fn generate_new_data(&mut self, _data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>) {
        self.pos[0] += (self.rng.normal() * 0.08) as f32;
        self.pos[1] += (self.rng.normal() * 0.08) as f32;
        (false, vec![self.pos[0], self.pos[1], 0.0, 0.0, 1.0])
    }
}

/// A generator with a genuine bug: panics (no fault plan involved) on its
/// fourth step. The supervisor must treat it exactly like an injected kill
/// minus the `fault_injected` marker.
struct PanicGen {
    steps: usize,
}

impl Generator for PanicGen {
    fn generate_new_data(&mut self, _data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>) {
        self.steps += 1;
        if self.steps > 3 {
            panic!("injected genuine bug (expected panic output in this test)");
        }
        (false, vec![0.1 * self.steps as f32, 0.2, 0.0, 0.0, 1.0])
    }
}

/// Batched green + blue flows, strict label budget, no training: the
/// recovery invariant (budget reached despite a dead rank) is the subject.
fn chaos_setting() -> AlSetting {
    AlSetting {
        result_dir: "/tmp/pal-fault-plane".into(),
        gene_process: GENS,
        pred_process: 1,
        ml_process: 0,
        orcl_process: ORACLES,
        committee_size: Some(1),
        exchange_mode: ExchangeMode::Batched,
        retrain_size: 10_000, // never flush
        strict_label_budget: true,
        seed: 11,
        batch: BatchSetting {
            max_size: GENS,
            max_delay: Duration::from_millis(2),
            max_outstanding: 2,
        },
        oracle_mode: OracleMode::Batched,
        oracle_batch: BatchSetting {
            max_size: 4,
            max_delay: Duration::from_millis(1),
            max_outstanding: 1,
        },
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(LABELS),
            min_retrain_rounds: 0,
            min_train_epochs: 0,
            max_wall: Some(Duration::from_secs(60)),
        },
        ..Default::default()
    }
}

fn walkers(n: usize) -> Vec<Box<dyn FnOnce() -> Box<dyn Generator> + Send>> {
    (0..n)
        .map(|i| {
            let seed = 300 + i as u64;
            Box::new(move || Box::new(MbWalker::new(seed)) as Box<dyn Generator>)
                as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect()
}

fn instant_oracles(n: usize) -> Vec<Box<dyn FnOnce() -> Box<dyn Oracle> + Send>> {
    (0..n)
        .map(|_| {
            Box::new(|| Box::new(PesOracle::fixed(MullerBrown::default(), 1)) as Box<dyn Oracle>)
                as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect()
}

fn chaos_kernels(s: &AlSetting) -> KernelSet {
    let max_sel = s.gene_process;
    KernelSet {
        generators: walkers(s.gene_process),
        oracles: instant_oracles(s.orcl_process),
        model: Arc::new(|mode: Mode, _member: usize| {
            Box::new(SyntheticModel::new(IN_DIM, OUT_DIM, Duration::ZERO, Duration::ZERO, 8, mode))
                as Box<dyn Model>
        }),
        utils: Arc::new(move || {
            Box::new(SelectAllUtils { max_per_iter: max_sel }) as Box<dyn Utils>
        }),
    }
}

fn run_with(setting: AlSetting, plan: FaultPlan) -> RunReport {
    let kernels = chaos_kernels(&setting);
    Workflow::new(setting).with_faults(plan).run(kernels).expect("degraded Ok, never Err")
}

// ---------------------------------------------------------------------------
// The chaos matrix: one kill per rank class
// ---------------------------------------------------------------------------

/// Oracle killed as its first batch arrives (the batch dies with the
/// host). The Manager must evict it on the rank-down notice, requeue the
/// retained in-flight inputs, relabel them elsewhere, and still reach the
/// strict budget — the eviction invariant, now under a real dead thread
/// instead of a simulated stall.
#[test]
fn killed_batched_oracle_still_reaches_label_budget() {
    let setting = chaos_setting();
    let victim = Topology::new(&setting).orcl_ranks()[0];
    let report = run_with(setting, FaultPlan::default().kill_after_recvs(victim, 1));

    assert!(
        report.oracle_labels >= LABELS,
        "labels lost with the dead oracle: {} < {LABELS}",
        report.oracle_labels
    );
    assert!(
        report.wall < Duration::from_secs(50),
        "run only finished via max_wall ({:?}): recovery failed",
        report.wall
    );
    assert!(report.faults.failed_ranks.contains(&victim), "{:?}", report.faults);
    assert!(report.faults.oracle_evictions >= 1, "{:?}", report.faults);
    assert!(report.faults.requeued_inputs >= 1, "in-flight inputs not requeued");
}

/// Prediction shard killed as its second batch arrives. The Exchange must
/// evict the whole shard, requeue the lost batch's items by origin, and
/// re-serve them on the surviving shard — red/blue flow keeps moving and
/// the label budget is still reached.
#[test]
fn killed_prediction_shard_still_reaches_label_budget() {
    let setting = AlSetting { pred_process: 2, ..chaos_setting() };
    let victim = Topology::new(&setting).pred_ranks()[0];
    let report = run_with(setting, FaultPlan::default().kill_after_recvs(victim, 2));

    assert!(
        report.oracle_labels >= LABELS,
        "labels starved by the dead shard: {} < {LABELS}",
        report.oracle_labels
    );
    assert!(report.wall < Duration::from_secs(50), "finished via max_wall: {:?}", report.wall);
    assert!(report.faults.failed_ranks.contains(&victim), "{:?}", report.faults);
    assert!(report.faults.shard_evictions >= 1, "{:?}", report.faults);
    assert!(report.faults.requeued_items >= 1, "lost batch's items not requeued");
}

/// Trainer killed as its first labeled flush arrives. Training is not on
/// the label path, so the run degrades (no more retrains for that member,
/// later flushes to it become dead letters) but the budget is reached.
#[test]
fn killed_trainer_degrades_but_reaches_label_budget() {
    let setting = AlSetting {
        pred_process: 2,
        ml_process: 2,
        committee_size: Some(2),
        retrain_size: 8, // flushes at 8 and 16 labels, well inside the run
        ..chaos_setting()
    };
    let victim = Topology::new(&setting).train_ranks()[0];
    let report = run_with(setting, FaultPlan::default().kill_after_recvs(victim, 1));

    assert!(report.oracle_labels >= LABELS, "labels: {}", report.oracle_labels);
    assert!(report.wall < Duration::from_secs(50), "finished via max_wall: {:?}", report.wall);
    assert!(report.faults.failed_ranks.contains(&victim), "{:?}", report.faults);
}

/// Generator killed after its third send. In batched exchange mode the
/// remaining generators keep the red flow alive (partial batches dispatch
/// on the deadline trigger), so the budget is still reached.
#[test]
fn killed_generator_still_reaches_label_budget() {
    let setting = chaos_setting();
    let victim = Topology::new(&setting).gene_ranks()[0];
    let report = run_with(setting, FaultPlan::default().kill_after_sends(victim, 3));

    assert!(report.oracle_labels >= LABELS, "labels: {}", report.oracle_labels);
    assert!(report.wall < Duration::from_secs(50), "finished via max_wall: {:?}", report.wall);
    assert!(report.faults.failed_ranks.contains(&victim), "{:?}", report.faults);
}

// ---------------------------------------------------------------------------
// Kill the Exchange: bounded drains, degraded completion (the join-order pin)
// ---------------------------------------------------------------------------

/// The Exchange itself dies mid-run. No further selections can arrive, so
/// the Manager must notice (rank-down), stop, run its p95-bounded drain,
/// and join every host promptly — the old join loop would have returned
/// `Err("kernel host panicked")` and, worse, could hang on hosts blocked
/// behind the dead relay.
#[test]
fn killed_exchange_completes_bounded_and_degraded() {
    let setting = chaos_setting();
    // 12 arrivals ≈ two generator rounds: far before the 24-label budget
    // can complete, so the kill always lands mid-run
    let report = run_with(setting, FaultPlan::default().kill_after_recvs(topology::EXCHANGE, 12));

    assert!(
        report.faults.failed_ranks.contains(&topology::EXCHANGE),
        "{:?}",
        report.faults
    );
    assert!(
        report.wall < Duration::from_secs(30),
        "Manager did not stop promptly on a dead Exchange: {:?}",
        report.wall
    );
    let manager = &report.kernel("manager")[0];
    assert!(manager.counter("exchange_down_stops") >= 1, "stop not attributed to the dead relay");
}

/// A lockstep-round participant dies. Lockstep gathers need every peer, so
/// the Exchange may already be blocked mid-gather on the dead generator —
/// only the Manager can break the cycle, and it must: escalate to
/// shutdown, drain, and complete degraded instead of hanging.
#[test]
fn lockstep_generator_death_aborts_cleanly() {
    let setting = AlSetting {
        exchange_mode: ExchangeMode::Lockstep,
        oracle_mode: OracleMode::PerLabel,
        orcl_process: 1,
        strict_label_budget: false,
        stop: StopCriteria {
            max_iterations: Some(1_000_000), // ended by the abort, not this
            max_labels: None,
            min_retrain_rounds: 0,
            min_train_epochs: 0,
            max_wall: Some(Duration::from_secs(30)),
        },
        ..chaos_setting()
    };
    let victim = Topology::new(&setting).gene_ranks()[0];
    let report = run_with(setting, FaultPlan::default().kill_after_sends(victim, 5));

    assert!(report.faults.failed_ranks.contains(&victim), "{:?}", report.faults);
    assert!(
        report.wall < Duration::from_secs(25),
        "lockstep abort did not complete promptly: {:?}",
        report.wall
    );
    let manager = &report.kernel("manager")[0];
    assert!(manager.counter("lockstep_abort_stops") >= 1, "Manager never escalated");
}

// ---------------------------------------------------------------------------
// Per-label oracle path: same eviction/requeue discipline
// ---------------------------------------------------------------------------

/// Oracle death in the legacy per-label mode. The retained in-flight input
/// must be requeued on the rank-down notice and relabeled by a surviving
/// oracle — the eviction machinery is not batched-mode-only.
#[test]
fn per_label_oracle_death_recovers_via_requeue() {
    let setting = AlSetting { oracle_mode: OracleMode::PerLabel, ..chaos_setting() };
    let victim = Topology::new(&setting).orcl_ranks()[0];
    let report = run_with(setting, FaultPlan::default().kill_after_recvs(victim, 1));

    assert!(
        report.oracle_labels >= LABELS,
        "labels lost with the dead oracle: {} < {LABELS}",
        report.oracle_labels
    );
    assert!(report.wall < Duration::from_secs(50), "finished via max_wall: {:?}", report.wall);
    assert!(report.faults.failed_ranks.contains(&victim), "{:?}", report.faults);
    assert!(report.faults.oracle_evictions >= 1, "{:?}", report.faults);
    assert!(report.faults.requeued_inputs >= 1, "retained input not requeued");
}

// ---------------------------------------------------------------------------
// Reproducibility + genuine panics
// ---------------------------------------------------------------------------

/// The same seeded plan twice: faults trigger on protocol events, not
/// wall-clock, so the failed ranks and the (budget-exact) label count are
/// identical across runs.
#[test]
fn same_fault_plan_is_reproducible() {
    let victim = Topology::new(&chaos_setting()).orcl_ranks()[0];
    let a = run_with(chaos_setting(), FaultPlan::default().kill_after_recvs(victim, 1));
    let b = run_with(chaos_setting(), FaultPlan::default().kill_after_recvs(victim, 1));

    assert_eq!(a.faults.failed_ranks, b.faults.failed_ranks);
    assert_eq!(a.oracle_labels, b.oracle_labels, "label count not reproducible");
    assert!(!a.faults.is_clean() && !b.faults.is_clean());
}

/// A genuine host bug — a plain `panic!`, no fault plan installed — takes
/// the same supervised path: degraded completion, the rank reported, but
/// *not* marked as an injected fault.
#[test]
fn genuine_panic_is_supervised_not_fatal() {
    let setting = chaos_setting();
    let victim = Topology::new(&setting).gene_ranks()[0];
    let mut kernels = chaos_kernels(&setting);
    kernels.generators[0] = Box::new(|| Box::new(PanicGen { steps: 0 }) as Box<dyn Generator>);
    let report = Workflow::new(setting).run(kernels).expect("degraded Ok, never Err");

    assert!(report.oracle_labels >= LABELS, "labels: {}", report.oracle_labels);
    assert!(report.faults.failed_ranks.contains(&victim), "{:?}", report.faults);
    let dead = report
        .kernels
        .iter()
        .find(|k| k.rank == victim)
        .expect("failed host still reports telemetry");
    assert_eq!(dead.counter("failed"), 1);
    assert_eq!(dead.counter("fault_injected"), 0, "genuine panic mislabeled as injected");
}
