//! End-to-end: the full PAL workflow with HLO-backed committee models, MD
//! generators, and analytic-PES oracles — the production configuration of
//! the cluster/photodynamics applications, scaled down for CI.

use std::sync::Arc;
use std::time::Duration;

use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::generators::{MdGenerator, MdLayout};
use pal::kernels::models::HloPotentialModel;
use pal::kernels::models::HloToyModel;
use pal::kernels::oracles::PesOracle;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::potential::{Morse, Pes};
use pal::runtime::{default_artifacts_dir, Manifest};
use pal::rng::Rng;

/// Skip (loudly) when the HLO execution path is unavailable — these tests
/// exercise the artifact-backed production models end-to-end and need both
/// built artifacts and a linked PJRT backend.
macro_rules! require_hlo {
    () => {
        if !pal::runtime::hlo_available() {
            eprintln!("skipping: PJRT backend/artifacts unavailable in this build");
            return;
        }
    };
}

fn dimer_layout() -> MdLayout {
    MdLayout { n_atoms: 2, n_globals: 1, n_states: 1 }
}

/// 3 MD generators on the Morse dimer, 2-member committee (2 pred + 2 train
/// ranks), 2 analytic oracles.
fn dimer_kernels(setting: &AlSetting) -> KernelSet {
    let layout = dimer_layout();
    let generators = (0..setting.gene_process)
        .map(|i| {
            let seed = 100 + i as u64;
            Box::new(move || {
                let mut rng = Rng::new(seed);
                let x0 = Morse::dimer().initial_geometry(&mut rng);
                Box::new(
                    MdGenerator::new(layout, x0, seed).with_dt(0.02).with_patience(3),
                ) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..setting.orcl_process)
        .map(|_| {
            Box::new(|| {
                // ~100 ms simulated QC cost so labeling overlaps trainer
                // startup (PJRT compile) as in a real deployment
                Box::new(pal::kernels::oracles::LatencyOracle::new(
                    PesOracle::fixed(Morse::dimer(), 1),
                    Duration::from_millis(100),
                )) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let dir = default_artifacts_dir();
    let model = Arc::new(move |mode: Mode, replica: usize| {
        let manifest = Manifest::load(&dir).expect("artifacts");
        let opts = pal::kernels::models::TrainOptions {
            epochs_per_round: 8,
            ..Default::default()
        };
        Box::new(
            HloPotentialModel::new(manifest, "dimer1", mode, 41 + replica as u32, opts)
                .expect("model"),
        ) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(0.05, 4)) as Box<dyn Utils>);
    KernelSet { generators, oracles, model, utils }
}

#[test]
fn hlo_dimer_workflow_labels_and_trains() {
    require_hlo!();
    let setting = AlSetting {
        result_dir: "/tmp/pal-e2e-dimer".into(),
        gene_process: 3,
        pred_process: 2,
        ml_process: 2,
        orcl_process: 2,
        retrain_size: 4,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(24),
            max_wall: Some(Duration::from_secs(120)),
            ..Default::default()
        },
        ..Default::default()
    };
    let kernels = dimer_kernels(&setting);
    let report = Workflow::new(setting).run(kernels).unwrap();
    assert!(report.oracle_labels >= 24, "labels {}", report.oracle_labels);
    assert!(report.retrain_rounds > 0, "no retraining happened");
    assert!(report.al_iterations > 0);
    // the committee actually served predictions through PJRT
    let samples = report.sum_counter("prediction", "samples");
    assert!(samples > 0);
    // reported training losses are finite (NaN = trainer finished its
    // round during shutdown, after the Manager stopped listening)
    for l in &report.final_losses {
        assert!(l.is_finite() || l.is_nan(), "loss {l}");
    }
}

#[test]
fn hlo_model_learns_morse_offline() {
    require_hlo!();
    // The model kernel alone: feed it oracle-labeled dimer data and verify
    // the loss decreases and validation improves — the learning-curve
    // mechanism behind examples/end_to_end.rs.
    let manifest = Manifest::load(default_artifacts_dir()).unwrap();
    let opts = pal::kernels::models::TrainOptions {
        epochs_per_round: 60,
        val_split: 0.25,
        ..Default::default()
    };
    let mut model =
        HloPotentialModel::new(manifest, "dimer1", Mode::Train, 7, opts).unwrap();

    let mut oracle = PesOracle::fixed(Morse::dimer(), 1);
    let mut rng = Rng::new(3);
    let mut points = Vec::new();
    for _ in 0..48 {
        let r = 0.9 + 1.6 * rng.f32();
        let input = vec![0.0, 0.0, 0.0, r, 0.0, 0.0, 0.0, 1.0];
        let label = oracle.run_calc(&input);
        points.push((input, label));
    }
    model.add_trainingset(&points);
    let v0 = model.validation_mse().unwrap().unwrap();
    model.retrain(&mut || false);
    let l1 = model.last_loss().unwrap();
    for _ in 0..3 {
        model.retrain(&mut || false);
    }
    let l2 = model.last_loss().unwrap();
    let v1 = model.validation_mse().unwrap().unwrap();
    assert!(l2 < l1, "train loss did not descend: {l1} -> {l2}");
    assert!(v1 < v0, "val mse did not improve: {v0} -> {v1}");
}

#[test]
fn hlo_model_weight_sync_roundtrip() {
    require_hlo!();
    let dir = default_artifacts_dir();
    let mk = |mode, seed| {
        HloPotentialModel::new(
            Manifest::load(&dir).unwrap(),
            "dimer1",
            mode,
            seed,
            Default::default(),
        )
        .unwrap()
    };
    let trainer = mk(Mode::Train, 1);
    let mut predictor = mk(Mode::Predict, 2);
    // different seeds → different weights
    assert_ne!(trainer.get_weight(), predictor.get_weight());
    // paper protocol: trainer → predictor flat-array sync
    let w = trainer.get_weight();
    assert_eq!(w.len(), trainer.get_weight_size());
    predictor.update(&w);
    assert_eq!(predictor.get_weight(), w);
    // synced predictors now agree on predictions
    let input = vec![0.0, 0.0, 0.0, 1.3, 0.0, 0.0, 0.0, 1.0];
    let mut trainer = trainer;
    let a = trainer.predict(&[input.clone()]);
    let b = predictor.predict(&[input]);
    for (x, y) in a[0].iter().zip(&b[0]) {
        assert!((x - y).abs() < 1e-5);
    }
}

#[test]
fn hlo_toy_quickstart_workflow() {
    require_hlo!();
    // The SI §S3 toy at reduced scale, over the real toy artifacts.
    let setting = AlSetting {
        result_dir: "/tmp/pal-e2e-toy".into(),
        gene_process: 5,
        pred_process: 2,
        ml_process: 2,
        orcl_process: 2,
        retrain_size: 5,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(10),
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..5usize)
        .map(|i| {
            Box::new(move || {
                Box::new(pal::kernels::generators::RandomGenerator::new(
                    4,
                    u64::MAX,
                    i as u64,
                )) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..2usize)
        .map(|_| {
            Box::new(|| {
                Box::new(pal::sim::workload::SyntheticOracle {
                    label_cost: Duration::from_millis(1),
                    out_dim: 4,
                }) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let dir = default_artifacts_dir();
    let model = Arc::new(move |mode: Mode, replica: usize| {
        let manifest = Manifest::load(&dir).unwrap();
        Box::new(HloToyModel::new(manifest, mode, replica as u32).unwrap()) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(0.01, 5)) as Box<dyn Utils>);
    let report = Workflow::new(setting)
        .run(KernelSet { generators, oracles, model, utils })
        .unwrap();
    assert!(report.oracle_labels >= 10);
    assert!(report.sum_counter("prediction", "batches") > 0);
}
