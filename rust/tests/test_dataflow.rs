//! Fig. 2 / Fig. 4 conformance: message counts and routes per AL iteration
//! match the paper's data-flow diagram, and payload accounting is sane.

use std::sync::Arc;
use std::time::Duration;

use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::sim::workload::{SyntheticGenerator, SyntheticModel, SyntheticOracle};

fn run(gene: usize, pred: usize, orcl: usize, ml: usize, iters: u64, threshold: f32)
    -> pal::telemetry::RunReport
{
    let s = AlSetting {
        result_dir: "/tmp/pal-dataflow".into(),
        gene_process: gene,
        pred_process: pred,
        orcl_process: orcl,
        ml_process: ml,
        retrain_size: 4,
        stop: StopCriteria {
            max_iterations: Some(iters),
            max_labels: None,
            max_wall: Some(Duration::from_secs(30)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..gene)
        .map(|i| {
            let seed = i as u64;
            Box::new(move || {
                Box::new(SyntheticGenerator::new(4, Duration::ZERO, u64::MAX, seed))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..orcl)
        .map(|_| {
            Box::new(|| {
                Box::new(SyntheticOracle { label_cost: Duration::ZERO, out_dim: 4 })
                    as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, replica: usize| {
        let mut m = SyntheticModel::new(4, 4, Duration::ZERO, Duration::ZERO, 8, mode);
        let w: Vec<f32> = (0..16).map(|k| ((k * (replica + 1)) % 7) as f32 * 0.05).collect();
        m.update(&w);
        Box::new(m) as Box<dyn Model>
    });
    let utils = Arc::new(move || {
        Box::new(CommitteeStdUtils::new(threshold, usize::MAX)) as Box<dyn Utils>
    });
    Workflow::new(s)
        .run(KernelSet { generators, oracles, model, utils })
        .unwrap()
}

#[test]
fn red_blue_flow_message_budget() {
    // With selection disabled (huge threshold), one iteration must cost
    // exactly: G gen→exchange + P exchange→pred + P pred→exchange +
    // G exchange→gene messages. Weight syncs (T→P at startup) and the
    // shutdown fan-out are bounded extras.
    let (g, p) = (5u64, 3u64);
    let iters = 20u64;
    let report = run(5, 3, 0, 0, iters, f32::MAX);
    let per_iter = g + p + p + g;
    let lower = per_iter * iters;
    // extras: final round's gen messages in flight + shutdown fan-out
    // (world_size messages) + stop signal
    let upper = per_iter * (iters + 2) + 30;
    assert!(
        report.messages >= lower && report.messages <= upper,
        "messages {} not in [{lower}, {upper}]",
        report.messages
    );
}

#[test]
fn green_yellow_flow_counts_match_labels() {
    // Everything uncertain → every generator input goes to the oracle.
    let report = run(3, 2, 2, 2, 15, 0.0);
    let selected = report.sum_counter("exchange", "selected_for_oracle");
    let dispatched = report.kernel("manager")[0].counter("dispatched");
    let labeled = report.oracle_labels;
    // monotone pipeline: selected >= dispatched >= labeled (in-flight at
    // shutdown accounts for the gaps); nothing is created from nothing
    assert!(selected >= dispatched, "selected {selected} < dispatched {dispatched}");
    assert!(dispatched >= labeled, "dispatched {dispatched} < labeled {labeled}");
    assert!(labeled > 0);
    // oracle-side view agrees with the manager's
    let oracle_labels = report.sum_counter("oracle", "labels");
    assert!(oracle_labels >= labeled, "oracle counted {oracle_labels}, manager {labeled}");
}

#[test]
fn train_flush_respects_threshold() {
    let report = run(4, 2, 2, 2, 25, 0.0);
    let manager = &report.kernel("manager")[0];
    let flushes = manager.counter("train_flushes");
    let points = manager.counter("train_points");
    if flushes > 0 {
        // every flush carries at least retrain_size (=4) points
        assert!(points >= flushes * 4, "{points} points over {flushes} flushes");
    }
    // each trainer receives every broadcast batch
    for t in report.kernel("training") {
        assert_eq!(t.counter("datapoints"), points, "trainer {}", t.rank);
    }
}

#[test]
fn predictions_scale_with_generators_and_iterations() {
    let report = run(6, 2, 0, 0, 12, f32::MAX);
    // every predictor sees G inputs per iteration
    for p in report.kernel("prediction") {
        let samples = p.counter("samples");
        assert!(samples >= 6 * 12, "predictor {} saw {samples}", p.rank);
        assert_eq!(p.counter("batches"), p.counter("batches"));
    }
}

#[test]
fn payload_accounting_is_consistent() {
    let report = run(3, 2, 1, 2, 10, 0.0);
    assert!(report.payload_bytes > 0);
    // mean message size should be small but nonzero (toy payloads)
    let mean = report.payload_bytes as f64 / report.messages as f64;
    assert!(mean > 4.0 && mean < 4096.0, "mean payload {mean}");
}

#[test]
fn weight_sync_zero_per_destination_copies_at_8_ranks() {
    // The trainer → replica weight sync exactly as training_host performs
    // it (hosts::sync_weights): one payload export charged as a single
    // ingest, then a refcount-only broadcast. Physical copy volume must be
    // flat in the destination count — zero copies *per destination*.
    use pal::comm::bus::{Src, World};
    use pal::comm::protocol::TAG_WEIGHTS;
    use pal::coordinator::hosts::sync_weights;

    const WEIGHT_LEN: usize = 1024;
    let mut copied_per_rank_count = Vec::new();
    for ranks in [2usize, 8] {
        let mut w = World::new(ranks + 1);
        let stats = w.stats();
        let mut eps = w.endpoints();
        let root = eps.remove(0);
        let dsts: Vec<usize> = (1..=ranks).collect();

        let mut trainer = SyntheticModel::new(4, 4, Duration::ZERO, Duration::ZERO, 1, Mode::Train)
            .with_weight_padding(WEIGHT_LEN);
        let weights: Vec<f32> = (0..WEIGHT_LEN).map(|i| (i % 97) as f32 * 0.01).collect();
        trainer.update(&weights);

        sync_weights(&root, &dsts, &trainer);

        // exactly one physical materialization for the whole fan-out —
        // zero per-destination copies — while logical traffic scales
        assert_eq!(stats.payload_clones(), 1, "one export ingest at {ranks} ranks");
        assert_eq!(stats.bytes_copied(), (WEIGHT_LEN * 4) as u64);
        assert_eq!(stats.payload_bytes(), (ranks * WEIGHT_LEN * 4) as u64);
        copied_per_rank_count.push(stats.bytes_copied());

        // every replica adopts the shared buffer bit-identically
        for e in eps.iter_mut() {
            let m = e
                .recv_timeout(Src::Rank(0), TAG_WEIGHTS, Duration::from_secs(1))
                .expect("weight sync delivered");
            let mut replica =
                SyntheticModel::new(4, 4, Duration::ZERO, Duration::ZERO, 1, Mode::Predict)
                    .with_weight_padding(WEIGHT_LEN);
            replica.update_from(&m.data);
            assert_eq!(replica.get_weight(), trainer.get_weight());
        }
    }
    assert_eq!(
        copied_per_rank_count[0], copied_per_rank_count[1],
        "physical weight-sync copies must not scale with the replica count"
    );
}
