//! §3.1 reproduction: the photodynamics latency measurement.
//!
//! Paper numbers (2 HoreKa CPU-GPU nodes): committee forward of 89
//! geometries = 51.5 ms per NN; MPI communication + trajectory propagation
//! = 4.27 ms; removing the oracle and training kernels does not change the
//! rate-limiting loop.
//!
//! This bench measures the same three quantities on the CPU-PJRT testbed:
//! (a) the 89-geometry committee forward per NN (photo1 artifacts),
//! (b) the exchange-loop remainder (gather + check + scatter + propagation),
//! (c) the ablation: full workflow vs oracle/training kernels disabled.
//!
//! Run: `cargo bench --bench sec31_latency`

use std::sync::Arc;
use std::time::Duration;

use pal::bench_util::{bench, Report, Row};
use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::generators::{MdGenerator, MdLayout};
use pal::kernels::models::{HloPotentialModel, TrainOptions};
use pal::kernels::oracles::{LatencyOracle, MultiStateOracle};
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::potential::{MultiState, Pes};
use pal::rng::Rng;
use pal::runtime::{default_artifacts_dir, Manifest};

const N_TRAJ: usize = 89;
const COMMITTEE: usize = 4;
const N_ATOMS: usize = 6;
const N_STATES: usize = 3;

fn run_workflow(with_oracle_training: bool, iters: u64) -> pal::telemetry::RunReport {
    let setting = AlSetting {
        result_dir: "/tmp/pal-bench-sec31".into(),
        gene_process: N_TRAJ,
        pred_process: COMMITTEE,
        ml_process: if with_oracle_training { COMMITTEE } else { 0 },
        orcl_process: if with_oracle_training { 4 } else { 0 },
        retrain_size: 8,
        stop: StopCriteria {
            max_iterations: Some(iters),
            max_labels: None,
            max_wall: Some(Duration::from_secs(120)),
            ..Default::default()
        },
        ..Default::default()
    };
    let layout = MdLayout { n_atoms: N_ATOMS, n_globals: 1, n_states: N_STATES };
    let pes = MultiState::photo(N_ATOMS, N_STATES);
    let generators = (0..N_TRAJ)
        .map(|i| {
            let pes = pes.clone();
            Box::new(move || {
                let mut rng = Rng::new(i as u64);
                let x0 = pes.initial_geometry(&mut rng);
                Box::new(MdGenerator::new(layout, x0, i as u64)) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..setting.orcl_process)
        .map(|_| {
            let pes = pes.clone();
            Box::new(move || {
                Box::new(LatencyOracle::new(
                    MultiStateOracle::new(pes, 1),
                    Duration::from_millis(100),
                )) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let dir = default_artifacts_dir();
    let model = Arc::new(move |mode: Mode, replica: usize| {
        let manifest = Manifest::load(&dir).expect("artifacts");
        let opts = TrainOptions { epochs_per_round: 8, ..Default::default() };
        Box::new(
            HloPotentialModel::new(manifest, "photo1", mode, replica as u32, opts).unwrap(),
        ) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(0.1, 8)) as Box<dyn Utils>);
    Workflow::new(setting)
        .run(KernelSet { generators, oracles, model, utils })
        .unwrap()
}

fn main() {
    // ---- (a) isolated committee forward: 89 geometries per NN ----
    let dir = default_artifacts_dir();
    let manifest = Manifest::load(&dir).expect("run `make artifacts`");
    let mut model = HloPotentialModel::new(
        manifest,
        "photo1",
        Mode::Predict,
        0,
        TrainOptions::default(),
    )
    .unwrap();
    let pes = MultiState::photo(N_ATOMS, N_STATES);
    let mut rng = Rng::new(0);
    let rows: Vec<Vec<f32>> = (0..N_TRAJ)
        .map(|_| {
            let mut row = pes.initial_geometry(&mut rng);
            row.push(0.0);
            row.extend_from_slice(&[1.0, 0.0, 0.0]);
            row
        })
        .collect();
    let fwd = bench(3, 30, || model.predict(&rows));

    let mut rep = Report::new("§3.1 — photodynamics latency breakdown (89 geometries, 4-NN committee)");
    rep.push(
        Row::new("committee forward per NN")
            .ms("mean", fwd.mean())
            .ms("p50", fwd.percentile(50.0))
            .ms("p99", fwd.percentile(99.0))
            .field("paper_ms", "51.5 (A100 node)"),
    );

    // ---- (b)+(c) full loop vs ablated loop ----
    let full = run_workflow(true, 30);
    let ablated = run_workflow(false, 30);
    for (name, r) in [("full workflow", &full), ("no oracle/training kernels", &ablated)] {
        let comm = r.mean_timer_ms("exchange", "gather_gen")
            + r.mean_timer_ms("exchange", "bcast_pred")
            + r.mean_timer_ms("exchange", "scatter_gene")
            + r.mean_timer_ms("exchange", "prediction_check");
        rep.push(
            Row::new(name)
                .f("pred_ms_per_NN", r.mean_timer_ms("prediction", "predict"))
                .f("comm+check_ms", comm)
                .f("gen_ms_per_step", r.mean_timer_ms("generator", "generate"))
                .field("iterations", r.al_iterations),
        );
    }
    rep.print();
    let f = full.mean_timer_ms("prediction", "predict");
    let a = ablated.mean_timer_ms("prediction", "predict");
    println!(
        "ablation check (paper: 'removing the oracle and training kernels does not\n\
         affect this result'): full {f:.2} ms vs ablated {a:.2} ms per NN (ratio {:.3})",
        f / a.max(1e-9)
    );
}
