//! Scaling sweep (abstract claim: "significant speed-ups through
//! asynchronous parallelization"): labeling throughput and makespan vs the
//! number of parallel oracle workers P, at fixed oracle cost.
//!
//! Run: `cargo bench --bench scaling`

use std::sync::Arc;
use std::time::Duration;

use pal::bench_util::{Report, Row};
use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::SelectAllUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::sim::workload::{SyntheticGenerator, SyntheticModel, SyntheticOracle};

const LABELS: u64 = 48;
const ORACLE_MS: u64 = 25;

fn run_p(p: usize) -> (Duration, u64) {
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-scaling".into(),
        gene_process: 8,
        pred_process: 2,
        ml_process: 2,
        orcl_process: p,
        retrain_size: 16,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(LABELS),
            max_wall: Some(Duration::from_secs(120)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..8usize)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(
                    4,
                    Duration::from_micros(200),
                    u64::MAX,
                    i as u64,
                )) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..p)
        .map(|_| {
            Box::new(|| {
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(ORACLE_MS),
                    out_dim: 4,
                }) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(|mode: Mode, _r: usize| {
        Box::new(SyntheticModel::new(
            4,
            4,
            Duration::ZERO,
            Duration::from_micros(300),
            16,
            mode,
        )) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(SelectAllUtils { max_per_iter: 8 }) as Box<dyn Utils>);
    let report = Workflow::new(s)
        .run(KernelSet { generators, oracles, model, utils })
        .unwrap();
    (report.wall, report.oracle_labels)
}

fn main() {
    let mut rep = Report::new(&format!(
        "Scaling — {LABELS} labels at {ORACLE_MS} ms/label vs oracle workers P"
    ));
    let mut t1 = None;
    for p in [1usize, 2, 4, 8, 16] {
        let (wall, labels) = run_p(p);
        let thpt = labels as f64 / wall.as_secs_f64();
        let t1v = *t1.get_or_insert(wall.as_secs_f64());
        rep.push(
            Row::new(format!("P={p}"))
                .ms("makespan", wall)
                .f("labels_per_s", thpt)
                .f("speedup_vs_P1", t1v / wall.as_secs_f64())
                .f("ideal", p as f64),
        );
    }
    rep.print();
    println!("(sub-linear tail expected once labeling stops being the bottleneck)");
}
