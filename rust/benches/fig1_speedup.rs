//! Fig. 1 reproduction: serial (1a) vs parallel (1b) active learning on the
//! same kernels and costs. Reports wall time per AL "unit of work" (one
//! round of generate → select → label N samples → train) and the measured
//! speedup, across three bottleneck regimes.
//!
//! Run: `cargo bench --bench fig1_speedup`

use std::sync::Arc;
use std::time::Duration;

use pal::bench_util::{Report, Row};
use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::SelectAllUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::serial::SerialWorkflow;
use pal::sim::speedup::Workload;
use pal::sim::workload::{SyntheticGenerator, SyntheticModel, SyntheticOracle};

struct Regime {
    name: &'static str,
    oracle_ms: u64,
    epoch_us: u64,
    epochs: usize,
    gen_ms: u64,
}

const GENS: usize = 4;
const ORACLES: usize = 2;
const MODELS: usize = 2;
const ITERS: u64 = 6;

fn serial_run(r: &Regime) -> Duration {
    let mut w = SerialWorkflow {
        generators: (0..GENS)
            .map(|i| {
                Box::new(SyntheticGenerator::new(
                    4,
                    Duration::from_millis(r.gen_ms),
                    u64::MAX,
                    i as u64,
                )) as Box<dyn Generator>
            })
            .collect(),
        oracles: (0..ORACLES)
            .map(|_| {
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(r.oracle_ms),
                    out_dim: 4,
                }) as Box<dyn Oracle>
            })
            .collect(),
        models: (0..MODELS)
            .map(|_| {
                Box::new(SyntheticModel::new(
                    4,
                    4,
                    Duration::ZERO,
                    Duration::from_micros(r.epoch_us),
                    r.epochs,
                    Mode::Train,
                )) as Box<dyn Model>
            })
            .collect(),
        utils: Box::new(SelectAllUtils { max_per_iter: GENS }),
        steps_per_iter: 1,
        iterations: ITERS,
    };
    w.run().wall
}

fn parallel_run(r: &Regime) -> Duration {
    let labels = ITERS * GENS as u64;
    // equal work: the serial baseline trains r.epochs per iteration per
    // model; require the same total epochs before stopping
    let min_epochs = ITERS * r.epochs as u64 * MODELS as u64;
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-fig1".into(),
        gene_process: GENS,
        pred_process: MODELS,
        ml_process: MODELS,
        orcl_process: ORACLES,
        retrain_size: GENS,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(labels),
            min_train_epochs: min_epochs,
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };
    let oracle_ms = r.oracle_ms;
    let (epoch_us, epochs, gen_ms) = (r.epoch_us, r.epochs, r.gen_ms);
    let generators = (0..GENS)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(
                    4,
                    Duration::from_millis(gen_ms),
                    u64::MAX,
                    i as u64,
                )) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..ORACLES)
        .map(|_| {
            Box::new(move || {
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(oracle_ms),
                    out_dim: 4,
                }) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _r: usize| {
        Box::new(SyntheticModel::new(
            4,
            4,
            Duration::ZERO,
            Duration::from_micros(epoch_us),
            epochs,
            mode,
        )) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(SelectAllUtils { max_per_iter: GENS }) as Box<dyn Utils>);
    let report = Workflow::new(s)
        .run(KernelSet { generators, oracles, model, utils })
        .unwrap();
    report.wall
}

fn main() {
    let regimes = [
        Regime { name: "oracle-bound (DFT-like)", oracle_ms: 40, epoch_us: 500, epochs: 8, gen_ms: 1 },
        Regime { name: "train-bound (xTB-like)", oracle_ms: 2, epoch_us: 2_000, epochs: 24, gen_ms: 1 },
        Regime { name: "balanced (CFD-like)", oracle_ms: 20, epoch_us: 1_200, epochs: 16, gen_ms: 8 },
    ];
    let mut rep = Report::new(
        "Fig. 1 — serial vs parallel AL wall time (same kernels, same label budget)",
    );
    for r in &regimes {
        let ts = serial_run(r);
        let tp = parallel_run(r);
        // analytic lower bound from the SI §S2 model
        let w = Workload {
            t_oracle: r.oracle_ms as f64 / 1e3,
            t_train: (r.epoch_us as f64 * r.epochs as f64) / 1e6,
            t_gen: r.gen_ms as f64 / 1e3,
            n_samples: GENS as u64,
            p_workers: ORACLES as u64,
        };
        rep.push(
            Row::new(r.name)
                .ms("serial", ts)
                .ms("parallel", tp)
                .f("speedup", ts.as_secs_f64() / tp.as_secs_f64())
                .f("analytic_lower_bound", w.speedup()),
        );
    }
    rep.print();
    println!("(paper claim: the parallel workflow overlaps labeling/training/generation;");
    println!(" speedup >= 1 everywhere, largest where no single kernel dominates)");
}
