//! Fig. 1 reproduction: serial (1a) vs parallel (1b) active learning on the
//! same kernels and costs. Reports wall time per AL "unit of work" (one
//! round of generate → select → label N samples → train) and the measured
//! speedup, across three bottleneck regimes.
//!
//! Run: `cargo bench --bench fig1_speedup`
//!
//! Results are also written machine-readable to `BENCH_speedup.json` so the
//! perf trajectory is tracked across PRs.

use std::sync::Arc;
use std::time::Duration;

use pal::bench_util::{Report, Row};
use pal::json::{obj, Value};
use pal::config::{AlSetting, BatchSetting, ExchangeMode, StopCriteria};
use pal::coordinator::selection::SelectAllUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::serial::SerialWorkflow;
use pal::sim::speedup::Workload;
use pal::sim::workload::{SyntheticGenerator, SyntheticModel, SyntheticOracle};

struct Regime {
    name: &'static str,
    oracle_ms: u64,
    epoch_us: u64,
    epochs: usize,
    gen_ms: u64,
}

const GENS: usize = 4;
const ORACLES: usize = 2;
const MODELS: usize = 2;
const ITERS: u64 = 6;

fn serial_run(r: &Regime) -> Duration {
    let mut w = SerialWorkflow {
        generators: (0..GENS)
            .map(|i| {
                Box::new(SyntheticGenerator::new(
                    4,
                    Duration::from_millis(r.gen_ms),
                    u64::MAX,
                    i as u64,
                )) as Box<dyn Generator>
            })
            .collect(),
        oracles: (0..ORACLES)
            .map(|_| {
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(r.oracle_ms),
                    out_dim: 4,
                }) as Box<dyn Oracle>
            })
            .collect(),
        models: (0..MODELS)
            .map(|_| {
                Box::new(SyntheticModel::new(
                    4,
                    4,
                    Duration::ZERO,
                    Duration::from_micros(r.epoch_us),
                    r.epochs,
                    Mode::Train,
                )) as Box<dyn Model>
            })
            .collect(),
        utils: Box::new(SelectAllUtils { max_per_iter: GENS }),
        steps_per_iter: 1,
        iterations: ITERS,
    };
    w.run().wall
}

fn parallel_run(r: &Regime) -> pal::telemetry::RunReport {
    let labels = ITERS * GENS as u64;
    // equal work: the serial baseline trains r.epochs per iteration per
    // model; require the same total epochs before stopping
    let min_epochs = ITERS * r.epochs as u64 * MODELS as u64;
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-fig1".into(),
        gene_process: GENS,
        pred_process: MODELS,
        ml_process: MODELS,
        orcl_process: ORACLES,
        retrain_size: GENS,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(labels),
            min_train_epochs: min_epochs,
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };
    let oracle_ms = r.oracle_ms;
    let (epoch_us, epochs, gen_ms) = (r.epoch_us, r.epochs, r.gen_ms);
    let generators = (0..GENS)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(
                    4,
                    Duration::from_millis(gen_ms),
                    u64::MAX,
                    i as u64,
                )) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..ORACLES)
        .map(|_| {
            Box::new(move || {
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(oracle_ms),
                    out_dim: 4,
                }) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _r: usize| {
        Box::new(SyntheticModel::new(
            4,
            4,
            Duration::ZERO,
            Duration::from_micros(epoch_us),
            epochs,
            mode,
        )) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(SelectAllUtils { max_per_iter: GENS }) as Box<dyn Utils>);
    let report = Workflow::new(s)
        .run(KernelSet { generators, oracles, model, utils })
        .unwrap();
    report
}

// ---------------------------------------------------------------------------
// Prediction-rank scaling: lockstep vs batched/sharded exchange
// ---------------------------------------------------------------------------

const SCALE_GENS: usize = 8;
/// Inference cost model: a 1 ms launch overhead + 1 ms per stacked item —
/// the regime the paper's §3.1 committee forward (tens of ms) lives in.
const PRED_BASE_MS: u64 = 1;
const PRED_PER_ITEM_MS: u64 = 1;

fn scaling_model(mode: Mode) -> Box<dyn Model> {
    Box::new(
        SyntheticModel::new(
            4,
            4,
            Duration::from_millis(PRED_BASE_MS),
            Duration::ZERO,
            1,
            mode,
        )
        .with_per_item_cost(Duration::from_millis(PRED_PER_ITEM_MS)),
    ) as Box<dyn Model>
}

fn scaling_kernels(s: &AlSetting) -> KernelSet {
    let generators = (0..s.gene_process)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(4, Duration::ZERO, u64::MAX, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _m: usize| scaling_model(mode));
    // no selection: this section isolates inference routing
    let utils = Arc::new(|| Box::new(SelectAllUtils { max_per_iter: 0 }) as Box<dyn Utils>);
    KernelSet {
        generators,
        oracles: Vec::<Box<dyn FnOnce() -> Box<dyn Oracle> + Send>>::new(),
        model,
        utils,
    }
}

/// Lockstep: every prediction rank evaluates every generator input each
/// round — adding ranks adds committee members, not throughput.
fn lockstep_items_per_s(preds: usize, rounds: u64) -> f64 {
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-scale-lockstep".into(),
        gene_process: SCALE_GENS,
        pred_process: preds,
        ml_process: 0,
        orcl_process: 0,
        stop: StopCriteria {
            max_iterations: Some(rounds),
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };
    let kernels = scaling_kernels(&s);
    let report = Workflow::new(s).run(kernels).unwrap();
    (report.al_iterations * SCALE_GENS as u64) as f64 / report.wall.as_secs_f64()
}

/// Batched: 2-member committee shards serve single-item batches
/// concurrently — adding ranks adds shards, i.e. throughput.
fn batched_items_per_s(preds: usize, batches: u64) -> f64 {
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-scale-batched".into(),
        gene_process: SCALE_GENS,
        pred_process: preds,
        ml_process: 0,
        orcl_process: 0,
        committee_size: Some(2),
        exchange_mode: ExchangeMode::Batched,
        batch: BatchSetting {
            max_size: 1,
            max_delay: Duration::from_millis(1),
            max_outstanding: 1,
        },
        stop: StopCriteria {
            max_iterations: Some(batches),
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };
    let kernels = scaling_kernels(&s);
    let report = Workflow::new(s).run(kernels).unwrap();
    let items = report.sum_counter("exchange", "batch_items").max(1);
    items as f64 / report.wall.as_secs_f64()
}

fn main() {
    let regimes = [
        Regime { name: "oracle-bound (DFT-like)", oracle_ms: 40, epoch_us: 500, epochs: 8, gen_ms: 1 },
        Regime { name: "train-bound (xTB-like)", oracle_ms: 2, epoch_us: 2_000, epochs: 24, gen_ms: 1 },
        Regime { name: "balanced (CFD-like)", oracle_ms: 20, epoch_us: 1_200, epochs: 16, gen_ms: 8 },
    ];
    let mut rep = Report::new(
        "Fig. 1 — serial vs parallel AL wall time (same kernels, same label budget)",
    );
    let mut regime_rows = Vec::new();
    for r in &regimes {
        let ts = serial_run(r);
        let preport = parallel_run(r);
        let tp = preport.wall;
        // analytic lower bound from the SI §S2 model
        let w = Workload {
            t_oracle: r.oracle_ms as f64 / 1e3,
            t_train: (r.epoch_us as f64 * r.epochs as f64) / 1e6,
            t_gen: r.gen_ms as f64 / 1e3,
            n_samples: GENS as u64,
            p_workers: ORACLES as u64,
        };
        rep.push(
            Row::new(r.name)
                .ms("serial", ts)
                .ms("parallel", tp)
                .f("speedup", ts.as_secs_f64() / tp.as_secs_f64())
                .f("analytic_lower_bound", w.speedup()),
        );
        regime_rows.push(obj(vec![
            ("regime", Value::Str(r.name.into())),
            ("serial_s", Value::Num(ts.as_secs_f64())),
            ("parallel_s", Value::Num(tp.as_secs_f64())),
            ("speedup", Value::Num(ts.as_secs_f64() / tp.as_secs_f64())),
            ("analytic_lower_bound", Value::Num(w.speedup())),
            ("messages", Value::Num(preport.messages as f64)),
            ("payload_bytes", Value::Num(preport.payload_bytes as f64)),
            ("bytes_copied", Value::Num(preport.bytes_copied as f64)),
        ]));
    }
    rep.print();
    println!("(paper claim: the parallel workflow overlaps labeling/training/generation;");
    println!(" speedup >= 1 everywhere, largest where no single kernel dominates)");

    // ---- prediction-rank scaling: lockstep vs batched/sharded exchange ----
    let mut rep2 = Report::new(
        "prediction scaling — items/s at 2/4/8 prediction ranks (8 generators, \
         1 ms + 1 ms/item inference)",
    );
    let mut first_batched = None;
    let mut scaling_rows = Vec::new();
    for preds in [2usize, 4, 8] {
        let lockstep = lockstep_items_per_s(preds, 40);
        let batched = batched_items_per_s(preds, 320);
        let base = *first_batched.get_or_insert(batched);
        rep2.push(
            Row::new(format!("pred={preds}"))
                .f("lockstep_items_per_s", lockstep)
                .f("batched_items_per_s", batched)
                .f("batched_scaling_vs_pred2", batched / base),
        );
        scaling_rows.push(obj(vec![
            ("pred_ranks", Value::Num(preds as f64)),
            ("lockstep_items_per_s", Value::Num(lockstep)),
            ("batched_items_per_s", Value::Num(batched)),
            ("batched_scaling_vs_pred2", Value::Num(batched / base)),
        ]));
    }
    rep2.print();
    println!("(lockstep broadcasts every input to every rank: throughput is flat in P;");
    println!(" the batched exchange routes batches across P/2 committee shards and scales)");

    let out = pal::json::to_string(&obj(vec![
        ("bench", Value::Str("fig1_speedup".into())),
        ("regimes", Value::Array(regime_rows)),
        ("prediction_scaling", Value::Array(scaling_rows)),
    ]));
    match std::fs::write("BENCH_speedup.json", &out) {
        Ok(()) => println!("\nwrote BENCH_speedup.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_speedup.json: {e}"),
    }
}
