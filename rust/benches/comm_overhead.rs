//! §4 "Communication bottleneck" reproduction: when prediction latency
//! drops below ~10 ms, generator↔prediction communication bounds the
//! exploration rate; variable-size messages add overhead (the paper's
//! `fixed_size_data=False` costs an extra size exchange per message).
//!
//! Measures: (a) raw bus throughput vs message size, (b) exchange-loop rate
//! vs simulated prediction latency, (c) fixed- vs variable-size message
//! cost (modeled as one extra header message per payload).
//!
//! Run: `cargo bench --bench comm_overhead`

use std::sync::Arc;
use std::time::Duration;

use pal::bench_util::{bench, Report, Row};
use pal::comm::bus::{Src, World};
use pal::config::{AlSetting, BatchSetting, ExchangeMode, StopCriteria};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::sim::workload::{SyntheticGenerator, SyntheticModel, SyntheticOracle};

fn bus_roundtrip(size: usize, pairs: usize) -> Duration {
    let mut w = World::new(2);
    let mut a = w.endpoint(0);
    let mut b = w.endpoint(1);
    let h = std::thread::spawn(move || {
        for _ in 0..pairs {
            let m = b.recv_timeout(Src::Rank(0), 1, Duration::from_secs(10)).unwrap();
            b.send(0, 2, m.data);
        }
    });
    let payload = vec![0.5f32; size];
    let t0 = std::time::Instant::now();
    for _ in 0..pairs {
        a.send(1, 1, payload.clone());
        a.recv_timeout(Src::Rank(1), 2, Duration::from_secs(10)).unwrap();
    }
    let dt = t0.elapsed();
    h.join().unwrap();
    dt / pairs as u32
}

fn exchange_rate(pred_ms: u64, iters: u64, extra_size_msg: bool) -> f64 {
    // extra_size_msg models fixed_size_data=False: each generator payload is
    // preceded by a 1-f32 "size" message, doubling message count on the red
    // flow (the paper's "additional communications ... thus lower efficiency")
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-comm".into(),
        gene_process: 8,
        pred_process: 2,
        ml_process: 0,
        orcl_process: 0,
        fixed_size_data: !extra_size_msg,
        stop: StopCriteria {
            max_iterations: Some(iters),
            max_labels: None,
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..8usize)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(64, Duration::ZERO, u64::MAX, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _r: usize| {
        Box::new(SyntheticModel::new(
            64,
            64,
            Duration::from_millis(pred_ms),
            Duration::ZERO,
            1,
            mode,
        )) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(f32::MAX, 0)) as Box<dyn Utils>);
    let report = Workflow::new(s)
        .run(KernelSet {
            generators,
            oracles: Vec::<Box<dyn FnOnce() -> Box<dyn Oracle> + Send>>::new(),
            model,
            utils,
        })
        .unwrap();
    report.al_iterations as f64 / report.wall.as_secs_f64()
}

/// Run the batched exchange inference-only at one micro-batch size and
/// report `(total bus messages, items served, wall seconds)`.
///
/// `batch_size = 1` is the one-request-at-a-time relay; larger sizes
/// coalesce. The topology is fixed (16 generators, one 2-member committee
/// shard) so the message delta is purely the coalescing win.
fn batched_messages(batch_size: usize, total_items: u64) -> (u64, u64, f64) {
    const GENS: usize = 16;
    let per_batch = batch_size.min(GENS) as u64;
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-batch".into(),
        gene_process: GENS,
        pred_process: 2,
        ml_process: 0,
        orcl_process: 0,
        committee_size: Some(2),
        exchange_mode: ExchangeMode::Batched,
        batch: BatchSetting {
            max_size: batch_size,
            // long deadline: batches fill to max_size, so each row isolates
            // one coalescing factor
            max_delay: Duration::from_millis(250),
            max_outstanding: 2,
        },
        stop: StopCriteria {
            max_iterations: Some(total_items / per_batch),
            max_labels: None,
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..GENS)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(64, Duration::ZERO, u64::MAX, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _m: usize| {
        Box::new(SyntheticModel::new(64, 64, Duration::ZERO, Duration::ZERO, 1, mode))
            as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(f32::MAX, 0)) as Box<dyn Utils>);
    let report = Workflow::new(s)
        .run(KernelSet {
            generators,
            oracles: Vec::<Box<dyn FnOnce() -> Box<dyn Oracle> + Send>>::new(),
            model,
            utils,
        })
        .unwrap();
    let items = report.sum_counter("exchange", "batch_items").max(1);
    (report.messages, items, report.wall.as_secs_f64())
}

fn main() {
    // ---- (a) raw bus round-trip vs payload size ----
    let mut rep = Report::new("comm bus — round-trip latency vs payload (1-D f32 arrays)");
    for size in [4usize, 64, 1024, 16 * 1024, 256 * 1024] {
        let rt = bench(1, 5, || bus_roundtrip(size, 200)).mean();
        rep.push(
            Row::new(format!("{size} f32"))
                .ms("roundtrip", rt)
                .f("MB_per_s", (size as f64 * 4.0 * 2.0) / rt.as_secs_f64() / 1e6),
        );
    }
    rep.print();

    // ---- (b) exchange-loop rate vs prediction latency (§4 claim) ----
    let mut rep2 = Report::new("§4 — exploration rate vs prediction latency (8 generators)");
    for pred_ms in [0u64, 1, 5, 10, 50] {
        let rate = exchange_rate(pred_ms, 60, false);
        rep2.push(
            Row::new(format!("pred={pred_ms}ms"))
                .f("iters_per_s", rate)
                .f("pred_bound_iters_per_s", if pred_ms == 0 { f64::NAN } else { 1000.0 / pred_ms as f64 }),
        );
    }
    rep2.print();
    println!("(paper: below ~10 ms inference the communication becomes the bottleneck —");
    println!(" visible here as iters/s flattening away from the prediction-bound line)");

    // ---- (c) fixed vs variable message sizes ----
    let fixed = exchange_rate(1, 80, false);
    let varsize = exchange_rate(1, 80, true);
    let mut rep3 = Report::new("§4 — fixed_size_data=True vs False (modeled size-header cost)");
    rep3.push(Row::new("fixed").f("iters_per_s", fixed));
    rep3.push(Row::new("variable").f("iters_per_s", varsize).f("overhead_pct", (fixed / varsize - 1.0) * 100.0));
    rep3.print();

    // ---- (d) batched exchange: bus messages per AL iteration vs batch size ----
    // One AL iteration = one step of every generator (16 items). batch=1 is
    // the unbatched one-request-at-a-time relay; coalescing amortizes the
    // controller↔predictor frames across the batch.
    const GENS_D: f64 = 16.0;
    let total_items = 320u64;
    let mut rep4 = Report::new(
        "batched exchange — bus messages per AL iteration (16 gens, 2-member shard)",
    );
    let mut per_iter_at = std::collections::BTreeMap::new();
    for batch in [1usize, 2, 4, 8, 16] {
        let (messages, items, wall) = batched_messages(batch, total_items);
        let al_iters = items as f64 / GENS_D;
        let per_iter = messages as f64 / al_iters;
        per_iter_at.insert(batch, per_iter);
        rep4.push(
            Row::new(format!("batch={batch}"))
                .f("msgs_per_al_iter", per_iter)
                .f("msgs_per_item", messages as f64 / items as f64)
                .f("items_per_s", items as f64 / wall),
        );
    }
    rep4.print();
    let reduction = per_iter_at[&1] / per_iter_at[&8];
    println!(
        "(batch=8 sends {reduction:.2}x fewer bus messages per AL iteration than the \
         unbatched relay{})",
        if reduction >= 2.0 { " — >= 2x target met" } else { " — BELOW the 2x target" }
    );
}
