//! §4 "Communication bottleneck" reproduction: when prediction latency
//! drops below ~10 ms, generator↔prediction communication bounds the
//! exploration rate; variable-size messages add overhead (the paper's
//! `fixed_size_data=False` costs an extra size exchange per message).
//!
//! Measures: (a) raw bus throughput vs message size, (b) exchange-loop rate
//! vs simulated prediction latency, (c) fixed- vs variable-size message
//! cost (modeled as one extra header message per payload), (d) batched
//! exchange message coalescing, (e) weight-broadcast physical copy cost:
//! shared `Payload` fan-out vs the per-destination clone it replaced,
//! (f) allocations per item on the decode→reduce path, (g) flat training
//! plane flush/weight-sync copy volume, (h) oracle-plane green-flow
//! messages per labeled sample, batched vs per-label (`BENCH_oracle.json`),
//! (i) adaptive vs static oracle routing under a heterogeneous-latency
//! pool (`BENCH_sched.json`), (j) fault recovery — one oracle killed at
//! ~50% of the label budget vs a clean run, time-to-evict and the
//! recovery wall-clock ratio (`BENCH_fault.json`, gated at 2x),
//! (k) memory plane — labels-only oracle-result bytes per label vs the
//! legacy interleaved frame (gated at 1.8x), device-resident weight-cache
//! upload bytes on repeat calls (gated at zero), and minibatch gather
//! allocations vs rolling-window size (gated flat; `BENCH_mem.json`),
//! (l) transport plane — fan-in messages/sec over the pluggable backends
//! at 8 ranks: the lock-free `shm` rings vs the default `channel` bus
//! (gated at 1.5x for small payloads) plus the `tcp` loopback rate and
//! its serialization copy volume (`BENCH_transport.json`),
//! (m) observability plane — the live metrics registry's cost: the
//! section-(i) adaptive labeling run with the registry enabled vs
//! disabled (gated at <= 2% wall overhead) and the disabled publish hot
//! path under the counting allocator (gated allocation-free;
//! `BENCH_obs.json`).
//!
//! Run: `cargo bench --bench comm_overhead`
//! (append `-- sched-only` for just the scheduler comparison,
//! `-- fault-only` for just the fault-recovery gate, `-- mem-only`
//! for just the memory-plane gates, `-- transport-only` for just the
//! transport-plane gate, or `-- obs-only` for just the observability
//! gates)
//!
//! Results are also written machine-readable to `BENCH_comm.json` so the
//! perf trajectory is tracked across PRs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use pal::bench_util::alloc::{alloc_count, CountingAlloc};
use pal::bench_util::{bench, black_box, Report, Row};
use pal::comm::bus::{Endpoint, Payload, Src, World};
use pal::comm::protocol::{
    decode_predict_batch_result, decode_predict_batch_result_rows, encode_oracle_batch_result_into,
    encode_oracle_labels_into, encode_predict_batch_result,
};
use pal::comm::transport::tcp::Bootstrap;
use pal::comm::{FaultPlan, TransportKind};
use pal::config::{
    AlSetting, BatchSetting, ExchangeMode, OracleMode, SchedPolicy, SchedSetting, StopCriteria,
    Topology,
};
use pal::coordinator::selection::{
    committee_std_check, committee_std_check_batch, CommitteeStdUtils, SelectAllUtils,
};
use pal::coordinator::workflow::Workflow;
use pal::data::batch::{Batch, BatchView, RowBlock};
use pal::data::Dataset;
use pal::json::{obj, Value};
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::runtime::UploadCache;
use pal::sim::workload::{SyntheticGenerator, SyntheticModel, SyntheticOracle};
use pal::telemetry::registry::{registry, Counter as ObsCounter, Gauge as ObsGauge};

// Counting allocator: only the allocations-per-item section reads the
// counters; the passthrough costs the other sections nothing measurable.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn bus_roundtrip(size: usize, pairs: usize) -> Duration {
    let mut w = World::new(2);
    let mut a = w.endpoint(0);
    let mut b = w.endpoint(1);
    let h = std::thread::spawn(move || {
        for _ in 0..pairs {
            let m = b.recv_timeout(Src::Rank(0), 1, Duration::from_secs(10)).unwrap();
            // echo is a zero-copy relay: re-sending the shared payload
            b.send(0, 2, m.data);
        }
    });
    let payload = vec![0.5f32; size];
    let t0 = std::time::Instant::now();
    for _ in 0..pairs {
        a.send(1, 1, payload.clone());
        a.recv_timeout(Src::Rank(1), 2, Duration::from_secs(10)).unwrap();
    }
    let dt = t0.elapsed();
    h.join().unwrap();
    dt / pairs as u32
}

fn exchange_rate(pred_ms: u64, iters: u64, extra_size_msg: bool) -> f64 {
    // extra_size_msg models fixed_size_data=False: each generator payload is
    // preceded by a 1-f32 "size" message, doubling message count on the red
    // flow (the paper's "additional communications ... thus lower efficiency")
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-comm".into(),
        gene_process: 8,
        pred_process: 2,
        ml_process: 0,
        orcl_process: 0,
        fixed_size_data: !extra_size_msg,
        stop: StopCriteria {
            max_iterations: Some(iters),
            max_labels: None,
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..8usize)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(64, Duration::ZERO, u64::MAX, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _r: usize| {
        Box::new(SyntheticModel::new(
            64,
            64,
            Duration::from_millis(pred_ms),
            Duration::ZERO,
            1,
            mode,
        )) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(f32::MAX, 0)) as Box<dyn Utils>);
    let report = Workflow::new(s)
        .run(KernelSet {
            generators,
            oracles: Vec::<Box<dyn FnOnce() -> Box<dyn Oracle> + Send>>::new(),
            model,
            utils,
        })
        .unwrap();
    report.al_iterations as f64 / report.wall.as_secs_f64()
}

/// One batched-exchange run: `(messages, items, wall_s, payload_bytes,
/// bytes_copied)`.
struct BatchedRun {
    messages: u64,
    items: u64,
    wall_s: f64,
    payload_bytes: u64,
    bytes_copied: u64,
}

/// Run the batched exchange inference-only at one micro-batch size.
///
/// `batch_size = 1` is the one-request-at-a-time relay; larger sizes
/// coalesce. The topology is fixed (16 generators, one 2-member committee
/// shard) so the message delta is purely the coalescing win.
fn batched_messages(batch_size: usize, total_items: u64) -> BatchedRun {
    const GENS: usize = 16;
    let per_batch = batch_size.min(GENS) as u64;
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-batch".into(),
        gene_process: GENS,
        pred_process: 2,
        ml_process: 0,
        orcl_process: 0,
        committee_size: Some(2),
        exchange_mode: ExchangeMode::Batched,
        batch: BatchSetting {
            max_size: batch_size,
            // long deadline: batches fill to max_size, so each row isolates
            // one coalescing factor
            max_delay: Duration::from_millis(250),
            max_outstanding: 2,
        },
        stop: StopCriteria {
            max_iterations: Some(total_items / per_batch),
            max_labels: None,
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..GENS)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(64, Duration::ZERO, u64::MAX, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _m: usize| {
        Box::new(SyntheticModel::new(64, 64, Duration::ZERO, Duration::ZERO, 1, mode))
            as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(f32::MAX, 0)) as Box<dyn Utils>);
    let report = Workflow::new(s)
        .run(KernelSet {
            generators,
            oracles: Vec::<Box<dyn FnOnce() -> Box<dyn Oracle> + Send>>::new(),
            model,
            utils,
        })
        .unwrap();
    BatchedRun {
        messages: report.messages,
        items: report.sum_counter("exchange", "batch_items").max(1),
        wall_s: report.wall.as_secs_f64(),
        payload_bytes: report.payload_bytes,
        bytes_copied: report.bytes_copied,
    }
}

/// Broadcast a `weight_len`-f32 vector to `ranks` destinations for `rounds`
/// rounds, either as one shared `Payload` per round (the trainer → replica
/// fan-out path) or as one materialized buffer per destination (the
/// per-destination clone the shared path replaced). Returns
/// `(bytes_copied, payload_bytes, payload_clones)` from the world stats.
fn weight_fanout(ranks: usize, weight_len: usize, rounds: usize, shared: bool) -> (u64, u64, u64) {
    let mut w = World::new(ranks + 1);
    let stats = w.stats();
    let mut eps = w.endpoints();
    let root = eps.remove(0);
    let dsts: Vec<usize> = (1..=ranks).collect();
    let handles: Vec<_> = eps
        .into_iter()
        .map(|mut e| {
            std::thread::spawn(move || {
                let mut got = 0usize;
                while got < rounds {
                    match e.recv_timeout(Src::Rank(0), 31, Duration::from_secs(10)) {
                        Ok(_) => got += 1,
                        Err(_) => break,
                    }
                }
            })
        })
        .collect();
    let weights = vec![0.5f32; weight_len];
    for _ in 0..rounds {
        if shared {
            // one ingest copy, then a refcount bump per destination
            root.bcast(&dsts, 31, weights.clone());
        } else {
            // old transport: one materialized buffer per destination
            for &d in &dsts {
                root.send(d, 31, weights.clone());
            }
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    (stats.bytes_copied(), stats.payload_bytes(), stats.payload_clones())
}

/// End-to-end twin of [`weight_fanout`]: a short batched workflow whose
/// trainers pad their weight vectors to `weight_len`
/// (`SyntheticModel::with_weight_padding`), so the trainer → replica weight
/// sync crosses the real transport. Returns `(payload_bytes, bytes_copied,
/// weight_updates)` — with shared payloads the copied fraction stays near
/// `1 / replicas_per_trainer` for the weight traffic.
fn weight_fanout_e2e(weight_len: usize) -> (u64, u64, u64) {
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-wfan".into(),
        gene_process: 4,
        pred_process: 8,
        ml_process: 2,
        orcl_process: 0,
        committee_size: Some(2),
        exchange_mode: ExchangeMode::Batched,
        batch: BatchSetting {
            max_size: 4,
            max_delay: Duration::from_millis(2),
            max_outstanding: 2,
        },
        stop: StopCriteria {
            max_iterations: Some(8),
            max_labels: None,
            max_wall: Some(Duration::from_secs(30)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..4usize)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(8, Duration::ZERO, u64::MAX, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _m: usize| {
        Box::new(
            SyntheticModel::new(8, 8, Duration::ZERO, Duration::ZERO, 1, mode)
                .with_weight_padding(weight_len),
        ) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(f32::MAX, 0)) as Box<dyn Utils>);
    let report = Workflow::new(s)
        .run(KernelSet {
            generators,
            oracles: Vec::<Box<dyn FnOnce() -> Box<dyn Oracle> + Send>>::new(),
            model,
            utils,
        })
        .unwrap();
    (
        report.payload_bytes,
        report.bytes_copied,
        report.sum_counter("prediction", "weight_updates"),
    )
}

/// Train-flush fan-out: broadcast one flush of `points` labeled datapoints
/// to `trainers` ranks, either as one shared payload (the Manager's path)
/// or as one materialized buffer per destination (the pattern it
/// replaced). Returns `bytes_copied` from the world stats.
fn train_flush_copies(trainers: usize, points: usize, width: usize, shared: bool) -> u64 {
    use pal::comm::codec::PackBuffer;
    use pal::data::batch::DatapointBlock;
    let mut w = World::new(trainers + 1);
    let stats = w.stats();
    let mut eps = w.endpoints();
    let root = eps.remove(0);
    let dsts: Vec<usize> = (1..=trainers).collect();
    let mut block = DatapointBlock::with_capacity(points, points * width, points * 2);
    for i in 0..points {
        let x: Vec<f32> = (0..width).map(|k| ((i * 7 + k) % 13) as f32 * 0.1).collect();
        block.push(&x, &[i as f32, 0.5]);
    }
    let mut pack = PackBuffer::new();
    let frame = pack.pack_train_block(&block).to_vec();
    if shared {
        // one ingest for the whole trainer fan-out
        root.bcast(&dsts, 30, frame);
    } else {
        // old pattern: one materialized buffer per destination
        for &d in &dsts {
            root.send(d, 30, frame.clone());
        }
    }
    stats.bytes_copied()
}

/// Weight sync over `rounds` rounds at `ranks` replicas: payload-cached
/// (materialize shared storage once, then refcount-only broadcasts) vs
/// owned-Vec export every round (one ingest per round). Returns
/// `(bytes_copied, payload_clones)`.
fn weight_sync_rounds(ranks: usize, len: usize, rounds: usize, cached: bool) -> (u64, u64) {
    use pal::comm::bus::Payload;
    let mut w = World::new(ranks + 1);
    let stats = w.stats();
    let mut eps = w.endpoints();
    let root = eps.remove(0);
    let dsts: Vec<usize> = (1..=ranks).collect();
    let weights = vec![0.5f32; len];
    if cached {
        // Model::get_weight_payload: one materialization, re-exported by
        // refcount while the weights are unchanged
        let payload = Payload::from(weights);
        root.note_ingest(payload.len());
        for _ in 0..rounds {
            root.bcast(&dsts, 31, &payload);
        }
    } else {
        // legacy Model::get_weight: a fresh owned export every round
        for _ in 0..rounds {
            root.bcast(&dsts, 31, weights.clone());
        }
    }
    (stats.bytes_copied(), stats.payload_clones())
}

/// Allocations per predicted item on the decode → committee-reduce hot
/// path, nested-Vec baseline vs the flat `BatchView` plane. Returns
/// `(allocs_per_item_nested, allocs_per_item_flat)`.
fn alloc_per_item(batch: usize, models: usize, width: usize, iters: u64) -> (f64, f64) {
    // pre-encode one committee round: per-member result frames + inputs
    let frames: Vec<Vec<f32>> = (0..models)
        .map(|m| {
            let items: Vec<Vec<f32>> = (0..batch)
                .map(|i| (0..width).map(|k| ((m * 31 + i * 7 + k) % 17) as f32 * 0.1).collect())
                .collect();
            encode_predict_batch_result(1, &items)
        })
        .collect();
    let inputs: Vec<Vec<f32>> = (0..batch).map(|i| vec![i as f32; 8]).collect();
    let input_batch = Batch::from_rows(&inputs).unwrap();
    let items_total = (iters * batch as u64) as f64;

    // nested baseline: owned row lists all the way down
    let before = alloc_count();
    for _ in 0..iters {
        let preds: Vec<Vec<Vec<f32>>> = frames
            .iter()
            .map(|f| decode_predict_batch_result(f).unwrap().1)
            .collect();
        black_box(committee_std_check(&inputs, &preds, 0.5, 8));
    }
    let nested = (alloc_count() - before) as f64 / items_total;

    // flat plane: strided views over the frames, contiguous outputs
    let before = alloc_count();
    for _ in 0..iters {
        let views: Vec<BatchView<'_>> = frames
            .iter()
            .map(|f| decode_predict_batch_result_rows(f).unwrap().1)
            .collect();
        black_box(committee_std_check_batch(&input_batch.view(), &views, 0.5, 8));
    }
    let flat = (alloc_count() - before) as f64 / items_total;
    (nested, flat)
}

/// One green-flow run: `(green_msgs, labels, bytes_copied, wall_s)`.
struct OracleRun {
    green_msgs: u64,
    labels: u64,
    bytes_copied: u64,
    wall_s: f64,
}

/// End-to-end workflow with 4 oracles, per-label vs batched oracle
/// dispatch. Green-flow messages are counted from telemetry: dispatch
/// frames (`dispatched` items per message in per-label mode,
/// `oracle_batches` frames in batched mode) plus result frames (one per
/// label in per-label mode, one per batch in batched mode). Everything
/// else — selection traffic, prediction relay — is identical between the
/// two runs by construction.
fn oracle_messages(mode: OracleMode, labels: u64) -> OracleRun {
    const GENS: usize = 8;
    const ORACLES: usize = 4;
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-oracle".into(),
        gene_process: GENS,
        pred_process: 2,
        ml_process: 0,
        orcl_process: ORACLES,
        committee_size: Some(2),
        exchange_mode: ExchangeMode::Batched,
        batch: BatchSetting {
            max_size: GENS,
            max_delay: Duration::from_millis(2),
            max_outstanding: 2,
        },
        oracle_mode: mode,
        oracle_batch: BatchSetting {
            max_size: 8,
            max_delay: Duration::from_millis(2),
            max_outstanding: 4,
        },
        strict_label_budget: true,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(labels),
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..GENS)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(16, Duration::ZERO, u64::MAX, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..ORACLES)
        .map(|_| {
            Box::new(|| {
                Box::new(SyntheticOracle { label_cost: Duration::ZERO, out_dim: 2 })
                    as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _m: usize| {
        Box::new(SyntheticModel::new(16, 16, Duration::ZERO, Duration::ZERO, 1, mode))
            as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(SelectAllUtils { max_per_iter: GENS }) as Box<dyn Utils>);
    let report = Workflow::new(s)
        .run(KernelSet { generators, oracles, model, utils })
        .unwrap();
    let manager = &report.kernel("manager")[0];
    let got_labels = report.oracle_labels.max(1);
    let green_msgs = match mode {
        // one message per dispatched input + one per label back
        OracleMode::PerLabel => {
            manager.counter("dispatched") + report.sum_counter("oracle", "labels")
        }
        // one frame per batch out + one result frame per batch back
        OracleMode::Batched => {
            manager.counter("oracle_batches") + report.sum_counter("oracle", "batches")
        }
    };
    OracleRun {
        green_msgs,
        labels: got_labels,
        bytes_copied: report.bytes_copied,
        wall_s: report.wall.as_secs_f64(),
    }
}

/// One heterogeneous-pool labeling run under `policy`: `(labels, wall_s)`.
///
/// 4 oracles, one of which costs 4x per label (8 ms vs 2 ms — the paper's
/// DFT-next-to-xTB shape at bench scale). Everything except `sched_policy`
/// is identical between the static and adaptive runs, so the labels/sec
/// delta is purely the routing win: EWMA least-estimated-completion-time
/// dispatch with per-oracle batch caps starves the slow oracle down to its
/// fair throughput share and keeps the final batches off it (the static
/// run's shutdown tail waits on a full-size batch stuck behind the slow
/// oracle).
fn sched_run(policy: SchedPolicy, labels: u64) -> (u64, f64) {
    const GENS: usize = 8;
    const ORACLES: usize = 4;
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-sched".into(),
        gene_process: GENS,
        pred_process: 2,
        ml_process: 0,
        orcl_process: ORACLES,
        committee_size: Some(2),
        exchange_mode: ExchangeMode::Batched,
        batch: BatchSetting {
            max_size: GENS,
            max_delay: Duration::from_millis(2),
            max_outstanding: 2,
        },
        oracle_mode: OracleMode::Batched,
        oracle_batch: BatchSetting {
            max_size: 8,
            max_delay: Duration::from_millis(1),
            max_outstanding: 2,
        },
        sched: SchedSetting {
            policy,
            // routing only: the 4x-slow oracle sits on the slow-streak
            // threshold (slow_factor default 4.0), so disable streak
            // eviction to keep the comparison about dispatch, not health
            slow_factor: 16.0,
            ..Default::default()
        },
        strict_label_budget: true,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(labels),
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..GENS)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(16, Duration::ZERO, u64::MAX, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..ORACLES)
        .map(|i| {
            Box::new(move || {
                let label_cost =
                    if i == 0 { Duration::from_millis(8) } else { Duration::from_millis(2) };
                Box::new(SyntheticOracle { label_cost, out_dim: 2 }) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _m: usize| {
        Box::new(SyntheticModel::new(16, 16, Duration::ZERO, Duration::ZERO, 1, mode))
            as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(SelectAllUtils { max_per_iter: GENS }) as Box<dyn Utils>);
    let report = Workflow::new(s)
        .run(KernelSet { generators, oracles, model, utils })
        .unwrap();
    (report.oracle_labels, report.wall.as_secs_f64())
}

/// One fault-recovery run: `(labels, wall_s, evictions, requeued_inputs,
/// time-to-first-evict ms, failed ranks)`.
struct FaultRun {
    labels: u64,
    wall_s: f64,
    evictions: u64,
    requeued: u64,
    evict_ms: f64,
    failed_ranks: Vec<usize>,
}

/// Strict-budget labeling run over 4 equal-cost oracles; with `kill`, a
/// seeded [`FaultPlan`] kills the first oracle on its 4th batch arrival —
/// about half of its share of the budget (each frame carries up to 8
/// labels, the pool serves ~`labels / 4` per oracle). The Manager must
/// evict the dead oracle, requeue its in-flight batch on the survivors,
/// and still reach the full budget; the wall-clock ratio vs the clean run
/// is the recovery cost the CI gate bounds.
fn fault_run(kill: bool, labels: u64) -> FaultRun {
    const GENS: usize = 8;
    const ORACLES: usize = 4;
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-fault".into(),
        gene_process: GENS,
        pred_process: 2,
        ml_process: 0,
        orcl_process: ORACLES,
        committee_size: Some(2),
        exchange_mode: ExchangeMode::Batched,
        batch: BatchSetting {
            max_size: GENS,
            max_delay: Duration::from_millis(2),
            max_outstanding: 2,
        },
        oracle_mode: OracleMode::Batched,
        oracle_batch: BatchSetting {
            max_size: 8,
            max_delay: Duration::from_millis(1),
            max_outstanding: 2,
        },
        strict_label_budget: true,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(labels),
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };
    let victim = Topology::new(&s).orcl_ranks()[0];
    let generators = (0..GENS)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(16, Duration::ZERO, u64::MAX, i as u64))
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..ORACLES)
        .map(|_| {
            Box::new(|| {
                Box::new(SyntheticOracle { label_cost: Duration::from_millis(2), out_dim: 2 })
                    as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _m: usize| {
        Box::new(SyntheticModel::new(16, 16, Duration::ZERO, Duration::ZERO, 1, mode))
            as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(SelectAllUtils { max_per_iter: GENS }) as Box<dyn Utils>);
    let mut wf = Workflow::new(s);
    if kill {
        wf = wf.with_faults(FaultPlan::default().kill_after_recvs(victim, 4));
    }
    let report = wf.run(KernelSet { generators, oracles, model, utils }).unwrap();
    let manager = &report.kernel("manager")[0];
    FaultRun {
        labels: report.oracle_labels,
        wall_s: report.wall.as_secs_f64(),
        evictions: report.faults.oracle_evictions,
        requeued: report.faults.requeued_inputs,
        evict_ms: manager.timer("time_to_first_evict").mean_ms(),
        failed_ranks: report.faults.failed_ranks.clone(),
    }
}

/// Section (j): fault recovery vs a clean run. Returns whether the gate
/// held (budget reached, oracle actually killed + evicted, recovery wall
/// within 2x of clean).
fn run_fault_section() -> bool {
    const FAULT_LABELS: u64 = 240;
    let clean = fault_run(false, FAULT_LABELS);
    let killed = fault_run(true, FAULT_LABELS);
    let lps_clean = clean.labels as f64 / clean.wall_s.max(1e-9);
    let lps_killed = killed.labels as f64 / killed.wall_s.max(1e-9);
    let recovery_ratio = killed.wall_s / clean.wall_s.max(1e-9);
    let target_met = killed.labels >= FAULT_LABELS
        && !killed.failed_ranks.is_empty()
        && killed.evictions >= 1
        && recovery_ratio <= 2.0;

    let mut rep = Report::new(format!(
        "fault recovery — one oracle killed at ~50% budget vs clean \
         (4 oracles, {FAULT_LABELS} labels, strict budget)"
    ));
    rep.push(
        Row::new("clean")
            .field("labels", clean.labels)
            .f("wall_s", clean.wall_s)
            .f("labels_per_s", lps_clean),
    );
    rep.push(
        Row::new("one oracle killed")
            .field("labels", killed.labels)
            .f("wall_s", killed.wall_s)
            .f("labels_per_s", lps_killed)
            .f("time_to_evict_ms", killed.evict_ms)
            .field("requeued_inputs", killed.requeued)
            .f("recovery_ratio_x", recovery_ratio),
    );
    rep.print();
    println!(
        "(killed run reached {} / {FAULT_LABELS} labels at {recovery_ratio:.2}x the clean \
         wall{})",
        killed.labels,
        if target_met { " — within the 2x recovery gate" } else { " — RECOVERY GATE MISSED" }
    );
    let fault_json = obj(vec![
        ("bench", Value::Str("fault_recovery".into())),
        ("oracles", Value::Num(4.0)),
        ("labels", Value::Num(FAULT_LABELS as f64)),
        (
            "clean",
            obj(vec![
                ("labels", Value::Num(clean.labels as f64)),
                ("wall_s", Value::Num(clean.wall_s)),
                ("labels_per_s", Value::Num(lps_clean)),
            ]),
        ),
        (
            "killed",
            obj(vec![
                ("labels", Value::Num(killed.labels as f64)),
                ("wall_s", Value::Num(killed.wall_s)),
                ("labels_per_s", Value::Num(lps_killed)),
                ("time_to_evict_ms", Value::Num(killed.evict_ms)),
                ("oracle_evictions", Value::Num(killed.evictions as f64)),
                ("requeued_inputs", Value::Num(killed.requeued as f64)),
                (
                    "failed_ranks",
                    Value::Array(
                        killed.failed_ranks.iter().map(|&r| Value::Num(r as f64)).collect(),
                    ),
                ),
            ]),
        ),
        ("recovery_ratio_x", Value::Num(recovery_ratio)),
        ("target_met", Value::Bool(target_met)),
    ]);
    match std::fs::write("BENCH_fault.json", pal::json::to_string(&fault_json)) {
        Ok(()) => println!("wrote BENCH_fault.json"),
        Err(e) => eprintln!("failed to write BENCH_fault.json: {e}"),
    }
    target_met
}

/// Steady-state allocations per `Dataset::minibatch` call at a given
/// rolling-window size. One warmup call sizes the gather scratch; the
/// measured loop must then be allocation-free regardless of window.
fn minibatch_allocs(window: usize) -> u64 {
    const DIM: usize = 8;
    const MB: usize = 16;
    const ITERS: u64 = 64;
    let mut d = Dataset::new(0.0, 7).with_rolling_window(window);
    let pts: Vec<(Vec<f32>, Vec<f32>)> =
        (0..window + 32).map(|i| (vec![i as f32; DIM], vec![i as f32])).collect();
    d.add(&pts);
    black_box(d.minibatch(MB));
    let a0 = alloc_count();
    for _ in 0..ITERS {
        black_box(d.minibatch(MB));
    }
    (alloc_count() - a0) / ITERS
}

/// Section (k): memory-plane gates. (1) labels-only oracle-result frame
/// vs the legacy interleaved frame, bytes per label at batch 8 (>= 1.8x
/// fewer); (2) identity-keyed weight upload cache, staged bytes on repeat
/// calls (zero after the first); (3) minibatch gather allocations flat in
/// the rolling-window size. Returns whether all three gates held.
fn run_mem_section() -> bool {
    // ---- labels-only result frames vs interleaved inputs+labels ----
    const MB_BATCH: usize = 8;
    const IN_W: usize = 32;
    const LAB_W: usize = 32;
    let inputs: Vec<Vec<f32>> = (0..MB_BATCH).map(|i| vec![i as f32; IN_W]).collect();
    let input_refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let mut labels = RowBlock::new();
    for i in 0..MB_BATCH {
        labels.push_row(&[i as f32; LAB_W]);
    }
    let mut legacy_frame = Vec::new();
    encode_oracle_batch_result_into(77, &input_refs, &labels, &mut legacy_frame);
    let mut labels_frame = Vec::new();
    encode_oracle_labels_into(77, &labels, &mut labels_frame);
    let legacy_bpl = legacy_frame.len() as f64 * 4.0 / MB_BATCH as f64;
    let labels_bpl = labels_frame.len() as f64 * 4.0 / MB_BATCH as f64;
    let bytes_reduction = legacy_bpl / labels_bpl.max(1e-9);

    // ---- device-resident weight cache: repeat uploads must stage zero ----
    const WLEN: usize = 4096;
    const ROUNDS: u64 = 32;
    let weights = Payload::from(vec![0.5f32; WLEN]);
    let mut cached = UploadCache::new(8);
    for _ in 0..ROUNDS {
        cached.ensure(&weights, &[WLEN as i64]).expect("stage shared weights");
    }
    let cs = cached.stats();
    let first_upload = 4 * WLEN as u64;
    let hit_upload_bytes = cs.bytes_uploaded.saturating_sub(first_upload);
    // pre-cache engine behaviour: every call stages a fresh buffer, so the
    // identity changes and the cache can never hit
    let mut uncached = UploadCache::new(8);
    for _ in 0..ROUNDS {
        let w = Payload::from(vec![0.5f32; WLEN]);
        uncached.ensure(&w, &[WLEN as i64]).expect("stage fresh weights");
    }
    let us = uncached.stats();
    let upload_reduction = us.bytes_uploaded as f64 / cs.bytes_uploaded.max(1) as f64;
    let cache_ok = hit_upload_bytes == 0 && cs.hits == ROUNDS - 1;

    // ---- minibatch gather: allocation count flat in the window size ----
    let allocs_64 = minibatch_allocs(64);
    let allocs_512 = minibatch_allocs(512);
    let minibatch_flat = allocs_64 == allocs_512;

    let target_met = bytes_reduction >= 1.8 && cache_ok && minibatch_flat;

    let mut rep = Report::new(format!(
        "memory plane — result bytes/label (batch {MB_BATCH}), weight-upload bytes \
         ({ROUNDS} rounds), minibatch allocs vs window"
    ));
    rep.push(
        Row::new("legacy interleaved result")
            .f("bytes_per_label", legacy_bpl)
            .field("frame_f32", legacy_frame.len()),
    );
    rep.push(
        Row::new("labels-only result")
            .f("bytes_per_label", labels_bpl)
            .field("frame_f32", labels_frame.len())
            .f("reduction_x", bytes_reduction),
    );
    rep.push(
        Row::new("weight upload, uncached")
            .field("bytes_uploaded", us.bytes_uploaded)
            .field("misses", us.misses),
    );
    rep.push(
        Row::new("weight upload, cached")
            .field("bytes_uploaded", cs.bytes_uploaded)
            .field("hits", cs.hits)
            .field("hit_upload_bytes", hit_upload_bytes)
            .f("reduction_x", upload_reduction),
    );
    rep.push(Row::new("minibatch allocs, window 64").field("allocs_per_call", allocs_64));
    rep.push(Row::new("minibatch allocs, window 512").field("allocs_per_call", allocs_512));
    rep.print();
    println!(
        "(labels-only results carry {bytes_reduction:.2}x fewer bytes per label{})",
        if bytes_reduction >= 1.8 { " — >= 1.8x target met" } else { " — BELOW the 1.8x target" }
    );
    println!(
        "(repeat weight uploads staged {hit_upload_bytes} bytes{})",
        if cache_ok { " — zero-byte cache-hit target met" } else { " — CACHE-HIT GATE MISSED" }
    );
    println!(
        "(minibatch allocs/call {allocs_64} at window 64 vs {allocs_512} at 512{})",
        if minibatch_flat { " — flat-in-window target met" } else { " — NOT FLAT" }
    );

    let mem_json = obj(vec![
        ("bench", Value::Str("mem_plane".into())),
        (
            "oracle_result",
            obj(vec![
                ("batch", Value::Num(MB_BATCH as f64)),
                ("input_width", Value::Num(IN_W as f64)),
                ("label_width", Value::Num(LAB_W as f64)),
                ("legacy_bytes_per_label", Value::Num(legacy_bpl)),
                ("labels_only_bytes_per_label", Value::Num(labels_bpl)),
                ("bytes_reduction_x", Value::Num(bytes_reduction)),
            ]),
        ),
        (
            "weight_upload",
            obj(vec![
                ("rounds", Value::Num(ROUNDS as f64)),
                ("weight_f32", Value::Num(WLEN as f64)),
                ("uncached_bytes", Value::Num(us.bytes_uploaded as f64)),
                ("cached_bytes", Value::Num(cs.bytes_uploaded as f64)),
                ("cache_hits", Value::Num(cs.hits as f64)),
                ("hit_upload_bytes", Value::Num(hit_upload_bytes as f64)),
                ("upload_reduction_x", Value::Num(upload_reduction)),
            ]),
        ),
        (
            "minibatch",
            obj(vec![
                ("allocs_per_call_window_64", Value::Num(allocs_64 as f64)),
                ("allocs_per_call_window_512", Value::Num(allocs_512 as f64)),
                ("flat_in_window", Value::Bool(minibatch_flat)),
            ]),
        ),
        ("target_met", Value::Bool(target_met)),
    ]);
    match std::fs::write("BENCH_mem.json", pal::json::to_string(&mem_json)) {
        Ok(()) => println!("wrote BENCH_mem.json"),
        Err(e) => eprintln!("failed to write BENCH_mem.json: {e}"),
    }
    target_met
}

/// Fan-in throughput core: every producer endpoint pushes `per_producer`
/// copies of one pre-built shared payload of `size` f32 at rank 0, which
/// drains them with the vectored receive. All senders start on a barrier;
/// the clock runs from the barrier release to the last receive. Returns
/// messages/sec.
fn measure_fan_in(
    mut consumer: Endpoint,
    producers: Vec<Endpoint>,
    size: usize,
    per_producer: usize,
) -> f64 {
    let total = producers.len() * per_producer;
    let barrier = Arc::new(std::sync::Barrier::new(producers.len() + 1));
    let handles: Vec<_> = producers
        .into_iter()
        .map(|e| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                // pre-built payload: sends are refcount bumps (or, on tcp,
                // serialized frames) — never a fresh ingest per message
                let payload = Payload::from(vec![0.5f32; size]);
                barrier.wait();
                for _ in 0..per_producer {
                    assert!(e.send(0, 41, &payload), "producer send failed");
                }
            })
        })
        .collect();
    barrier.wait();
    let t0 = std::time::Instant::now();
    let mut got = 0usize;
    while got < total {
        let batch = consumer.recv_ready_all(Src::Any, 41);
        if batch.is_empty() {
            consumer.recv_timeout(Src::Any, 41, Duration::from_secs(30)).expect("fan-in recv");
            got += 1;
        } else {
            got += batch.len();
        }
    }
    let dt = t0.elapsed();
    for h in handles {
        h.join().unwrap();
    }
    total as f64 / dt.as_secs_f64()
}

/// One in-process fan-in run (7 producers → rank 0) over `kind`:
/// `(msgs_per_s, bytes_copied)`. Both in-process backends move shared
/// payloads without touching the bytes, so the copy count doubles as a
/// zero-copy check.
fn transport_throughput(kind: TransportKind, size: usize, per_producer: usize) -> (f64, u64) {
    let mut w = World::with_backend(8, Duration::ZERO, kind);
    let stats = w.stats();
    let mut eps = w.endpoints();
    let consumer = eps.remove(0);
    let msgs_per_s = measure_fan_in(consumer, eps, size, per_producer);
    (msgs_per_s, stats.bytes_copied())
}

/// Socket twin of [`transport_throughput`]: a loopback pair of tcp worlds
/// in one process, producers homed on the connect side, consumer behind
/// the listener. Returns `(msgs_per_s, producer-side bytes_copied)` — on
/// tcp the frame serialization at the process boundary is a real copy,
/// so the copy volume ≈ the full payload traffic.
fn tcp_transport_throughput(size: usize, per_producer: usize) -> (f64, u64) {
    let boot = Bootstrap::bind("127.0.0.1:0").expect("bind loopback");
    let addr = boot.local_addr().expect("loopback addr").to_string();
    let follower = std::thread::spawn(move || {
        let locals: Vec<usize> = (1..8).collect();
        let (mut w, _monitor) =
            World::connect(&addr, 8, &locals, Duration::ZERO, Duration::from_secs(10))
                .expect("connect loopback");
        let stats = w.stats();
        let producers: Vec<Endpoint> = locals.iter().map(|&r| w.endpoint(r)).collect();
        (producers, stats)
    });
    let (mut w, _monitor) = World::listen(boot, 8, &[0], Duration::ZERO).expect("listen loopback");
    let consumer = w.endpoint(0);
    let (producers, follower_stats) = follower.join().expect("join tcp follower");
    let msgs_per_s = measure_fan_in(consumer, producers, size, per_producer);
    (msgs_per_s, follower_stats.bytes_copied())
}

/// Section (l): transport plane — fan-in msgs/sec over the pluggable
/// backends at 8 ranks. The gate: the lock-free shm rings must move small
/// payloads at >= 1.5x the channel backend's rate. The tcp loopback rate
/// is reported, not gated — serialization at the process boundary puts it
/// in a different class. Returns whether the gate held.
fn run_transport_section() -> bool {
    const SMALL: usize = 1;
    const LARGE: usize = 1024;
    const SMALL_MSGS: usize = 4000;
    const LARGE_MSGS: usize = 500;

    let (ch_s, ch_s_copied) = transport_throughput(TransportKind::Channel, SMALL, SMALL_MSGS);
    let (shm_s, shm_s_copied) = transport_throughput(TransportKind::Shm, SMALL, SMALL_MSGS);
    let (tcp_s, tcp_s_copied) = tcp_transport_throughput(SMALL, SMALL_MSGS);
    let (ch_l, ch_l_copied) = transport_throughput(TransportKind::Channel, LARGE, LARGE_MSGS);
    let (shm_l, shm_l_copied) = transport_throughput(TransportKind::Shm, LARGE, LARGE_MSGS);
    let (tcp_l, tcp_l_copied) = tcp_transport_throughput(LARGE, LARGE_MSGS);

    let speedup_small = shm_s / ch_s.max(1e-9);
    let speedup_large = shm_l / ch_l.max(1e-9);
    // in-process backends must also stay zero-copy on the shared payloads
    let target_met = speedup_small >= 1.5 && shm_s_copied == 0 && ch_s_copied == 0;

    let mut rep = Report::new(format!(
        "transport plane — fan-in msgs/sec at 8 ranks, 7 producers -> rank 0 \
         ({SMALL_MSGS} small / {LARGE_MSGS} large msgs per producer)"
    ));
    rep.push(
        Row::new(format!("channel, {SMALL} f32"))
            .f("msgs_per_s", ch_s)
            .field("bytes_copied", ch_s_copied),
    );
    rep.push(
        Row::new(format!("shm, {SMALL} f32"))
            .f("msgs_per_s", shm_s)
            .field("bytes_copied", shm_s_copied)
            .f("speedup_x", speedup_small),
    );
    rep.push(
        Row::new(format!("tcp loopback, {SMALL} f32"))
            .f("msgs_per_s", tcp_s)
            .field("bytes_copied", tcp_s_copied),
    );
    rep.push(
        Row::new(format!("channel, {LARGE} f32"))
            .f("msgs_per_s", ch_l)
            .field("bytes_copied", ch_l_copied),
    );
    rep.push(
        Row::new(format!("shm, {LARGE} f32"))
            .f("msgs_per_s", shm_l)
            .field("bytes_copied", shm_l_copied)
            .f("speedup_x", speedup_large),
    );
    rep.push(
        Row::new(format!("tcp loopback, {LARGE} f32"))
            .f("msgs_per_s", tcp_l)
            .field("bytes_copied", tcp_l_copied),
    );
    rep.print();
    println!(
        "(shm moves small payloads at {speedup_small:.2}x the channel rate{})",
        if target_met { " — >= 1.5x target met" } else { " — TRANSPORT GATE MISSED" }
    );

    let transport_json = obj(vec![
        ("bench", Value::Str("transport_plane".into())),
        ("ranks", Value::Num(8.0)),
        ("producers", Value::Num(7.0)),
        (
            "small_payload",
            obj(vec![
                ("size_f32", Value::Num(SMALL as f64)),
                ("msgs_per_producer", Value::Num(SMALL_MSGS as f64)),
                ("channel_msgs_per_s", Value::Num(ch_s)),
                ("shm_msgs_per_s", Value::Num(shm_s)),
                ("tcp_msgs_per_s", Value::Num(tcp_s)),
                ("channel_bytes_copied", Value::Num(ch_s_copied as f64)),
                ("shm_bytes_copied", Value::Num(shm_s_copied as f64)),
                ("tcp_bytes_copied", Value::Num(tcp_s_copied as f64)),
                ("shm_speedup_x", Value::Num(speedup_small)),
                ("target_met", Value::Bool(target_met)),
            ]),
        ),
        (
            "large_payload",
            obj(vec![
                ("size_f32", Value::Num(LARGE as f64)),
                ("msgs_per_producer", Value::Num(LARGE_MSGS as f64)),
                ("channel_msgs_per_s", Value::Num(ch_l)),
                ("shm_msgs_per_s", Value::Num(shm_l)),
                ("tcp_msgs_per_s", Value::Num(tcp_l)),
                ("channel_bytes_copied", Value::Num(ch_l_copied as f64)),
                ("shm_bytes_copied", Value::Num(shm_l_copied as f64)),
                ("tcp_bytes_copied", Value::Num(tcp_l_copied as f64)),
                ("shm_speedup_x", Value::Num(speedup_large)),
            ]),
        ),
        ("target_met", Value::Bool(target_met)),
    ]);
    match std::fs::write("BENCH_transport.json", pal::json::to_string(&transport_json)) {
        Ok(()) => println!("wrote BENCH_transport.json"),
        Err(e) => eprintln!("failed to write BENCH_transport.json: {e}"),
    }
    target_met
}

/// One publish pass against the process-wide registry in whatever enabled
/// state it currently holds: each iteration is a plausible coordinator
/// step (counter bump, gauge overwrite, RTT observation, endpoint slot
/// update). Returns `(ns per iteration, allocations observed)`.
fn obs_publish_pass(events: u64) -> (f64, u64) {
    let reg = black_box(registry());
    let a0 = alloc_count();
    let t0 = Instant::now();
    for i in 0..events {
        reg.inc(ObsCounter::Labels);
        reg.gauge_set(ObsGauge::OracleQueueDepth, i % 64);
        reg.observe_oracle_rtt(Duration::from_millis(i % 32));
        reg.endpoint_outstanding(5, i % 4, (i % 4) * 8);
        reg.endpoint_ewma_ms(5, 2.5);
    }
    let dt = t0.elapsed();
    (dt.as_nanos() as f64 / events as f64, alloc_count() - a0)
}

/// Section (m): observability-plane gates. (1) The section-(i) adaptive
/// labeling workload runs with the registry disabled and enabled; min
/// wall over the trials must agree within 2% (sleep-bounded synthetic
/// oracles give both modes the same deterministic floor, so min isolates
/// the registry cost from scheduler noise). (2) A tight publish loop
/// against the *disabled* registry — the default state of every
/// non-observed run — must be allocation-free under the counting
/// allocator. Returns whether both gates held.
fn run_obs_section() -> bool {
    const OBS_LABELS: u64 = 240;
    const TRIALS: usize = 3;
    const HOT_EVENTS: u64 = 1_000_000;
    let reg = registry();

    // ---- enabled-vs-disabled wall on a real labeling run ----
    reg.set_enabled(false);
    let mut disabled_wall = f64::INFINITY;
    for _ in 0..TRIALS {
        disabled_wall = disabled_wall.min(sched_run(SchedPolicy::Adaptive, OBS_LABELS).1);
    }
    let mut enabled_wall = f64::INFINITY;
    for _ in 0..TRIALS {
        reg.reset_for_run(None);
        reg.set_enabled(true);
        let wall = sched_run(SchedPolicy::Adaptive, OBS_LABELS).1;
        reg.set_enabled(false);
        enabled_wall = enabled_wall.min(wall);
    }
    // last enabled trial's live view — proves the run actually published
    let enabled_labels = reg.counter(ObsCounter::Labels);
    let wall_ratio = enabled_wall / disabled_wall.max(1e-9);

    // ---- publish hot path: disabled must be branch-only, alloc-free ----
    let (disabled_ns, disabled_allocs) = obs_publish_pass(HOT_EVENTS);
    reg.reset_for_run(None);
    reg.set_enabled(true);
    let (enabled_ns, enabled_allocs) = obs_publish_pass(HOT_EVENTS);
    reg.set_enabled(false);

    let target_met = wall_ratio <= 1.02 && disabled_allocs == 0 && enabled_labels >= OBS_LABELS;

    let mut rep = Report::new(format!(
        "observability plane — registry enabled vs disabled on the adaptive \
         labeling run ({OBS_LABELS} labels, min of {TRIALS}), publish hot path \
         ({HOT_EVENTS} events)"
    ));
    rep.push(
        Row::new("registry disabled")
            .f("wall_s", disabled_wall)
            .f("ns_per_event", disabled_ns)
            .field("hot_allocs", disabled_allocs),
    );
    rep.push(
        Row::new("registry enabled")
            .f("wall_s", enabled_wall)
            .f("ns_per_event", enabled_ns)
            .field("hot_allocs", enabled_allocs)
            .field("live_labels", enabled_labels)
            .f("wall_ratio_x", wall_ratio),
    );
    rep.print();
    println!(
        "(enabled registry cost {wall_ratio:.3}x the disabled wall{})",
        if wall_ratio <= 1.02 {
            " — within the 2% overhead gate"
        } else {
            " — OVERHEAD GATE MISSED"
        }
    );
    println!(
        "(disabled publish hot path made {disabled_allocs} allocations over {HOT_EVENTS} \
         events{})",
        if disabled_allocs == 0 { " — allocation-free target met" } else { " — NOT ALLOC-FREE" }
    );

    let obs_json = obj(vec![
        ("bench", Value::Str("obs_plane".into())),
        (
            "overhead",
            obj(vec![
                ("labels", Value::Num(OBS_LABELS as f64)),
                ("trials", Value::Num(TRIALS as f64)),
                ("disabled_wall_s", Value::Num(disabled_wall)),
                ("enabled_wall_s", Value::Num(enabled_wall)),
                ("enabled_live_labels", Value::Num(enabled_labels as f64)),
                ("enabled_over_disabled_wall_x", Value::Num(wall_ratio)),
            ]),
        ),
        (
            "hot_path",
            obj(vec![
                ("events", Value::Num(HOT_EVENTS as f64)),
                ("disabled_ns_per_event", Value::Num(disabled_ns)),
                ("enabled_ns_per_event", Value::Num(enabled_ns)),
                ("disabled_allocs", Value::Num(disabled_allocs as f64)),
                ("enabled_allocs", Value::Num(enabled_allocs as f64)),
            ]),
        ),
        ("target_met", Value::Bool(target_met)),
    ]);
    match std::fs::write("BENCH_obs.json", pal::json::to_string(&obs_json)) {
        Ok(()) => println!("wrote BENCH_obs.json"),
        Err(e) => eprintln!("failed to write BENCH_obs.json: {e}"),
    }
    target_met
}

fn main() {
    // `cargo bench --bench comm_overhead -- sched-only` runs just the
    // scheduler comparison, `-- fault-only` just the fault-recovery gate,
    // `-- mem-only` just the memory-plane gates, `-- transport-only` just
    // the transport-plane gate, `-- obs-only` just the observability-plane
    // gates (all CI gates); no args runs everything.
    let sched_only = std::env::args().any(|a| a == "sched-only");
    let fault_only = std::env::args().any(|a| a == "fault-only");
    let mem_only = std::env::args().any(|a| a == "mem-only");
    let transport_only = std::env::args().any(|a| a == "transport-only");
    let obs_only = std::env::args().any(|a| a == "obs-only");
    if obs_only {
        // ---- (m) observability plane: registry overhead + hot path ----
        if !run_obs_section() {
            std::process::exit(1);
        }
        return;
    }
    if transport_only {
        // ---- (l) transport plane: backend fan-in throughput gate ----
        if !run_transport_section() {
            std::process::exit(1);
        }
        return;
    }
    if mem_only {
        // ---- (k) memory plane: result bytes, upload cache, minibatch ----
        if !run_mem_section() {
            std::process::exit(1);
        }
        return;
    }
    if !sched_only && !fault_only {
        run_comm_sections();
    }
    if fault_only {
        // ---- (j) fault recovery: killed-oracle wall vs clean ----
        if !run_fault_section() {
            std::process::exit(1);
        }
        return;
    }

    // ---- (i) adaptive vs static routing under a heterogeneous pool ----
    const SCHED_LABELS: u64 = 240;
    let (labels_static, wall_static) = sched_run(SchedPolicy::Static, SCHED_LABELS);
    let (labels_adaptive, wall_adaptive) = sched_run(SchedPolicy::Adaptive, SCHED_LABELS);
    let lps_static = labels_static as f64 / wall_static.max(1e-9);
    let lps_adaptive = labels_adaptive as f64 / wall_adaptive.max(1e-9);
    let speedup = lps_adaptive / lps_static.max(1e-9);
    let mut rep9 = Report::new(format!(
        "adaptive dispatch — labels/sec vs static routing \
         (4 oracles, one 4x slower, {SCHED_LABELS} labels)"
    ));
    rep9.push(
        Row::new("static least-outstanding")
            .field("labels", labels_static)
            .f("wall_s", wall_static)
            .f("labels_per_s", lps_static),
    );
    rep9.push(
        Row::new("adaptive EWMA/ECT")
            .field("labels", labels_adaptive)
            .f("wall_s", wall_adaptive)
            .f("labels_per_s", lps_adaptive)
            .f("speedup_x", speedup),
    );
    rep9.print();
    println!(
        "(adaptive routing labels {speedup:.2}x faster than static under the 4x-slow \
         oracle{})",
        if speedup >= 1.3 { " — >= 1.3x target met" } else { " — BELOW the 1.3x target" }
    );
    let sched_json = obj(vec![
        ("bench", Value::Str("sched_throughput".into())),
        ("oracles", Value::Num(4.0)),
        ("slow_oracle_factor", Value::Num(4.0)),
        ("labels", Value::Num(SCHED_LABELS as f64)),
        (
            "static",
            obj(vec![
                ("labels", Value::Num(labels_static as f64)),
                ("wall_s", Value::Num(wall_static)),
                ("labels_per_s", Value::Num(lps_static)),
            ]),
        ),
        (
            "adaptive",
            obj(vec![
                ("labels", Value::Num(labels_adaptive as f64)),
                ("wall_s", Value::Num(wall_adaptive)),
                ("labels_per_s", Value::Num(lps_adaptive)),
            ]),
        ),
        ("speedup_x", Value::Num(speedup)),
        ("target_met", Value::Bool(speedup >= 1.3)),
    ]);
    match std::fs::write("BENCH_sched.json", pal::json::to_string(&sched_json)) {
        Ok(()) => println!("wrote BENCH_sched.json"),
        Err(e) => eprintln!("failed to write BENCH_sched.json: {e}"),
    }

    if !sched_only {
        // ---- (j) fault recovery: killed-oracle wall vs clean ----
        if !run_fault_section() {
            std::process::exit(1);
        }
        // ---- (k) memory plane: result bytes, upload cache, minibatch ----
        if !run_mem_section() {
            std::process::exit(1);
        }
        // ---- (l) transport plane: backend fan-in throughput gate ----
        if !run_transport_section() {
            std::process::exit(1);
        }
        // ---- (m) observability plane: registry overhead + hot path ----
        if !run_obs_section() {
            std::process::exit(1);
        }
    }
}

fn run_comm_sections() {
    let mut json_sections: Vec<(&str, Value)> = vec![("bench", Value::Str("comm_overhead".into()))];

    // ---- (a) raw bus round-trip vs payload size ----
    let mut rep = Report::new("comm bus — round-trip latency vs payload (1-D f32 arrays)");
    let mut roundtrip_rows = Vec::new();
    for size in [4usize, 64, 1024, 16 * 1024, 256 * 1024] {
        let rt = bench(1, 5, || bus_roundtrip(size, 200)).mean();
        let mb_per_s = (size as f64 * 4.0 * 2.0) / rt.as_secs_f64() / 1e6;
        rep.push(Row::new(format!("{size} f32")).ms("roundtrip", rt).f("MB_per_s", mb_per_s));
        roundtrip_rows.push(obj(vec![
            ("size_f32", Value::Num(size as f64)),
            ("roundtrip_ms", Value::Num(rt.as_secs_f64() * 1e3)),
            ("mb_per_s", Value::Num(mb_per_s)),
        ]));
    }
    rep.print();
    json_sections.push(("bus_roundtrip", Value::Array(roundtrip_rows)));

    // ---- (b) exchange-loop rate vs prediction latency (§4 claim) ----
    let mut rep2 = Report::new("§4 — exploration rate vs prediction latency (8 generators)");
    let mut rate_rows = Vec::new();
    for pred_ms in [0u64, 1, 5, 10, 50] {
        let rate = exchange_rate(pred_ms, 60, false);
        rep2.push(
            Row::new(format!("pred={pred_ms}ms"))
                .f("iters_per_s", rate)
                .f("pred_bound_iters_per_s", if pred_ms == 0 { f64::NAN } else { 1000.0 / pred_ms as f64 }),
        );
        rate_rows.push(obj(vec![
            ("pred_ms", Value::Num(pred_ms as f64)),
            ("iters_per_s", Value::Num(rate)),
        ]));
    }
    rep2.print();
    json_sections.push(("exchange_rate", Value::Array(rate_rows)));
    println!("(paper: below ~10 ms inference the communication becomes the bottleneck —");
    println!(" visible here as iters/s flattening away from the prediction-bound line)");

    // ---- (c) fixed vs variable message sizes ----
    let fixed = exchange_rate(1, 80, false);
    let varsize = exchange_rate(1, 80, true);
    let mut rep3 = Report::new("§4 — fixed_size_data=True vs False (modeled size-header cost)");
    rep3.push(Row::new("fixed").f("iters_per_s", fixed));
    rep3.push(Row::new("variable").f("iters_per_s", varsize).f("overhead_pct", (fixed / varsize - 1.0) * 100.0));
    rep3.print();
    json_sections.push((
        "fixed_vs_variable",
        obj(vec![
            ("fixed_iters_per_s", Value::Num(fixed)),
            ("variable_iters_per_s", Value::Num(varsize)),
        ]),
    ));

    // ---- (d) batched exchange: bus messages per AL iteration vs batch size ----
    // One AL iteration = one step of every generator (16 items). batch=1 is
    // the unbatched one-request-at-a-time relay; coalescing amortizes the
    // controller↔predictor frames across the batch.
    const GENS_D: f64 = 16.0;
    let total_items = 320u64;
    let mut rep4 = Report::new(
        "batched exchange — bus messages per AL iteration (16 gens, 2-member shard)",
    );
    let mut per_iter_at = std::collections::BTreeMap::new();
    let mut batched_rows = Vec::new();
    for batch in [1usize, 2, 4, 8, 16] {
        let r = batched_messages(batch, total_items);
        let al_iters = r.items as f64 / GENS_D;
        let per_iter = r.messages as f64 / al_iters;
        per_iter_at.insert(batch, per_iter);
        rep4.push(
            Row::new(format!("batch={batch}"))
                .f("msgs_per_al_iter", per_iter)
                .f("msgs_per_item", r.messages as f64 / r.items as f64)
                .f("items_per_s", r.items as f64 / r.wall_s)
                .f("bytes_copied_frac", r.bytes_copied as f64 / r.payload_bytes as f64),
        );
        batched_rows.push(obj(vec![
            ("batch", Value::Num(batch as f64)),
            ("messages", Value::Num(r.messages as f64)),
            ("items", Value::Num(r.items as f64)),
            ("items_per_s", Value::Num(r.items as f64 / r.wall_s)),
            ("wall_s", Value::Num(r.wall_s)),
            ("payload_bytes", Value::Num(r.payload_bytes as f64)),
            ("bytes_copied", Value::Num(r.bytes_copied as f64)),
        ]));
    }
    rep4.print();
    json_sections.push(("batched", Value::Array(batched_rows)));
    let reduction = per_iter_at[&1] / per_iter_at[&8];
    println!(
        "(batch=8 sends {reduction:.2}x fewer bus messages per AL iteration than the \
         unbatched relay{})",
        if reduction >= 2.0 { " — >= 2x target met" } else { " — BELOW the 2x target" }
    );

    // ---- (e) weight broadcast: shared Payload vs per-destination clone ----
    // The trainer → replica fan-out at 8 prediction ranks; physical copy
    // volume should drop by the destination count (8x), logical traffic is
    // identical by construction.
    const FAN_RANKS: usize = 8;
    const WEIGHT_LEN: usize = 100_000;
    const FAN_ROUNDS: usize = 20;
    let (copied_clone, logical_clone, clones_clone) =
        weight_fanout(FAN_RANKS, WEIGHT_LEN, FAN_ROUNDS, false);
    let (copied_shared, logical_shared, clones_shared) =
        weight_fanout(FAN_RANKS, WEIGHT_LEN, FAN_ROUNDS, true);
    let copy_reduction = copied_clone as f64 / copied_shared.max(1) as f64;
    let mut rep5 = Report::new(format!(
        "weight broadcast — physical copies at {FAN_RANKS} prediction ranks \
         ({WEIGHT_LEN} f32 weights, {FAN_ROUNDS} rounds)"
    ));
    rep5.push(
        Row::new("per-dest clone (old)")
            .field("bytes_copied", copied_clone)
            .field("payload_bytes", logical_clone)
            .field("payload_clones", clones_clone),
    );
    rep5.push(
        Row::new("shared Payload (new)")
            .field("bytes_copied", copied_shared)
            .field("payload_bytes", logical_shared)
            .field("payload_clones", clones_shared)
            .f("copy_reduction_x", copy_reduction),
    );
    // end-to-end confirmation: the same fan-out through a real workflow
    // (2 trainers × 4 shard replicas, padded weights) — the physical copy
    // fraction of the logical traffic collapses once payloads are shared
    let (e2e_logical, e2e_copied, e2e_updates) = weight_fanout_e2e(WEIGHT_LEN);
    rep5.push(
        Row::new("e2e workflow (8 preds, 2 trainers)")
            .field("bytes_copied", e2e_copied)
            .field("payload_bytes", e2e_logical)
            .field("weight_updates", e2e_updates)
            .f("copied_frac", e2e_copied as f64 / e2e_logical.max(1) as f64),
    );
    rep5.print();
    println!(
        "(shared fan-out copies {copy_reduction:.2}x fewer bytes than per-destination \
         clones{})",
        if copy_reduction >= 4.0 { " — >= 4x target met" } else { " — BELOW the 4x target" }
    );
    json_sections.push((
        "weight_broadcast",
        obj(vec![
            ("ranks", Value::Num(FAN_RANKS as f64)),
            ("weight_len", Value::Num(WEIGHT_LEN as f64)),
            ("rounds", Value::Num(FAN_ROUNDS as f64)),
            ("bytes_copied_per_dest_clone", Value::Num(copied_clone as f64)),
            ("bytes_copied_shared", Value::Num(copied_shared as f64)),
            ("payload_bytes_logical", Value::Num(logical_shared as f64)),
            ("copy_reduction_x", Value::Num(copy_reduction)),
            ("target_met", Value::Bool(copy_reduction >= 4.0)),
            ("e2e_payload_bytes", Value::Num(e2e_logical as f64)),
            ("e2e_bytes_copied", Value::Num(e2e_copied as f64)),
            ("e2e_weight_updates", Value::Num(e2e_updates as f64)),
        ]),
    ));

    let out = pal::json::to_string(&obj(json_sections));
    match std::fs::write("BENCH_comm.json", &out) {
        Ok(()) => println!("\nwrote BENCH_comm.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_comm.json: {e}"),
    }

    // ---- (f) allocations per item: nested-Vec baseline vs flat plane ----
    // One committee round-trip's receive side (decode every member's result
    // frame + committee_std_check), counted by the global CountingAlloc.
    const AP_MODELS: usize = 3;
    const AP_WIDTH: usize = 32;
    const AP_ITERS: u64 = 200;
    let mut rep6 = Report::new(format!(
        "allocations per predicted item — decode + committee reduce \
         ({AP_MODELS}-member committee, width {AP_WIDTH})"
    ));
    let mut alloc_rows = Vec::new();
    let mut reduction_at_8 = 0.0;
    for batch in [1usize, 8, 32] {
        let (nested, flat) = alloc_per_item(batch, AP_MODELS, AP_WIDTH, AP_ITERS);
        let reduction = nested / flat.max(1e-9);
        if batch == 8 {
            reduction_at_8 = reduction;
        }
        rep6.push(
            Row::new(format!("batch={batch}"))
                .f("allocs_per_item_nested", nested)
                .f("allocs_per_item_flat", flat)
                .f("reduction_x", reduction),
        );
        alloc_rows.push(obj(vec![
            ("batch", Value::Num(batch as f64)),
            ("models", Value::Num(AP_MODELS as f64)),
            ("width", Value::Num(AP_WIDTH as f64)),
            ("allocs_per_item_nested", Value::Num(nested)),
            ("allocs_per_item_flat", Value::Num(flat)),
            ("reduction_x", Value::Num(reduction)),
        ]));
    }
    rep6.print();
    println!(
        "(flat plane allocates {reduction_at_8:.1}x less per item at batch=8{})",
        if reduction_at_8 >= 10.0 { " — >= 10x target met" } else { " — BELOW the 10x target" }
    );
    let alloc_json = obj(vec![
        ("bench", Value::Str("alloc_per_item".into())),
        ("sections", Value::Array(alloc_rows)),
        ("reduction_x_at_batch8", Value::Num(reduction_at_8)),
        ("target_met", Value::Bool(reduction_at_8 >= 10.0)),
    ]);
    match std::fs::write("BENCH_alloc.json", pal::json::to_string(&alloc_json)) {
        Ok(()) => println!("wrote BENCH_alloc.json"),
        Err(e) => eprintln!("failed to write BENCH_alloc.json: {e}"),
    }

    // ---- (g) flat training plane: flush fan-out + weight sync ----
    // Physical bytes copied per flushed datapoint (one shared flush payload
    // vs per-trainer clones), and the payload-cached weight sync (refcount
    // re-export) vs an owned export every round at 8 replicas.
    const TF_TRAINERS: usize = 3;
    const TF_POINTS: usize = 64;
    const TF_WIDTH: usize = 32;
    let flush_shared = train_flush_copies(TF_TRAINERS, TF_POINTS, TF_WIDTH, true);
    let flush_cloned = train_flush_copies(TF_TRAINERS, TF_POINTS, TF_WIDTH, false);
    let per_point_shared = flush_shared as f64 / TF_POINTS as f64;
    let per_point_cloned = flush_cloned as f64 / TF_POINTS as f64;

    const WS_RANKS: usize = 8;
    const WS_LEN: usize = 100_000;
    const WS_ROUNDS: usize = 20;
    let (ws_copied_cached, ws_clones_cached) = weight_sync_rounds(WS_RANKS, WS_LEN, WS_ROUNDS, true);
    let (ws_copied_owned, ws_clones_owned) = weight_sync_rounds(WS_RANKS, WS_LEN, WS_ROUNDS, false);
    let ws_reduction = ws_copied_owned as f64 / ws_copied_cached.max(1) as f64;

    let mut rep7 = Report::new(format!(
        "flat training plane — flush fan-out ({TF_TRAINERS} trainers, {TF_POINTS} points) \
         + weight sync ({WS_RANKS} ranks, {WS_LEN} f32, {WS_ROUNDS} rounds)"
    ));
    rep7.push(
        Row::new("train flush: shared payload")
            .field("bytes_copied", flush_shared)
            .f("bytes_copied_per_point", per_point_shared),
    );
    rep7.push(
        Row::new("train flush: per-dest clone (old)")
            .field("bytes_copied", flush_cloned)
            .f("bytes_copied_per_point", per_point_cloned)
            .f("reduction_x", flush_cloned as f64 / flush_shared.max(1) as f64),
    );
    rep7.push(
        Row::new("weight sync: payload-cached")
            .field("bytes_copied", ws_copied_cached)
            .field("payload_clones", ws_clones_cached),
    );
    rep7.push(
        Row::new("weight sync: owned export (old)")
            .field("bytes_copied", ws_copied_owned)
            .field("payload_clones", ws_clones_owned)
            .f("reduction_x", ws_reduction),
    );
    rep7.print();
    println!(
        "(payload-cached weight sync copies {ws_reduction:.1}x fewer bytes over \
         {WS_ROUNDS} unchanged-weight rounds at {WS_RANKS} ranks)"
    );
    let train_json = obj(vec![
        ("bench", Value::Str("train_plane".into())),
        (
            "train_flush",
            obj(vec![
                ("trainers", Value::Num(TF_TRAINERS as f64)),
                ("points", Value::Num(TF_POINTS as f64)),
                ("width", Value::Num(TF_WIDTH as f64)),
                ("bytes_copied_shared", Value::Num(flush_shared as f64)),
                ("bytes_copied_cloned", Value::Num(flush_cloned as f64)),
                ("bytes_copied_per_point_shared", Value::Num(per_point_shared)),
                ("bytes_copied_per_point_cloned", Value::Num(per_point_cloned)),
            ]),
        ),
        (
            "weight_sync",
            obj(vec![
                ("ranks", Value::Num(WS_RANKS as f64)),
                ("weight_len", Value::Num(WS_LEN as f64)),
                ("rounds", Value::Num(WS_ROUNDS as f64)),
                ("bytes_copied_cached", Value::Num(ws_copied_cached as f64)),
                ("bytes_copied_owned", Value::Num(ws_copied_owned as f64)),
                ("payload_clones_cached", Value::Num(ws_clones_cached as f64)),
                ("payload_clones_owned", Value::Num(ws_clones_owned as f64)),
                ("copy_reduction_x", Value::Num(ws_reduction)),
                ("target_met", Value::Bool(ws_reduction >= 4.0)),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_train.json", pal::json::to_string(&train_json)) {
        Ok(()) => println!("wrote BENCH_train.json"),
        Err(e) => eprintln!("failed to write BENCH_train.json: {e}"),
    }

    // ---- (h) oracle plane: green-flow messages per labeled sample ----
    // 4 oracles, identical selection traffic; only the dispatch leg
    // changes. Per-label ships 2 messages per label; batched at
    // oracle_batch.max_size = 8 amortizes 2 frames across up to 8 labels.
    const ORACLE_LABELS: u64 = 160;
    let per_label = oracle_messages(OracleMode::PerLabel, ORACLE_LABELS);
    let batched = oracle_messages(OracleMode::Batched, ORACLE_LABELS);
    let msgs_per_label_pl = per_label.green_msgs as f64 / per_label.labels as f64;
    let msgs_per_label_b = batched.green_msgs as f64 / batched.labels as f64;
    let msg_reduction = msgs_per_label_pl / msgs_per_label_b.max(1e-9);
    let mut rep8 = Report::new(format!(
        "oracle plane — green-flow messages per labeled sample \
         (4 oracles, {ORACLE_LABELS} labels, oracle_batch.max_size = 8)"
    ));
    rep8.push(
        Row::new("per-label (old)")
            .field("green_msgs", per_label.green_msgs)
            .field("labels", per_label.labels)
            .f("msgs_per_label", msgs_per_label_pl)
            .f("bytes_copied_per_label", per_label.bytes_copied as f64 / per_label.labels as f64),
    );
    rep8.push(
        Row::new("batched (oracle plane)")
            .field("green_msgs", batched.green_msgs)
            .field("labels", batched.labels)
            .f("msgs_per_label", msgs_per_label_b)
            .f("bytes_copied_per_label", batched.bytes_copied as f64 / batched.labels as f64)
            .f("msg_reduction_x", msg_reduction),
    );
    rep8.print();
    println!(
        "(batched oracle dispatch ships {msg_reduction:.2}x fewer green-flow messages per \
         label{})",
        if msg_reduction >= 2.0 { " — >= 2x target met" } else { " — BELOW the 2x target" }
    );
    let oracle_json = obj(vec![
        ("bench", Value::Str("oracle_plane".into())),
        ("oracles", Value::Num(4.0)),
        ("labels", Value::Num(ORACLE_LABELS as f64)),
        ("oracle_batch_max_size", Value::Num(8.0)),
        (
            "per_label",
            obj(vec![
                ("green_msgs", Value::Num(per_label.green_msgs as f64)),
                ("labels", Value::Num(per_label.labels as f64)),
                ("msgs_per_label", Value::Num(msgs_per_label_pl)),
                ("bytes_copied", Value::Num(per_label.bytes_copied as f64)),
                ("wall_s", Value::Num(per_label.wall_s)),
            ]),
        ),
        (
            "batched",
            obj(vec![
                ("green_msgs", Value::Num(batched.green_msgs as f64)),
                ("labels", Value::Num(batched.labels as f64)),
                ("msgs_per_label", Value::Num(msgs_per_label_b)),
                ("bytes_copied", Value::Num(batched.bytes_copied as f64)),
                ("wall_s", Value::Num(batched.wall_s)),
            ]),
        ),
        ("msg_reduction_x", Value::Num(msg_reduction)),
        ("target_met", Value::Bool(msg_reduction >= 2.0)),
    ]);
    match std::fs::write("BENCH_oracle.json", pal::json::to_string(&oracle_json)) {
        Ok(()) => println!("wrote BENCH_oracle.json"),
        Err(e) => eprintln!("failed to write BENCH_oracle.json: {e}"),
    }
}
