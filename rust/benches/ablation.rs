//! Design-choice ablations (DESIGN.md §5): knobs the paper exposes but does
//! not sweep — retrain_size (training-buffer threshold), uncertainty
//! patience, and dynamic oracle-list re-scoring. Reports how each choice
//! moves labeling/training throughput on a fixed workload.
//!
//! Run: `cargo bench --bench ablation`

use std::sync::Arc;
use std::time::Duration;

use pal::bench_util::{Report, Row};
use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::sim::workload::{SyntheticGenerator, SyntheticModel, SyntheticOracle};
use pal::telemetry::RunReport;

fn run(retrain_size: usize, dynamic: bool, threshold: f32) -> RunReport {
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-ablation".into(),
        gene_process: 6,
        pred_process: 2,
        ml_process: 2,
        orcl_process: 2,
        retrain_size,
        dynamic_oracle_list: dynamic,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(40),
            max_wall: Some(Duration::from_secs(15)),
            ..Default::default()
        },
        ..Default::default()
    };
    let generators = (0..6usize)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(
                    8,
                    Duration::from_millis(1),
                    u64::MAX,
                    i as u64,
                )) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..2usize)
        .map(|_| {
            Box::new(|| {
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(15),
                    out_dim: 8,
                }) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, replica: usize| {
        let mut m = SyntheticModel::new(
            8,
            8,
            Duration::ZERO,
            Duration::from_micros(400),
            16,
            mode,
        );
        let w: Vec<f32> = (0..64).map(|k| ((k + replica * 11) % 7) as f32 * 0.07).collect();
        m.update(&w);
        Box::new(m) as Box<dyn Model>
    });
    let utils =
        Arc::new(move || Box::new(CommitteeStdUtils::new(threshold, 6)) as Box<dyn Utils>);
    Workflow::new(s)
        .run(KernelSet { generators, oracles, model, utils })
        .unwrap()
}

fn main() {
    // ---- retrain_size sweep: small = fresher models, more flush traffic ----
    let mut rep = Report::new("ablation — retrain_size (training-buffer threshold)");
    for rs in [2usize, 8, 20] {
        let r = run(rs, false, 0.0);
        let manager = &r.kernel("manager")[0];
        rep.push(
            Row::new(format!("retrain_size={rs}"))
                .ms("makespan", r.wall)
                .field("labels", r.oracle_labels)
                .field("retrain_rounds", r.retrain_rounds)
                .field("flushes", manager.counter("train_flushes"))
                .f("weight_syncs", r.sum_counter("prediction", "weight_updates") as f64),
        );
    }
    rep.print();
    println!("(small thresholds buy model freshness with more broadcast/retrain churn)");

    // ---- dynamic oracle list on/off ----
    let mut rep2 = Report::new("ablation — dynamic_orcale_list (buffer re-scoring)");
    for dynamic in [false, true] {
        let r = run(4, dynamic, 0.0);
        let manager = &r.kernel("manager")[0];
        rep2.push(
            Row::new(if dynamic { "on" } else { "off" })
                .ms("makespan", r.wall)
                .field("labels", r.oracle_labels)
                .field("adjustments", manager.counter("adjustments"))
                .field("queue_dropped", manager.counter("adjusted_dropped"))
                .f("rescores", r.sum_counter("prediction", "rescores") as f64),
        );
    }
    rep2.print();
    println!("(re-scoring prunes stale queue entries at the cost of predictor cycles)");

    // ---- selection threshold sweep: labeling pressure vs exploration ----
    let mut rep3 = Report::new("ablation — committee-std selection threshold");
    for th in [0.0f32, 0.2, 0.6] {
        let r = run(8, false, th);
        rep3.push(
            Row::new(format!("threshold={th}"))
                .ms("makespan", r.wall)
                .field("labels", r.oracle_labels)
                .field("selected", r.sum_counter("exchange", "selected_for_oracle"))
                .field("iterations", r.al_iterations),
        );
    }
    rep3.print();
    println!("(higher thresholds label less per iteration; the run needs more");
    println!(" exploration to hit the same label budget — the paper's UQ economy)");
}
