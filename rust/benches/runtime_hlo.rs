//! Runtime hot path (§Perf): per-artifact execution latency on the PJRT CPU
//! client — committee forwards at every exported batch size, the
//! energy-only fused-Pallas euq path, and the single-member train step.
//!
//! Run: `cargo bench --bench runtime_hlo`

use pal::bench_util::{bench, Report, Row};
use pal::runtime::{default_artifacts_dir, Manifest, TensorIn};
use pal::rng::Rng;

fn main() {
    let dir = default_artifacts_dir();
    let manifest = Manifest::load(&dir).expect("run `make artifacts`");
    let engine = pal::runtime::Engine::new(manifest).unwrap();
    let mut rng = Rng::new(0);

    let mut rep = Report::new("runtime — HLO artifact execution latency (PJRT CPU)");
    let names: Vec<String> = engine
        .manifest()
        .entries
        .keys()
        .filter(|n| {
            n.starts_with("potential_ground_fwd")
                || n.starts_with("potential_ground1_fwd")
                || n.starts_with("potential_ground_euq")
                || n.starts_with("potential_photo_fwd")
                || n.starts_with("potential_ground_train")
                || n.starts_with("potential_ground1_train")
                || n.starts_with("surrogate_fwd")
                || n.starts_with("toy_fwd")
        })
        .cloned()
        .collect();

    for name in names {
        let entry = engine.entry(&name).unwrap();
        let inputs: Vec<Vec<f32>> = entry
            .inputs
            .iter()
            .map(|spec| rng.uniform_vec(spec.len(), -0.5, 0.5))
            .collect();
        let tensor_ins: Vec<TensorIn> = inputs.iter().map(|v| TensorIn::F32(v)).collect();
        engine.warm(&name).unwrap();
        let stats = bench(3, 25, || engine.call(&name, &tensor_ins).unwrap());
        let batch = entry.meta.get("batch").as_usize().unwrap_or(1);
        rep.push(
            Row::new(&name)
                .ms("mean", stats.mean())
                .ms("p99", stats.percentile(99.0))
                .f("us_per_sample", stats.mean().as_secs_f64() * 1e6 / batch as f64),
        );
    }
    rep.print();

    // compile-time table (one-time cost per kernel host)
    let mut rep2 = Report::new("runtime — one-time compile cost");
    for name in ["potential_ground_fwd_b89", "potential_ground_train_t32", "toy_fwd_b20"] {
        let m2 = Manifest::load(&dir).unwrap();
        let e2 = pal::runtime::Engine::new(m2).unwrap();
        let ns = e2.warm(name).unwrap();
        rep2.push(Row::new(name).f("compile_ms", ns as f64 / 1e6));
    }
    rep2.print();
}
