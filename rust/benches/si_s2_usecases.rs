//! SI §S2 reproduction: analytic speedup model vs measured runs for the
//! three use cases (DFT+GNN, xTB reaction networks, CFD), at bench-friendly
//! timescales that preserve the paper's cost ratios.
//!
//! Paper predictions: UC1 → S = 1 + P/N (→2 at P=N, oracle-limited
//! otherwise); UC2 → S ≈ 1 (training-bound); UC3 → S → 3 (balanced).
//!
//! Run: `cargo bench --bench si_s2_usecases`

use std::sync::Arc;
use std::time::Duration;

use pal::bench_util::{Report, Row};
use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::SelectAllUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::serial::SerialWorkflow;
use pal::sim::speedup;
use pal::sim::workload::{SyntheticGenerator, SyntheticModel, SyntheticOracle};

/// One scaled use case: times in ms (paper hours/minutes scaled down,
/// ratios preserved).
struct UseCase {
    name: &'static str,
    oracle_ms: u64,
    train_total_ms: u64,
    gen_ms: u64,
    n: usize, // samples per iteration
    p: usize, // oracle workers
    analytic: f64,
}

const EPOCHS: usize = 16;

fn serial_wall(uc: &UseCase, iters: u64) -> Duration {
    let mut w = SerialWorkflow {
        generators: (0..uc.n)
            .map(|i| {
                Box::new(SyntheticGenerator::new(
                    4,
                    Duration::from_millis(uc.gen_ms / uc.n.max(1) as u64),
                    u64::MAX,
                    i as u64,
                )) as Box<dyn Generator>
            })
            .collect(),
        oracles: (0..uc.p)
            .map(|_| {
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(uc.oracle_ms),
                    out_dim: 4,
                }) as Box<dyn Oracle>
            })
            .collect(),
        models: vec![Box::new(SyntheticModel::new(
            4,
            4,
            Duration::ZERO,
            Duration::from_micros(uc.train_total_ms * 1000 / EPOCHS as u64),
            EPOCHS,
            Mode::Train,
        )) as Box<dyn Model>],
        utils: Box::new(SelectAllUtils { max_per_iter: usize::MAX }),
        steps_per_iter: 1,
        iterations: iters,
    };
    w.run().wall
}

fn parallel_wall(uc: &UseCase, iters: u64) -> Duration {
    let _ = iters;
    let labels = iters * uc.n as u64;
    let _ = &labels;
    let s = AlSetting {
        result_dir: "/tmp/pal-bench-s2".into(),
        gene_process: uc.n,
        pred_process: 1,
        ml_process: 1,
        orcl_process: uc.p,
        retrain_size: uc.n,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(labels),
            // equal-work semantics: the serial baseline trains EPOCHS
            // epochs per iteration; require the same total epochs (rounds
            // are variable-sized under interrupts)
            min_train_epochs: iters * EPOCHS as u64,
            max_wall: Some(Duration::from_secs(120)),
            ..Default::default()
        },
        ..Default::default()
    };
    let (gen_ms, n) = (uc.gen_ms, uc.n);
    let oracle_ms = uc.oracle_ms;
    let epoch_us = uc.train_total_ms * 1000 / EPOCHS as u64;
    let generators = (0..uc.n)
        .map(|i| {
            Box::new(move || {
                Box::new(SyntheticGenerator::new(
                    4,
                    Duration::from_millis(gen_ms / n.max(1) as u64),
                    u64::MAX,
                    i as u64,
                )) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles = (0..uc.p)
        .map(|_| {
            Box::new(move || {
                Box::new(SyntheticOracle {
                    label_cost: Duration::from_millis(oracle_ms),
                    out_dim: 4,
                }) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, _r: usize| {
        Box::new(SyntheticModel::new(
            4,
            4,
            Duration::ZERO,
            Duration::from_micros(epoch_us),
            EPOCHS,
            mode,
        )) as Box<dyn Model>
    });
    let utils =
        Arc::new(|| Box::new(SelectAllUtils { max_per_iter: usize::MAX }) as Box<dyn Utils>);
    Workflow::new(s)
        .run(KernelSet { generators, oracles, model, utils })
        .unwrap()
        .wall
}

fn main() {
    // paper: UC1 t_o = t_t = 1 h; UC2 t_o = 10 s, t_t = 1 h, t_gen = 10 min;
    // UC3 all = 10 min. Scaled: 1 h → 80 ms, 10 min → ~13 ms, 10 s → ~0.2ms.
    let cases = [
        UseCase {
            name: "UC1 DFT+GNN (P=N)",
            oracle_ms: 80,
            train_total_ms: 80,
            gen_ms: 1,
            n: 4,
            p: 4,
            analytic: speedup::use_case_1(4, 4).speedup(),
        },
        UseCase {
            name: "UC1 DFT+GNN (P=N/2)",
            oracle_ms: 80,
            train_total_ms: 80,
            gen_ms: 1,
            n: 4,
            p: 2,
            analytic: speedup::use_case_1(4, 2).speedup(),
        },
        UseCase {
            name: "UC2 xTB (train-bound)",
            oracle_ms: 1,
            train_total_ms: 80,
            gen_ms: 13,
            n: 4,
            p: 4,
            analytic: speedup::use_case_2(4, 4).speedup(),
        },
        UseCase {
            name: "UC3 CFD (balanced)",
            oracle_ms: 52,
            train_total_ms: 52,
            gen_ms: 52,
            n: 4,
            p: 4,
            analytic: speedup::use_case_3(4, 4).speedup(),
        },
    ];

    let mut rep = Report::new("SI §S2 — speedup: measured vs analytic (eqs. 1-4)");
    for uc in &cases {
        let iters = 8;
        let ts = serial_wall(uc, iters);
        let tp = parallel_wall(uc, iters);
        rep.push(
            Row::new(uc.name)
                .ms("serial", ts)
                .ms("parallel", tp)
                .f("measured_S", ts.as_secs_f64() / tp.as_secs_f64())
                .f("analytic_S", uc.analytic),
        );
    }
    rep.print();
    println!("(analytic S is a lower bound — the paper notes parallel resources are");
    println!(" never idle, so measured S can exceed it when trainers keep training)");
}
