//! `pal` — launcher CLI for the PAL workflow.
//!
//! ```text
//! pal info                         # artifact + topology summary
//! pal speedup [--n N --p P]        # SI §S2 analytic speedup table
//! pal run [--config file.json]     # run the toy workflow (SI §S3 example)
//! ```

use std::time::Duration;

use pal::cli::Args;
use pal::config::{AlSetting, Topology};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::{KernelSet, Mode};
use pal::runtime::{default_artifacts_dir, Manifest};
use pal::sim::speedup;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "info" => cmd_info(&args),
        "speedup" => cmd_speedup(&args),
        "run" => cmd_run(&args),
        _ => {
            eprintln!(
                "usage: pal <info|speedup|run> [options]\n\
                 \n\
                 info                       artifact + topology summary\n\
                 speedup [--n N --p P]      SI §S2 analytic speedup table\n\
                 run [--config f.json]      run the SI toy workflow\n\
                 \x20   [--iters N]          bound exchange iterations (default 50)\n\
                 \x20   [--transport T]      rank bus backend: channel|shm|tcp\n\
                 \x20   [--metrics-addr A]   serve live /metrics + /status on A\n\
                 \x20                        (e.g. 127.0.0.1:9090; port 0 = ephemeral)\n\
                 \x20   [--trace-out F]      write per-phase Chrome trace JSON to F"
            );
            if cmd == "help" { 0 } else { 2 }
        }
    };
    std::process::exit(code);
}

fn cmd_info(_args: &Args) -> i32 {
    let dir = default_artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("{} artifacts:", m.entries.len());
            for e in m.entries.values() {
                let ins: Vec<String> =
                    e.inputs.iter().map(|t| format!("{}{:?}", t.name, t.shape)).collect();
                println!("  {:32} {}", e.name, ins.join(" "));
            }
        }
        Err(e) => {
            eprintln!("no manifest: {e:#}");
            return 1;
        }
    }
    let s = AlSetting::default_toy();
    let t = Topology::new(&s);
    println!(
        "\ntoy topology: {} ranks (manager=0, exchange=1, pred={:?}, train={:?}, gene={:?}, orcl={:?})",
        t.n_ranks(),
        t.pred,
        t.train,
        t.gene,
        t.orcl
    );
    0
}

fn cmd_speedup(args: &Args) -> i32 {
    let n = args.get_u64("n", 8);
    let p = args.get_u64("p", 8);
    println!("SI §S2 analytic speedup (N={n}, P={p})\n");
    println!("{:<34} {:>9} {:>11} {:>8}", "use case", "T_serial", "T_parallel", "S");
    for (name, w) in [
        ("1: DFT+GNN (t_o = t_t)", speedup::use_case_1(n, p)),
        ("2: xTB oracle (train-bound)", speedup::use_case_2(n, p)),
        ("3: CFD (balanced)", speedup::use_case_3(n, p)),
    ] {
        println!(
            "{:<34} {:>9.2} {:>11.2} {:>8.3}",
            name,
            w.t_serial(),
            w.t_parallel(),
            w.speedup()
        );
    }
    0
}

fn cmd_run(args: &Args) -> i32 {
    let mut setting = match args.get("config") {
        Some(path) => match std::fs::read_to_string(path)
            .map_err(anyhow::Error::from)
            .and_then(|t| AlSetting::from_json(&t))
        {
            Ok(s) => s,
            Err(e) => {
                eprintln!("bad config: {e:#}");
                return 1;
            }
        },
        None => AlSetting::default_toy(),
    };
    let iters = args.get_u64("iters", 50);
    setting.stop.max_iterations = Some(iters);
    setting.stop.max_wall = Some(Duration::from_secs(args.get_u64("max-wall-s", 120)));
    if let Some(t) = args.get("transport") {
        setting.transport = match pal::comm::TransportKind::parse(t) {
            Ok(k) => k,
            Err(e) => {
                eprintln!("bad --transport: {e}");
                return 2;
            }
        };
    }
    if let Some(a) = args.get("metrics-addr") {
        setting.metrics_addr = Some(a.to_string());
    }
    if let Some(f) = args.get("trace-out") {
        setting.trace_out = Some(f.to_string());
    }

    let dir = default_artifacts_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("artifacts missing (run `make artifacts`): {e:#}");
            return 1;
        }
    };

    // SI §S3 toy workflow: random generators, sin-labeling oracles, HLO toy
    // committee (linear 4→4).
    let gens: Vec<_> = (0..setting.gene_process)
        .map(|i| {
            let seed = setting.seed + i as u64;
            Box::new(move || {
                Box::new(pal::kernels::generators::RandomGenerator::new(4, 300_000 + seed, seed))
                    as Box<dyn pal::kernels::Generator>
            }) as Box<dyn FnOnce() -> Box<dyn pal::kernels::Generator> + Send>
        })
        .collect();
    let oracles: Vec<_> = (0..setting.orcl_process)
        .map(|_| {
            Box::new(move || {
                Box::new(pal::sim::workload::SyntheticOracle {
                    label_cost: Duration::from_millis(5),
                    out_dim: 4,
                }) as Box<dyn pal::kernels::Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn pal::kernels::Oracle> + Send>
        })
        .collect();
    let mdir = manifest.dir.clone();
    let model = std::sync::Arc::new(move |mode: Mode, replica: usize| {
        let m = Manifest::load(&mdir).expect("manifest reload");
        Box::new(
            pal::kernels::models::HloToyModel::new(m, mode, replica as u32)
                .expect("toy model build"),
        ) as Box<dyn pal::kernels::Model>
    });
    let utils = std::sync::Arc::new(|| {
        Box::new(CommitteeStdUtils::new(0.05, 8)) as Box<dyn pal::kernels::Utils>
    });

    let kernels = KernelSet { generators: gens, oracles, model, utils };
    match Workflow::new(setting).run(kernels) {
        Ok(report) => {
            println!(
                "done: {} exchange iterations, {} oracle labels, {} retrain rounds in {:.2}s",
                report.al_iterations,
                report.oracle_labels,
                report.retrain_rounds,
                report.wall.as_secs_f64()
            );
            println!(
                "prediction mean latency {:.3} ms; messages {}, payload {} KiB \
                 (physically copied {} KiB in {} buffers)",
                report.mean_timer_ms("prediction", "predict"),
                report.messages,
                report.payload_bytes / 1024,
                report.bytes_copied / 1024,
                report.payload_clones
            );
            if !report.faults.is_clean() {
                let f = &report.faults;
                println!(
                    "DEGRADED: failed ranks {:?}; evictions {} oracle / {} shard; \
                     requeued {} inputs / {} items; lost {} inputs; \
                     {} bad frames, {} dead letters",
                    f.failed_ranks,
                    f.oracle_evictions,
                    f.shard_evictions,
                    f.requeued_inputs,
                    f.requeued_items,
                    f.lost_inputs,
                    f.bad_frames,
                    f.dead_letters
                );
            }
            0
        }
        Err(e) => {
            eprintln!("workflow failed: {e:#}");
            1
        }
    }
}
