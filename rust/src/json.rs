//! Minimal JSON parser + serializer (offline substrate).
//!
//! The offline crate set has no `serde`, so the config system
//! ([`crate::config`]) and the artifact manifest ([`crate::runtime`])
//! parse JSON with this module. Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (sufficient for our ASCII
//! manifests/configs) and serializes deterministically (object keys in
//! insertion order).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    /// Sorted map — manifest/config keys are unique and order-insensitive.
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Value::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        self.as_object().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    /// `a.b.c` style path lookup.
    pub fn path(&self, path: &str) -> &Value {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part);
        }
        cur
    }
}

/// Parse error with byte offset for debuggability.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { offset: self.pos, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        self.skip_ws();
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn parse_value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_object(),
            Some(b'[') => self.parse_array(),
            Some(b'"') => Ok(Value::Str(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Value::Bool(true)),
            Some(b'f') => self.parse_lit("false", Value::Bool(false)),
            Some(b'n') => self.parse_lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_number(),
            _ => self.err("unexpected character"),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("expected '{lit}'"))
        }
    }

    fn parse_number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| ParseError { offset: start, msg: "bad utf8".into() })?;
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| ParseError { offset: start, msg: format!("bad number: {e}") })
    }

    fn parse_string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return self.err("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| ParseError { offset: self.pos, msg: "bad utf8".into() })?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| ParseError { offset: self.pos, msg: "bad hex".into() })?;
                        self.pos += 4;
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| ParseError { offset: start, msg: "bad utf8".into() })?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn parse_array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(arr));
        }
        loop {
            arr.push(self.parse_value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(arr)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn parse_object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.expect(b':')?;
            let val = self.parse_value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document. Trailing whitespace is allowed, trailing garbage is
/// an error.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.parse_value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string(self))
    }
}

/// Serialize a [`Value`] to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => escape_into(s, out),
        Value::Array(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Value::Object(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

/// Convenience builders used by config/telemetry serialization.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Value {
    Value::Array(xs.iter().map(|x| Value::Num(*x)).collect())
}

pub fn arr_f32(xs: &[f32]) -> Value {
    Value::Array(xs.iter().map(|x| Value::Num(*x as f64)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_array().unwrap().len(), 3);
        assert_eq!(v.get("a").as_array().unwrap()[0], Value::Num(1.0));
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
        assert_eq!(v.path("a").as_array().unwrap()[2].get("b"), &Value::Null);
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn parses_utf8_passthrough() {
        assert_eq!(parse("\"åβ\"").unwrap(), Value::Str("åβ".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Object(Default::default()));
        assert_eq!(to_string(&parse("[]").unwrap()), "[]");
    }

    #[test]
    fn escape_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn path_lookup() {
        let v = parse(r#"{"a":{"b":{"c":7}}}"#).unwrap();
        assert_eq!(v.path("a.b.c").as_i64(), Some(7));
        assert_eq!(v.path("a.x.c"), &Value::Null);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(to_string(&Value::Num(3.0)), "3");
        assert_eq!(to_string(&Value::Num(3.25)), "3.25");
    }
}
