//! Müller-Brown surface — the canonical 2-D test PES for transition-state
//! search, standing in for the HAT reaction-path exploration (§3.2).

use super::Pes;
use crate::rng::Rng;

/// The standard 4-Gaussian Müller-Brown surface, scaled by `0.01` so its
/// energy range is O(1) like the other PES here. Treated as one "atom"
/// whose (x, y) are the first two coordinates (z ignored, kept zero).
#[derive(Debug, Clone)]
pub struct MullerBrown {
    pub scale: f64,
}

const A: [f64; 4] = [-200.0, -100.0, -170.0, 15.0];
const AX: [f64; 4] = [-1.0, -1.0, -6.5, 0.7];
const BXY: [f64; 4] = [0.0, 0.0, 11.0, 0.6];
const CY: [f64; 4] = [-10.0, -10.0, -6.5, 0.7];
const X0: [f64; 4] = [1.0, 0.0, -0.5, -1.0];
const Y0: [f64; 4] = [0.0, 0.5, 1.5, 1.0];

/// Approximate locations of the three minima (textbook values).
pub const MINIMA: [(f64, f64); 3] =
    [(-0.558, 1.442), (0.623, 0.028), (-0.050, 0.467)];

impl Default for MullerBrown {
    fn default() -> Self {
        MullerBrown { scale: 0.01 }
    }
}

impl MullerBrown {
    fn eg(&self, x: f64, y: f64) -> (f64, f64, f64) {
        let (mut e, mut gx, mut gy) = (0.0, 0.0, 0.0);
        for k in 0..4 {
            let dx = x - X0[k];
            let dy = y - Y0[k];
            let t = A[k] * (AX[k] * dx * dx + BXY[k] * dx * dy + CY[k] * dy * dy).exp();
            e += t;
            gx += t * (2.0 * AX[k] * dx + BXY[k] * dy);
            gy += t * (BXY[k] * dx + 2.0 * CY[k] * dy);
        }
        (e * self.scale, gx * self.scale, gy * self.scale)
    }
}

impl Pes for MullerBrown {
    fn n_atoms(&self) -> usize {
        1
    }

    fn energy(&self, x: &[f32]) -> f64 {
        self.eg(x[0] as f64, x[1] as f64).0
    }

    fn forces(&self, x: &[f32]) -> Vec<f32> {
        let (_, gx, gy) = self.eg(x[0] as f64, x[1] as f64);
        vec![-gx as f32, -gy as f32, 0.0]
    }

    fn initial_geometry(&self, rng: &mut Rng) -> Vec<f32> {
        let (mx, my) = MINIMA[rng.below(3)];
        vec![
            mx as f32 + (rng.normal() * 0.05) as f32,
            my as f32 + (rng.normal() * 0.05) as f32,
            0.0,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::potential::test_util::check_forces;

    #[test]
    fn minima_are_local_minima() {
        let mb = MullerBrown::default();
        for (mx, my) in MINIMA {
            let e0 = mb.energy(&[mx as f32, my as f32, 0.0]);
            for (dx, dy) in [(0.05, 0.0), (-0.05, 0.0), (0.0, 0.05), (0.0, -0.05)] {
                let e = mb.energy(&[(mx + dx) as f32, (my + dy) as f32, 0.0]);
                assert!(e > e0 - 1e-6, "minimum ({mx},{my}) not minimal: {e0} vs {e}");
            }
        }
    }

    #[test]
    fn global_minimum_is_first() {
        let mb = MullerBrown::default();
        let es: Vec<f64> = MINIMA
            .iter()
            .map(|&(x, y)| mb.energy(&[x as f32, y as f32, 0.0]))
            .collect();
        assert!(es[0] < es[1] && es[0] < es[2], "{es:?}");
    }

    #[test]
    fn forces_match_finite_difference() {
        let mb = MullerBrown::default();
        check_forces(&mb, &[0.2, 0.7, 0.0], 2e-2);
    }
}
