//! Lennard-Jones cluster potential (end-to-end driver workload).

use super::{add_pair_force, dist, Pes};
use crate::rng::Rng;

/// Truncation-free 12-6 Lennard-Jones: `V = Σ 4ε[(σ/r)¹² − (σ/r)⁶]`.
#[derive(Debug, Clone)]
pub struct LennardJones {
    pub n_atoms: usize,
    pub epsilon: f64,
    pub sigma: f64,
}

impl LennardJones {
    pub fn cluster(n: usize) -> Self {
        LennardJones { n_atoms: n, epsilon: 1.0, sigma: 1.0 }
    }

    fn pair_energy(&self, r: f64) -> f64 {
        let sr6 = (self.sigma / r).powi(6);
        4.0 * self.epsilon * (sr6 * sr6 - sr6)
    }

    fn pair_dv_dr(&self, r: f64) -> f64 {
        let sr6 = (self.sigma / r).powi(6);
        4.0 * self.epsilon * (-12.0 * sr6 * sr6 + 6.0 * sr6) / r
    }
}

impl Pes for LennardJones {
    fn n_atoms(&self) -> usize {
        self.n_atoms
    }

    fn energy(&self, x: &[f32]) -> f64 {
        let mut e = 0.0;
        for i in 0..self.n_atoms {
            for j in (i + 1)..self.n_atoms {
                e += self.pair_energy(dist(x, i, j).max(0.3));
            }
        }
        e
    }

    fn forces(&self, x: &[f32]) -> Vec<f32> {
        let mut f = vec![0.0f32; x.len()];
        for i in 0..self.n_atoms {
            for j in (i + 1)..self.n_atoms {
                let r = dist(x, i, j).max(0.3);
                add_pair_force(&mut f, x, i, j, self.pair_dv_dr(r));
            }
        }
        f
    }

    fn initial_geometry(&self, rng: &mut Rng) -> Vec<f32> {
        // jittered cubic lattice at ~2^(1/6) σ spacing (LJ minimum distance)
        let a = 1.12 * self.sigma as f32;
        let side = (self.n_atoms as f64).cbrt().ceil() as usize;
        let mut x = Vec::with_capacity(3 * self.n_atoms);
        'fill: for i in 0..side {
            for j in 0..side {
                for k in 0..side {
                    if x.len() >= 3 * self.n_atoms {
                        break 'fill;
                    }
                    x.push(i as f32 * a + (rng.normal() * 0.03) as f32);
                    x.push(j as f32 * a + (rng.normal() * 0.03) as f32);
                    x.push(k as f32 * a + (rng.normal() * 0.03) as f32);
                }
            }
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::potential::test_util::check_forces;

    #[test]
    fn dimer_minimum_near_two_sixth_sigma() {
        let lj = LennardJones::cluster(2);
        let rmin = 2f64.powf(1.0 / 6.0);
        let e_min = lj.energy(&[0.0, 0.0, 0.0, rmin as f32, 0.0, 0.0]);
        assert!((e_min + 1.0).abs() < 1e-5, "{e_min}");
        for r in [0.95 * rmin, 1.05 * rmin] {
            let e = lj.energy(&[0.0, 0.0, 0.0, r as f32, 0.0, 0.0]);
            assert!(e > e_min);
        }
    }

    #[test]
    fn forces_match_finite_difference() {
        let lj = LennardJones::cluster(5);
        let mut rng = Rng::new(1);
        let x = lj.initial_geometry(&mut rng);
        check_forces(&lj, &x, 5e-3);
    }

    #[test]
    fn initial_geometry_has_no_overlaps() {
        let lj = LennardJones::cluster(8);
        let mut rng = Rng::new(2);
        let x = lj.initial_geometry(&mut rng);
        assert_eq!(x.len(), 24);
        for i in 0..8 {
            for j in (i + 1)..8 {
                assert!(super::dist(&x, i, j) > 0.8);
            }
        }
    }
}
