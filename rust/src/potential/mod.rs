//! Analytic potential-energy surfaces (oracle substrate).
//!
//! The paper's oracles are quantum-chemistry codes (TDDFT/DFT/xTB via
//! Turbomole) and a CFD solver. None are available here, so each application
//! gets an analytic stand-in with the same interface: smooth, nontrivial
//! `energy(x)` and `forces(x) = -∇E` over flat coordinate arrays. AL
//! dynamics only depend on label values + oracle cost (injected separately
//! by [`crate::kernels::oracles::LatencyOracle`]), so these preserve the
//! behaviour the paper's experiments exercise — see DESIGN.md §3.

mod gupta;
mod lj;
mod morse;
pub mod muller_brown;
mod multistate;

pub use gupta::Gupta;
pub use lj::LennardJones;
pub use morse::Morse;
pub use muller_brown::{MullerBrown, MINIMA};
pub use multistate::MultiState;

/// A potential-energy surface over flat `[n_atoms * 3]` coordinates.
pub trait Pes {
    /// Number of atoms.
    fn n_atoms(&self) -> usize;

    /// Total energy.
    fn energy(&self, x: &[f32]) -> f64;

    /// Forces `-∇E`, same length as `x`. Default: central finite
    /// differences (implementations override with analytic forms).
    fn forces(&self, x: &[f32]) -> Vec<f32> {
        let mut f = vec![0.0f32; x.len()];
        let mut xp = x.to_vec();
        let h = 1e-4f32;
        for i in 0..x.len() {
            xp[i] = x[i] + h;
            let ep = self.energy(&xp);
            xp[i] = x[i] - h;
            let em = self.energy(&xp);
            xp[i] = x[i];
            f[i] = (-(ep - em) / (2.0 * h as f64)) as f32;
        }
        f
    }

    /// A reasonable equilibrium-ish starting geometry.
    fn initial_geometry(&self, rng: &mut crate::rng::Rng) -> Vec<f32>;
}

/// Pair distance helper over flat coords.
pub(crate) fn dist(x: &[f32], i: usize, j: usize) -> f64 {
    let (xi, xj) = (&x[3 * i..3 * i + 3], &x[3 * j..3 * j + 3]);
    let dx = (xi[0] - xj[0]) as f64;
    let dy = (xi[1] - xj[1]) as f64;
    let dz = (xi[2] - xj[2]) as f64;
    (dx * dx + dy * dy + dz * dz).sqrt()
}

/// Accumulate a pair force with magnitude `dv_dr` (dV/dr) on atoms i, j.
pub(crate) fn add_pair_force(f: &mut [f32], x: &[f32], i: usize, j: usize, dv_dr: f64) {
    let r = dist(x, i, j).max(1e-9);
    for k in 0..3 {
        let u = ((x[3 * i + k] - x[3 * j + k]) as f64) / r;
        // F_i = -dV/dr * unit(i-j)
        f[3 * i + k] -= (dv_dr * u) as f32;
        f[3 * j + k] += (dv_dr * u) as f32;
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::Pes;

    /// Assert analytic forces match finite differences.
    pub fn check_forces(pes: &dyn Pes, x: &[f32], tol: f64) {
        let f = pes.forces(x);
        let mut xp = x.to_vec();
        let h = 1e-3f32;
        for i in 0..x.len() {
            xp[i] = x[i] + h;
            let ep = pes.energy(&xp);
            xp[i] = x[i] - h;
            let em = pes.energy(&xp);
            xp[i] = x[i];
            let fd = -(ep - em) / (2.0 * h as f64);
            assert!(
                (fd - f[i] as f64).abs() < tol * fd.abs().max(1.0),
                "force mismatch at {i}: analytic {} vs fd {fd}",
                f[i]
            );
        }
    }
}
