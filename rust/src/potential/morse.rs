//! Morse potential — pairwise bonded model, the classic diatomic test PES.

use super::{add_pair_force, dist, Pes};
use crate::rng::Rng;

/// Sum-of-pairs Morse potential:
/// `V = Σ_{i<j} D (1 - exp(-a (r_ij - r0)))² - D`.
#[derive(Debug, Clone)]
pub struct Morse {
    pub n_atoms: usize,
    /// Well depth.
    pub d: f64,
    /// Width parameter.
    pub a: f64,
    /// Equilibrium bond length.
    pub r0: f64,
}

impl Morse {
    /// A dimer with H₂-ish dimensionless parameters.
    pub fn dimer() -> Self {
        Morse { n_atoms: 2, d: 1.0, a: 1.3, r0: 1.4 }
    }

    /// `n`-atom Morse cluster.
    pub fn cluster(n: usize) -> Self {
        Morse { n_atoms: n, d: 1.0, a: 1.3, r0: 1.4 }
    }

    fn pair_energy(&self, r: f64) -> f64 {
        let e = 1.0 - (-self.a * (r - self.r0)).exp();
        self.d * e * e - self.d
    }

    fn pair_dv_dr(&self, r: f64) -> f64 {
        let ex = (-self.a * (r - self.r0)).exp();
        2.0 * self.d * (1.0 - ex) * self.a * ex
    }
}

impl Pes for Morse {
    fn n_atoms(&self) -> usize {
        self.n_atoms
    }

    fn energy(&self, x: &[f32]) -> f64 {
        debug_assert_eq!(x.len(), 3 * self.n_atoms);
        let mut e = 0.0;
        for i in 0..self.n_atoms {
            for j in (i + 1)..self.n_atoms {
                e += self.pair_energy(dist(x, i, j));
            }
        }
        e
    }

    fn forces(&self, x: &[f32]) -> Vec<f32> {
        let mut f = vec![0.0f32; x.len()];
        for i in 0..self.n_atoms {
            for j in (i + 1)..self.n_atoms {
                let r = dist(x, i, j);
                add_pair_force(&mut f, x, i, j, self.pair_dv_dr(r));
            }
        }
        f
    }

    fn initial_geometry(&self, rng: &mut Rng) -> Vec<f32> {
        // atoms on a jittered line at roughly r0 spacing
        let mut x = vec![0.0f32; 3 * self.n_atoms];
        for i in 0..self.n_atoms {
            x[3 * i] = i as f32 * self.r0 as f32;
            for k in 0..3 {
                x[3 * i + k] += (rng.normal() * 0.05) as f32;
            }
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::potential::test_util::check_forces;

    #[test]
    fn minimum_at_r0() {
        let m = Morse::dimer();
        let e_min = m.energy(&[0.0, 0.0, 0.0, 1.4, 0.0, 0.0]);
        let e_off1 = m.energy(&[0.0, 0.0, 0.0, 1.2, 0.0, 0.0]);
        let e_off2 = m.energy(&[0.0, 0.0, 0.0, 1.7, 0.0, 0.0]);
        assert!(e_min < e_off1 && e_min < e_off2);
        assert!((e_min - (-1.0)).abs() < 1e-9); // depth −D at r0
    }

    #[test]
    fn forces_match_finite_difference() {
        let m = Morse::cluster(4);
        let mut rng = Rng::new(0);
        let x = m.initial_geometry(&mut rng);
        check_forces(&m, &x, 1e-3);
    }

    #[test]
    fn forces_vanish_at_equilibrium_dimer() {
        let m = Morse::dimer();
        let f = m.forces(&[0.0, 0.0, 0.0, 1.4, 0.0, 0.0]);
        for fi in f {
            assert!(fi.abs() < 1e-5, "{fi}");
        }
    }

    #[test]
    fn dissociation_limit_is_zero() {
        let m = Morse::dimer();
        let e = m.energy(&[0.0, 0.0, 0.0, 100.0, 0.0, 0.0]);
        assert!(e.abs() < 1e-6);
    }
}
