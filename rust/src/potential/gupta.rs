//! Gupta-style many-body potential — bismuth-cluster stand-in (§3.3).
//!
//! The inorganic-cluster application labels Biₙ cluster geometries with
//! DFT (TPSS/dhf-TZVP). We substitute a second-moment tight-binding
//! (Gupta/RGL) potential: a many-body functional form actually used for
//! heavy metals, so cluster-size-dependent cohesion — the feature the
//! application stresses — is qualitatively right. A per-cluster "charge"
//! global feature scales the pair repulsion, giving distinct PES per charge
//! state as in the paper.

use super::{dist, Pes};
use crate::rng::Rng;

/// Gupta potential: `E_i = A Σ_j exp(-p(r/r0-1)) − √(Σ_j ξ² exp(-2q(r/r0-1)))`.
#[derive(Debug, Clone)]
pub struct Gupta {
    pub n_atoms: usize,
    pub a: f64,
    pub xi: f64,
    pub p: f64,
    pub q: f64,
    pub r0: f64,
    /// Charge state: scales the repulsive prefactor `A(1 + 0.1·charge)`.
    pub charge: f64,
}

impl Gupta {
    /// Bismuth-ish dimensionless parameters (metallic, soft).
    pub fn bismuth(n_atoms: usize, charge: f64) -> Self {
        Gupta { n_atoms, a: 0.0976, xi: 1.244, p: 10.93, q: 2.8, r0: 3.07, charge }
    }

    fn a_eff(&self) -> f64 {
        self.a * (1.0 + 0.1 * self.charge)
    }
}

impl Pes for Gupta {
    fn n_atoms(&self) -> usize {
        self.n_atoms
    }

    fn energy(&self, x: &[f32]) -> f64 {
        let n = self.n_atoms;
        let mut e = 0.0;
        for i in 0..n {
            let mut rep = 0.0;
            let mut att2 = 0.0;
            for j in 0..n {
                if i == j {
                    continue;
                }
                let r = dist(x, i, j).max(0.5);
                rep += self.a_eff() * (-self.p * (r / self.r0 - 1.0)).exp();
                att2 += self.xi * self.xi * (-2.0 * self.q * (r / self.r0 - 1.0)).exp();
            }
            e += rep - att2.sqrt();
        }
        e
    }

    // forces: inherited finite-difference default (the oracle is *supposed*
    // to be expensive — the paper's DFT stand-in; analytic speed is not the
    // point here).

    fn initial_geometry(&self, rng: &mut Rng) -> Vec<f32> {
        let a = self.r0 as f32;
        let side = (self.n_atoms as f64).cbrt().ceil() as usize;
        let mut x = Vec::with_capacity(3 * self.n_atoms);
        'fill: for i in 0..side {
            for j in 0..side {
                for k in 0..side {
                    if x.len() >= 3 * self.n_atoms {
                        break 'fill;
                    }
                    x.push(i as f32 * a + (rng.normal() * 0.1) as f32);
                    x.push(j as f32 * a + (rng.normal() * 0.1) as f32);
                    x.push(k as f32 * a + (rng.normal() * 0.1) as f32);
                }
            }
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimer_binds() {
        let g = Gupta::bismuth(2, 0.0);
        // near r0 the dimer should be bound (negative energy)
        let e = g.energy(&[0.0, 0.0, 0.0, 3.0, 0.0, 0.0]);
        assert!(e < 0.0, "{e}");
        // far apart → ~0
        let e_far = g.energy(&[0.0, 0.0, 0.0, 60.0, 0.0, 0.0]);
        assert!(e_far.abs() < 1e-6);
    }

    #[test]
    fn charge_changes_pes() {
        let neutral = Gupta::bismuth(3, 0.0);
        let cation = Gupta::bismuth(3, 1.0);
        let x = [0.0, 0.0, 0.0, 3.0, 0.0, 0.0, 1.5, 2.6, 0.0];
        assert!((neutral.energy(&x) - cation.energy(&x)).abs() > 1e-6);
    }

    #[test]
    fn cohesion_grows_with_cluster_size() {
        // per-atom energy should decrease (more binding) from dimer to
        // tetramer — the many-body effect LJ/Morse can't show.
        let mut rng = Rng::new(0);
        let e2 = {
            let g = Gupta::bismuth(2, 0.0);
            g.energy(&g.initial_geometry(&mut rng)) / 2.0
        };
        let e4 = {
            let g = Gupta::bismuth(4, 0.0);
            g.energy(&g.initial_geometry(&mut rng)) / 4.0
        };
        assert!(e4 < e2, "per-atom: dimer {e2}, tetramer {e4}");
    }

    #[test]
    fn finite_difference_forces_consistent() {
        // the default FD forces should at least be self-consistent with a
        // coarser FD evaluation
        let g = Gupta::bismuth(3, 0.0);
        let x = [0.0, 0.0, 0.0, 3.0, 0.2, 0.0, 1.4, 2.7, 0.1];
        let f = g.forces(&x);
        assert_eq!(f.len(), 9);
        assert!(f.iter().any(|v| v.abs() > 1e-4));
    }
}
