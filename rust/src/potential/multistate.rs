//! Multi-state PES — excited-state (TDDFT) stand-in for photodynamics (§3.1).
//!
//! The photodynamics application propagates surface-hopping MD on several
//! excited-state surfaces of an organic semiconductor, labeled by TDDFT.
//! We substitute a ladder of Morse-like surfaces with state-dependent well
//! depth, displaced minima, and a harmonic coupling bump near the crossing
//! region — enough structure for committee models to disagree in the
//! crossing zone (where the paper's UQ triggers oracle calls).

use super::{dist, Pes};
use crate::rng::Rng;

/// `n_states` stacked surfaces over an `n_atoms` geometry.
#[derive(Debug, Clone)]
pub struct MultiState {
    pub n_atoms: usize,
    pub n_states: usize,
    pub d: f64,
    pub a: f64,
    pub r0: f64,
    /// Vertical excitation gap between adjacent states.
    pub gap: f64,
}

impl MultiState {
    /// Sulfone-ish toy: 6 atoms, 3 states (S0, S1, S2).
    pub fn photo(n_atoms: usize, n_states: usize) -> Self {
        MultiState { n_atoms, n_states, d: 1.0, a: 1.1, r0: 1.5, gap: 0.8 }
    }

    /// Energy of one state.
    pub fn state_energy(&self, x: &[f32], state: usize) -> f64 {
        debug_assert!(state < self.n_states);
        let s = state as f64;
        // state-displaced equilibrium and shallower well per excitation
        let r0 = self.r0 * (1.0 + 0.08 * s);
        let d = self.d / (1.0 + 0.3 * s);
        let mut e = self.gap * s;
        for i in 0..self.n_atoms {
            for j in (i + 1)..self.n_atoms {
                let r = dist(x, i, j);
                let m = 1.0 - (-self.a * (r - r0)).exp();
                e += d * m * m - d;
                // crossing bump: states approach near r ≈ 1.5 r0
                if state > 0 {
                    let dr = r - 1.5 * self.r0;
                    e -= 0.3 * self.gap * (-dr * dr / 0.08).exp();
                }
            }
        }
        e
    }

    /// Energies of all states.
    pub fn energies(&self, x: &[f32]) -> Vec<f64> {
        (0..self.n_states).map(|s| self.state_energy(x, s)).collect()
    }

    /// Forces on one state via central differences (TDDFT gradients are the
    /// expensive oracle step; cost realism is injected by LatencyOracle).
    pub fn state_forces(&self, x: &[f32], state: usize) -> Vec<f32> {
        let mut f = vec![0.0f32; x.len()];
        let mut xp = x.to_vec();
        let h = 1e-4f32;
        for i in 0..x.len() {
            xp[i] = x[i] + h;
            let ep = self.state_energy(&xp, state);
            xp[i] = x[i] - h;
            let em = self.state_energy(&xp, state);
            xp[i] = x[i];
            f[i] = (-(ep - em) / (2.0 * h as f64)) as f32;
        }
        f
    }
}

impl Pes for MultiState {
    fn n_atoms(&self) -> usize {
        self.n_atoms
    }

    /// Ground-state energy (Pes trait view).
    fn energy(&self, x: &[f32]) -> f64 {
        self.state_energy(x, 0)
    }

    fn forces(&self, x: &[f32]) -> Vec<f32> {
        self.state_forces(x, 0)
    }

    fn initial_geometry(&self, rng: &mut Rng) -> Vec<f32> {
        let mut x = vec![0.0f32; 3 * self.n_atoms];
        for i in 0..self.n_atoms {
            // ring-ish arrangement
            let th = 2.0 * std::f64::consts::PI * i as f64 / self.n_atoms as f64;
            x[3 * i] = (self.r0 * th.cos()) as f32 + (rng.normal() * 0.05) as f32;
            x[3 * i + 1] = (self.r0 * th.sin()) as f32 + (rng.normal() * 0.05) as f32;
            x[3 * i + 2] = (rng.normal() * 0.05) as f32;
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn states_are_ordered_at_equilibrium() {
        let ms = MultiState::photo(4, 3);
        let mut rng = Rng::new(0);
        let x = ms.initial_geometry(&mut rng);
        let es = ms.energies(&x);
        assert!(es[0] < es[1] && es[1] < es[2], "{es:?}");
    }

    #[test]
    fn gap_shrinks_near_crossing_region() {
        let ms = MultiState::photo(2, 2);
        // equilibrium-ish vs stretched into the bump region
        let near = [0.0, 0.0, 0.0, ms.r0 as f32, 0.0, 0.0];
        let cross = [0.0, 0.0, 0.0, (1.5 * ms.r0) as f32, 0.0, 0.0];
        let g_near = ms.state_energy(&near, 1) - ms.state_energy(&near, 0);
        let g_cross = ms.state_energy(&cross, 1) - ms.state_energy(&cross, 0);
        assert!(g_cross < g_near, "gap near {g_near}, at crossing {g_cross}");
    }

    #[test]
    fn state_forces_shape() {
        let ms = MultiState::photo(3, 3);
        let mut rng = Rng::new(1);
        let x = ms.initial_geometry(&mut rng);
        for s in 0..3 {
            assert_eq!(ms.state_forces(&x, s).len(), 9);
        }
    }
}
