//! Device-resident upload cache: skip the host→device literal build for
//! unchanged shared payloads.
//!
//! Every [`Engine::call`](super::Engine::call) used to stage each f32 input
//! as a fresh `Literal` — a host-side copy plus (with a real backend) a
//! host→device transfer — even when the input was the *same unchanged
//! weight buffer* as the previous call. Committee replicas hold their
//! weights as an adopted shared [`Payload`] between syncs, so on the
//! prediction hot path the weights input is byte-identical across thousands
//! of `predict_batch` calls.
//!
//! The cache keys staged literals by **payload identity**
//! ([`Payload::ident`]: backing-`Arc` address + view range): equal identity
//! means the same immutable values, so the staged literal can be reused
//! verbatim. Each entry pins a clone of its payload, which keeps the `Arc`
//! alive and the identity unambiguous (no address reuse while cached).
//! Invalidation is by construction — any local weight write drops the
//! shared payload (`w_shared = None`) and a fresh sync arrives as a new
//! `Arc` with a new identity — so there is no explicit invalidate call to
//! forget; stale entries age out of the FIFO capacity bound.
//!
//! [`UploadStats`] separates reused from uploaded bytes; the release-mode
//! CI pass (`test_mem_plane`) pins a repeat upload of unchanged weights to
//! **zero** staged bytes, and `BENCH_mem.json` tracks the cached-vs-uncached
//! upload volume.

use std::collections::{HashMap, VecDeque};

use anyhow::Context;

use crate::comm::bus::{Payload, PayloadId};

use super::pjrt_stub as xla;

/// Upload accounting: what the cache staged vs. what it skipped.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct UploadStats {
    /// Cache hits: calls served from an already-staged literal.
    pub hits: u64,
    /// Cache misses: fresh host→device literal builds.
    pub misses: u64,
    /// Bytes copied into staged literals (misses only).
    pub bytes_uploaded: u64,
    /// Bytes whose re-upload a hit skipped.
    pub bytes_reused: u64,
}

struct CacheSlot {
    lit: xla::Literal,
    dims: Vec<i64>,
    /// Pins the backing buffer: the identity key stays unambiguous (the
    /// address cannot be recycled by a new allocation) while the slot lives.
    _keepalive: Payload,
}

/// Identity-keyed cache of staged input literals (see module docs).
pub struct UploadCache {
    slots: HashMap<PayloadId, CacheSlot>,
    /// Insertion order for FIFO eviction once `cap` is exceeded.
    order: VecDeque<PayloadId>,
    cap: usize,
    stats: UploadStats,
}

impl UploadCache {
    /// A cache holding at most `cap` staged literals (FIFO eviction).
    pub fn new(cap: usize) -> Self {
        UploadCache {
            slots: HashMap::new(),
            order: VecDeque::new(),
            cap: cap.max(1),
            stats: UploadStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn stats(&self) -> UploadStats {
        self.stats
    }

    /// Make sure `p` is staged for `dims`. Returns `true` when a fresh
    /// upload happened (miss), `false` on a pure-bookkeeping hit. A cached
    /// entry staged under different dims is restaged (counts as a miss).
    pub fn ensure(&mut self, p: &Payload, dims: &[i64]) -> anyhow::Result<bool> {
        let id = p.ident();
        if let Some(slot) = self.slots.get(&id) {
            if slot.dims == dims {
                self.stats.hits += 1;
                self.stats.bytes_reused += 4 * p.len() as u64;
                return Ok(false);
            }
            // same buffer requested under a new shape: drop the stale slot
            self.slots.remove(&id);
            self.order.retain(|k| *k != id);
        }
        let lit = xla::Literal::vec1(p.as_slice())
            .reshape(dims)
            .context("reshaping cached shared input")?;
        self.stats.misses += 1;
        self.stats.bytes_uploaded += 4 * p.len() as u64;
        self.slots.insert(
            id,
            CacheSlot { lit, dims: dims.to_vec(), _keepalive: p.clone() },
        );
        self.order.push_back(id);
        while self.slots.len() > self.cap {
            if let Some(old) = self.order.pop_front() {
                self.slots.remove(&old);
            }
        }
        Ok(true)
    }

    /// The staged literal for `p`, if present.
    pub fn get(&self, p: &Payload) -> Option<&xla::Literal> {
        self.slots.get(&p.ident()).map(|s| &s.lit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeat_ensure_is_a_zero_byte_hit() {
        let mut c = UploadCache::new(4);
        let w = Payload::from(vec![1.0; 8]);
        assert!(c.ensure(&w, &[8]).unwrap(), "first stage is a miss");
        assert!(!c.ensure(&w, &[8]).unwrap(), "second stage is a hit");
        assert!(!c.ensure(&w.clone(), &[8]).unwrap(), "clones share identity");
        let s = c.stats();
        assert_eq!((s.misses, s.hits), (1, 2));
        assert_eq!(s.bytes_uploaded, 32, "exactly one upload of 8 f32");
        assert_eq!(s.bytes_reused, 64);
        assert!(c.get(&w).is_some());
    }

    #[test]
    fn new_buffer_or_new_dims_restages() {
        let mut c = UploadCache::new(4);
        let a = Payload::from(vec![1.0; 6]);
        let b = Payload::from(vec![1.0; 6]); // equal values, new buffer
        assert!(c.ensure(&a, &[6]).unwrap());
        assert!(c.ensure(&b, &[6]).unwrap(), "fresh identity must upload");
        assert!(c.ensure(&a, &[2, 3]).unwrap(), "dims change must restage");
        assert!(!c.ensure(&a, &[2, 3]).unwrap());
        assert_eq!(c.stats().misses, 3);
    }

    #[test]
    fn capacity_evicts_oldest_entry() {
        let mut c = UploadCache::new(2);
        let ws: Vec<Payload> = (0..3).map(|i| Payload::from(vec![i as f32; 4])).collect();
        for w in &ws {
            c.ensure(w, &[4]).unwrap();
        }
        assert_eq!(c.len(), 2);
        assert!(c.get(&ws[0]).is_none(), "oldest entry evicted");
        assert!(c.get(&ws[1]).is_some() && c.get(&ws[2]).is_some());
        // a re-ensure of the evicted payload is a fresh miss
        assert!(c.ensure(&ws[0], &[4]).unwrap());
    }

    #[test]
    fn sub_views_cache_independently() {
        let mut c = UploadCache::new(4);
        let p = Payload::from(vec![0.0, 1.0, 2.0, 3.0]);
        assert!(c.ensure(&p, &[4]).unwrap());
        assert!(c.ensure(&p.slice(0..2), &[2]).unwrap(), "view is its own key");
        assert!(!c.ensure(&p.slice(0..2), &[2]).unwrap());
        assert_eq!(c.len(), 2);
    }
}
