//! Artifact manifest: typed view over `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::json::{self, Value};

/// Element type of a tensor crossing the rust↔HLO boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    U32,
}

impl DType {
    fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "u32" => Ok(DType::U32),
            other => bail!("unsupported dtype in manifest: {other}"),
        }
    }
}

/// Shape + dtype of one artifact input or output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    /// Total element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn parse(v: &Value) -> anyhow::Result<Self> {
        let name = v.get("name").as_str().context("tensor missing name")?.to_string();
        let shape = v
            .get("shape")
            .as_array()
            .context("tensor missing shape")?
            .iter()
            .map(|d| d.as_usize().context("bad dim"))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let dtype = DType::parse(v.get("dtype").as_str().unwrap_or("f32"))?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata written by aot.py (param_size, n_members, ...).
    pub meta: Value,
}

impl ArtifactEntry {
    /// Integer metadata accessor (panics are reserved for programmer error,
    /// so this returns a Result).
    pub fn meta_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.meta
            .get(key)
            .as_usize()
            .with_context(|| format!("artifact {}: missing meta.{key}", self.name))
    }

    pub fn meta_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.meta
            .get(key)
            .as_f64()
            .with_context(|| format!("artifact {}: missing meta.{key}", self.name))
    }

    fn parse(v: &Value) -> anyhow::Result<Self> {
        let name = v.get("name").as_str().context("entry missing name")?.to_string();
        let file = v.get("file").as_str().context("entry missing file")?.to_string();
        let inputs = v
            .get("inputs")
            .as_array()
            .context("entry missing inputs")?
            .iter()
            .map(TensorSpec::parse)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let outputs = v
            .get("outputs")
            .as_array()
            .context("entry missing outputs")?
            .iter()
            .map(TensorSpec::parse)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ArtifactEntry { name, file, inputs, outputs, meta: v.get("meta").clone() })
    }
}

/// Parsed manifest: artifact directory + entries by name.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> anyhow::Result<Self> {
        let v = json::parse(text).context("manifest.json is not valid JSON")?;
        let version = v.get("version").as_i64().unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut entries = BTreeMap::new();
        for e in v.get("entries").as_array().context("manifest missing entries")? {
            let entry = ArtifactEntry::parse(e)?;
            if entries.insert(entry.name.clone(), entry.clone()).is_some() {
                bail!("duplicate artifact name {}", entry.name);
            }
        }
        Ok(Manifest { dir, entries })
    }

    /// Lookup an entry, with a helpful error listing near-misses.
    pub fn entry(&self, name: &str) -> anyhow::Result<&ArtifactEntry> {
        self.entries.get(name).with_context(|| {
            let known: Vec<&str> = self
                .entries
                .keys()
                .filter(|k| k.split('_').next() == name.split('_').next())
                .map(|s| s.as_str())
                .collect();
            format!("unknown artifact {name}; similar: {known:?}")
        })
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// All entries whose name starts with `prefix` (e.g. one model family).
    pub fn with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a ArtifactEntry> {
        self.entries.values().filter(move |e| e.name.starts_with(prefix))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "toy_fwd_b20", "file": "toy_fwd_b20.hlo.txt",
         "inputs": [{"name": "w", "shape": [60], "dtype": "f32"},
                    {"name": "x", "shape": [20, 4], "dtype": "f32"}],
         "outputs": [{"name": "y", "shape": [3, 20, 4], "dtype": "f32"}],
         "meta": {"param_size": 20, "n_members": 3}}
      ]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(MANIFEST, "/tmp".into()).unwrap();
        let e = m.entry("toy_fwd_b20").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[1].shape, vec![20, 4]);
        assert_eq!(e.inputs[1].len(), 80);
        assert_eq!(e.outputs[0].shape, vec![3, 20, 4]);
        assert_eq!(e.meta_usize("param_size").unwrap(), 20);
    }

    #[test]
    fn unknown_entry_is_error() {
        let m = Manifest::parse(MANIFEST, "/tmp".into()).unwrap();
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn version_checked() {
        assert!(Manifest::parse(r#"{"version": 2, "entries": []}"#, "/tmp".into()).is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let dup = MANIFEST.replace("]\n    }", concat!(
            ",{\"name\": \"toy_fwd_b20\", \"file\": \"x\", ",
            "\"inputs\": [], \"outputs\": [], \"meta\": {}}]\n    }"));
        // Only assert when the replace actually produced a duplicate doc.
        if dup != MANIFEST {
            assert!(Manifest::parse(&dup, "/tmp".into()).is_err());
        }
    }

    #[test]
    fn prefix_filter() {
        let m = Manifest::parse(MANIFEST, "/tmp".into()).unwrap();
        assert_eq!(m.with_prefix("toy").count(), 1);
        assert_eq!(m.with_prefix("potential").count(), 0);
    }
}
