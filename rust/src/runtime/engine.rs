//! PJRT execution engine: compile-once, execute-many, flat `Vec<f32>` I/O.

use std::cell::RefCell;
use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Context};

use super::artifact::{ArtifactEntry, DType, Manifest};
// The external `xla` crate needs an XLA/PJRT shared library that the offline
// build can't link; the stub exposes the same API and fails only at compile
// (`Engine::backend_available` lets callers probe before relying on it).
use super::pjrt_stub as xla;
use super::upload_cache::{UploadCache, UploadStats};
use crate::comm::Payload;

/// Borrowed input tensor for [`Engine::call`].
#[derive(Debug, Clone, Copy)]
pub enum TensorIn<'a> {
    /// Flat f32 data; must match the spec's element count. Staged as a
    /// fresh literal on every call — use for per-call data (minibatches).
    F32(&'a [f32]),
    /// Scalar u32 (seeds).
    U32(u32),
    /// Shared f32 payload, staged through the engine's identity-keyed
    /// upload cache: an unchanged payload (same backing buffer and range)
    /// skips the host-side literal build on repeat calls. Use for inputs
    /// that are stable across many calls — committee weights between syncs.
    Shared(&'a Payload),
}

/// Per-artifact execution statistics (used by the §Perf pass).
#[derive(Debug, Default, Clone)]
pub struct CallStats {
    pub calls: u64,
    pub total_ns: u128,
    pub compile_ns: u128,
}

/// A PJRT CPU client plus a lazily-compiled executable cache.
///
/// Not `Send`/`Sync` by construction (raw PJRT handles); build one per
/// kernel-host thread — see the module docs.
pub struct Engine {
    manifest: Manifest,
    client: xla::PjRtClient,
    executables: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: RefCell<HashMap<String, CallStats>>,
    /// Identity-keyed staging cache for [`TensorIn::Shared`] inputs.
    uploads: RefCell<UploadCache>,
}

/// How many distinct shared payloads the upload cache retains per engine.
/// A kernel host stages at most a handful of stable tensors (its own
/// weights, a replicated committee block); 8 leaves headroom without
/// pinning unbounded device memory.
const UPLOAD_CACHE_CAP: usize = 8;

impl Engine {
    /// Create a CPU engine over a manifest.
    pub fn new(manifest: Manifest) -> anyhow::Result<Self> {
        // Many engines (one per kernel rank) share the host: multi-threaded
        // eigen inside each PJRT client oversubscribes the machine and
        // inflates tail latency. Our per-call tensors are small; force
        // single-threaded execution unless the user overrides XLA_FLAGS.
        if std::env::var_os("XLA_FLAGS").is_none() {
            std::env::set_var("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false");
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            manifest,
            client,
            executables: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
            uploads: RefCell::new(UploadCache::new(UPLOAD_CACHE_CAP)),
        })
    }

    /// Convenience: load the default artifacts directory.
    pub fn from_default_dir() -> anyhow::Result<Self> {
        Engine::new(Manifest::load(super::default_artifacts_dir())?)
    }

    /// Whether a real PJRT backend is linked into this build. When false,
    /// [`Engine::call`] fails at compile time for every artifact; callers
    /// that need execution (HLO model kernels, runtime tests) should probe
    /// this and fall back or skip.
    pub fn backend_available() -> bool {
        xla::BACKEND_AVAILABLE
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Look up the artifact entry (shape metadata) for `name`.
    pub fn entry(&self, name: &str) -> anyhow::Result<ArtifactEntry> {
        Ok(self.manifest.entry(name)?.clone())
    }

    /// Ensure `name` is compiled; returns compile wall time in ns (0 if cached).
    pub fn warm(&self, name: &str) -> anyhow::Result<u128> {
        if self.executables.borrow().contains_key(name) {
            return Ok(0);
        }
        let entry = self.manifest.entry(name)?;
        let path = self.manifest.hlo_path(entry);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let dt = t0.elapsed().as_nanos();
        self.executables.borrow_mut().insert(name.to_string(), exe);
        self.stats.borrow_mut().entry(name.to_string()).or_default().compile_ns += dt;
        Ok(dt)
    }

    fn validate(&self, entry: &ArtifactEntry, inputs: &[TensorIn]) -> anyhow::Result<()> {
        if inputs.len() != entry.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                entry.name,
                entry.inputs.len(),
                inputs.len()
            );
        }
        for (spec, input) in entry.inputs.iter().zip(inputs) {
            match (spec.dtype, input) {
                (DType::F32, TensorIn::F32(data)) => {
                    if data.len() != spec.len() {
                        bail!(
                            "artifact {} input {}: expected {} elements ({:?}), got {}",
                            entry.name,
                            spec.name,
                            spec.len(),
                            spec.shape,
                            data.len()
                        );
                    }
                }
                (DType::F32, TensorIn::Shared(p)) => {
                    if p.len() != spec.len() {
                        bail!(
                            "artifact {} input {}: expected {} elements ({:?}), got {}",
                            entry.name,
                            spec.name,
                            spec.len(),
                            spec.shape,
                            p.len()
                        );
                    }
                }
                (DType::U32, TensorIn::U32(_)) => {
                    if !spec.shape.is_empty() {
                        bail!("artifact {} input {}: u32 inputs must be scalar", entry.name, spec.name);
                    }
                }
                (want, _) => {
                    bail!("artifact {} input {}: dtype mismatch (manifest {want:?})", entry.name, spec.name)
                }
            }
        }
        Ok(())
    }

    /// Execute artifact `name`. Returns one flat `Vec<f32>` per output, in
    /// manifest order.
    pub fn call(&self, name: &str, inputs: &[TensorIn]) -> anyhow::Result<Vec<Vec<f32>>> {
        let entry = self.manifest.entry(name)?.clone();
        self.validate(&entry, inputs)?;
        self.warm(name)?;

        // Stage shared inputs through the identity cache first: an unchanged
        // payload reuses its literal from a previous call, skipping the
        // host-side copy entirely.
        {
            let mut uploads = self.uploads.borrow_mut();
            for (spec, input) in entry.inputs.iter().zip(inputs) {
                if let TensorIn::Shared(p) = input {
                    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                    uploads
                        .ensure(p, &dims)
                        .with_context(|| format!("staging shared input {}", spec.name))?;
                }
            }
        }

        // Per-call inputs are staged fresh; `None` marks cache-resident slots.
        let mut owned: Vec<Option<xla::Literal>> = Vec::with_capacity(inputs.len());
        for (spec, input) in entry.inputs.iter().zip(inputs) {
            let lit = match input {
                TensorIn::F32(data) => {
                    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                    Some(
                        xla::Literal::vec1(data)
                            .reshape(&dims)
                            .with_context(|| format!("reshaping input {}", spec.name))?,
                    )
                }
                TensorIn::U32(v) => Some(xla::Literal::scalar(*v)),
                TensorIn::Shared(_) => None,
            };
            owned.push(lit);
        }

        let t0 = Instant::now();
        let uploads = self.uploads.borrow();
        let mut literals: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
        for (input, slot) in inputs.iter().zip(owned.iter()) {
            if let TensorIn::Shared(p) = input {
                literals.push(uploads.get(p).expect("staged above"));
            } else {
                literals.push(slot.as_ref().expect("owned literal staged above"));
            }
        }
        let exes = self.executables.borrow();
        let exe = exes.get(name).expect("warmed above");
        let result = exe
            .execute::<&xla::Literal>(&literals)
            .with_context(|| format!("executing artifact {name}"))?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        drop(exes);
        drop(uploads);

        // aot.py lowers with return_tuple=True — always a tuple root.
        let parts = result.to_tuple().context("decomposing result tuple")?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "artifact {name}: manifest promises {} outputs, executable returned {}",
                entry.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (spec, lit) in entry.outputs.iter().zip(parts) {
            let v = lit
                .to_vec::<f32>()
                .with_context(|| format!("reading output {} of {name}", spec.name))?;
            if v.len() != spec.len() {
                bail!(
                    "artifact {name} output {}: expected {} elements, got {}",
                    spec.name,
                    spec.len(),
                    v.len()
                );
            }
            out.push(v);
        }

        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.total_ns += t0.elapsed().as_nanos();
        Ok(out)
    }

    /// Snapshot of per-artifact stats (name → stats).
    pub fn stats(&self) -> HashMap<String, CallStats> {
        self.stats.borrow().clone()
    }

    /// Snapshot of the shared-input upload cache counters.
    pub fn upload_stats(&self) -> UploadStats {
        self.uploads.borrow().stats()
    }

    /// Mean execution latency of `name` in milliseconds, if called.
    pub fn mean_latency_ms(&self, name: &str) -> Option<f64> {
        let stats = self.stats.borrow();
        let s = stats.get(name)?;
        if s.calls == 0 {
            return None;
        }
        Some(s.total_ns as f64 / s.calls as f64 / 1e6)
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests need built artifacts; they live in `rust/tests/` as
    //! integration tests so `cargo test --lib` stays artifact-free. Here we
    //! only test validation logic against a fake manifest.
    use super::*;
    use std::path::PathBuf;

    fn fake_manifest() -> Manifest {
        Manifest::parse(
            r#"{"version":1,"entries":[
                {"name":"f","file":"f.hlo.txt",
                 "inputs":[{"name":"a","shape":[2,3],"dtype":"f32"},
                           {"name":"s","shape":[],"dtype":"u32"}],
                 "outputs":[{"name":"y","shape":[6],"dtype":"f32"}],
                 "meta":{}}]}"#,
            PathBuf::from("/nonexistent"),
        )
        .unwrap()
    }

    #[test]
    fn validate_checks_arity_and_shape() {
        let engine = Engine::new(fake_manifest()).unwrap();
        let entry = engine.entry("f").unwrap();
        let data = [0f32; 6];
        assert!(engine.validate(&entry, &[TensorIn::F32(&data), TensorIn::U32(1)]).is_ok());
        // wrong arity
        assert!(engine.validate(&entry, &[TensorIn::F32(&data)]).is_err());
        // wrong element count
        let short = [0f32; 5];
        assert!(engine
            .validate(&entry, &[TensorIn::F32(&short), TensorIn::U32(1)])
            .is_err());
        // dtype mismatch
        assert!(engine
            .validate(&entry, &[TensorIn::U32(3), TensorIn::U32(1)])
            .is_err());
        // shared payloads validate like flat f32
        let good = Payload::from(vec![0f32; 6]);
        let bad = Payload::from(vec![0f32; 5]);
        assert!(engine
            .validate(&entry, &[TensorIn::Shared(&good), TensorIn::U32(1)])
            .is_ok());
        assert!(engine
            .validate(&entry, &[TensorIn::Shared(&bad), TensorIn::U32(1)])
            .is_err());
    }

    #[test]
    fn missing_hlo_file_is_error() {
        let engine = Engine::new(fake_manifest()).unwrap();
        let data = [0f32; 6];
        assert!(engine.call("f", &[TensorIn::F32(&data), TensorIn::U32(1)]).is_err());
    }
}
