//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute them.
//!
//! The compile path (`python/compile/aot.py`) lowers every L2 entry point to
//! HLO *text* and records shapes/dtypes plus model metadata in
//! `manifest.json`. This module is the only place the rust side touches XLA:
//!
//! ```text
//! Manifest::load(dir)          — parse manifest.json
//! Engine::new(&manifest)       — PJRT CPU client
//! engine.call(name, &inputs)   — compile-once-then-execute, Vec<f32> I/O
//! ```
//!
//! `Engine` is deliberately **not** `Send`: PJRT handles are thread-affine in
//! the `xla` crate, so each kernel host thread builds its own engine. This
//! mirrors the paper's process model (every MPI rank owns its model replica)
//! and keeps prediction decoupled from training — a training engine running
//! a long step never blocks the prediction engine.

mod artifact;
mod engine;
pub mod pjrt_stub;
mod upload_cache;

pub use artifact::{ArtifactEntry, DType, Manifest, TensorSpec};
pub use engine::{Engine, TensorIn};
pub use upload_cache::{UploadCache, UploadStats};

/// True when HLO artifacts exist *and* a real PJRT backend is linked, i.e.
/// the full artifact execution path can run. Tests and examples that
/// exercise HLO-backed models probe this and skip (loudly) otherwise, the
/// same way GPU-gated suites skip without a device.
pub fn hlo_available() -> bool {
    Engine::backend_available() && Manifest::load(default_artifacts_dir()).is_ok()
}

/// Default artifacts directory, overridable with `PAL_ARTIFACTS`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    match std::env::var("PAL_ARTIFACTS") {
        Ok(p) => p.into(),
        Err(_) => {
            // Walk up from CWD until we find artifacts/manifest.json so
            // examples work from target/ subdirectories too.
            let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
            loop {
                let cand = dir.join("artifacts");
                if cand.join("manifest.json").exists() {
                    return cand;
                }
                if !dir.pop() {
                    return "artifacts".into();
                }
            }
        }
    }
}
