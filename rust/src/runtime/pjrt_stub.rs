//! Offline stand-in for the `xla` crate's PJRT surface.
//!
//! The build environment has no XLA/PJRT shared library, so [`Engine`]
//! (`super::engine`) is compiled against this API-compatible stub instead of
//! the external `xla` crate. Construction, file loading, and shape
//! validation all behave normally; only [`PjRtClient::compile`] fails — with
//! a clear "backend unavailable" error — so every artifact-free code path
//! (manifest parsing, input validation, error reporting) works and tests
//! that need real execution can probe [`BACKEND_AVAILABLE`] and skip.
//!
//! [`Engine`]: super::Engine

/// Whether a real PJRT backend is linked into this build.
pub const BACKEND_AVAILABLE: bool = false;

/// Error for operations that need the real backend.
#[derive(Debug, Clone)]
pub struct Unavailable(pub String);

impl std::fmt::Display for Unavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Unavailable {}

fn unavailable(what: &str) -> Unavailable {
    Unavailable(format!(
        "{what}: PJRT backend not linked into this build (offline stub); \
         rebuild against the xla crate to execute artifacts"
    ))
}

/// Parsed (but not compiled) HLO module text.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read HLO text from disk. Fails on missing/unreadable files exactly
    /// like the real parser, so artifact-path errors surface the same way.
    pub fn from_text_file(path: &str) -> Result<Self, Unavailable> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Unavailable(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// PJRT client handle. Constructible (so engines can be built and validated
/// everywhere); compilation requires the real backend.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Unavailable> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Unavailable> {
        Err(unavailable("compiling HLO"))
    }
}

/// Compiled executable handle (never constructed by the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Unavailable> {
        Err(unavailable("executing"))
    }
}

/// Device buffer handle (never constructed by the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Unavailable> {
        Err(unavailable("fetching buffer"))
    }
}

/// Host literal. Constructible for input staging; device round-trips fail.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn scalar(_v: u32) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal, Unavailable> {
        Ok(Literal)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Unavailable> {
        Err(unavailable("decomposing tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Unavailable> {
        Err(unavailable("reading literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_compile_reports_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation;
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("PJRT backend not linked"));
    }

    #[test]
    fn missing_file_is_a_read_error() {
        let err = HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("reading HLO text"));
    }
}
