//! Micro-benchmark harness (offline `criterion` substitute).
//!
//! Each bench target is a plain binary (`harness = false`); this module
//! provides warmup + timed sampling with mean/p50/p99 reporting and a
//! markdown table writer so bench output can be pasted into
//! EXPERIMENTS.md directly.

use std::time::{Duration, Instant};

pub mod alloc;

pub use alloc::CountingAlloc;

/// Prevent the optimizer from discarding a value (stable-rust black_box).
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

/// Timing statistics over samples.
#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: Vec<Duration>,
}

impl Stats {
    pub fn mean(&self) -> Duration {
        if self.samples.is_empty() {
            return Duration::ZERO;
        }
        self.samples.iter().sum::<Duration>() / self.samples.len() as u32
    }

    fn sorted(&self) -> Vec<Duration> {
        let mut s = self.samples.clone();
        s.sort();
        s
    }

    pub fn percentile(&self, p: f64) -> Duration {
        let s = self.sorted();
        if s.is_empty() {
            return Duration::ZERO;
        }
        let idx = ((s.len() as f64 - 1.0) * p / 100.0).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn min(&self) -> Duration {
        self.samples.iter().min().copied().unwrap_or(Duration::ZERO)
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean().as_secs_f64() * 1e3
    }
}

/// Run `f` for `warmup` unmeasured iterations then `samples` measured ones.
pub fn bench<T>(warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        black_box(f());
        out.push(t0.elapsed());
    }
    Stats { samples: out }
}

/// One row of a bench report.
#[derive(Debug, Clone)]
pub struct Row {
    pub name: String,
    pub fields: Vec<(String, String)>,
}

impl Row {
    pub fn new(name: impl Into<String>) -> Self {
        Row { name: name.into(), fields: vec![] }
    }

    pub fn field(mut self, key: &str, value: impl std::fmt::Display) -> Self {
        self.fields.push((key.to_string(), value.to_string()));
        self
    }

    pub fn ms(self, key: &str, d: Duration) -> Self {
        let v = format!("{:.3}", d.as_secs_f64() * 1e3);
        self.field(key, v)
    }

    pub fn f(self, key: &str, v: f64) -> Self {
        let s = format!("{v:.3}");
        self.field(key, s)
    }
}

/// Markdown table printer: collects rows, prints an aligned table.
#[derive(Debug, Default)]
pub struct Report {
    pub title: String,
    pub rows: Vec<Row>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Self {
        Report { title: title.into(), rows: vec![] }
    }

    pub fn push(&mut self, row: Row) {
        self.rows.push(row);
    }

    /// Render as a GitHub-markdown table.
    pub fn render(&self) -> String {
        let mut out = format!("\n### {}\n\n", self.title);
        if self.rows.is_empty() {
            return out;
        }
        let mut cols: Vec<String> = vec!["case".into()];
        for r in &self.rows {
            for (k, _) in &r.fields {
                if !cols.contains(k) {
                    cols.push(k.clone());
                }
            }
        }
        out.push('|');
        for c in &cols {
            out.push_str(&format!(" {c} |"));
        }
        out.push_str("\n|");
        for _ in &cols {
            out.push_str("---|");
        }
        out.push('\n');
        for r in &self.rows {
            out.push('|');
            out.push_str(&format!(" {} |", r.name));
            for c in cols.iter().skip(1) {
                let v = r
                    .fields
                    .iter()
                    .find(|(k, _)| k == c)
                    .map(|(_, v)| v.as_str())
                    .unwrap_or("");
                out.push_str(&format!(" {v} |"));
            }
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let s = bench(2, 10, || 1 + 1);
        assert_eq!(s.samples.len(), 10);
        assert!(s.mean() < Duration::from_millis(1));
    }

    #[test]
    fn percentiles_ordered() {
        let s = bench(0, 20, || std::thread::sleep(Duration::from_micros(100)));
        assert!(s.percentile(50.0) <= s.percentile(99.0));
        assert!(s.min() <= s.mean());
    }

    #[test]
    fn report_renders_markdown() {
        let mut rep = Report::new("test table");
        rep.push(Row::new("a").field("x", 1).f("y", 2.5));
        rep.push(Row::new("b").field("x", 3));
        let md = rep.render();
        assert!(md.contains("### test table"));
        assert!(md.contains("| a | 1 | 2.500 |"));
        assert!(md.contains("| b | 3 |  |"));
    }
}
