//! Counting allocator for allocation-regression tests and benches.
//!
//! [`CountingAlloc`] wraps the system allocator and counts every
//! allocation (and reallocation) through two global atomics. The library
//! never installs it — production binaries keep the plain system allocator
//! — a bench or test binary opts in with:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: pal::bench_util::CountingAlloc = pal::bench_util::CountingAlloc::new();
//! ```
//!
//! and then brackets the code under measurement with [`alloc_count`]
//! deltas. Counts are exact only while nothing else runs concurrently, so
//! measuring tests must live alone in their test binary (see
//! `rust/tests/test_flat_plane.rs`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Heap allocations observed so far (monotonic; diff around a region).
pub fn alloc_count() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

/// Heap bytes requested so far (monotonic; diff around a region).
pub fn alloc_bytes() -> u64 {
    ALLOC_BYTES.load(Ordering::Relaxed)
}

/// System-allocator wrapper that counts allocations; see the module docs.
#[derive(Default)]
pub struct CountingAlloc;

impl CountingAlloc {
    pub const fn new() -> Self {
        CountingAlloc
    }
}

// SAFETY: defers every operation to `System`; the atomics only observe.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // a grow is the moving cost this crate's flat buffers try to avoid,
        // so count it like a fresh allocation
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}
