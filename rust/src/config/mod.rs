//! Configuration: the paper's `AL_SETTING` dict (SI §S3) as a typed struct,
//! plus the rank topology derived from it.

mod settings;
pub mod topology;

pub use settings::{
    AlSetting, BatchSetting, ExchangeMode, OracleMode, SchedPolicy, SchedSetting, StopCriteria,
};
pub use topology::Topology;
