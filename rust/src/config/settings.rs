//! `AL_SETTING` (SI §S3) as a typed, validated struct.

use std::time::Duration;

use anyhow::{bail, Context};

use crate::comm::TransportKind;
use crate::json::{self, obj, Value};

/// Workflow-level stop criteria (ours; the paper leaves stopping to
/// user-defined kernel logic, these bound a run for benches/tests).
#[derive(Debug, Clone)]
pub struct StopCriteria {
    /// Stop after this many Exchange iterations (None = unbounded).
    pub max_iterations: Option<u64>,
    /// Stop after this many oracle labels (None = unbounded).
    pub max_labels: Option<u64>,
    /// When `max_labels` is set, additionally require this many completed
    /// retraining rounds before stopping — "equal work" semantics for
    /// speedup comparisons against the serial baseline (which always trains
    /// after labeling).
    pub min_retrain_rounds: u64,
    /// When `max_labels` is set, additionally require this many total
    /// training epochs across trainers (equal-work comparisons; interrupts
    /// make *rounds* variable-sized, epochs are the stable unit).
    pub min_train_epochs: u64,
    /// Wall-clock budget.
    pub max_wall: Option<Duration>,
}

impl Default for StopCriteria {
    fn default() -> Self {
        StopCriteria {
            max_iterations: None,
            max_labels: None,
            min_retrain_rounds: 0,
            min_train_epochs: 0,
            max_wall: None,
        }
    }
}

/// How the Exchange relays generator → prediction traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Paper-faithful lockstep rounds: gather one input from every
    /// generator, broadcast the whole list to every prediction rank, gather
    /// the committee's outputs, scatter checked results back.
    Lockstep,
    /// Coalesce concurrent generator requests into micro-batches
    /// ([`BatchSetting`]: size- and deadline-triggered) and route each batch
    /// to one committee *shard* — a group of `committee_size` prediction
    /// ranks holding one replica of each committee member. Batches to
    /// different shards are in flight concurrently; when every shard has
    /// `max_outstanding` batches pending, requests queue (FIFO
    /// backpressure) until a shard frees.
    Batched,
}

/// How the Manager relays selected inputs to the oracle pool (green flow).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OracleMode {
    /// Paper-faithful: one `TAG_TO_ORACLE` message per input, one
    /// `TAG_ORACLE_RESULT` message per label, dispatched to the first free
    /// oracle.
    PerLabel,
    /// Coalesce buffered inputs into micro-batches ([`AlSetting::oracle_batch`]:
    /// size- and deadline-triggered) and dispatch each batch to the
    /// least-loaded oracle (`TAG_ORACLE_BATCH` out, labels-only
    /// `TAG_ORACLE_LABELS` back — the Manager retains the dispatched
    /// inputs, so result frames skip them). Oracles with heterogeneous
    /// latencies naturally receive work
    /// proportional to their speed; when every oracle has
    /// `oracle_batch.max_outstanding` batches in flight, inputs queue in the
    /// oracle buffer (FIFO backpressure). Labels and training-set order are
    /// bit-identical to [`OracleMode::PerLabel`] (single-oracle runs are
    /// FIFO end to end; see `rust/tests/test_determinism.rs`).
    Batched,
}

/// Micro-batching knobs for [`ExchangeMode::Batched`] and
/// [`OracleMode::Batched`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchSetting {
    /// Size trigger: dispatch as soon as this many requests are queued.
    pub max_size: usize,
    /// Deadline trigger: dispatch a partial batch once the oldest queued
    /// request has waited this long.
    pub max_delay: Duration,
    /// Batches in flight per shard before backpressure kicks in.
    pub max_outstanding: usize,
}

impl Default for BatchSetting {
    fn default() -> Self {
        BatchSetting {
            max_size: 8,
            max_delay: Duration::from_millis(2),
            max_outstanding: 2,
        }
    }
}

/// Routing policy for the shared dispatch core
/// ([`crate::coordinator::dispatch`]) used by both the batched exchange
/// (prediction shards) and the batched oracle plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// PR-5 behavior, bit-for-bit: round-robin with least-outstanding
    /// fallback on the prediction plane, least-outstanding (lowest-index
    /// ties) on the oracle plane. Full batches, no health tracking. The
    /// wire- and determinism-default.
    Static,
    /// Latency-aware: per-endpoint EWMA round-trip cost feeds
    /// least-estimated-completion-time routing (deterministic lowest-index
    /// ties), slow endpoints receive proportionally smaller batches, and
    /// endpoints that time out or deliver `evict_after` consecutive slow
    /// responses move to a rejected set (in-flight work requeued) until
    /// they recover.
    Adaptive,
}

/// Knobs for [`SchedPolicy::Adaptive`] plus the latency-scaled shutdown
/// drain (`sched_*` JSON keys). All fields are inert under
/// [`SchedPolicy::Static`] except `drain_factor`, which scales the
/// Manager's shutdown drain bound with observed p95 oracle latency in both
/// policies (the drain only waits longer, never ingests differently, so
/// static-policy label streams stay bit-identical).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedSetting {
    /// Routing policy (`sched_policy`: "static" | "adaptive").
    pub policy: SchedPolicy,
    /// EWMA smoothing for per-item round-trip cost (`sched_ewma_alpha`,
    /// in (0, 1]; 1 = latest sample only).
    pub ewma_alpha: f64,
    /// A completion counts as *slow* when its per-item cost exceeds
    /// `slow_factor ×` the fastest peer's EWMA (`sched_slow_factor`).
    pub slow_factor: f64,
    /// Consecutive slow completions before eviction (`sched_evict_after`).
    pub evict_after: u32,
    /// In-flight batch age that triggers eviction of its endpoint
    /// (`sched_timeout_ms`; absent or 0 disables timeout eviction).
    pub timeout: Option<Duration>,
    /// How long an evicted endpoint stays rejected before it may be routed
    /// to again (`sched_rejoin_ms`). A late reply arriving earlier also
    /// readmits it.
    pub rejoin_backoff: Duration,
    /// Shutdown drain bound = `max(300 ms, drain_factor × p95 RTT)`
    /// (`sched_drain_factor`).
    pub drain_factor: f64,
}

impl Default for SchedSetting {
    fn default() -> Self {
        SchedSetting {
            policy: SchedPolicy::Static,
            ewma_alpha: 0.3,
            slow_factor: 4.0,
            evict_after: 3,
            timeout: None,
            rejoin_backoff: Duration::from_millis(500),
            drain_factor: 3.0,
        }
    }
}

impl SchedSetting {
    pub fn validate(&self) -> anyhow::Result<()> {
        if !(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0) {
            bail!("sched_ewma_alpha must be in (0, 1] (got {})", self.ewma_alpha);
        }
        if !(self.slow_factor >= 1.0) {
            bail!("sched_slow_factor must be >= 1 (got {})", self.slow_factor);
        }
        if self.evict_after == 0 {
            bail!("sched_evict_after must be >= 1");
        }
        if !(self.drain_factor >= 1.0) {
            bail!("sched_drain_factor must be >= 1 (got {})", self.drain_factor);
        }
        Ok(())
    }
}

/// Mirror of the paper's `AL_SETTING` (SI §S3) plus reproduction-specific
/// knobs. Field names follow the paper where a counterpart exists.
#[derive(Debug, Clone)]
pub struct AlSetting {
    /// Directory for metadata/results (`result_dir`).
    pub result_dir: String,
    /// Number of prediction processes (`pred_process`).
    pub pred_process: usize,
    /// Number of oracle processes (`orcl_process`).
    pub orcl_process: usize,
    /// Number of generator processes (`gene_process`).
    pub gene_process: usize,
    /// Number of training processes (`ml_process`).
    pub ml_process: usize,
    /// Fixed-size messages (`fixed_size_data`). When false, payloads carry
    /// a size header on every exchange (extra overhead, see §4).
    pub fixed_size_data: bool,
    /// Seconds between progress snapshots (`progress_save_interval`).
    pub progress_save_interval: Duration,
    /// Labeled samples buffered before a retraining broadcast
    /// (`retrain_size`).
    pub retrain_size: usize,
    /// Re-score the oracle buffer with fresh models after each retraining
    /// (`dynamic_orcale_list` — the paper's spelling).
    pub dynamic_oracle_list: bool,
    /// Task placement per node (`task_per_node`) — informational in the
    /// single-node reproduction, but validated for shape.
    pub task_per_node: Option<Vec<usize>>,
    /// Simulated per-message interconnect latency (reproduction knob;
    /// 0 = in-process).
    pub comm_latency: Duration,
    /// Deterministic seed for all kernel RNG streams.
    pub seed: u64,
    /// Workflow stop criteria.
    pub stop: StopCriteria,
    /// Max epochs per retraining round before the trainer yields to check
    /// for new data (bounded version of the paper's `max_epo`).
    pub epochs_per_round: usize,
    /// Blocking-receive granularity; every blocking wait polls shutdown at
    /// this period.
    pub poll_interval: Duration,
    /// Exchange relay strategy (lockstep rounds vs batched/sharded).
    pub exchange_mode: ExchangeMode,
    /// Micro-batching knobs (used by [`ExchangeMode::Batched`]).
    pub batch: BatchSetting,
    /// Oracle dispatch strategy (per-label messages vs batched frames).
    pub oracle_mode: OracleMode,
    /// Micro-batching knobs for the oracle plane (used by
    /// [`OracleMode::Batched`]).
    pub oracle_batch: BatchSetting,
    /// Dispatch-core routing policy and adaptive knobs (`sched_*` keys),
    /// shared by the batched exchange and the batched oracle plane.
    pub sched: SchedSetting,
    /// Committee members per prediction shard. `None` = all prediction
    /// ranks form one shard (the paper's layout). In batched mode,
    /// `pred_process / committee_size` shards serve batches concurrently,
    /// and each trainer syncs weights to its member's replica in every
    /// shard.
    pub committee_size: Option<usize>,
    /// When true and `stop.max_labels` is set, the Manager never dispatches
    /// more than `max_labels` inputs to the oracles: no oracle hours are
    /// spent past the stop criterion, and the final label count is exact
    /// (required for bit-stable deterministic runs). When false (default),
    /// labeling continues until the stop fires — the paper's behavior, and
    /// what the equal-work speedup benches rely on.
    pub strict_label_budget: bool,
    /// Delivery backend for the rank bus (`"channel"` | `"shm"` |
    /// `"tcp"`); see [`crate::comm::transport`]. `tcp` additionally needs
    /// the multi-process bootstrap (leader/follower entry points).
    pub transport: TransportKind,
    /// When set, `Workflow::run` starts the live metrics/admin HTTP
    /// server ([`crate::telemetry::server`]) on this address for the
    /// duration of the run (`metrics_addr`; e.g. `"127.0.0.1:9090"`,
    /// port 0 for ephemeral). `None` (default) keeps the registry
    /// publication path a no-op.
    pub metrics_addr: Option<String>,
    /// When set, `Workflow::run` records per-rank phase spans
    /// ([`crate::telemetry::trace`]) and drains them into this file as
    /// Chrome trace-event JSON at join (`trace_out`).
    pub trace_out: Option<String>,
}

impl Default for AlSetting {
    fn default() -> Self {
        AlSetting {
            result_dir: "results/run".into(),
            pred_process: 1,
            orcl_process: 1,
            gene_process: 1,
            ml_process: 1,
            fixed_size_data: true,
            progress_save_interval: Duration::from_secs(60),
            retrain_size: 20,
            dynamic_oracle_list: false,
            task_per_node: None,
            comm_latency: Duration::ZERO,
            seed: 0,
            stop: StopCriteria::default(),
            epochs_per_round: 32,
            poll_interval: Duration::from_millis(2),
            exchange_mode: ExchangeMode::Lockstep,
            batch: BatchSetting::default(),
            oracle_mode: OracleMode::PerLabel,
            oracle_batch: BatchSetting::default(),
            sched: SchedSetting::default(),
            committee_size: None,
            strict_label_budget: false,
            transport: TransportKind::Channel,
            metrics_addr: None,
            trace_out: None,
        }
    }
}

/// Convert a user-supplied seconds value into a [`Duration`], rejecting
/// negative (or NaN) input with a config error instead of the panic
/// `Duration::from_secs_f64` would raise.
fn non_negative_secs(key: &str, x: f64) -> anyhow::Result<Duration> {
    if !(x >= 0.0) || !x.is_finite() {
        bail!("{key} must be a non-negative number (got {x})");
    }
    Ok(Duration::from_secs_f64(x))
}

impl AlSetting {
    /// The SI toy configuration (3 predictors, 5 oracles, 20 generators,
    /// 3 trainers), bounded for tests.
    pub fn default_toy() -> Self {
        AlSetting {
            result_dir: "results/toy".into(),
            pred_process: 3,
            orcl_process: 5,
            gene_process: 20,
            ml_process: 3,
            retrain_size: 20,
            stop: StopCriteria {
                max_iterations: Some(200),
                max_labels: Some(200),
                max_wall: Some(Duration::from_secs(60)),
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// Committee members per prediction shard (defaults to every prediction
    /// rank in one shard, the paper's layout).
    pub fn committee(&self) -> usize {
        self.committee_size.unwrap_or(self.pred_process).max(1)
    }

    /// Number of prediction shards (`pred_process / committee()`).
    pub fn n_shards(&self) -> usize {
        (self.pred_process / self.committee()).max(1)
    }

    /// Validate invariants the coordinator relies on.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.pred_process == 0 || self.gene_process == 0 {
            bail!("pred_process and gene_process must be >= 1");
        }
        let committee = self.committee();
        if self.pred_process % committee != 0 {
            bail!(
                "committee_size ({committee}) must divide pred_process ({}): every shard \
                 holds one replica of each committee member",
                self.pred_process
            );
        }
        if self.exchange_mode == ExchangeMode::Lockstep && committee != self.pred_process {
            bail!(
                "lockstep exchange broadcasts to the whole prediction kernel; \
                 committee_size ({committee}) must equal pred_process ({}) — use \
                 exchange_mode = \"batched\" for sharded prediction",
                self.pred_process
            );
        }
        if self.ml_process > 0 && self.ml_process != committee {
            // paper §2.4: "An equal number of ML models as in the prediction
            // kernel are trained in parallel within the training kernel" —
            // with shards, one trainer per distinct member; replicas across
            // shards share that member's weight stream.
            bail!(
                "ml_process ({}) must equal the committee size ({committee}) or be 0 \
                 (training disabled)",
                self.ml_process
            );
        }
        if self.batch.max_size == 0 {
            bail!("batch.max_size must be >= 1");
        }
        if self.batch.max_outstanding == 0 {
            bail!("batch.max_outstanding must be >= 1");
        }
        if self.oracle_batch.max_size == 0 {
            bail!("oracle_batch.max_size must be >= 1");
        }
        if self.oracle_batch.max_outstanding == 0 {
            bail!("oracle_batch.max_outstanding must be >= 1");
        }
        if self.ml_process > 0 && self.retrain_size == 0 {
            bail!("retrain_size must be >= 1 when training is enabled");
        }
        self.sched.validate()?;
        if let Some(tpn) = &self.task_per_node {
            let total: usize = tpn.iter().sum();
            let want = self.pred_process + self.orcl_process + self.gene_process + self.ml_process + 2;
            if total != want {
                bail!("task_per_node sums to {total}, expected {want}");
            }
        }
        Ok(())
    }

    /// Oracle+training kernels disabled → pure prediction-generation loop
    /// (paper §2.5: "can be disabled to convert PAL into a
    /// prediction-generation workflow").
    pub fn is_inference_only(&self) -> bool {
        self.orcl_process == 0 && self.ml_process == 0
    }

    /// Parse from JSON (same field names as SI §S3 where applicable).
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let v = json::parse(text).context("AL setting is not valid JSON")?;
        let mut s = AlSetting::default();
        if let Some(x) = v.get("result_dir").as_str() {
            s.result_dir = x.to_string();
        }
        if let Some(x) = v.get("pred_process").as_usize() {
            s.pred_process = x;
        }
        if let Some(x) = v.get("orcl_process").as_usize() {
            s.orcl_process = x;
        }
        if let Some(x) = v.get("gene_process").as_usize() {
            s.gene_process = x;
        }
        if let Some(x) = v.get("ml_process").as_usize() {
            s.ml_process = x;
        }
        if let Some(x) = v.get("fixed_size_data").as_bool() {
            s.fixed_size_data = x;
        }
        if let Some(x) = v.get("progress_save_interval").as_f64() {
            s.progress_save_interval = non_negative_secs("progress_save_interval", x)?;
        }
        if let Some(x) = v.get("retrain_size").as_usize() {
            s.retrain_size = x;
        }
        if let Some(x) = v.get("dynamic_orcale_list").as_bool() {
            s.dynamic_oracle_list = x;
        }
        if let Some(x) = v.get("dynamic_oracle_list").as_bool() {
            s.dynamic_oracle_list = x;
        }
        if let Some(arr) = v.get("task_per_node").as_array() {
            s.task_per_node =
                Some(arr.iter().filter_map(|x| x.as_usize()).collect());
        }
        if let Some(x) = v.get("comm_latency_ms").as_f64() {
            s.comm_latency = non_negative_secs("comm_latency_ms", x / 1e3)?;
        }
        if let Some(x) = v.get("seed").as_f64() {
            s.seed = x as u64;
        }
        if let Some(x) = v.get("max_iterations").as_f64() {
            s.stop.max_iterations = Some(x as u64);
        }
        if let Some(x) = v.get("max_labels").as_f64() {
            s.stop.max_labels = Some(x as u64);
        }
        if let Some(x) = v.get("max_wall_s").as_f64() {
            s.stop.max_wall = Some(non_negative_secs("max_wall_s", x)?);
        }
        if let Some(x) = v.get("epochs_per_round").as_usize() {
            s.epochs_per_round = x;
        }
        if let Some(x) = v.get("exchange_mode").as_str() {
            s.exchange_mode = match x {
                "lockstep" => ExchangeMode::Lockstep,
                "batched" => ExchangeMode::Batched,
                other => bail!("unknown exchange_mode: {other} (lockstep|batched)"),
            };
        }
        if let Some(x) = v.get("batch_max_size").as_usize() {
            s.batch.max_size = x;
        }
        if let Some(x) = v.get("batch_max_delay_ms").as_f64() {
            s.batch.max_delay = non_negative_secs("batch_max_delay_ms", x / 1e3)?;
        }
        if let Some(x) = v.get("batch_max_outstanding").as_usize() {
            s.batch.max_outstanding = x;
        }
        if let Some(x) = v.get("oracle_mode").as_str() {
            s.oracle_mode = match x {
                "per_label" => OracleMode::PerLabel,
                "batched" => OracleMode::Batched,
                other => bail!("unknown oracle_mode: {other} (per_label|batched)"),
            };
        }
        if let Some(x) = v.get("oracle_batch_max_size").as_usize() {
            s.oracle_batch.max_size = x;
        }
        if let Some(x) = v.get("oracle_batch_max_delay_ms").as_f64() {
            s.oracle_batch.max_delay = non_negative_secs("oracle_batch_max_delay_ms", x / 1e3)?;
        }
        if let Some(x) = v.get("oracle_batch_max_outstanding").as_usize() {
            s.oracle_batch.max_outstanding = x;
        }
        if let Some(x) = v.get("sched_policy").as_str() {
            s.sched.policy = match x {
                "static" => SchedPolicy::Static,
                "adaptive" => SchedPolicy::Adaptive,
                other => bail!("unknown sched_policy: {other} (static|adaptive)"),
            };
        }
        if let Some(x) = v.get("sched_ewma_alpha").as_f64() {
            s.sched.ewma_alpha = x;
        }
        if let Some(x) = v.get("sched_slow_factor").as_f64() {
            s.sched.slow_factor = x;
        }
        if let Some(x) = v.get("sched_evict_after").as_usize() {
            s.sched.evict_after = x as u32;
        }
        if let Some(x) = v.get("sched_timeout_ms").as_f64() {
            let d = non_negative_secs("sched_timeout_ms", x / 1e3)?;
            s.sched.timeout = if d.is_zero() { None } else { Some(d) };
        }
        if let Some(x) = v.get("sched_rejoin_ms").as_f64() {
            s.sched.rejoin_backoff = non_negative_secs("sched_rejoin_ms", x / 1e3)?;
        }
        if let Some(x) = v.get("sched_drain_factor").as_f64() {
            s.sched.drain_factor = x;
        }
        if let Some(x) = v.get("committee_size").as_usize() {
            s.committee_size = Some(x);
        }
        if let Some(x) = v.get("strict_label_budget").as_bool() {
            s.strict_label_budget = x;
        }
        if let Some(x) = v.get("transport").as_str() {
            s.transport = match TransportKind::parse(x) {
                Ok(k) => k,
                Err(e) => bail!("{e}"),
            };
        }
        if let Some(x) = v.get("metrics_addr").as_str() {
            if !x.is_empty() {
                s.metrics_addr = Some(x.to_string());
            }
        }
        if let Some(x) = v.get("trace_out").as_str() {
            if !x.is_empty() {
                s.trace_out = Some(x.to_string());
            }
        }
        s.validate()?;
        Ok(s)
    }

    /// Serialize (for progress snapshots / reproducibility records).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("result_dir", Value::Str(self.result_dir.clone())),
            ("pred_process", Value::Num(self.pred_process as f64)),
            ("orcl_process", Value::Num(self.orcl_process as f64)),
            ("gene_process", Value::Num(self.gene_process as f64)),
            ("ml_process", Value::Num(self.ml_process as f64)),
            ("fixed_size_data", Value::Bool(self.fixed_size_data)),
            (
                "progress_save_interval",
                Value::Num(self.progress_save_interval.as_secs_f64()),
            ),
            ("retrain_size", Value::Num(self.retrain_size as f64)),
            ("dynamic_orcale_list", Value::Bool(self.dynamic_oracle_list)),
            ("comm_latency_ms", Value::Num(self.comm_latency.as_secs_f64() * 1e3)),
            ("seed", Value::Num(self.seed as f64)),
            ("epochs_per_round", Value::Num(self.epochs_per_round as f64)),
            (
                "exchange_mode",
                Value::Str(
                    match self.exchange_mode {
                        ExchangeMode::Lockstep => "lockstep",
                        ExchangeMode::Batched => "batched",
                    }
                    .into(),
                ),
            ),
            ("batch_max_size", Value::Num(self.batch.max_size as f64)),
            (
                "batch_max_delay_ms",
                Value::Num(self.batch.max_delay.as_secs_f64() * 1e3),
            ),
            ("batch_max_outstanding", Value::Num(self.batch.max_outstanding as f64)),
            (
                "oracle_mode",
                Value::Str(
                    match self.oracle_mode {
                        OracleMode::PerLabel => "per_label",
                        OracleMode::Batched => "batched",
                    }
                    .into(),
                ),
            ),
            ("oracle_batch_max_size", Value::Num(self.oracle_batch.max_size as f64)),
            (
                "oracle_batch_max_delay_ms",
                Value::Num(self.oracle_batch.max_delay.as_secs_f64() * 1e3),
            ),
            (
                "oracle_batch_max_outstanding",
                Value::Num(self.oracle_batch.max_outstanding as f64),
            ),
            (
                "sched_policy",
                Value::Str(
                    match self.sched.policy {
                        SchedPolicy::Static => "static",
                        SchedPolicy::Adaptive => "adaptive",
                    }
                    .into(),
                ),
            ),
            ("sched_ewma_alpha", Value::Num(self.sched.ewma_alpha)),
            ("sched_slow_factor", Value::Num(self.sched.slow_factor)),
            ("sched_evict_after", Value::Num(self.sched.evict_after as f64)),
            (
                "sched_timeout_ms",
                Value::Num(self.sched.timeout.map_or(0.0, |d| d.as_secs_f64() * 1e3)),
            ),
            (
                "sched_rejoin_ms",
                Value::Num(self.sched.rejoin_backoff.as_secs_f64() * 1e3),
            ),
            ("sched_drain_factor", Value::Num(self.sched.drain_factor)),
            ("committee_size", Value::Num(self.committee() as f64)),
            ("strict_label_budget", Value::Bool(self.strict_label_budget)),
            ("transport", Value::Str(self.transport.as_str().into())),
            // empty string = unset; from_json treats "" as None
            (
                "metrics_addr",
                Value::Str(self.metrics_addr.clone().unwrap_or_default()),
            ),
            ("trace_out", Value::Str(self.trace_out.clone().unwrap_or_default())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        AlSetting::default().validate().unwrap();
        AlSetting::default_toy().validate().unwrap();
    }

    #[test]
    fn trainer_predictor_parity_enforced() {
        let s = AlSetting { pred_process: 3, ml_process: 2, ..Default::default() };
        assert!(s.validate().is_err());
        let ok = AlSetting { pred_process: 3, ml_process: 3, ..Default::default() };
        assert!(ok.validate().is_ok());
        let disabled = AlSetting { pred_process: 3, ml_process: 0, ..Default::default() };
        assert!(disabled.validate().is_ok());
    }

    #[test]
    fn zero_generators_rejected() {
        let s = AlSetting { gene_process: 0, ..Default::default() };
        assert!(s.validate().is_err());
    }

    #[test]
    fn task_per_node_sum_checked() {
        let mut s = AlSetting::default_toy();
        s.task_per_node = Some(vec![1, 2]);
        assert!(s.validate().is_err());
        let want = s.pred_process + s.orcl_process + s.gene_process + s.ml_process + 2;
        s.task_per_node = Some(vec![want / 2, want - want / 2]);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn json_roundtrip() {
        let s = AlSetting::default_toy();
        let text = json::to_string(&s.to_json());
        let s2 = AlSetting::from_json(&text).unwrap();
        assert_eq!(s2.pred_process, s.pred_process);
        assert_eq!(s2.gene_process, s.gene_process);
        assert_eq!(s2.retrain_size, s.retrain_size);
        assert_eq!(s2.fixed_size_data, s.fixed_size_data);
    }

    #[test]
    fn transport_key_roundtrips_and_rejects_unknown() {
        // default stays the channel bus
        assert_eq!(AlSetting::default().transport, TransportKind::Channel);
        for (spelling, kind) in [
            ("channel", TransportKind::Channel),
            ("shm", TransportKind::Shm),
            ("tcp", TransportKind::Tcp),
        ] {
            let s =
                AlSetting::from_json(&format!(r#"{{"transport": "{spelling}"}}"#)).unwrap();
            assert_eq!(s.transport, kind);
            // round-trip through to_json preserves the spelling
            let s2 = AlSetting::from_json(&json::to_string(&s.to_json())).unwrap();
            assert_eq!(s2.transport, kind);
        }
        // unknown value is a loud error naming the accepted spellings
        let err = AlSetting::from_json(r#"{"transport": "carrier-pigeon"}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown transport"), "got: {err}");
        assert!(err.contains("channel|shm|tcp"), "got: {err}");
    }

    #[test]
    fn observability_keys_roundtrip() {
        // unset by default, emitted as "" and parsed back as None
        let s = AlSetting::default();
        assert_eq!(s.metrics_addr, None);
        assert_eq!(s.trace_out, None);
        let s2 = AlSetting::from_json(&json::to_string(&s.to_json())).unwrap();
        assert_eq!(s2.metrics_addr, None);
        assert_eq!(s2.trace_out, None);
        // set values survive the round-trip
        let s = AlSetting::from_json(
            r#"{"metrics_addr": "127.0.0.1:9090", "trace_out": "trace.json"}"#,
        )
        .unwrap();
        assert_eq!(s.metrics_addr.as_deref(), Some("127.0.0.1:9090"));
        assert_eq!(s.trace_out.as_deref(), Some("trace.json"));
        let s2 = AlSetting::from_json(&json::to_string(&s.to_json())).unwrap();
        assert_eq!(s2.metrics_addr, s.metrics_addr);
        assert_eq!(s2.trace_out, s.trace_out);
    }

    #[test]
    fn json_accepts_paper_field_spelling() {
        let s = AlSetting::from_json(
            r#"{"pred_process": 2, "ml_process": 2, "dynamic_orcale_list": true,
                "retrain_size": 5}"#,
        )
        .unwrap();
        assert!(s.dynamic_oracle_list);
        assert_eq!(s.retrain_size, 5);
    }

    #[test]
    fn json_accepts_correct_oracle_list_spelling_and_it_wins_on_conflict() {
        // the correctly spelled key alone works
        let s = AlSetting::from_json(r#"{"dynamic_oracle_list": true}"#).unwrap();
        assert!(s.dynamic_oracle_list);
        // on conflict, the correct spelling wins over the paper's typo
        let s = AlSetting::from_json(
            r#"{"dynamic_orcale_list": true, "dynamic_oracle_list": false}"#,
        )
        .unwrap();
        assert!(!s.dynamic_oracle_list);
        let s = AlSetting::from_json(
            r#"{"dynamic_orcale_list": false, "dynamic_oracle_list": true}"#,
        )
        .unwrap();
        assert!(s.dynamic_oracle_list);
        // serialization keeps emitting the paper key for round-trip
        // compatibility with SI §S3 configs, and the value survives
        let mut s = AlSetting::default();
        s.dynamic_oracle_list = true;
        let text = json::to_string(&s.to_json());
        assert!(text.contains("dynamic_orcale_list"), "paper key emitted: {text}");
        assert!(!text.contains("\"dynamic_oracle_list\""), "only the paper key: {text}");
        assert!(AlSetting::from_json(&text).unwrap().dynamic_oracle_list);
    }

    #[test]
    fn sched_knobs_validated_and_roundtrip() {
        // defaults: static policy, valid
        let s = AlSetting::default();
        assert_eq!(s.sched.policy, SchedPolicy::Static);
        s.validate().unwrap();

        let s = AlSetting::from_json(
            r#"{"sched_policy": "adaptive", "sched_ewma_alpha": 0.5,
                "sched_slow_factor": 3, "sched_evict_after": 2,
                "sched_timeout_ms": 250, "sched_rejoin_ms": 1000,
                "sched_drain_factor": 2}"#,
        )
        .unwrap();
        assert_eq!(s.sched.policy, SchedPolicy::Adaptive);
        assert_eq!(s.sched.ewma_alpha, 0.5);
        assert_eq!(s.sched.slow_factor, 3.0);
        assert_eq!(s.sched.evict_after, 2);
        assert_eq!(s.sched.timeout, Some(Duration::from_millis(250)));
        assert_eq!(s.sched.rejoin_backoff, Duration::from_secs(1));
        assert_eq!(s.sched.drain_factor, 2.0);
        let text = json::to_string(&s.to_json());
        let s2 = AlSetting::from_json(&text).unwrap();
        assert_eq!(s2.sched, s.sched);

        // timeout 0 = disabled, and survives a round-trip as such
        let s = AlSetting::from_json(r#"{"sched_timeout_ms": 0}"#).unwrap();
        assert_eq!(s.sched.timeout, None);
        let s2 = AlSetting::from_json(&json::to_string(&s.to_json())).unwrap();
        assert_eq!(s2.sched.timeout, None);

        // bad knobs are clean errors
        for bad in [
            r#"{"sched_policy": "bogus"}"#,
            r#"{"sched_ewma_alpha": 0}"#,
            r#"{"sched_ewma_alpha": 1.5}"#,
            r#"{"sched_slow_factor": 0.5}"#,
            r#"{"sched_evict_after": 0}"#,
            r#"{"sched_timeout_ms": -1}"#,
            r#"{"sched_rejoin_ms": -1}"#,
            r#"{"sched_drain_factor": 0.2}"#,
        ] {
            assert!(AlSetting::from_json(bad).is_err(), "{bad} must be a clean error");
        }
    }

    #[test]
    fn sharded_committee_validation() {
        let mut s = AlSetting { pred_process: 4, ml_process: 2, ..Default::default() };
        s.committee_size = Some(2);
        // lockstep broadcasts to every predictor: one shard only
        assert!(s.validate().is_err());
        s.exchange_mode = ExchangeMode::Batched;
        assert!(s.validate().is_ok());
        assert_eq!(s.committee(), 2);
        assert_eq!(s.n_shards(), 2);
        // committee must divide pred_process
        s.committee_size = Some(3);
        s.ml_process = 3;
        assert!(s.validate().is_err());
        // trainers must match members, not replicas
        s.committee_size = Some(2);
        s.ml_process = 4;
        assert!(s.validate().is_err());
    }

    #[test]
    fn batch_knobs_validated_and_roundtrip() {
        let mut s = AlSetting::default();
        s.batch.max_size = 0;
        assert!(s.validate().is_err());
        s.batch.max_size = 4;
        s.batch.max_outstanding = 0;
        assert!(s.validate().is_err());

        let s = AlSetting::from_json(
            r#"{"pred_process": 4, "ml_process": 2, "committee_size": 2,
                "exchange_mode": "batched", "batch_max_size": 16,
                "batch_max_delay_ms": 5, "batch_max_outstanding": 3}"#,
        )
        .unwrap();
        assert_eq!(s.exchange_mode, ExchangeMode::Batched);
        assert_eq!(s.batch.max_size, 16);
        assert_eq!(s.batch.max_delay, Duration::from_millis(5));
        assert_eq!(s.batch.max_outstanding, 3);
        assert_eq!(s.n_shards(), 2);
        let text = json::to_string(&s.to_json());
        let s2 = AlSetting::from_json(&text).unwrap();
        assert_eq!(s2.exchange_mode, s.exchange_mode);
        assert_eq!(s2.batch, s.batch);
        assert_eq!(s2.committee(), s.committee());
    }

    #[test]
    fn oracle_batch_knobs_validated_and_roundtrip() {
        let mut s = AlSetting::default();
        s.oracle_batch.max_size = 0;
        assert!(s.validate().is_err());
        s.oracle_batch.max_size = 4;
        s.oracle_batch.max_outstanding = 0;
        assert!(s.validate().is_err());

        let s = AlSetting::from_json(
            r#"{"oracle_mode": "batched", "oracle_batch_max_size": 16,
                "oracle_batch_max_delay_ms": 5, "oracle_batch_max_outstanding": 3}"#,
        )
        .unwrap();
        assert_eq!(s.oracle_mode, OracleMode::Batched);
        assert_eq!(s.oracle_batch.max_size, 16);
        assert_eq!(s.oracle_batch.max_delay, Duration::from_millis(5));
        assert_eq!(s.oracle_batch.max_outstanding, 3);
        let text = json::to_string(&s.to_json());
        let s2 = AlSetting::from_json(&text).unwrap();
        assert_eq!(s2.oracle_mode, s.oracle_mode);
        assert_eq!(s2.oracle_batch, s.oracle_batch);
        assert!(AlSetting::from_json(r#"{"oracle_mode": "bogus"}"#).is_err());
    }

    #[test]
    fn negative_durations_rejected_not_panicking() {
        for bad in [
            r#"{"oracle_batch_max_delay_ms": -5}"#,
            r#"{"batch_max_delay_ms": -1}"#,
            r#"{"progress_save_interval": -2}"#,
            r#"{"comm_latency_ms": -3}"#,
            r#"{"max_wall_s": -4}"#,
        ] {
            assert!(AlSetting::from_json(bad).is_err(), "{bad} must be a clean error");
        }
    }

    #[test]
    fn inference_only_detection() {
        let mut s = AlSetting::default();
        s.orcl_process = 0;
        s.ml_process = 0;
        assert!(s.is_inference_only());
    }
}
