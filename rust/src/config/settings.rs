//! `AL_SETTING` (SI §S3) as a typed, validated struct.

use std::time::Duration;

use anyhow::{bail, Context};

use crate::json::{self, obj, Value};

/// Workflow-level stop criteria (ours; the paper leaves stopping to
/// user-defined kernel logic, these bound a run for benches/tests).
#[derive(Debug, Clone)]
pub struct StopCriteria {
    /// Stop after this many Exchange iterations (None = unbounded).
    pub max_iterations: Option<u64>,
    /// Stop after this many oracle labels (None = unbounded).
    pub max_labels: Option<u64>,
    /// When `max_labels` is set, additionally require this many completed
    /// retraining rounds before stopping — "equal work" semantics for
    /// speedup comparisons against the serial baseline (which always trains
    /// after labeling).
    pub min_retrain_rounds: u64,
    /// When `max_labels` is set, additionally require this many total
    /// training epochs across trainers (equal-work comparisons; interrupts
    /// make *rounds* variable-sized, epochs are the stable unit).
    pub min_train_epochs: u64,
    /// Wall-clock budget.
    pub max_wall: Option<Duration>,
}

impl Default for StopCriteria {
    fn default() -> Self {
        StopCriteria {
            max_iterations: None,
            max_labels: None,
            min_retrain_rounds: 0,
            min_train_epochs: 0,
            max_wall: None,
        }
    }
}

/// Mirror of the paper's `AL_SETTING` (SI §S3) plus reproduction-specific
/// knobs. Field names follow the paper where a counterpart exists.
#[derive(Debug, Clone)]
pub struct AlSetting {
    /// Directory for metadata/results (`result_dir`).
    pub result_dir: String,
    /// Number of prediction processes (`pred_process`).
    pub pred_process: usize,
    /// Number of oracle processes (`orcl_process`).
    pub orcl_process: usize,
    /// Number of generator processes (`gene_process`).
    pub gene_process: usize,
    /// Number of training processes (`ml_process`).
    pub ml_process: usize,
    /// Fixed-size messages (`fixed_size_data`). When false, payloads carry
    /// a size header on every exchange (extra overhead, see §4).
    pub fixed_size_data: bool,
    /// Seconds between progress snapshots (`progress_save_interval`).
    pub progress_save_interval: Duration,
    /// Labeled samples buffered before a retraining broadcast
    /// (`retrain_size`).
    pub retrain_size: usize,
    /// Re-score the oracle buffer with fresh models after each retraining
    /// (`dynamic_orcale_list` — the paper's spelling).
    pub dynamic_oracle_list: bool,
    /// Task placement per node (`task_per_node`) — informational in the
    /// single-node reproduction, but validated for shape.
    pub task_per_node: Option<Vec<usize>>,
    /// Simulated per-message interconnect latency (reproduction knob;
    /// 0 = in-process).
    pub comm_latency: Duration,
    /// Deterministic seed for all kernel RNG streams.
    pub seed: u64,
    /// Workflow stop criteria.
    pub stop: StopCriteria,
    /// Max epochs per retraining round before the trainer yields to check
    /// for new data (bounded version of the paper's `max_epo`).
    pub epochs_per_round: usize,
    /// Blocking-receive granularity; every blocking wait polls shutdown at
    /// this period.
    pub poll_interval: Duration,
}

impl Default for AlSetting {
    fn default() -> Self {
        AlSetting {
            result_dir: "results/run".into(),
            pred_process: 1,
            orcl_process: 1,
            gene_process: 1,
            ml_process: 1,
            fixed_size_data: true,
            progress_save_interval: Duration::from_secs(60),
            retrain_size: 20,
            dynamic_oracle_list: false,
            task_per_node: None,
            comm_latency: Duration::ZERO,
            seed: 0,
            stop: StopCriteria::default(),
            epochs_per_round: 32,
            poll_interval: Duration::from_millis(2),
        }
    }
}

impl AlSetting {
    /// The SI toy configuration (3 predictors, 5 oracles, 20 generators,
    /// 3 trainers), bounded for tests.
    pub fn default_toy() -> Self {
        AlSetting {
            result_dir: "results/toy".into(),
            pred_process: 3,
            orcl_process: 5,
            gene_process: 20,
            ml_process: 3,
            retrain_size: 20,
            stop: StopCriteria {
                max_iterations: Some(200),
                max_labels: Some(200),
                max_wall: Some(Duration::from_secs(60)),
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// Validate invariants the coordinator relies on.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.pred_process == 0 || self.gene_process == 0 {
            bail!("pred_process and gene_process must be >= 1");
        }
        if self.ml_process > 0 && self.ml_process != self.pred_process {
            // paper §2.4: "An equal number of ML models as in the prediction
            // kernel are trained in parallel within the training kernel"
            bail!(
                "ml_process ({}) must equal pred_process ({}) or be 0 (training disabled)",
                self.ml_process,
                self.pred_process
            );
        }
        if self.ml_process > 0 && self.retrain_size == 0 {
            bail!("retrain_size must be >= 1 when training is enabled");
        }
        if let Some(tpn) = &self.task_per_node {
            let total: usize = tpn.iter().sum();
            let want = self.pred_process + self.orcl_process + self.gene_process + self.ml_process + 2;
            if total != want {
                bail!("task_per_node sums to {total}, expected {want}");
            }
        }
        Ok(())
    }

    /// Oracle+training kernels disabled → pure prediction-generation loop
    /// (paper §2.5: "can be disabled to convert PAL into a
    /// prediction-generation workflow").
    pub fn is_inference_only(&self) -> bool {
        self.orcl_process == 0 && self.ml_process == 0
    }

    /// Parse from JSON (same field names as SI §S3 where applicable).
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let v = json::parse(text).context("AL setting is not valid JSON")?;
        let mut s = AlSetting::default();
        if let Some(x) = v.get("result_dir").as_str() {
            s.result_dir = x.to_string();
        }
        if let Some(x) = v.get("pred_process").as_usize() {
            s.pred_process = x;
        }
        if let Some(x) = v.get("orcl_process").as_usize() {
            s.orcl_process = x;
        }
        if let Some(x) = v.get("gene_process").as_usize() {
            s.gene_process = x;
        }
        if let Some(x) = v.get("ml_process").as_usize() {
            s.ml_process = x;
        }
        if let Some(x) = v.get("fixed_size_data").as_bool() {
            s.fixed_size_data = x;
        }
        if let Some(x) = v.get("progress_save_interval").as_f64() {
            s.progress_save_interval = Duration::from_secs_f64(x);
        }
        if let Some(x) = v.get("retrain_size").as_usize() {
            s.retrain_size = x;
        }
        if let Some(x) = v.get("dynamic_orcale_list").as_bool() {
            s.dynamic_oracle_list = x;
        }
        if let Some(x) = v.get("dynamic_oracle_list").as_bool() {
            s.dynamic_oracle_list = x;
        }
        if let Some(arr) = v.get("task_per_node").as_array() {
            s.task_per_node =
                Some(arr.iter().filter_map(|x| x.as_usize()).collect());
        }
        if let Some(x) = v.get("comm_latency_ms").as_f64() {
            s.comm_latency = Duration::from_secs_f64(x / 1e3);
        }
        if let Some(x) = v.get("seed").as_f64() {
            s.seed = x as u64;
        }
        if let Some(x) = v.get("max_iterations").as_f64() {
            s.stop.max_iterations = Some(x as u64);
        }
        if let Some(x) = v.get("max_labels").as_f64() {
            s.stop.max_labels = Some(x as u64);
        }
        if let Some(x) = v.get("max_wall_s").as_f64() {
            s.stop.max_wall = Some(Duration::from_secs_f64(x));
        }
        if let Some(x) = v.get("epochs_per_round").as_usize() {
            s.epochs_per_round = x;
        }
        s.validate()?;
        Ok(s)
    }

    /// Serialize (for progress snapshots / reproducibility records).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("result_dir", Value::Str(self.result_dir.clone())),
            ("pred_process", Value::Num(self.pred_process as f64)),
            ("orcl_process", Value::Num(self.orcl_process as f64)),
            ("gene_process", Value::Num(self.gene_process as f64)),
            ("ml_process", Value::Num(self.ml_process as f64)),
            ("fixed_size_data", Value::Bool(self.fixed_size_data)),
            (
                "progress_save_interval",
                Value::Num(self.progress_save_interval.as_secs_f64()),
            ),
            ("retrain_size", Value::Num(self.retrain_size as f64)),
            ("dynamic_orcale_list", Value::Bool(self.dynamic_oracle_list)),
            ("comm_latency_ms", Value::Num(self.comm_latency.as_secs_f64() * 1e3)),
            ("seed", Value::Num(self.seed as f64)),
            ("epochs_per_round", Value::Num(self.epochs_per_round as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        AlSetting::default().validate().unwrap();
        AlSetting::default_toy().validate().unwrap();
    }

    #[test]
    fn trainer_predictor_parity_enforced() {
        let s = AlSetting { pred_process: 3, ml_process: 2, ..Default::default() };
        assert!(s.validate().is_err());
        let ok = AlSetting { pred_process: 3, ml_process: 3, ..Default::default() };
        assert!(ok.validate().is_ok());
        let disabled = AlSetting { pred_process: 3, ml_process: 0, ..Default::default() };
        assert!(disabled.validate().is_ok());
    }

    #[test]
    fn zero_generators_rejected() {
        let s = AlSetting { gene_process: 0, ..Default::default() };
        assert!(s.validate().is_err());
    }

    #[test]
    fn task_per_node_sum_checked() {
        let mut s = AlSetting::default_toy();
        s.task_per_node = Some(vec![1, 2]);
        assert!(s.validate().is_err());
        let want = s.pred_process + s.orcl_process + s.gene_process + s.ml_process + 2;
        s.task_per_node = Some(vec![want / 2, want - want / 2]);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn json_roundtrip() {
        let s = AlSetting::default_toy();
        let text = json::to_string(&s.to_json());
        let s2 = AlSetting::from_json(&text).unwrap();
        assert_eq!(s2.pred_process, s.pred_process);
        assert_eq!(s2.gene_process, s.gene_process);
        assert_eq!(s2.retrain_size, s.retrain_size);
        assert_eq!(s2.fixed_size_data, s.fixed_size_data);
    }

    #[test]
    fn json_accepts_paper_field_spelling() {
        let s = AlSetting::from_json(
            r#"{"pred_process": 2, "ml_process": 2, "dynamic_orcale_list": true,
                "retrain_size": 5}"#,
        )
        .unwrap();
        assert!(s.dynamic_oracle_list);
        assert_eq!(s.retrain_size, 5);
    }

    #[test]
    fn inference_only_detection() {
        let mut s = AlSetting::default();
        s.orcl_process = 0;
        s.ml_process = 0;
        assert!(s.is_inference_only());
    }
}
