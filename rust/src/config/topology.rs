//! Rank topology: the paper's process layout.
//!
//! "The total number of processes initialized by PAL should be the summation
//! of processes in the four kernels with two additional processes for the
//! Controller" (SI §S3). Rank 0 is the Manager sub-kernel, rank 1 the
//! Exchange sub-kernel (Fig. 2's two controller boxes), then prediction,
//! training, generator, and oracle ranks in contiguous blocks.

use super::AlSetting;

/// Derived rank layout for one workflow run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    pub pred: std::ops::Range<usize>,
    pub train: std::ops::Range<usize>,
    pub gene: std::ops::Range<usize>,
    pub orcl: std::ops::Range<usize>,
}

/// Manager controller rank (buffers, oracle dispatch, shutdown).
pub const MANAGER: usize = 0;
/// Exchange controller rank (high-frequency generator↔prediction loop).
pub const EXCHANGE: usize = 1;

impl Topology {
    pub fn new(s: &AlSetting) -> Self {
        let pred_start = 2;
        let train_start = pred_start + s.pred_process;
        let gene_start = train_start + s.ml_process;
        let orcl_start = gene_start + s.gene_process;
        Topology {
            pred: pred_start..train_start,
            train: train_start..gene_start,
            gene: gene_start..orcl_start,
            orcl: orcl_start..orcl_start + s.orcl_process,
        }
    }

    /// Total number of ranks (kernels + 2 controller sub-kernels).
    pub fn n_ranks(&self) -> usize {
        self.orcl.end
    }

    pub fn pred_ranks(&self) -> Vec<usize> {
        self.pred.clone().collect()
    }

    pub fn train_ranks(&self) -> Vec<usize> {
        self.train.clone().collect()
    }

    pub fn gene_ranks(&self) -> Vec<usize> {
        self.gene.clone().collect()
    }

    pub fn orcl_ranks(&self) -> Vec<usize> {
        self.orcl.clone().collect()
    }

    /// The predictor that trainer `train_rank` pushes weights to
    /// (paper: prediction models are replicas of training models, 1:1).
    pub fn predictor_for_trainer(&self, train_rank: usize) -> usize {
        debug_assert!(self.train.contains(&train_rank));
        self.pred.start + (train_rank - self.train.start)
    }

    /// Index of a generator rank within the generator kernel (0-based),
    /// used to order scatter lists ("sorted by the rank of generator").
    pub fn gene_index(&self, rank: usize) -> usize {
        debug_assert!(self.gene.contains(&rank));
        rank - self.gene.start
    }

    /// Which kernel a rank belongs to (for telemetry labels).
    pub fn kernel_of(&self, rank: usize) -> &'static str {
        if rank == MANAGER {
            "manager"
        } else if rank == EXCHANGE {
            "exchange"
        } else if self.pred.contains(&rank) {
            "prediction"
        } else if self.train.contains(&rank) {
            "training"
        } else if self.gene.contains(&rank) {
            "generator"
        } else if self.orcl.contains(&rank) {
            "oracle"
        } else {
            "unknown"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Topology {
        Topology::new(&AlSetting::default_toy())
    }

    #[test]
    fn layout_matches_si_example() {
        // SI §S3: 3 pred + 5 orcl + 20 gene + 3 ml + 2 controller = 33
        let t = toy();
        assert_eq!(t.n_ranks(), 33);
        assert_eq!(t.pred, 2..5);
        assert_eq!(t.train, 5..8);
        assert_eq!(t.gene, 8..28);
        assert_eq!(t.orcl, 28..33);
    }

    #[test]
    fn blocks_are_disjoint_and_cover() {
        let t = toy();
        let mut seen = vec![0u8; t.n_ranks()];
        seen[MANAGER] += 1;
        seen[EXCHANGE] += 1;
        for r in t.pred_ranks().into_iter()
            .chain(t.train_ranks())
            .chain(t.gene_ranks())
            .chain(t.orcl_ranks())
        {
            seen[r] += 1;
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }

    #[test]
    fn trainer_predictor_pairing() {
        let t = toy();
        assert_eq!(t.predictor_for_trainer(5), 2);
        assert_eq!(t.predictor_for_trainer(7), 4);
    }

    #[test]
    fn kernel_labels() {
        let t = toy();
        assert_eq!(t.kernel_of(0), "manager");
        assert_eq!(t.kernel_of(1), "exchange");
        assert_eq!(t.kernel_of(2), "prediction");
        assert_eq!(t.kernel_of(5), "training");
        assert_eq!(t.kernel_of(8), "generator");
        assert_eq!(t.kernel_of(28), "oracle");
    }

    #[test]
    fn disabled_kernels_shrink_world() {
        let s = AlSetting {
            pred_process: 2,
            ml_process: 0,
            orcl_process: 0,
            gene_process: 4,
            ..Default::default()
        };
        let t = Topology::new(&s);
        assert_eq!(t.n_ranks(), 8);
        assert!(t.train_ranks().is_empty());
        assert!(t.orcl_ranks().is_empty());
    }
}
