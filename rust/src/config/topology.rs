//! Rank topology: the paper's process layout.
//!
//! "The total number of processes initialized by PAL should be the summation
//! of processes in the four kernels with two additional processes for the
//! Controller" (SI §S3). Rank 0 is the Manager sub-kernel, rank 1 the
//! Exchange sub-kernel (Fig. 2's two controller boxes), then prediction,
//! training, generator, and oracle ranks in contiguous blocks.

use super::AlSetting;

/// Derived rank layout for one workflow run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    pub pred: std::ops::Range<usize>,
    pub train: std::ops::Range<usize>,
    pub gene: std::ops::Range<usize>,
    pub orcl: std::ops::Range<usize>,
    /// Committee members per prediction shard. Prediction rank
    /// `pred.start + i` hosts member `i % committee` of shard
    /// `i / committee`; the default (one shard) is the paper's layout.
    pub committee: usize,
}

/// Manager controller rank (buffers, oracle dispatch, shutdown).
pub const MANAGER: usize = 0;
/// Exchange controller rank (high-frequency generator↔prediction loop).
pub const EXCHANGE: usize = 1;

impl Topology {
    pub fn new(s: &AlSetting) -> Self {
        let pred_start = 2;
        let train_start = pred_start + s.pred_process;
        let gene_start = train_start + s.ml_process;
        let orcl_start = gene_start + s.gene_process;
        Topology {
            pred: pred_start..train_start,
            train: train_start..gene_start,
            gene: gene_start..orcl_start,
            orcl: orcl_start..orcl_start + s.orcl_process,
            committee: s.committee(),
        }
    }

    /// Total number of ranks (kernels + 2 controller sub-kernels).
    pub fn n_ranks(&self) -> usize {
        self.orcl.end
    }

    pub fn pred_ranks(&self) -> Vec<usize> {
        self.pred.clone().collect()
    }

    pub fn train_ranks(&self) -> Vec<usize> {
        self.train.clone().collect()
    }

    pub fn gene_ranks(&self) -> Vec<usize> {
        self.gene.clone().collect()
    }

    pub fn orcl_ranks(&self) -> Vec<usize> {
        self.orcl.clone().collect()
    }

    /// Number of prediction shards (groups of `committee` ranks).
    pub fn n_shards(&self) -> usize {
        (self.pred.len() / self.committee.max(1)).max(1)
    }

    /// Ranks of prediction shard `shard` (one replica of every member).
    pub fn shard_ranks(&self, shard: usize) -> Vec<usize> {
        debug_assert!(shard < self.n_shards());
        let start = self.pred.start + shard * self.committee;
        (start..start + self.committee).collect()
    }

    /// All shards, as rank lists (shard 0 first).
    pub fn shards(&self) -> Vec<Vec<usize>> {
        (0..self.n_shards()).map(|s| self.shard_ranks(s)).collect()
    }

    /// Committee-member index hosted by prediction rank `pred_rank`.
    pub fn member_of_pred(&self, pred_rank: usize) -> usize {
        debug_assert!(self.pred.contains(&pred_rank));
        (pred_rank - self.pred.start) % self.committee.max(1)
    }

    /// The first-shard predictor paired with trainer `train_rank`
    /// (paper: prediction models are replicas of training models, 1:1).
    pub fn predictor_for_trainer(&self, train_rank: usize) -> usize {
        debug_assert!(self.train.contains(&train_rank));
        self.pred.start + (train_rank - self.train.start)
    }

    /// Every replica of trainer `train_rank`'s member across all shards —
    /// weight pushes go to each so shards stay interchangeable.
    pub fn replicas_for_trainer(&self, train_rank: usize) -> Vec<usize> {
        debug_assert!(self.train.contains(&train_rank));
        let member = train_rank - self.train.start;
        (0..self.n_shards())
            .map(|s| self.pred.start + s * self.committee + member)
            .collect()
    }

    /// Prediction ranks the Manager targets for oracle-buffer re-scoring:
    /// one full committee (the first shard) is enough — replicas in other
    /// shards hold the same member weights.
    pub fn rescore_ranks(&self) -> Vec<usize> {
        if self.pred.is_empty() {
            vec![]
        } else {
            self.shard_ranks(0)
        }
    }

    /// Index of a generator rank within the generator kernel (0-based),
    /// used to order scatter lists ("sorted by the rank of generator").
    pub fn gene_index(&self, rank: usize) -> usize {
        debug_assert!(self.gene.contains(&rank));
        rank - self.gene.start
    }

    /// Which kernel a rank belongs to (for telemetry labels).
    pub fn kernel_of(&self, rank: usize) -> &'static str {
        if rank == MANAGER {
            "manager"
        } else if rank == EXCHANGE {
            "exchange"
        } else if self.pred.contains(&rank) {
            "prediction"
        } else if self.train.contains(&rank) {
            "training"
        } else if self.gene.contains(&rank) {
            "generator"
        } else if self.orcl.contains(&rank) {
            "oracle"
        } else {
            "unknown"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Topology {
        Topology::new(&AlSetting::default_toy())
    }

    #[test]
    fn layout_matches_si_example() {
        // SI §S3: 3 pred + 5 orcl + 20 gene + 3 ml + 2 controller = 33
        let t = toy();
        assert_eq!(t.n_ranks(), 33);
        assert_eq!(t.pred, 2..5);
        assert_eq!(t.train, 5..8);
        assert_eq!(t.gene, 8..28);
        assert_eq!(t.orcl, 28..33);
    }

    #[test]
    fn blocks_are_disjoint_and_cover() {
        let t = toy();
        let mut seen = vec![0u8; t.n_ranks()];
        seen[MANAGER] += 1;
        seen[EXCHANGE] += 1;
        for r in t.pred_ranks().into_iter()
            .chain(t.train_ranks())
            .chain(t.gene_ranks())
            .chain(t.orcl_ranks())
        {
            seen[r] += 1;
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }

    #[test]
    fn trainer_predictor_pairing() {
        let t = toy();
        assert_eq!(t.predictor_for_trainer(5), 2);
        assert_eq!(t.predictor_for_trainer(7), 4);
    }

    #[test]
    fn kernel_labels() {
        let t = toy();
        assert_eq!(t.kernel_of(0), "manager");
        assert_eq!(t.kernel_of(1), "exchange");
        assert_eq!(t.kernel_of(2), "prediction");
        assert_eq!(t.kernel_of(5), "training");
        assert_eq!(t.kernel_of(8), "generator");
        assert_eq!(t.kernel_of(28), "oracle");
    }

    #[test]
    fn sharded_layout_partitions_predictors() {
        // 6 predictors, committee 2 → shards {2,3} {4,5} {6,7}
        let s = AlSetting {
            pred_process: 6,
            ml_process: 2,
            committee_size: Some(2),
            exchange_mode: crate::config::ExchangeMode::Batched,
            ..Default::default()
        };
        let t = Topology::new(&s);
        assert_eq!(t.n_shards(), 3);
        assert_eq!(t.shard_ranks(0), vec![2, 3]);
        assert_eq!(t.shard_ranks(2), vec![6, 7]);
        let all: Vec<usize> = t.shards().into_iter().flatten().collect();
        assert_eq!(all, t.pred_ranks());
        // member layout: rank 2 and 4 and 6 host member 0; 3/5/7 member 1
        assert_eq!(t.member_of_pred(2), 0);
        assert_eq!(t.member_of_pred(5), 1);
        assert_eq!(t.member_of_pred(6), 0);
        // trainer 8 (member 0) syncs ranks 2, 4, 6; trainer 9 → 3, 5, 7
        assert_eq!(t.train, 8..10);
        assert_eq!(t.replicas_for_trainer(8), vec![2, 4, 6]);
        assert_eq!(t.replicas_for_trainer(9), vec![3, 5, 7]);
        assert_eq!(t.rescore_ranks(), vec![2, 3]);
    }

    #[test]
    fn single_shard_matches_legacy_pairing() {
        let t = toy();
        assert_eq!(t.n_shards(), 1);
        assert_eq!(t.shard_ranks(0), t.pred_ranks());
        assert_eq!(t.replicas_for_trainer(5), vec![t.predictor_for_trainer(5)]);
        assert_eq!(t.rescore_ranks(), t.pred_ranks());
    }

    #[test]
    fn disabled_kernels_shrink_world() {
        let s = AlSetting {
            pred_process: 2,
            ml_process: 0,
            orcl_process: 0,
            gene_process: 4,
            ..Default::default()
        };
        let t = Topology::new(&s);
        assert_eq!(t.n_ranks(), 8);
        assert!(t.train_ranks().is_empty());
        assert!(t.orcl_ranks().is_empty());
    }
}
