//! Deterministic Müller–Brown active-learning scenario.
//!
//! A full Manager + Exchange workflow whose labels, retrain rounds, and
//! final training losses are **bit-stable across runs** — the shared
//! harness behind `rust/tests/test_determinism.rs` (oracle-plane and
//! memory-plane pins) and `rust/tests/test_transport.rs` (cross-backend
//! bit-identity, TCP loopback e2e).
//!
//! Determinism is by construction, not by luck:
//!
//! * generators are fixed-seed walkers that ignore `data_to_gene`, so
//!   trajectories don't depend on when weight syncs land;
//! * selection is a pure function of the *inputs* (Müller–Brown energy
//!   threshold), not of the committee's predictions;
//! * batches are full (`batch.max_size = gene_process`, long deadline) and
//!   items are ordered by origin rank inside a batch, so batch composition
//!   is arrival-order independent;
//! * a single oracle labels in dispatch order, and the Manager's strict
//!   label budget (`strict_label_budget`) dispatches exactly
//!   `stop.max_labels` inputs — never an in-flight extra;
//! * trainers run fixed-epoch rounds (interrupts ignored), so the final
//!   loss is a pure function of the (deterministic) labeled dataset.
//!
//! Because no part of the recipe depends on message *timing* — only on
//! per-(src, tag) FIFO order, which every transport backend guarantees —
//! the same scenario must produce bit-identical results over the
//! `channel`, `shm`, and (single-host) `tcp` transports. That is exactly
//! the cross-backend conformance contract.

use std::sync::Arc;
use std::time::Duration;

use crate::comm::TransportKind;
use crate::config::{AlSetting, BatchSetting, ExchangeMode, OracleMode, StopCriteria};
use crate::coordinator::selection::committee_mean;
use crate::coordinator::workflow::Workflow;
use crate::kernels::oracles::PesOracle;
use crate::kernels::{Generator, KernelSet, Mode, Model, Oracle, OracleFactory, Utils};
use crate::potential::{MullerBrown, Pes};
use crate::rng::Rng;
use crate::sim::workload::SyntheticModel;
use crate::telemetry::RunReport;

/// Wire layout for a 1-"atom" PES with 1 global and 1 state:
/// input `[x, y, z, g, s]`, label `[e, fx, fy, fz]`.
pub const IN_DIM: usize = 5;
/// Label width: `[e, fx, fy, fz]`.
pub const OUT_DIM: usize = 4;

/// Generator count (and batch size — full batches only).
pub const GENS: usize = 4;
/// Committee members (= trainer count).
pub const MEMBERS: usize = 2;
/// Prediction shards per committee member.
pub const SHARDS: usize = 2;
/// Strict oracle-label budget for the run.
pub const LABELS: u64 = 12;
/// Labeled pairs per retrain flush.
pub const RETRAIN_SIZE: usize = 4;

/// Fixed-seed random walker over the Müller–Brown landscape. Ignores the
/// checked predictions entirely: the trajectory is a pure function of the
/// seed, which is what makes the whole loop replayable.
pub struct MbWalker {
    rng: Rng,
    pos: [f32; 2],
}

impl MbWalker {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let pes = MullerBrown::default();
        let x0 = pes.initial_geometry(&mut rng);
        MbWalker { rng, pos: [x0[0], x0[1]] }
    }
}

impl Generator for MbWalker {
    fn generate_new_data(&mut self, _data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>) {
        self.pos[0] += (self.rng.normal() * 0.08) as f32;
        self.pos[1] += (self.rng.normal() * 0.08) as f32;
        (false, vec![self.pos[0], self.pos[1], 0.0, 0.0, 1.0])
    }
}

/// Selection that depends only on the *input*: configurations whose
/// Müller–Brown energy exceeds `threshold` go to the oracle (high-energy =
/// poorly-sampled transition regions). The checked payloads are the
/// committee means, but nothing downstream consumes them.
pub struct EnergySelectUtils {
    pub pes: MullerBrown,
    pub threshold: f64,
    pub max_per_batch: usize,
}

impl Utils for EnergySelectUtils {
    fn prediction_check(
        &mut self,
        list_data_to_pred: &[Vec<f32>],
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let checked = committee_mean(preds_per_model);
        let to_orcl: Vec<Vec<f32>> = list_data_to_pred
            .iter()
            .filter(|x| self.pes.energy(&x[..3]) > self.threshold)
            .take(self.max_per_batch)
            .cloned()
            .collect();
        (to_orcl, checked)
    }
}

/// Fixed-epoch committee member: like the synthetic model but immune to
/// retraining interrupts, so every round runs the same number of epochs.
pub struct FixedEpochModel(pub SyntheticModel);

impl Model for FixedEpochModel {
    fn predict(&mut self, list: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.0.predict(list)
    }
    fn update(&mut self, w: &[f32]) {
        self.0.update(w)
    }
    fn get_weight(&self) -> Vec<f32> {
        self.0.get_weight()
    }
    fn get_weight_size(&self) -> usize {
        self.0.get_weight_size()
    }
    fn add_trainingset(&mut self, points: &[(Vec<f32>, Vec<f32>)]) {
        self.0.add_trainingset(points)
    }
    fn retrain(&mut self, _interrupt: &mut dyn FnMut() -> bool) -> bool {
        self.0.retrain(&mut || false)
    }
    fn last_loss(&self) -> Option<f32> {
        self.0.last_loss()
    }
    fn last_round_epochs(&self) -> u64 {
        self.0.last_round_epochs()
    }
}

/// The deterministic run recipe: batched exchange, strict label budget of
/// [`LABELS`], full timing-independent batches, and a stop rule that waits
/// for every flushed batch to finish retraining.
pub fn deterministic_setting(oracle_mode: OracleMode) -> AlSetting {
    let flushes = LABELS / RETRAIN_SIZE as u64; // 3
    AlSetting {
        result_dir: "/tmp/pal-determinism".into(),
        gene_process: GENS,
        pred_process: MEMBERS * SHARDS,
        ml_process: MEMBERS,
        orcl_process: 1, // single oracle → labels land in dispatch order
        committee_size: Some(MEMBERS),
        exchange_mode: ExchangeMode::Batched,
        retrain_size: RETRAIN_SIZE,
        strict_label_budget: true,
        // exercise the rescore path end to end on every retrain:
        // EnergySelectUtils keeps the default (identity)
        // `adjust_input_for_oracle`, so the full drain → rescore →
        // replace → scheduler-resync round-trip runs without changing the
        // dispatch order — rescore replacements are bit-identical across
        // oracle modes by construction, and any regression that perturbs
        // the buffer or the batched scheduler clock breaks bit-stability
        dynamic_oracle_list: true,
        seed: 7,
        batch: BatchSetting {
            // full batches only: every batch holds one item per generator,
            // ordered by rank — composition is timing-independent
            max_size: GENS,
            max_delay: Duration::from_secs(10),
            max_outstanding: 2,
        },
        oracle_mode,
        oracle_batch: BatchSetting {
            // selections arrive in multiples of GENS = RETRAIN_SIZE, so the
            // size trigger always forms *full* oracle batches aligned with
            // the retrain flush boundary — batch composition (not just item
            // order) is timing-independent, and label arrival partitions
            // the train buffer exactly like the per-label path. One batch
            // in flight at a time: with 2+, two result frames could land in
            // one Manager drain and merge two retrain flushes into one,
            // making the flush partitioning timing-dependent.
            max_size: RETRAIN_SIZE,
            max_delay: Duration::from_secs(10),
            max_outstanding: 1,
        },
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(LABELS),
            // wait for every flushed batch to finish retraining (one
            // RETRAIN_DONE per trainer per flush) before shutting down
            min_retrain_rounds: flushes * MEMBERS as u64,
            min_train_epochs: 0,
            max_wall: Some(Duration::from_secs(60)),
        },
        ..Default::default()
    }
}

/// The scenario's oracle side alone: one fixed Müller–Brown PES oracle.
/// Split out so a TCP follower process can host exactly these oracles
/// while the leader runs [`deterministic_kernels_without_oracles`].
pub fn deterministic_oracles() -> Vec<OracleFactory> {
    vec![Box::new(|| {
        Box::new(PesOracle::fixed(MullerBrown::default(), 1)) as Box<dyn Oracle>
    }) as OracleFactory]
}

/// The full in-process kernel set: walkers, PES oracle, fixed-epoch
/// committee, energy-threshold selection.
pub fn deterministic_kernels() -> KernelSet {
    let mut kernels = deterministic_kernels_without_oracles();
    kernels.oracles = deterministic_oracles();
    kernels
}

/// The kernel set a TCP *leader* passes to
/// `Workflow::run_tcp_leader` — identical to [`deterministic_kernels`]
/// minus the oracles, which the follower process hosts.
pub fn deterministic_kernels_without_oracles() -> KernelSet {
    let generators = (0..GENS)
        .map(|i| {
            let seed = 100 + i as u64;
            Box::new(move || Box::new(MbWalker::new(seed)) as Box<dyn Generator>)
                as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let model = Arc::new(move |mode: Mode, member: usize| {
        let mut inner =
            SyntheticModel::new(IN_DIM, OUT_DIM, Duration::ZERO, Duration::ZERO, 8, mode);
        inner.update(&dataset_seed_weights(member));
        Box::new(FixedEpochModel(inner)) as Box<dyn Model>
    });
    let utils = Arc::new(|| {
        Box::new(EnergySelectUtils {
            pes: MullerBrown::default(),
            // far below every reachable energy → select everything, so the
            // selected sequence is exactly the generator round-robin
            threshold: -1e9,
            max_per_batch: GENS,
        }) as Box<dyn Utils>
    });
    KernelSet { generators, oracles: Vec::new(), model, utils }
}

/// Member-specific deterministic initial weights (`IN_DIM * OUT_DIM`
/// linear map); replicas of the same member match exactly.
pub fn dataset_seed_weights(member: usize) -> Vec<f32> {
    (0..IN_DIM * OUT_DIM)
        .map(|k| ((k + member * 11) % 7) as f32 * 0.05)
        .collect()
}

/// One full deterministic run on the default (`channel`) transport.
pub fn run_once(oracle_mode: OracleMode) -> RunReport {
    run_with_transport(oracle_mode, TransportKind::Channel)
}

/// One full deterministic run on the given in-process transport backend.
pub fn run_with_transport(oracle_mode: OracleMode, transport: TransportKind) -> RunReport {
    let mut setting = deterministic_setting(oracle_mode);
    setting.transport = transport;
    Workflow::new(setting).run(deterministic_kernels()).unwrap()
}
