//! Synthetic latency workloads: kernel implementations with *configurable
//! simulated cost*, used by the speedup/scaling benches to reproduce the
//! paper's use-case cost structure at bench-friendly timescales.

use std::time::Duration;

use crate::comm::bus::Payload;
use crate::data::batch::{Batch, BatchView, DatapointBlock, DatapointView, RowBlock};
use crate::kernels::{Generator, Mode, Model, Oracle, Utils};

/// Spin-sleep for `d` (thread::sleep granularity is fine at our scales).
pub fn busy_wait(d: Duration) {
    if d > Duration::ZERO {
        std::thread::sleep(d);
    }
}

/// Generator producing a fixed-width random-walk vector, with optional
/// per-step cost. Signals stop after `max_steps`.
pub struct SyntheticGenerator {
    pub dim: usize,
    pub step_cost: Duration,
    pub max_steps: u64,
    steps: u64,
    state: Vec<f32>,
    rng: crate::rng::Rng,
}

impl SyntheticGenerator {
    pub fn new(dim: usize, step_cost: Duration, max_steps: u64, seed: u64) -> Self {
        let mut rng = crate::rng::Rng::new(seed);
        let state = rng.normal_vec(dim);
        SyntheticGenerator { dim, step_cost, max_steps, steps: 0, state, rng }
    }
}

impl Generator for SyntheticGenerator {
    fn generate_new_data(&mut self, data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>) {
        busy_wait(self.step_cost);
        self.steps += 1;
        if let Some(pred) = data_to_gene {
            // random walk biased by the prediction (arbitrary but
            // deterministic dynamics; zeroed predictions → fresh restart,
            // mirroring the SI toy example)
            if pred.iter().all(|&p| p == 0.0) {
                self.state = self.rng.normal_vec(self.dim);
            } else {
                for (s, p) in self.state.iter_mut().zip(pred) {
                    *s = 0.9 * *s + 0.1 * p + (self.rng.normal() * 0.1) as f32;
                }
            }
        }
        (self.steps >= self.max_steps, self.state.clone())
    }
}

/// Oracle with fixed simulated cost; label = elementwise `sin` of the input
/// (nontrivial learnable map).
pub struct SyntheticOracle {
    pub label_cost: Duration,
    pub out_dim: usize,
}

impl Oracle for SyntheticOracle {
    fn run_calc(&mut self, input: &[f32]) -> Vec<f32> {
        busy_wait(self.label_cost);
        (0..self.out_dim)
            .map(|k| input.iter().enumerate().map(|(i, &v)| ((i + k + 1) as f32 * v).sin()).sum())
            .collect()
    }

    /// Native batch labeling: one coalesced wait for the whole batch, label
    /// values written straight into the contiguous block (bit-identical to
    /// the per-label path).
    fn run_calc_batch(&mut self, inputs: &BatchView<'_>) -> RowBlock {
        busy_wait(self.label_cost * inputs.rows() as u32);
        let mut out = RowBlock::with_capacity(inputs.rows(), inputs.rows() * self.out_dim);
        let mut row = vec![0.0f32; self.out_dim];
        for input in inputs.iter() {
            for (k, slot) in row.iter_mut().enumerate() {
                *slot = input
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| ((i + k + 1) as f32 * v).sin())
                    .sum();
            }
            out.push_row(&row);
        }
        out
    }
}

/// Model whose predict/train have fixed simulated cost. "Prediction" is a
/// linear readout of trainable weights; retraining runs `epochs` of
/// simulated epochs, each costing `epoch_cost`, interruptible between
/// epochs (paper §S5 `req_data.Test()` semantics).
///
/// The prediction cost model is `predict_cost + n_items *
/// predict_cost_per_item` per call: a fixed launch overhead plus a
/// per-stacked-item term, so benches can reproduce both overhead-bound and
/// throughput-bound inference regimes.
pub struct SyntheticModel {
    pub in_dim: usize,
    pub out_dim: usize,
    pub predict_cost: Duration,
    /// Marginal cost per stacked input row (default zero: call-bound).
    pub predict_cost_per_item: Duration,
    pub epoch_cost: Duration,
    pub epochs: usize,
    weights: Vec<f32>,
    /// Weights adopted from a shared wire payload (`update_from`): the
    /// replica reads through the same buffer the trainer materialized, so
    /// a weight sync costs this model zero copies. Cleared whenever the
    /// weights are mutated locally (`update` / `retrain`).
    shared_weights: Option<Payload>,
    /// Flat training set: inputs and labels in two contiguous buffers.
    dataset: DatapointBlock,
    last_loss: Option<f32>,
    last_round_epochs: u64,
    pub mode: Mode,
}

impl SyntheticModel {
    pub fn new(
        in_dim: usize,
        out_dim: usize,
        predict_cost: Duration,
        epoch_cost: Duration,
        epochs: usize,
        mode: Mode,
    ) -> Self {
        SyntheticModel {
            in_dim,
            out_dim,
            predict_cost,
            predict_cost_per_item: Duration::ZERO,
            epoch_cost,
            epochs,
            weights: vec![0.0; in_dim * out_dim],
            shared_weights: None,
            dataset: DatapointBlock::new(),
            last_loss: None,
            last_round_epochs: 0,
            mode,
        }
    }

    /// Set the marginal per-stacked-item prediction cost.
    pub fn with_per_item_cost(mut self, d: Duration) -> Self {
        self.predict_cost_per_item = d;
        self
    }

    /// Pad the weight vector to at least `n` entries. The readout still uses
    /// only the first `in_dim * out_dim` weights; the padding models
    /// realistic MLP weight-payload sizes so comm benches can measure the
    /// trainer → replica fan-out cost without inflating the predict cost.
    /// Every replica must be constructed with the same padding (weight
    /// messages are fixed-size).
    pub fn with_weight_padding(mut self, n: usize) -> Self {
        if self.weights.len() < n {
            self.weights.resize(n, 0.0);
        }
        self
    }

    /// Active weights: the adopted shared payload when one is held (a
    /// prediction replica after a zero-copy sync), the owned buffer
    /// otherwise.
    fn active_weights(&self) -> &[f32] {
        match &self.shared_weights {
            Some(p) => p.as_slice(),
            None => &self.weights,
        }
    }

    /// Move adopted shared weights into the owned buffer before a local
    /// mutation (retraining) — shared payloads are immutable.
    fn materialize_weights(&mut self) {
        if let Some(p) = self.shared_weights.take() {
            self.weights.copy_from_slice(p.as_slice());
        }
    }

    fn predict_one_into(&self, x: &[f32], out: &mut [f32]) {
        let w = self.active_weights();
        for (o, slot) in out.iter_mut().enumerate() {
            *slot = x
                .iter()
                .take(self.in_dim)
                .enumerate()
                .map(|(i, &v)| v * w[o * self.in_dim + i])
                .sum();
        }
    }

    fn predict_one(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.out_dim];
        self.predict_one_into(x, &mut out);
        out
    }
}

impl Model for SyntheticModel {
    fn predict(&mut self, list_data_to_pred: &[Vec<f32>]) -> Vec<Vec<f32>> {
        busy_wait(
            self.predict_cost + self.predict_cost_per_item * list_data_to_pred.len() as u32,
        );
        list_data_to_pred.iter().map(|x| self.predict_one(x)).collect()
    }

    fn predict_batch(&mut self, batch: &BatchView<'_>) -> RowBlock {
        // native flat path: one output buffer for the whole batch, rows
        // written in place — no per-row boxing
        busy_wait(self.predict_cost + self.predict_cost_per_item * batch.rows() as u32);
        let mut out = Batch::zeros(batch.rows(), self.out_dim);
        for i in 0..batch.rows() {
            self.predict_one_into(batch.row(i), out.row_mut(i));
        }
        out.into_row_block()
    }

    fn update(&mut self, weight_array: &[f32]) {
        self.shared_weights = None;
        let n = self.weights.len();
        self.weights.copy_from_slice(&weight_array[..n]);
    }

    fn update_from(&mut self, weights: &Payload) {
        // native flat path: adopt the shared buffer (refcount bump, zero
        // copies) when the size matches the fixed weight-message contract
        if weights.len() == self.weights.len() {
            self.shared_weights = Some(weights.clone());
        } else {
            self.update(weights.as_slice());
        }
    }

    fn get_weight(&self) -> Vec<f32> {
        self.active_weights().to_vec()
    }

    fn get_weight_payload(&self) -> Payload {
        match &self.shared_weights {
            // already shared: re-exporting is a refcount bump
            Some(p) => p.clone(),
            // one copy straight into shared storage (the default shim pays
            // an extra get_weight clone on top)
            None => Payload::from(&self.weights[..]),
        }
    }

    fn get_weight_size(&self) -> usize {
        self.weights.len()
    }

    fn add_trainingset(&mut self, datapoints: &[(Vec<f32>, Vec<f32>)]) {
        for (x, y) in datapoints {
            self.dataset.push(x, y);
        }
    }

    fn add_trainingset_batch(&mut self, datapoints: &DatapointView<'_>) {
        // native flat path: reserve once, then copy every pair straight
        // from the decoded payload into the flat training set — O(1)
        // allocations regardless of the batch size
        self.dataset.extend_from_view(datapoints);
    }

    fn retrain(&mut self, interrupt: &mut dyn FnMut() -> bool) -> bool {
        self.materialize_weights();
        let dataset = std::mem::take(&mut self.dataset);
        self.last_round_epochs = 0;
        for _ in 0..self.epochs {
            self.last_round_epochs += 1;
            busy_wait(self.epoch_cost);
            // one LMS pass over the data (cheap, just to make weights move)
            let mut loss = 0.0f32;
            let n = dataset.len().max(1);
            for (x, y) in dataset.iter() {
                let pred = self.predict_one(x);
                for (o, (&p, &t)) in pred.iter().zip(y.iter()).enumerate() {
                    let err = t - p;
                    loss += err * err;
                    for i in 0..self.in_dim.min(x.len()) {
                        self.weights[o * self.in_dim + i] += 0.01 * err * x[i] / n as f32;
                    }
                }
            }
            self.last_loss = Some(loss / n as f32);
            if interrupt() {
                break;
            }
        }
        self.dataset = dataset;
        false
    }

    fn last_loss(&self) -> Option<f32> {
        self.last_loss
    }

    fn last_round_epochs(&self) -> u64 {
        self.last_round_epochs
    }
}

/// Std-threshold utils over the synthetic model committee (see
/// [`crate::coordinator::selection`] for the production implementation).
pub struct SyntheticUtils {
    pub threshold: f32,
    pub max_per_iter: usize,
}

impl Utils for SyntheticUtils {
    fn prediction_check(
        &mut self,
        list_data_to_pred: &[Vec<f32>],
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        crate::coordinator::selection::committee_std_check(
            list_data_to_pred,
            preds_per_model,
            self.threshold,
            self.max_per_iter,
        )
    }

    fn prediction_check_batch(
        &mut self,
        inputs: &BatchView<'_>,
        preds_per_model: &[BatchView<'_>],
    ) -> (RowBlock, RowBlock) {
        crate::coordinator::selection::committee_std_check_batch(
            inputs,
            preds_per_model,
            self.threshold,
            self.max_per_iter,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_stops_at_max_steps() {
        let mut g = SyntheticGenerator::new(4, Duration::ZERO, 3, 0);
        assert!(!g.generate_new_data(None).0);
        assert!(!g.generate_new_data(Some(&[0.1; 4])).0);
        assert!(g.generate_new_data(Some(&[0.1; 4])).0);
    }

    #[test]
    fn generator_restarts_on_zero_prediction() {
        let mut g = SyntheticGenerator::new(4, Duration::ZERO, 100, 0);
        let (_, before) = g.generate_new_data(None);
        let (_, after) = g.generate_new_data(Some(&[0.0; 4]));
        assert_ne!(before, after);
    }

    #[test]
    fn oracle_label_deterministic() {
        let mut o = SyntheticOracle { label_cost: Duration::ZERO, out_dim: 2 };
        let a = o.run_calc(&[0.5, -0.5]);
        let b = o.run_calc(&[0.5, -0.5]);
        assert_eq!(a, b);
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn model_learns_linear_map() {
        let mut m = SyntheticModel::new(2, 1, Duration::ZERO, Duration::ZERO, 3000, Mode::Train);
        // y = x0 + 2 x1
        let data: Vec<(Vec<f32>, Vec<f32>)> = (0..20)
            .map(|i| {
                let x = vec![(i as f32) / 10.0 - 1.0, ((i * 7 % 13) as f32) / 6.0 - 1.0];
                let y = vec![x[0] + 2.0 * x[1]];
                (x, y)
            })
            .collect();
        m.add_trainingset(&data);
        m.retrain(&mut || false);
        assert!(m.last_loss().unwrap() < 0.05, "loss {:?}", m.last_loss());
    }

    #[test]
    fn retrain_interruptible() {
        let mut m = SyntheticModel::new(2, 1, Duration::ZERO, Duration::from_millis(1), 1000, Mode::Train);
        m.add_trainingset(&[(vec![1.0, 0.0], vec![1.0])]);
        let mut calls = 0;
        let t0 = std::time::Instant::now();
        m.retrain(&mut || {
            calls += 1;
            calls >= 3
        });
        assert!(t0.elapsed() < Duration::from_millis(500));
        assert_eq!(calls, 3);
    }

    #[test]
    fn predict_batch_matches_nested_predict() {
        let mut m = SyntheticModel::new(3, 2, Duration::ZERO, Duration::ZERO, 1, Mode::Predict);
        let w: Vec<f32> = (0..6).map(|i| (i as f32) * 0.25 - 0.5).collect();
        m.update(&w);
        let rows: Vec<Vec<f32>> = (0..5)
            .map(|i| (0..3).map(|j| (i * 3 + j) as f32 * 0.1).collect())
            .collect();
        let nested = m.predict(&rows);
        let batch = Batch::from_rows(&rows).unwrap();
        let flat = m.predict_batch(&batch.view());
        assert_eq!(flat.to_nested(), nested);
        let view = flat.as_view().expect("native output is uniform");
        assert_eq!((view.rows(), view.width()), (5, 2));
    }

    #[test]
    fn weight_roundtrip() {
        let mut m = SyntheticModel::new(3, 2, Duration::ZERO, Duration::ZERO, 1, Mode::Predict);
        let w: Vec<f32> = (0..6).map(|i| i as f32).collect();
        m.update(&w);
        assert_eq!(m.get_weight(), w);
        assert_eq!(m.get_weight_size(), 6);
    }

    #[test]
    fn weight_payload_bit_equal_and_adopted_without_copy() {
        let mut trainer = SyntheticModel::new(3, 2, Duration::ZERO, Duration::ZERO, 1, Mode::Train);
        let w: Vec<f32> = (0..6).map(|i| i as f32 * 0.5 - 1.0).collect();
        trainer.update(&w);
        let p = trainer.get_weight_payload();
        assert_eq!(p.as_slice(), trainer.get_weight().as_slice());

        let mut replica = SyntheticModel::new(3, 2, Duration::ZERO, Duration::ZERO, 1, Mode::Predict);
        let handles_before = p.shared_handles();
        replica.update_from(&p);
        // adoption shares the buffer instead of copying it
        assert_eq!(p.shared_handles(), handles_before + 1);
        assert_eq!(replica.get_weight(), w);
        assert_eq!(replica.get_weight_size(), 6);
        // the adopted replica predicts exactly like the legacy-updated one
        let mut legacy = SyntheticModel::new(3, 2, Duration::ZERO, Duration::ZERO, 1, Mode::Predict);
        legacy.update(&w);
        let x = vec![vec![0.1, 0.2, 0.3]];
        assert_eq!(replica.predict(&x), legacy.predict(&x));
        // re-exporting adopted weights is a refcount bump, bit-identical
        assert_eq!(replica.get_weight_payload().as_slice(), p.as_slice());
        // local mutation materializes first and keeps training correct
        replica.add_trainingset(&[(vec![1.0, 0.0, 0.0], vec![1.0, 0.0])]);
        replica.retrain(&mut || false);
        assert_ne!(replica.get_weight(), w);
    }

    #[test]
    fn add_trainingset_batch_matches_nested_add() {
        let pts: Vec<(Vec<f32>, Vec<f32>)> = (0..5)
            .map(|i| (vec![i as f32, 1.0], vec![i as f32 * 0.5]))
            .collect();
        let mut nested = SyntheticModel::new(2, 1, Duration::ZERO, Duration::ZERO, 50, Mode::Train);
        nested.add_trainingset(&pts);
        let mut flat = SyntheticModel::new(2, 1, Duration::ZERO, Duration::ZERO, 50, Mode::Train);
        let block = DatapointBlock::from_pairs(&pts);
        flat.add_trainingset_batch(&block.view());
        nested.retrain(&mut || false);
        flat.retrain(&mut || false);
        assert_eq!(nested.get_weight(), flat.get_weight());
        assert_eq!(nested.last_loss(), flat.last_loss());
    }

    #[test]
    fn weight_padding_grows_payload_not_readout() {
        let mut m = SyntheticModel::new(2, 1, Duration::ZERO, Duration::ZERO, 1, Mode::Predict)
            .with_weight_padding(64);
        assert_eq!(m.get_weight_size(), 64);
        let mut w = vec![0.0f32; 64];
        w[0] = 1.0;
        w[1] = 2.0;
        m.update(&w);
        assert_eq!(m.get_weight(), w);
        // readout uses only the first in_dim * out_dim weights
        let preds = m.predict(&[vec![1.0, 1.0]]);
        assert_eq!(preds, vec![vec![3.0]]);
    }
}
