//! SI §S2: analytic runtime/speedup model for parallel vs serial AL.
//!
//! Implements equations (1)–(4) and the three use-case estimates. The
//! `si_s2_usecases` bench compares these predictions against measured runs
//! of the full coordinator and the serial baseline.

/// Workload parameters (SI §S2.1). Times in seconds (scale-free: only
/// ratios matter for the speedup).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Workload {
    /// Time to label a single sample (`t_oracle`).
    pub t_oracle: f64,
    /// Time to train the ML model (`t_train`).
    pub t_train: f64,
    /// Time for the generator+predictor phase (`t_gen`).
    pub t_gen: f64,
    /// Samples to label per iteration (`N`).
    pub n_samples: u64,
    /// Parallel labeling workers (`P <= N` assumed by the paper).
    pub p_workers: u64,
}

impl Workload {
    /// Eq. (1): `T_serial = N/P · t_oracle + t_train + t_gen`.
    pub fn t_serial(&self) -> f64 {
        self.oracle_phase() + self.t_train + self.t_gen
    }

    /// Eq. (2): `T_parallel = max(N/P · t_oracle, t_train, t_gen)`.
    pub fn t_parallel(&self) -> f64 {
        self.oracle_phase().max(self.t_train).max(self.t_gen)
    }

    /// Eq. (3)/(4): `S = T_serial / T_parallel` (a lower bound — the paper
    /// notes parallel resources are never idle).
    pub fn speedup(&self) -> f64 {
        self.t_serial() / self.t_parallel()
    }

    /// `N/P · t_oracle` with the paper's `P ≤ N` assumption relaxed to
    /// `ceil` semantics for small integer cases.
    pub fn oracle_phase(&self) -> f64 {
        if self.p_workers == 0 {
            return f64::INFINITY;
        }
        (self.n_samples as f64 / self.p_workers as f64) * self.t_oracle
    }

    /// Which module bounds `T_parallel`.
    pub fn bottleneck(&self) -> Bottleneck {
        let o = self.oracle_phase();
        if o >= self.t_train && o >= self.t_gen {
            Bottleneck::Oracle
        } else if self.t_train >= self.t_gen {
            Bottleneck::Training
        } else {
            Bottleneck::Generation
        }
    }
}

/// The binding module in eq. (2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bottleneck {
    Oracle,
    Training,
    Generation,
}

/// SI §S2.2 use case 1 — DFT oracle + GNN training, `t_oracle = t_train`,
/// `t_gen ≪ both`. Paper: `S = 1 + P/N` (→ 2 at `P = N`).
pub fn use_case_1(n: u64, p: u64) -> Workload {
    Workload { t_oracle: 1.0, t_train: 1.0, t_gen: 0.001, n_samples: n, p_workers: p }
}

/// SI §S2.2 use case 2 — cheap xTB oracle, training-bound. Paper: `S ≈ 1`.
/// (10 s oracle, 1 h training, 10 min generator; scale-free ratios.)
pub fn use_case_2(n: u64, p: u64) -> Workload {
    Workload { t_oracle: 10.0, t_train: 3600.0, t_gen: 600.0, n_samples: n, p_workers: p }
}

/// SI §S2.2 use case 3 — CFD, balanced costs. Paper: `S → 3`.
pub fn use_case_3(n: u64, p: u64) -> Workload {
    Workload { t_oracle: 600.0, t_train: 600.0, t_gen: 600.0, n_samples: n, p_workers: p }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_eq2_basic() {
        let w = Workload { t_oracle: 2.0, t_train: 3.0, t_gen: 1.0, n_samples: 10, p_workers: 5 };
        assert!((w.t_serial() - (4.0 + 3.0 + 1.0)).abs() < 1e-12);
        assert!((w.t_parallel() - 4.0).abs() < 1e-12);
        assert!((w.speedup() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn use_case_1_limit_is_one_plus_p_over_n() {
        // balanced oracle/training: S = 1 + P/N when N/P >= 1 (eq. 7)
        for (n, p) in [(8u64, 8u64), (16, 8), (32, 8)] {
            let w = use_case_1(n, p);
            let expected = 1.0 + p as f64 / n as f64;
            // t_gen is negligible but nonzero; allow small slack
            assert!(
                (w.speedup() - expected).abs() < 0.01,
                "N={n} P={p}: {} vs {expected}",
                w.speedup()
            );
        }
        // P = N → speedup 2
        assert!((use_case_1(8, 8).speedup() - 2.0).abs() < 0.01);
    }

    #[test]
    fn use_case_2_no_speedup() {
        // training-bound: S ≈ 1 (eq. 10); with N=P=1 the oracle is 10s vs 3600s train
        let s = use_case_2(1, 1).speedup();
        assert!(s < 1.2, "expected ~1, got {s}");
        assert_eq!(use_case_2(1, 1).bottleneck(), Bottleneck::Training);
    }

    #[test]
    fn use_case_3_approaches_three() {
        // balanced: S = 3 exactly at P = N (eq. 13)
        let s = use_case_3(4, 4).speedup();
        assert!((s - 3.0).abs() < 1e-9, "{s}");
        assert_eq!(use_case_3(4, 4).bottleneck(), Bottleneck::Oracle);
    }

    #[test]
    fn speedup_at_least_one() {
        // S >= 1 for any non-degenerate workload
        for t_o in [0.1, 1.0, 10.0] {
            for t_t in [0.1, 1.0, 10.0] {
                for t_g in [0.1, 1.0, 10.0] {
                    let w = Workload {
                        t_oracle: t_o,
                        t_train: t_t,
                        t_gen: t_g,
                        n_samples: 6,
                        p_workers: 3,
                    };
                    assert!(w.speedup() >= 1.0);
                    assert!(w.speedup() <= 3.0 + 1e-9); // bounded by #modules
                }
            }
        }
    }

    #[test]
    fn more_workers_shrink_oracle_phase() {
        let a = use_case_1(16, 2);
        let b = use_case_1(16, 8);
        assert!(b.oracle_phase() < a.oracle_phase());
        assert!(b.speedup() >= a.speedup());
    }

    #[test]
    fn zero_workers_is_infinite() {
        let w = Workload { t_oracle: 1.0, t_train: 1.0, t_gen: 1.0, n_samples: 4, p_workers: 0 };
        assert!(w.oracle_phase().is_infinite());
    }
}
