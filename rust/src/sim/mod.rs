//! Analytic models + synthetic workloads for the speedup experiments,
//! plus the deterministic Müller–Brown end-to-end scenario shared by the
//! determinism and transport-conformance suites.

pub mod scenario;
pub mod speedup;
pub mod workload;
