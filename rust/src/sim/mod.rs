//! Analytic models + synthetic workloads for the speedup experiments.

pub mod speedup;
pub mod workload;
