//! # PAL — Parallel Active Learning for machine-learned potentials
//!
//! Rust reproduction of *"PAL — Parallel active learning for machine-learned
//! potentials"* (Zhou et al., KIT, 2024). The crate implements the paper's
//! five-kernel architecture — **prediction**, **generator**, **training**,
//! **oracle**, and a two-part **controller** (Manager + Exchange) — on top of
//! an in-process MPI-work-alike ([`comm`]), with all ML compute AOT-compiled
//! from JAX/Pallas to HLO and executed through the PJRT C API ([`runtime`]).
//! Python never runs on the request path.
//!
//! ## Layout
//!
//! | module | role |
//! |---|---|
//! | [`comm`] | MPI-like message passing substrate (ranks, tags, requests, batch frames) |
//! | [`config`] | `AL_SETTING`-style configuration + rank/shard topology + batching knobs |
//! | [`coordinator`] | the paper's contribution: Manager + Exchange controllers (lockstep *and* batched/sharded relay), buffers, selection |
//! | [`kernels`] | user-facing kernel traits + built-in generators/oracles/models (models take stacked input lists) |
//! | [`runtime`] | PJRT artifact loading & execution (`artifacts/*.hlo.txt`) |
//! | [`potential`] | analytic PES substrate standing in for DFT/TDDFT/xTB oracles |
//! | [`serial`] | the Fig.-1a serial active-learning baseline |
//! | [`sim`] | SI §S2 analytic speedup model + synthetic workloads |
//! | [`data`] | labeled dataset store, splits, rolling windows |
//! | [`telemetry`] | post-mortem per-kernel timing/counters + the live observability plane (metrics registry, HTTP surface, trace recorder) |
//! | [`json`], [`rng`], [`prop`], [`bench_util`] | offline substrates (no external deps available) |
//!
//! ## Batched, sharded prediction (beyond the paper)
//!
//! The paper's Exchange runs lockstep rounds: every generator's input is
//! broadcast to every prediction rank, so adding prediction ranks adds
//! committee members but no throughput. With
//! `AlSetting { exchange_mode: ExchangeMode::Batched, .. }` the Exchange
//! instead coalesces concurrent generator requests into micro-batches
//! (dispatch at `batch.max_size` queued items, or when the oldest has
//! waited `batch.max_delay`), routes each batch to one prediction *shard*
//! — `committee_size` ranks holding one replica of each committee member,
//! chosen round-robin with a least-outstanding fallback — and scatters
//! per-item results back to the originating generators. When every shard
//! has `batch.max_outstanding` batches in flight, requests queue and
//! release in FIFO order (backpressure). Trainers push weights to their
//! member's replica in every shard, so shards stay interchangeable, and
//! `stop.max_labels` can be made a hard dispatch budget with
//! `strict_label_budget` (exact label counts; see
//! `rust/tests/test_determinism.rs` for a bit-stable end-to-end run).
//!
//! ## Zero-copy transport
//!
//! The simulated MPI bus moves [`comm::Payload`]s — immutable,
//! `Arc<[f32]>`-backed range views. Owned data is copied into shared
//! storage at most once at the bus boundary; after that, broadcasts,
//! scatters of shared data, relay re-sends, payload row slices
//! ([`comm::Payload::slice`]), and the trainer → replica weight fan-out
//! are refcount bumps, so physical copy volume is independent of the
//! destination count. [`comm::bus::WorldStats`] (surfaced as
//! `RunReport::payload_clones` / `bytes_copied` next to the logical
//! `messages` / `payload_bytes`) keeps the distinction honest, and the
//! codec's reusable [`comm::codec::PackBuffer`] scratches and `*_into`
//! encoders keep the re-encode half of every Exchange hop allocation-free
//! in steady state, with borrowed-view decoders
//! ([`comm::codec::unpack_views`]) as the single parse path underneath the
//! owned variants. See [`comm`] for the full copy-vs-share rules.
//!
//! ## Flat data plane
//!
//! In-memory batches are as copy-free as the transport. Uniform-width
//! traffic decodes straight into strided [`data::BatchView`]s over the
//! received payload (zero allocations), models serve
//! `Model::predict_batch(&BatchView) -> RowBlock` (contiguous row storage,
//! uniform `rows × width` in practice; the nested-`Vec` `predict` remains
//! as a compatibility shim and ragged legacy kernels keep working),
//! committee reductions ([`coordinator::selection::committee_std_batch`]
//! etc.) are single-pass strided loops with zero inner-loop allocations,
//! and checked results scatter back as [`comm::Payload::slice`] row views
//! of one shared buffer. Selection staging ([`coordinator::buffers`]) and
//! the batch scheduler queue rows in flat [`data::RowQueue`]s. The whole
//! decode → reduce path allocates a small constant independent of batch
//! size — pinned by the counting-allocator test `test_flat_plane` and
//! tracked per item in `BENCH_alloc.json` (`cargo bench --bench
//! comm_overhead`). Ragged traffic falls back to the nested-`Vec` path;
//! wire bytes are identical either way.
//!
//! ## Flat training plane
//!
//! The training side is flat end to end, too. Labeled samples stage
//! contiguously from the oracle onward: the Manager's
//! `TrainBuffer` holds one [`data::DatapointBlock`] (paired input/label
//! row blocks) filled straight from decoded oracle-result views, a flush
//! encodes the block in place ([`comm::codec::encode_train_block_into`];
//! wire bytes identical to the nested `pack_datapoints`) and broadcasts
//! one shared payload, and trainers decode borrowed pair views
//! ([`comm::codec::decode_train_block_views`]) into
//! `Model::add_trainingset_batch` — O(1) allocations per flush on the
//! native models, pinned by the counting-allocator test `test_flat_train`.
//! Weight syncs are refcount-only: `Model::get_weight_payload` exports one
//! shared buffer, every shard replica adopts it via `Model::update_from`
//! (zero per-destination copies, asserted through
//! [`comm::bus::WorldStats`]), and `Utils::adjust_input_for_oracle_batch`
//! re-scores the oracle buffer over strided views without materializing
//! nested `Vec`s. Gathers are vectored
//! ([`comm::bus::Endpoint::recv_ready_all`]): one mailbox drain per round
//! instead of one wake-up per source. `BENCH_train.json` tracks
//! bytes-copied per flushed datapoint and per weight sync.
//!
//! ## Oracle plane (green flow)
//!
//! Labeling has the same exchange discipline as prediction. With
//! `AlSetting { oracle_mode: OracleMode::Batched, .. }` the Manager stops
//! shipping one message per input and one per label: the
//! [`coordinator::oracle_plane::OracleScheduler`] coalesces
//! Manager-selected inputs into size-/deadline-triggered micro-batches
//! (`oracle_batch.max_size` / `max_delay`), routes each batch to the
//! **least-loaded** oracle (oracles have wildly heterogeneous latencies —
//! DFT hours vs xTB seconds — so least-outstanding routing feeds fast
//! oracles proportionally more work), and applies per-oracle backpressure
//! at `oracle_batch.max_outstanding` (excess inputs wait in the
//! `OracleBuffer`, where `dynamic_orcale_list` re-scoring can still
//! reorder them). On the wire, `TAG_ORACLE_BATCH` carries the inputs and
//! `TAG_ORACLE_LABELS` returns *only* the labels under the echoed batch id
//! — the Manager retains each dispatched input block and pairs label row
//! `i` with retained input row `i`, so inputs never re-ship (the legacy
//! interleaved `TAG_ORACLE_BATCH_RESULT` layout is still decoded for
//! mixed-version runs); oracles label through
//! `Oracle::run_calc_batch(&BatchView) -> RowBlock` (default shim loops
//! `run_calc`, so labels are bit-identical to the per-label path — proven
//! end to end in `rust/tests/test_determinism.rs`), and labels ingest
//! straight into the Manager's `TrainBuffer` as borrowed views with
//! constant allocations per batch (`rust/tests/test_oracle_plane.rs`). The
//! per-label path (`OracleMode::PerLabel`, the default) is preserved
//! bit-compatible. `BENCH_oracle.json` tracks green-flow messages per
//! labeled sample (≥ 2× fewer at batch 8 with 4 oracles).
//!
//! ## Memory plane
//!
//! The last per-iteration copies on the green + yellow paths are gone:
//!
//! * **Flat [`data::Dataset`]** — each split stores its rows in one
//!   [`data::RowQueue`] (contiguous values + end offsets) instead of
//!   `Vec<Vec<f32>>`; `minibatch` is a strided gather into a reused
//!   scratch pair, so a training step allocates a small constant
//!   independent of the rolling-window size, and `apply_window` drops
//!   index ranges instead of shifting boxed rows. RNG draw order and
//!   window semantics are bit-identical to the nested store (pinned in
//!   `rust/tests/test_determinism.rs`).
//! * **Device-resident weight cache** — [`runtime::Engine::call`] keys
//!   [`runtime::TensorIn::Shared`] inputs by payload identity
//!   ([`comm::Payload::ident`]) in an [`runtime::UploadCache`]: weights
//!   adopted from a trainer sync stage once and every subsequent
//!   `predict_batch`/`train_step`/`validation_mse` between syncs reuses
//!   the staged literal (zero re-upload bytes; cache hits tracked by
//!   [`runtime::UploadStats`] and folded into each host's telemetry as
//!   `upload_cache_*` counters, aggregated in `RunReport::to_json`).
//!   Invalidation is by construction: any local weight write drops the
//!   shared payload, and a fresh sync is a new identity.
//! * **Labels-only oracle results** — see the oracle plane above; batched
//!   result frames carry labels, not echoed inputs, ~halving green-flow
//!   result bytes at batch 8.
//!
//! All three are pinned by the counting-allocator/cache tests in
//! `rust/tests/test_mem_plane.rs` and tracked in `BENCH_mem.json`
//! (`cargo bench --bench comm_overhead`); `scripts/check_bench.py` diffs
//! every `BENCH_*.json` against the committed `BENCH_baseline.json` and
//! fails CI on a >10% regression of any gated metric.
//!
//! ## Adaptive dispatch core
//!
//! Both batched planes now share one scheduler state machine:
//! [`coordinator::dispatch::DispatchCore`] owns the size-/deadline
//! triggers, per-endpoint outstanding counts, backpressure, and sequential
//! batch ids, behind a routing [`coordinator::dispatch::Policy`]. The
//! static policies (round-robin for prediction shards, least-outstanding
//! for oracles) reproduce the pre-extraction schedulers bit-for-bit and
//! remain the default — `test_determinism` and the equivalence suite in
//! `rust/tests/test_dispatch_core.rs` pin this. Opting in with
//! `sched_policy = "adaptive"` turns on per-endpoint EWMA latency tracking
//! from completion timestamps: batches route to the endpoint with the
//! least estimated completion time (deterministic lowest-index ties),
//! batch caps shrink proportionally for slow endpoints (`sched_ewma_alpha`),
//! and a health plane evicts endpoints that time out (`sched_timeout_ms`)
//! or deliver `sched_evict_after` consecutive slow completions
//! (`sched_slow_factor ×` the fastest peer) — their in-flight work is
//! requeued and relabeled/re-served elsewhere, the endpoint rejoins after
//! `sched_rejoin_ms` or immediately when a late reply proves recovery, and
//! the last active endpoint is never evicted. The Manager's shutdown drain
//! bound scales with observed p95 oracle RTT (`sched_drain_factor`)
//! instead of a fixed 300 ms, so paid-for labels survive slow pools.
//! `BENCH_sched.json` (`cargo bench --bench comm_overhead`) tracks the
//! labels/sec win of adaptive routing over static least-outstanding under
//! a heterogeneous-latency oracle pool.
//!
//! ## Fault plane
//!
//! Chaos is a first-class, *deterministic* input. A
//! [`comm::FaultPlan`] — kill rank *k* after its *N*th send/receive or at
//! time *t*, drop or delay specific `(src, tag)` messages — installs into
//! the [`comm::World`] before endpoints are handed out, so a seeded chaos
//! run replays bit-for-bit and an **empty plan is free**: no fault hooks
//! on the hot paths, runs bit-identical to a plain build (pinned in
//! `rust/tests/test_determinism.rs`). Every host thread runs supervised
//! (`catch_unwind` at the thread boundary): a panicking or fault-killed
//! host announces itself over the control plane (`TAG_RANK_DOWN`, which
//! outlives the dead rank's endpoint) and returns a failed telemetry
//! record, so `Workflow::run` completes with a *degraded* `RunReport`
//! whose `faults` section (failed ranks, evictions, requeues, lost inputs,
//! bad frames, dead letters) says what happened — never a poisoned join.
//!
//! What the run *tolerates* (completes, and still reaches a strict label
//! budget): any single non-last oracle or prediction shard dying mid-run
//! — the Manager/Exchange evict it on the rank-down notice or on the
//! first dead-letter send, requeue its in-flight inputs, and relabel them
//! elsewhere, in both batched and per-label oracle modes. What *degrades*
//! (completes, possibly short of the budget): dead trainers (no further
//! retrains), dead generators in batched exchange mode (less red flow),
//! a dead Exchange or all oracles dead (the Manager stops and drains
//! honestly), any lockstep-round participant dying (lockstep rounds need
//! every peer, so the run aborts cleanly into a degraded report). What
//! *aborts*: death of the Manager itself — it runs on the caller thread
//! as the shutdown authority. See [`comm`] for the injection layer and
//! `rust/tests/test_fault_plane.rs` for the chaos matrix.
//!
//! ## Transport plane
//!
//! The bus is now a *protocol* over a pluggable delivery layer: tag/src
//! matching, latency visibility, gathers, fault injection, and
//! [`comm::bus::WorldStats`] accounting all live in [`comm::bus`], while
//! raw rank-to-rank delivery sits behind the [`comm::transport`] traits.
//! Three backends ship (`AlSetting { transport, .. }`, JSON key
//! `"transport"`, CLI `pal run --transport=`):
//!
//! * **`channel`** (default) — the original `std::sync::mpsc` bus,
//!   bit-identical to every prior release;
//! * **`shm`** — lock-free shared-memory idiom: one bounded Vyukov ring
//!   per rank pair, payload ownership handed off on send (fan-out stays
//!   refcount-only), no mutex and no per-message allocation on the hot
//!   path, receivers spin briefly ([`comm::transport::spin_then`]) before
//!   parking;
//! * **`tcp`** — length-prefixed frames over `std::net` with per-peer
//!   writer threads and a demux reader, `World::listen`/`World::connect`
//!   bootstrap, and a star relay through the listener, so a Workflow can
//!   span real OS processes (`Workflow::run_tcp_leader` +
//!   `Workflow::run_tcp_follower` put oracle ranks in follower
//!   processes).
//!
//! The conformance contract is behavioral equivalence: the deterministic
//! Müller–Brown scenario ([`sim::scenario`]) must produce **bit-identical**
//! labels, retrain rounds, and losses on every backend
//! (`rust/tests/test_transport.rs`, including a two-process tcp e2e), and
//! `BENCH_transport.json` gates the shm rings at ≥ 1.5× the channel
//! backend's small-payload fan-in rate with zero payload bytes copied.
//!
//! ## Observability plane
//!
//! A live run is no longer a black box that only yields a `RunReport` at
//! join. Three layers sit on the post-mortem [`telemetry`]:
//!
//! * **[`telemetry::registry`]** — one process-wide `MetricsRegistry` of
//!   relaxed atomics that the Manager, Exchange, dispatch core, oracle
//!   plane, and host supervisors publish into while running: labels/sec
//!   and campaign progress, queue depths, per-endpoint outstanding
//!   batches / EWMA latency / liveness, log₂-bucketed oracle- and
//!   prediction-leg RTT histograms, live fault counters, per-rank kernel
//!   state, and the [`comm::bus::WorldStats`] logical-vs-physical byte
//!   split. Every publish is enabled-gated: the disabled registry (the
//!   default — no `--metrics-addr`) costs one relaxed load and a branch,
//!   zero stores and zero allocations, so unobserved runs stay
//!   bit-identical (pinned in `rust/tests/test_observability.rs`).
//!   Naming scheme: `pal_` prefix, counters end `_total`, instantaneous
//!   gauges are bare, histograms are `_ms` log₂ buckets, per-endpoint
//!   series carry `{rank,kind}` labels (see [`telemetry::registry`]).
//! * **[`telemetry::server`]** — `pal run --metrics-addr=127.0.0.1:9090`
//!   (config key `metrics_addr`; port 0 binds ephemerally) serves
//!   `/metrics` (Prometheus text exposition), `/status` (JSON snapshot
//!   whose `faults` section is field-consistent with the final
//!   `RunReport.faults` — same counters, same call sites), and
//!   `/healthz`, on the same `std::net` stack as the tcp transport; the
//!   scrape path never locks the publish path.
//! * **[`telemetry::trace`]** — `pal run --trace-out=trace.json` (config
//!   key `trace_out`) records bounded per-rank spans — `predict`,
//!   `oracle_calc`, `retrain`, `weight_sync` work spans plus
//!   `pred_batch`/`oracle_batch` dispatch-leg lifecycles and
//!   `rank_down`/`evict` instants — and drains them at join into Chrome
//!   trace-event JSON loadable in Perfetto. Span counts equal the
//!   matching `RunReport` counters by construction (same call sites).
//!
//! `rust/tests/test_observability.rs` scrapes both endpoints mid-run,
//! clean and under chaos, and `BENCH_obs.json` gates the cost: a
//! registry-enabled labeling run within 2% of the disabled wall, and the
//! disabled publish hot path allocation-free under the counting
//! allocator.
//!
//! ## Performance
//!
//! Perf-tracking benches write machine-readable JSON next to their
//! human-readable tables, so the trajectory is comparable across PRs:
//!
//! ```text
//! cargo bench --bench comm_overhead   # → BENCH_comm.json  + BENCH_fault.json
//! cargo bench --bench fig1_speedup    # → BENCH_speedup.json
//! ```
//!
//! `comm_overhead` measures raw bus round-trips, exchange-loop rates vs
//! prediction latency, message coalescing under the batched exchange, and
//! the physical-copy reduction of shared-payload weight broadcasts
//! (`bytes_copied` vs per-destination clones at 8 prediction ranks).
//! `fig1_speedup` reproduces the paper's serial-vs-parallel comparison and
//! the prediction-rank scaling of the sharded exchange. The remaining
//! benches (`sec31_latency`, `ablation`, `si_s2_usecases`, `scaling`)
//! print tables only.

pub mod bench_util;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod json;
pub mod kernels;
pub mod potential;
pub mod prop;
pub mod rng;
pub mod runtime;
pub mod serial;
pub mod sim;
pub mod telemetry;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
