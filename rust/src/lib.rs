//! # PAL — Parallel Active Learning for machine-learned potentials
//!
//! Rust reproduction of *"PAL — Parallel active learning for machine-learned
//! potentials"* (Zhou et al., KIT, 2024). The crate implements the paper's
//! five-kernel architecture — **prediction**, **generator**, **training**,
//! **oracle**, and a two-part **controller** (Manager + Exchange) — on top of
//! an in-process MPI-work-alike ([`comm`]), with all ML compute AOT-compiled
//! from JAX/Pallas to HLO and executed through the PJRT C API ([`runtime`]).
//! Python never runs on the request path.
//!
//! ## Layout
//!
//! | module | role |
//! |---|---|
//! | [`comm`] | MPI-like message passing substrate (ranks, tags, requests) |
//! | [`config`] | `AL_SETTING`-style configuration + rank topology |
//! | [`coordinator`] | the paper's contribution: Manager + Exchange controllers, buffers, selection |
//! | [`kernels`] | user-facing kernel traits + built-in generators/oracles/models |
//! | [`runtime`] | PJRT artifact loading & execution (`artifacts/*.hlo.txt`) |
//! | [`potential`] | analytic PES substrate standing in for DFT/TDDFT/xTB oracles |
//! | [`serial`] | the Fig.-1a serial active-learning baseline |
//! | [`sim`] | SI §S2 analytic speedup model + synthetic workloads |
//! | [`data`] | labeled dataset store, splits, rolling windows |
//! | [`telemetry`] | per-kernel timing and counters |
//! | [`json`], [`rng`], [`prop`], [`bench_util`] | offline substrates (no external deps available) |

pub mod bench_util;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod json;
pub mod kernels;
pub mod potential;
pub mod prop;
pub mod rng;
pub mod runtime;
pub mod serial;
pub mod sim;
pub mod telemetry;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
