//! Mini property-testing framework (offline `proptest` substitute).
//!
//! Usage (`no_run`: doctest binaries can't see the xla rpath):
//! ```no_run
//! use pal::prop::{forall, Gen};
//! forall(64, |g| (g.usize(1, 10), g.vec_f32(5, -1.0, 1.0)), |(n, v)| {
//!     v.len() == 5 && n >= 1
//! });
//! ```
//!
//! On failure it retries with progressively simpler inputs derived from the
//! failing seed (cheap shrinking: re-generates with smaller size hints) and
//! panics with the seed so the case is reproducible.

use crate::rng::Rng;

/// Value generator handed to the input closure.
pub struct Gen {
    rng: Rng,
    /// Size hint in [0.0, 1.0]; shrinking re-runs with smaller hints.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::new(seed), size }
    }

    /// Uniform usize in [lo, hi], scaled toward `lo` when shrinking.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + if span == 0 { 0 } else { self.rng.below(span + 1) }
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        let span = (hi - lo) * self.size as f32;
        lo + self.rng.f32() * span
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo) * self.size
    }

    pub fn bool(&mut self) -> bool {
        self.rng.f64() < 0.5
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize) -> Vec<f32> {
        self.rng.normal_vec(len)
    }

    /// A list of `count` arrays of width `w`.
    pub fn arrays(&mut self, count: usize, w: usize) -> Vec<Vec<f32>> {
        (0..count).map(|_| self.vec_normal(w)).collect()
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases: generate an input, check the property.
/// Panics with the reproducing seed on the first failure (after attempting
/// smaller-sized reproductions for a friendlier counterexample).
pub fn forall<T: std::fmt::Debug>(
    cases: u64,
    mut make: impl FnMut(&mut Gen) -> T,
    mut prop: impl FnMut(T) -> bool,
) {
    for case in 0..cases {
        let seed = 0x9E3779B9u64.wrapping_mul(case + 1);
        let input = make(&mut Gen::new(seed, 1.0));
        if !prop(input) {
            // try to find a smaller failing case from the same seed
            for &size in &[0.1, 0.3, 0.6] {
                let small = make(&mut Gen::new(seed, size));
                if !prop(small) {
                    let repro = make(&mut Gen::new(seed, size));
                    panic!(
                        "property failed (seed={seed}, size={size}); counterexample: {repro:?}"
                    );
                }
            }
            let repro = make(&mut Gen::new(seed, 1.0));
            panic!("property failed (seed={seed}); counterexample: {repro:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(50, |g| g.usize(0, 10), |n| n <= 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(50, |g| g.usize(0, 100), |n| n < 90);
    }

    #[test]
    fn generators_respect_bounds() {
        forall(
            100,
            |g| (g.f32(-2.0, 3.0), g.usize(5, 9)),
            |(x, n)| (-2.0..=3.0).contains(&x) && (5..=9).contains(&n),
        );
    }

    #[test]
    fn deterministic_per_case() {
        let mut first = vec![];
        forall(5, |g| g.vec_f32(3, 0.0, 1.0), |v| {
            first.push(v);
            true
        });
        let mut second = vec![];
        forall(5, |g| g.vec_f32(3, 0.0, 1.0), |v| {
            second.push(v);
            true
        });
        assert_eq!(first, second);
    }
}
