//! Deterministic PRNG substrate (offline replacement for the `rand` crate).
//!
//! xoshiro256++ with splitmix64 seeding — fast, good-quality, and identical
//! across platforms, which matters because generators, oracles, and tests all
//! derive reproducible streams from a single seed.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded construction. Any u64 is a valid seed (zero included).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (e.g. one per rank).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard-normal f32 vector.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Uniform f32 vector in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.range(lo as f64, hi as f64) as f32).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(20, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
