//! Selection strategies: implementations of the paper's `prediction_check`
//! and `adjust_input_for_oracle` utilities (SI "Utilities").
//!
//! Every reduction exists twice: the legacy nested-`Vec` form
//! ([`committee_std`], [`committee_mean`], [`committee_std_check`]) kept
//! for user kernels and fallback paths, and the flat-data-plane form
//! ([`committee_std_batch`], [`committee_mean_batch`],
//! [`committee_std_check_batch`]) operating on strided [`BatchView`]s —
//! single-pass loops with zero inner-loop allocations, numerically
//! identical to the nested form (same summation order, pinned by property
//! tests). Top-k capping uses `select_nth_unstable_by` partial selection,
//! so only the selected prefix is ever sorted.

use crate::data::batch::{Batch, BatchView, RowBlock};
use crate::kernels::Utils;

/// Move the `k` largest-std entries of `cand` to the front via partial
/// selection (`select_nth_unstable_by`, O(n)) and sort exactly that prefix
/// descending; the tail keeps its arbitrary post-partition order. The one
/// shared implementation for every top-k consumer, so tie-breaking and
/// NaN handling can never diverge between them. Ties at the cut are broken
/// arbitrarily (but deterministically for a given input).
fn front_top_k_by_std(cand: &mut [usize], stds: &[f32], k: usize) {
    let desc = |a: &usize, b: &usize| {
        stds[*b].partial_cmp(&stds[*a]).unwrap_or(std::cmp::Ordering::Equal)
    };
    let k = k.min(cand.len());
    if cand.len() > k {
        if k == 0 {
            return;
        }
        let _ = cand.select_nth_unstable_by(k - 1, desc);
        cand[..k].sort_by(desc);
    } else {
        cand.sort_by(desc);
    }
}

/// Order `cand` by std descending and keep only the top `k`.
fn top_by_std_desc(mut cand: Vec<usize>, stds: &[f32], k: usize) -> Vec<usize> {
    front_top_k_by_std(&mut cand, stds, k);
    cand.truncate(k);
    cand
}

/// Committee std over models for each generator: `preds[model][generator]`.
/// Returns per-generator max-component std.
pub fn committee_std(preds_per_model: &[Vec<Vec<f32>>]) -> Vec<f32> {
    let n_models = preds_per_model.len();
    if n_models == 0 {
        return vec![];
    }
    let n_gen = preds_per_model[0].len();
    let mut out = Vec::with_capacity(n_gen);
    for g in 0..n_gen {
        let width = preds_per_model[0][g].len();
        let mut max_std = 0.0f32;
        for k in 0..width {
            let vals: Vec<f32> = preds_per_model.iter().map(|m| m[g][k]).collect();
            let mean = vals.iter().sum::<f32>() / n_models as f32;
            let var = if n_models > 1 {
                vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
                    / (n_models as f32 - 1.0)
            } else {
                0.0
            };
            max_std = max_std.max(var.sqrt());
        }
        out.push(max_std);
    }
    out
}

/// Committee mean per generator.
pub fn committee_mean(preds_per_model: &[Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
    let n_models = preds_per_model.len();
    if n_models == 0 {
        return vec![];
    }
    let n_gen = preds_per_model[0].len();
    (0..n_gen)
        .map(|g| {
            let width = preds_per_model[0][g].len();
            (0..width)
                .map(|k| {
                    preds_per_model.iter().map(|m| m[g][k]).sum::<f32>() / n_models as f32
                })
                .collect()
        })
        .collect()
}

/// The paper's example `prediction_check`: inputs whose committee std
/// exceeds `threshold` go to the oracle (capped at `max_per_iter`, highest
/// std first); their returned prediction is zeroed so the generator knows
/// not to trust it, everyone else receives the committee mean.
pub fn committee_std_check(
    list_data_to_pred: &[Vec<f32>],
    preds_per_model: &[Vec<Vec<f32>>],
    threshold: f32,
    max_per_iter: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let stds = committee_std(preds_per_model);
    let mut means = committee_mean(preds_per_model);
    // candidates above threshold, capped by partial selection
    let cand: Vec<usize> = (0..stds.len()).filter(|&g| stds[g] > threshold).collect();
    let cand = top_by_std_desc(cand, &stds, max_per_iter);
    let mut to_orcl = Vec::with_capacity(cand.len());
    for &g in &cand {
        to_orcl.push(list_data_to_pred[g].clone());
        for v in &mut means[g] {
            *v = 0.0; // paper: "send 0 instead to generator"
        }
    }
    (to_orcl, means)
}

// ---------------------------------------------------------------------------
// Flat-data-plane reductions (strided, zero inner-loop allocations)
// ---------------------------------------------------------------------------

/// Committee std over models for each row of the batch: `preds[model]` is a
/// `rows × width` view (typically straight over a received result payload).
/// Returns the per-row max-component std. Single pass per component, no
/// inner-loop allocations; numerically identical to [`committee_std`] (same
/// summation order over models).
pub fn committee_std_batch(preds_per_model: &[BatchView<'_>]) -> Vec<f32> {
    let n_models = preds_per_model.len();
    if n_models == 0 {
        return vec![];
    }
    let rows = preds_per_model[0].rows();
    let width = preds_per_model[0].width();
    let mut out = Vec::with_capacity(rows);
    for g in 0..rows {
        let mut max_std = 0.0f32;
        for k in 0..width {
            let mut sum = 0.0f32;
            for m in preds_per_model {
                sum += m.row(g)[k];
            }
            let mean = sum / n_models as f32;
            let var = if n_models > 1 {
                let mut acc = 0.0f32;
                for m in preds_per_model {
                    let d = m.row(g)[k] - mean;
                    acc += d * d;
                }
                acc / (n_models as f32 - 1.0)
            } else {
                0.0
            };
            max_std = max_std.max(var.sqrt());
        }
        out.push(max_std);
    }
    out
}

/// Committee mean per row, as one contiguous [`Batch`]. Numerically
/// identical to [`committee_mean`].
pub fn committee_mean_batch(preds_per_model: &[BatchView<'_>]) -> Batch {
    let n_models = preds_per_model.len();
    if n_models == 0 {
        return Batch::new();
    }
    let rows = preds_per_model[0].rows();
    let width = preds_per_model[0].width();
    let mut out = Batch::zeros(rows, width);
    for g in 0..rows {
        let row = out.row_mut(g);
        for (k, slot) in row.iter_mut().enumerate() {
            let mut sum = 0.0f32;
            for m in preds_per_model {
                sum += m.row(g)[k];
            }
            *slot = sum / n_models as f32;
        }
    }
    out
}

/// Flat twin of [`committee_std_check`]: same selection and zeroing
/// semantics, but inputs/outputs stay contiguous — the checked block is the
/// mean batch with selected rows zeroed in place, ready to scatter as
/// payload row slices.
pub fn committee_std_check_batch(
    inputs: &BatchView<'_>,
    preds_per_model: &[BatchView<'_>],
    threshold: f32,
    max_per_iter: usize,
) -> (RowBlock, RowBlock) {
    let stds = committee_std_batch(preds_per_model);
    let mut means = committee_mean_batch(preds_per_model);
    let cand: Vec<usize> = (0..stds.len()).filter(|&g| stds[g] > threshold).collect();
    let cand = top_by_std_desc(cand, &stds, max_per_iter);
    let mut to_orcl = RowBlock::with_capacity(cand.len(), cand.len() * inputs.width());
    for &g in &cand {
        to_orcl.push_row(inputs.row(g));
        means.row_mut(g).fill(0.0);
    }
    (to_orcl, means.into_row_block())
}

/// Std-threshold [`Utils`] with the paper's dynamic oracle-buffer
/// adjustment: re-sort buffered inputs by fresh committee std and drop the
/// ones the retrained committee now agrees on.
pub struct CommitteeStdUtils {
    pub threshold: f32,
    pub max_per_iter: usize,
}

impl CommitteeStdUtils {
    pub fn new(threshold: f32, max_per_iter: usize) -> Self {
        CommitteeStdUtils { threshold, max_per_iter }
    }
}

impl Utils for CommitteeStdUtils {
    fn prediction_check(
        &mut self,
        list_data_to_pred: &[Vec<f32>],
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        committee_std_check(list_data_to_pred, preds_per_model, self.threshold, self.max_per_iter)
    }

    fn prediction_check_batch(
        &mut self,
        inputs: &BatchView<'_>,
        preds_per_model: &[BatchView<'_>],
    ) -> (RowBlock, RowBlock) {
        committee_std_check_batch(inputs, preds_per_model, self.threshold, self.max_per_iter)
    }

    fn adjust_input_for_oracle(
        &mut self,
        buffer: Vec<Vec<f32>>,
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> Vec<Vec<f32>> {
        if preds_per_model.is_empty() || buffer.is_empty() {
            return buffer;
        }
        let stds = committee_std(preds_per_model);
        debug_assert_eq!(stds.len(), buffer.len());
        // drop entries the retrained committee now agrees on, then order by
        // uncertainty with partial selection: the `max_per_iter` most
        // uncertain survivors are exactly sorted at the front (the next
        // dispatch window), while the rest stay buffered behind them —
        // partitioned below the window's minimum but otherwise unordered.
        // This trades exact tail ordering between rescores for an O(n)
        // pass instead of a full sort; each rescore re-fronts the current
        // top-k, and nothing above threshold is ever discarded.
        let mut keep: Vec<usize> =
            (0..buffer.len()).filter(|&i| stds[i] > self.threshold).collect();
        front_top_k_by_std(&mut keep, &stds, self.max_per_iter);
        keep.into_iter().map(|i| buffer[i].clone()).collect()
    }

    /// Flat twin of the nested adjustment above: identical selection and
    /// ordering (same `committee_std` summation order, same partial
    /// selection), but the drained buffer is read by stride and the kept
    /// rows copy once into one contiguous block — no per-row boxing.
    fn adjust_input_for_oracle_batch(
        &mut self,
        buffer: &BatchView<'_>,
        preds_per_model: &[BatchView<'_>],
    ) -> RowBlock {
        if preds_per_model.is_empty() || buffer.is_empty() {
            return buffer.to_row_block();
        }
        let stds = committee_std_batch(preds_per_model);
        debug_assert_eq!(stds.len(), buffer.rows());
        let mut keep: Vec<usize> =
            (0..buffer.rows()).filter(|&i| stds[i] > self.threshold).collect();
        front_top_k_by_std(&mut keep, &stds, self.max_per_iter);
        let mut out = RowBlock::with_capacity(keep.len(), keep.len() * buffer.width());
        for &i in &keep {
            out.push_row(buffer.row(i));
        }
        out
    }
}

/// Label-everything utils (serial-baseline parity tests; no UQ gating).
pub struct SelectAllUtils {
    pub max_per_iter: usize,
}

impl Utils for SelectAllUtils {
    fn prediction_check(
        &mut self,
        list_data_to_pred: &[Vec<f32>],
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let means = committee_mean(preds_per_model);
        let take = self.max_per_iter.min(list_data_to_pred.len());
        (list_data_to_pred[..take].to_vec(), means)
    }

    fn prediction_check_batch(
        &mut self,
        inputs: &BatchView<'_>,
        preds_per_model: &[BatchView<'_>],
    ) -> (RowBlock, RowBlock) {
        let means = committee_mean_batch(preds_per_model);
        let take = self.max_per_iter.min(inputs.rows());
        let mut to_orcl = RowBlock::with_capacity(take, take * inputs.width());
        for g in 0..take {
            to_orcl.push_row(inputs.row(g));
        }
        (to_orcl, means.into_row_block())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 2 models × 3 generators × width 2.
    fn preds() -> Vec<Vec<Vec<f32>>> {
        vec![
            vec![vec![1.0, 2.0], vec![0.0, 0.0], vec![5.0, 5.0]],
            vec![vec![1.0, 2.0], vec![1.0, 0.0], vec![5.0, 7.0]],
        ]
    }

    #[test]
    fn std_zero_when_models_agree() {
        let s = committee_std(&preds());
        assert!(s[0].abs() < 1e-7);
        assert!(s[1] > 0.5);
        assert!(s[2] > 1.0);
    }

    #[test]
    fn std_ddof1_matches_manual() {
        // two models, values 0 and 1 → std (ddof=1) = sqrt(0.5)*sqrt(2) = 0.7071
        let p = vec![vec![vec![0.0]], vec![vec![1.0]]];
        let s = committee_std(&p);
        assert!((s[0] - std::f32::consts::FRAC_1_SQRT_2).abs() < 1e-6, "{}", s[0]);
    }

    #[test]
    fn mean_is_elementwise() {
        let m = committee_mean(&preds());
        assert_eq!(m[0], vec![1.0, 2.0]);
        assert_eq!(m[1], vec![0.5, 0.0]);
        assert_eq!(m[2], vec![5.0, 6.0]);
    }

    #[test]
    fn check_selects_above_threshold_and_zeroes() {
        let inputs = vec![vec![10.0], vec![20.0], vec![30.0]];
        let (orcl, checked) = committee_std_check(&inputs, &preds(), 0.3, 10);
        // generators 1 and 2 exceed threshold; 2 has larger std → first
        assert_eq!(orcl, vec![vec![30.0], vec![20.0]]);
        assert_eq!(checked[0], vec![1.0, 2.0]); // untouched mean
        assert_eq!(checked[1], vec![0.0, 0.0]); // zeroed
        assert_eq!(checked[2], vec![0.0, 0.0]); // zeroed
        assert_eq!(checked.len(), 3); // one entry per generator, always
    }

    #[test]
    fn check_caps_selection() {
        let inputs = vec![vec![10.0], vec![20.0], vec![30.0]];
        let (orcl, checked) = committee_std_check(&inputs, &preds(), 0.3, 1);
        assert_eq!(orcl.len(), 1);
        assert_eq!(orcl[0], vec![30.0]);
        assert_eq!(checked.len(), 3);
    }

    #[test]
    fn adjust_drops_agreed_and_sorts() {
        let mut u = CommitteeStdUtils::new(0.3, 10);
        let buffer = vec![vec![1.0], vec![2.0], vec![3.0]];
        let adjusted = u.adjust_input_for_oracle(buffer, &preds());
        // generator-0-like entry (std 0) dropped; order: highest std first
        assert_eq!(adjusted, vec![vec![3.0], vec![2.0]]);
    }

    #[test]
    fn adjust_is_subset_invariant() {
        let mut u = CommitteeStdUtils::new(0.0, 10);
        let buffer = vec![vec![1.0], vec![2.0], vec![3.0]];
        let adjusted = u.adjust_input_for_oracle(buffer.clone(), &preds());
        for a in &adjusted {
            assert!(buffer.contains(a));
        }
    }

    #[test]
    fn single_model_std_is_zero() {
        let p = vec![vec![vec![3.0, 4.0]]];
        assert_eq!(committee_std(&p), vec![0.0]);
    }

    /// The nested preds() fixture as owned batches (2 models × 3 rows × 2).
    fn pred_batches() -> Vec<Batch> {
        preds().iter().map(|m| Batch::from_rows(m).unwrap()).collect()
    }

    #[test]
    fn batch_reductions_match_nested_bitwise() {
        let nested = preds();
        let batches = pred_batches();
        let views: Vec<BatchView<'_>> = batches.iter().map(|b| b.view()).collect();
        assert_eq!(committee_std_batch(&views), committee_std(&nested));
        assert_eq!(committee_mean_batch(&views).to_nested(), committee_mean(&nested));
        // empty committee
        assert!(committee_std_batch(&[]).is_empty());
        assert_eq!(committee_mean_batch(&[]).rows(), 0);
    }

    #[test]
    fn batch_check_matches_nested_check() {
        let inputs = vec![vec![10.0], vec![20.0], vec![30.0]];
        let input_batch = Batch::from_rows(&inputs).unwrap();
        let batches = pred_batches();
        let views: Vec<BatchView<'_>> = batches.iter().map(|b| b.view()).collect();
        for (threshold, cap) in [(0.3f32, 10usize), (0.3, 1), (f32::MAX, 8), (0.0, 2)] {
            let (n_orcl, n_checked) = committee_std_check(&inputs, &preds(), threshold, cap);
            let (b_orcl, b_checked) =
                committee_std_check_batch(&input_batch.view(), &views, threshold, cap);
            assert_eq!(b_orcl.to_nested(), n_orcl, "to_orcl thr={threshold} cap={cap}");
            assert_eq!(b_checked.to_nested(), n_checked, "checked thr={threshold} cap={cap}");
        }
    }

    #[test]
    fn select_all_batch_matches_nested() {
        let mut u = SelectAllUtils { max_per_iter: 2 };
        let inputs = vec![vec![1.0], vec![2.0], vec![3.0]];
        let (n_orcl, n_checked) = u.prediction_check(&inputs, &preds());
        let input_batch = Batch::from_rows(&inputs).unwrap();
        let batches = pred_batches();
        let views: Vec<BatchView<'_>> = batches.iter().map(|b| b.view()).collect();
        let (b_orcl, b_checked) = u.prediction_check_batch(&input_batch.view(), &views);
        assert_eq!(b_orcl.to_nested(), n_orcl);
        assert_eq!(b_checked.to_nested(), n_checked);
    }

    #[test]
    fn adjust_partial_selection_fronts_most_uncertain_and_keeps_survivors() {
        let mut u = CommitteeStdUtils::new(0.3, 1);
        let buffer = vec![vec![1.0], vec![2.0], vec![3.0]];
        // two entries exceed the threshold; only the next dispatch window
        // (max_per_iter = 1) is exactly ordered, but the other survivor
        // must stay buffered — nothing above threshold is discarded
        let adjusted = u.adjust_input_for_oracle(buffer, &preds());
        assert_eq!(adjusted.len(), 2);
        assert_eq!(adjusted[0], vec![3.0], "most uncertain entry leads");
        assert!(adjusted.contains(&vec![2.0]), "survivor beyond the window kept");
    }

    #[test]
    fn adjust_batch_matches_nested_adjust() {
        let buffer = vec![vec![1.0], vec![2.0], vec![3.0]];
        let buffer_batch = Batch::from_rows(&buffer).unwrap();
        let batches = pred_batches();
        let views: Vec<BatchView<'_>> = batches.iter().map(|b| b.view()).collect();
        for (threshold, cap) in [(0.3f32, 10usize), (0.3, 1), (f32::MAX, 4), (0.0, 2)] {
            let mut n = CommitteeStdUtils::new(threshold, cap);
            let mut b = CommitteeStdUtils::new(threshold, cap);
            let nested = n.adjust_input_for_oracle(buffer.clone(), &preds());
            let flat = b.adjust_input_for_oracle_batch(&buffer_batch.view(), &views);
            assert_eq!(flat.to_nested(), nested, "thr={threshold} cap={cap}");
        }
        // empty committee: both return the buffer unchanged
        let mut u = CommitteeStdUtils::new(0.0, 4);
        assert_eq!(
            u.adjust_input_for_oracle_batch(&buffer_batch.view(), &[]).to_nested(),
            buffer
        );
    }

    #[test]
    fn select_all_caps() {
        let mut u = SelectAllUtils { max_per_iter: 2 };
        let inputs = vec![vec![1.0], vec![2.0], vec![3.0]];
        let (orcl, checked) = u.prediction_check(&inputs, &preds());
        assert_eq!(orcl.len(), 2);
        assert_eq!(checked.len(), 3);
    }
}
