//! Selection strategies: implementations of the paper's `prediction_check`
//! and `adjust_input_for_oracle` utilities (SI "Utilities").

use crate::kernels::Utils;

/// Committee std over models for each generator: `preds[model][generator]`.
/// Returns per-generator max-component std.
pub fn committee_std(preds_per_model: &[Vec<Vec<f32>>]) -> Vec<f32> {
    let n_models = preds_per_model.len();
    if n_models == 0 {
        return vec![];
    }
    let n_gen = preds_per_model[0].len();
    let mut out = Vec::with_capacity(n_gen);
    for g in 0..n_gen {
        let width = preds_per_model[0][g].len();
        let mut max_std = 0.0f32;
        for k in 0..width {
            let vals: Vec<f32> = preds_per_model.iter().map(|m| m[g][k]).collect();
            let mean = vals.iter().sum::<f32>() / n_models as f32;
            let var = if n_models > 1 {
                vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
                    / (n_models as f32 - 1.0)
            } else {
                0.0
            };
            max_std = max_std.max(var.sqrt());
        }
        out.push(max_std);
    }
    out
}

/// Committee mean per generator.
pub fn committee_mean(preds_per_model: &[Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
    let n_models = preds_per_model.len();
    if n_models == 0 {
        return vec![];
    }
    let n_gen = preds_per_model[0].len();
    (0..n_gen)
        .map(|g| {
            let width = preds_per_model[0][g].len();
            (0..width)
                .map(|k| {
                    preds_per_model.iter().map(|m| m[g][k]).sum::<f32>() / n_models as f32
                })
                .collect()
        })
        .collect()
}

/// The paper's example `prediction_check`: inputs whose committee std
/// exceeds `threshold` go to the oracle (capped at `max_per_iter`, highest
/// std first); their returned prediction is zeroed so the generator knows
/// not to trust it, everyone else receives the committee mean.
pub fn committee_std_check(
    list_data_to_pred: &[Vec<f32>],
    preds_per_model: &[Vec<Vec<f32>>],
    threshold: f32,
    max_per_iter: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let stds = committee_std(preds_per_model);
    let mut means = committee_mean(preds_per_model);
    // rank candidate generators by std, descending
    let mut cand: Vec<usize> = (0..stds.len()).filter(|&g| stds[g] > threshold).collect();
    cand.sort_by(|&a, &b| stds[b].partial_cmp(&stds[a]).unwrap_or(std::cmp::Ordering::Equal));
    cand.truncate(max_per_iter);
    let mut to_orcl = Vec::with_capacity(cand.len());
    for &g in &cand {
        to_orcl.push(list_data_to_pred[g].clone());
        for v in &mut means[g] {
            *v = 0.0; // paper: "send 0 instead to generator"
        }
    }
    (to_orcl, means)
}

/// Std-threshold [`Utils`] with the paper's dynamic oracle-buffer
/// adjustment: re-sort buffered inputs by fresh committee std and drop the
/// ones the retrained committee now agrees on.
pub struct CommitteeStdUtils {
    pub threshold: f32,
    pub max_per_iter: usize,
}

impl CommitteeStdUtils {
    pub fn new(threshold: f32, max_per_iter: usize) -> Self {
        CommitteeStdUtils { threshold, max_per_iter }
    }
}

impl Utils for CommitteeStdUtils {
    fn prediction_check(
        &mut self,
        list_data_to_pred: &[Vec<f32>],
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        committee_std_check(list_data_to_pred, preds_per_model, self.threshold, self.max_per_iter)
    }

    fn adjust_input_for_oracle(
        &mut self,
        buffer: Vec<Vec<f32>>,
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> Vec<Vec<f32>> {
        if preds_per_model.is_empty() || buffer.is_empty() {
            return buffer;
        }
        let stds = committee_std(preds_per_model);
        debug_assert_eq!(stds.len(), buffer.len());
        // sort by std descending, keep those still above threshold
        let mut idx: Vec<usize> = (0..buffer.len()).collect();
        idx.sort_by(|&a, &b| stds[b].partial_cmp(&stds[a]).unwrap_or(std::cmp::Ordering::Equal));
        idx.into_iter()
            .filter(|&i| stds[i] > self.threshold)
            .map(|i| buffer[i].clone())
            .collect()
    }
}

/// Label-everything utils (serial-baseline parity tests; no UQ gating).
pub struct SelectAllUtils {
    pub max_per_iter: usize,
}

impl Utils for SelectAllUtils {
    fn prediction_check(
        &mut self,
        list_data_to_pred: &[Vec<f32>],
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let means = committee_mean(preds_per_model);
        let take = self.max_per_iter.min(list_data_to_pred.len());
        (list_data_to_pred[..take].to_vec(), means)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 2 models × 3 generators × width 2.
    fn preds() -> Vec<Vec<Vec<f32>>> {
        vec![
            vec![vec![1.0, 2.0], vec![0.0, 0.0], vec![5.0, 5.0]],
            vec![vec![1.0, 2.0], vec![1.0, 0.0], vec![5.0, 7.0]],
        ]
    }

    #[test]
    fn std_zero_when_models_agree() {
        let s = committee_std(&preds());
        assert!(s[0].abs() < 1e-7);
        assert!(s[1] > 0.5);
        assert!(s[2] > 1.0);
    }

    #[test]
    fn std_ddof1_matches_manual() {
        // two models, values 0 and 1 → std (ddof=1) = sqrt(0.5)*sqrt(2) = 0.7071
        let p = vec![vec![vec![0.0]], vec![vec![1.0]]];
        let s = committee_std(&p);
        assert!((s[0] - std::f32::consts::FRAC_1_SQRT_2).abs() < 1e-6, "{}", s[0]);
    }

    #[test]
    fn mean_is_elementwise() {
        let m = committee_mean(&preds());
        assert_eq!(m[0], vec![1.0, 2.0]);
        assert_eq!(m[1], vec![0.5, 0.0]);
        assert_eq!(m[2], vec![5.0, 6.0]);
    }

    #[test]
    fn check_selects_above_threshold_and_zeroes() {
        let inputs = vec![vec![10.0], vec![20.0], vec![30.0]];
        let (orcl, checked) = committee_std_check(&inputs, &preds(), 0.3, 10);
        // generators 1 and 2 exceed threshold; 2 has larger std → first
        assert_eq!(orcl, vec![vec![30.0], vec![20.0]]);
        assert_eq!(checked[0], vec![1.0, 2.0]); // untouched mean
        assert_eq!(checked[1], vec![0.0, 0.0]); // zeroed
        assert_eq!(checked[2], vec![0.0, 0.0]); // zeroed
        assert_eq!(checked.len(), 3); // one entry per generator, always
    }

    #[test]
    fn check_caps_selection() {
        let inputs = vec![vec![10.0], vec![20.0], vec![30.0]];
        let (orcl, checked) = committee_std_check(&inputs, &preds(), 0.3, 1);
        assert_eq!(orcl.len(), 1);
        assert_eq!(orcl[0], vec![30.0]);
        assert_eq!(checked.len(), 3);
    }

    #[test]
    fn adjust_drops_agreed_and_sorts() {
        let mut u = CommitteeStdUtils::new(0.3, 10);
        let buffer = vec![vec![1.0], vec![2.0], vec![3.0]];
        let adjusted = u.adjust_input_for_oracle(buffer, &preds());
        // generator-0-like entry (std 0) dropped; order: highest std first
        assert_eq!(adjusted, vec![vec![3.0], vec![2.0]]);
    }

    #[test]
    fn adjust_is_subset_invariant() {
        let mut u = CommitteeStdUtils::new(0.0, 10);
        let buffer = vec![vec![1.0], vec![2.0], vec![3.0]];
        let adjusted = u.adjust_input_for_oracle(buffer.clone(), &preds());
        for a in &adjusted {
            assert!(buffer.contains(a));
        }
    }

    #[test]
    fn single_model_std_is_zero() {
        let p = vec![vec![vec![3.0, 4.0]]];
        assert_eq!(committee_std(&p), vec![0.0]);
    }

    #[test]
    fn select_all_caps() {
        let mut u = SelectAllUtils { max_per_iter: 2 };
        let inputs = vec![vec![1.0], vec![2.0], vec![3.0]];
        let (orcl, checked) = u.prediction_check(&inputs, &preds());
        assert_eq!(orcl.len(), 2);
        assert_eq!(checked.len(), 3);
    }
}
