//! Exchange controller sub-kernel: the dedicated high-frequency loop
//! between generator and prediction kernels (Fig. 2: "One dedicated
//! controller sub-kernel ensures high-frequency communication between
//! generation and prediction kernels").

use std::time::Instant;

use crate::comm::bus::Endpoint;
use crate::comm::codec;
use crate::comm::protocol::*;
use crate::config::{topology, AlSetting, Topology};
use crate::coordinator::hosts::{gather_poll, is_down, ShutdownFlag};
use crate::kernels::Utils;
use crate::telemetry::KernelTelemetry;

/// Run the Exchange loop until stop criteria or shutdown.
///
/// One iteration = one lockstep round of the red+blue flows of Fig. 4:
/// gather `data_to_pred` from every generator → broadcast to predictors →
/// gather committee predictions → `prediction_check` → forward selected
/// inputs to the Manager → scatter checked predictions to generators.
pub fn exchange_host(
    mut ep: Endpoint,
    mut utils: Box<dyn Utils>,
    setting: &AlSetting,
    topo: &Topology,
    down: ShutdownFlag,
) -> KernelTelemetry {
    let mut tel = KernelTelemetry::new("exchange", ep.rank());
    let poll = setting.poll_interval;
    let gene = topo.gene_ranks();
    let pred = topo.pred_ranks();
    let oracle_enabled = !topo.orcl_ranks().is_empty();
    let mut iterations: u64 = 0;
    let t_start = Instant::now();

    'outer: loop {
        if is_down(&down) {
            break;
        }
        if let Some(max) = setting.stop.max_iterations {
            if iterations >= max {
                ep.send(topology::MANAGER, TAG_STOP, vec![]);
                tel.bump("stop_signals");
                break;
            }
        }
        if let Some(max_wall) = setting.stop.max_wall {
            if t_start.elapsed() >= max_wall {
                ep.send(topology::MANAGER, TAG_STOP, vec![]);
                tel.bump("stop_signals");
                break;
            }
        }

        // red flow: inputs from every generator
        let t0 = Instant::now();
        if !setting.fixed_size_data {
            // consume the size headers first (SI §S3 variable-size mode)
            match gather_poll(&mut ep, &gene, TAG_GEN_SIZE, &down, poll) {
                Some(sizes) => {
                    tel.add("size_headers", sizes.len() as u64);
                }
                None => break,
            }
        }
        let raw = match gather_poll(&mut ep, &gene, TAG_GEN_TO_PRED, &down, poll) {
            Some(r) => r,
            None => break,
        };
        tel.record("gather_gen", t0.elapsed());

        let mut any_stop = false;
        let inputs: Vec<Vec<f32>> = raw
            .iter()
            .map(|m| {
                let (stop, data) = decode_gen(m);
                any_stop |= stop;
                data.to_vec()
            })
            .collect();
        if any_stop {
            // a generator met its stop criterion (SI §S6); tell the Manager
            ep.send(topology::MANAGER, TAG_STOP, vec![]);
            tel.bump("stop_signals");
        }

        // broadcast the same input list to every prediction process
        let t1 = Instant::now();
        let packed_inputs = codec::pack_vecs(&inputs);
        ep.bcast(&pred, TAG_PRED_IN, &packed_inputs);
        tel.record("bcast_pred", t1.elapsed());

        // blue flow: committee predictions
        let t2 = Instant::now();
        let packed_preds = match gather_poll(&mut ep, &pred, TAG_PRED_OUT, &down, poll) {
            Some(p) => p,
            None => break,
        };
        tel.record("gather_pred", t2.elapsed());

        let mut preds_per_model = Vec::with_capacity(packed_preds.len());
        for p in &packed_preds {
            match codec::unpack(p) {
                Some(list) if list.len() == gene.len() => preds_per_model.push(list),
                _ => {
                    tel.bump("malformed");
                    continue 'outer;
                }
            }
        }

        // controller-side UQ decision (paper: "the uncertainty
        // quantification ... is handled centrally by the controller kernel")
        let t3 = Instant::now();
        let (to_orcl, checked) = utils.prediction_check(&inputs, &preds_per_model);
        tel.record("prediction_check", t3.elapsed());
        assert_eq!(
            checked.len(),
            gene.len(),
            "prediction_check must return one entry per generator"
        );

        if oracle_enabled && !to_orcl.is_empty() {
            tel.add("selected_for_oracle", to_orcl.len() as u64);
            ep.send(topology::MANAGER, TAG_ORCL_SELECT, codec::pack_vecs(&to_orcl));
        }

        // scatter checked predictions back, ordered by generator rank
        let t4 = Instant::now();
        ep.scatter(&gene, TAG_GENE_IN, checked);
        tel.record("scatter_gene", t4.elapsed());

        iterations += 1;
        tel.bump("iterations");
    }
    tel
}

#[cfg(test)]
mod tests {
    //! Exchange is exercised end-to-end in `rust/tests/`; unit-level
    //! protocol pieces (encode/decode, selection) have their own tests.
    //! Here: the stop-criteria bookkeeping contract.
    use super::*;
    use crate::comm::World;
    use crate::config::AlSetting;
    use crate::coordinator::selection::CommitteeStdUtils;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn exchange_stops_at_zero_max_iterations() {
        let mut s = AlSetting::default();
        s.gene_process = 1;
        s.pred_process = 1;
        s.ml_process = 0;
        s.orcl_process = 0;
        s.stop.max_iterations = Some(0);
        let topo = Topology::new(&s);
        let mut world = World::new(topo.n_ranks());
        let manager_ep = world.endpoint(topology::MANAGER);
        let ex_ep = world.endpoint(topology::EXCHANGE);
        let down = Arc::new(AtomicBool::new(false));
        let tel = exchange_host(
            ex_ep,
            Box::new(CommitteeStdUtils::new(0.5, 4)),
            &s,
            &topo,
            down,
        );
        assert_eq!(tel.counter("iterations"), 0);
        assert_eq!(tel.counter("stop_signals"), 1);
        drop(manager_ep);
    }
}
