//! Exchange controller sub-kernel: the dedicated high-frequency loop
//! between generator and prediction kernels (Fig. 2: "One dedicated
//! controller sub-kernel ensures high-frequency communication between
//! generation and prediction kernels").
//!
//! Two relay strategies ([`crate::config::ExchangeMode`]):
//!
//! * **Lockstep** — the paper's Fig. 4 rounds: gather one input from every
//!   generator, broadcast the list to every prediction rank, gather the
//!   committee's outputs, `prediction_check`, scatter back.
//! * **Batched** — requests from generators are coalesced into
//!   micro-batches ([`BatchScheduler`]: dispatch at `batch.max_size` queued
//!   items, or when the oldest request has waited `batch.max_delay`), each
//!   batch is routed to one prediction *shard* (a full committee replica
//!   group) chosen round-robin with a least-outstanding fallback, and
//!   per-item results are scattered back to the originating generators.
//!   When every shard already has `batch.max_outstanding` batches in
//!   flight, requests queue and are released in FIFO order (backpressure).

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use crate::comm::bus::{Endpoint, Payload, Src};
use crate::comm::codec;
use crate::comm::protocol::*;
use crate::config::{
    topology, AlSetting, BatchSetting, ExchangeMode, SchedPolicy, SchedSetting, Topology,
};
use crate::coordinator::dispatch::{
    BuiltinPolicy, DispatchConfig, DispatchCore, DispatchLeg, Eviction,
};
use crate::coordinator::hosts::{gather_poll, is_down, ShutdownFlag};
use crate::data::batch::{PayloadBatch, RowBlock, RowQueue, SharedRows};
use crate::kernels::Utils;
use crate::telemetry::registry::{registry, Counter, Gauge};
use crate::telemetry::KernelTelemetry;

/// Run the Exchange loop until stop criteria or shutdown.
///
/// In lockstep mode one iteration is one Fig.-4 round (every generator steps
/// once); in batched mode one iteration is one completed batch round-trip.
pub fn exchange_host(
    ep: Endpoint,
    utils: Box<dyn Utils>,
    setting: &AlSetting,
    topo: &Topology,
    down: ShutdownFlag,
) -> KernelTelemetry {
    match setting.exchange_mode {
        ExchangeMode::Lockstep => lockstep_host(ep, utils, setting, topo, down),
        ExchangeMode::Batched => batched_host(ep, utils, setting, topo, down),
    }
}

// ---------------------------------------------------------------------------
// Lockstep relay (paper-faithful Fig. 4 rounds)
// ---------------------------------------------------------------------------

fn lockstep_host(
    mut ep: Endpoint,
    mut utils: Box<dyn Utils>,
    setting: &AlSetting,
    topo: &Topology,
    down: ShutdownFlag,
) -> KernelTelemetry {
    let mut tel = KernelTelemetry::new("exchange", ep.rank());
    let poll = setting.poll_interval;
    let gene = topo.gene_ranks();
    let pred = topo.pred_ranks();
    let oracle_enabled = !topo.orcl_ranks().is_empty();
    // reusable scratches: the stacked input rows live in one flat RowBlock,
    // re-encoded each round without fresh allocations, then converted once
    // into a shared payload that fans out to every prediction rank by
    // refcount
    let mut pack_buf = codec::PackBuffer::new();
    let mut orcl_pack = codec::PackBuffer::new();
    let mut inputs = RowBlock::new();
    let mut iterations: u64 = 0;
    let t_start = Instant::now();

    'outer: loop {
        if is_down(&down) {
            break;
        }
        if ep.try_recv(Src::Any, TAG_RANK_DOWN).is_some() {
            // lockstep rounds need every generator and every prediction
            // rank alive — the next gather would hang on a dead peer, so
            // abort the run (the batched mode degrades instead)
            tel.bump("rank_down_notices");
            registry().inc(Counter::RankDownNotices);
            ep.send(topology::MANAGER, TAG_STOP, Payload::empty());
            tel.bump("stop_signals");
            break;
        }
        if let Some(max) = setting.stop.max_iterations {
            if iterations >= max {
                ep.send(topology::MANAGER, TAG_STOP, Payload::empty());
                tel.bump("stop_signals");
                break;
            }
        }
        if let Some(max_wall) = setting.stop.max_wall {
            if t_start.elapsed() >= max_wall {
                ep.send(topology::MANAGER, TAG_STOP, Payload::empty());
                tel.bump("stop_signals");
                break;
            }
        }

        // red flow: inputs from every generator
        let t0 = Instant::now();
        if !setting.fixed_size_data {
            // consume the size headers first (SI §S3 variable-size mode)
            match gather_poll(&mut ep, &gene, TAG_GEN_SIZE, &down, poll) {
                Some(sizes) => {
                    tel.add("size_headers", sizes.len() as u64);
                }
                None => break,
            }
        }
        let raw = match gather_poll(&mut ep, &gene, TAG_GEN_TO_PRED, &down, poll) {
            Some(r) => r,
            None => break,
        };
        tel.record("gather_gen", t0.elapsed());

        let mut any_stop = false;
        inputs.clear();
        for m in &raw {
            let (stop, data) = decode_gen(m);
            any_stop |= stop;
            inputs.push_row(data);
        }
        if any_stop {
            // a generator met its stop criterion (SI §S6); tell the Manager
            ep.send(topology::MANAGER, TAG_STOP, Payload::empty());
            tel.bump("stop_signals");
        }

        // broadcast the same input list to every prediction process
        let t1 = Instant::now();
        ep.bcast(&pred, TAG_PRED_IN, pack_buf.pack_row_block(&inputs));
        tel.record("bcast_pred", t1.elapsed());

        // blue flow: committee predictions
        let t2 = Instant::now();
        let packed_preds = match gather_poll(&mut ep, &pred, TAG_PRED_OUT, &down, poll) {
            Some(p) => p,
            None => break,
        };
        tel.record("gather_pred", t2.elapsed());

        // flat fast path: uniform inputs + uniform equal-width committee
        // replies reduce as strided views straight over the received
        // payloads — no nested materialization anywhere
        let flat_views = {
            let mut vs = Vec::with_capacity(packed_preds.len());
            let mut ok = true;
            for p in &packed_preds {
                match codec::unpack_batch_view(p) {
                    Some(v) if v.rows() == gene.len() => vs.push(v),
                    _ => {
                        ok = false;
                        break;
                    }
                }
            }
            ok = ok && vs.windows(2).all(|w| w[0].width() == w[1].width());
            if ok {
                Some(vs)
            } else {
                None
            }
        };

        // controller-side UQ decision (paper: "the uncertainty
        // quantification ... is handled centrally by the controller kernel")
        let checked = match (inputs.as_view(), flat_views) {
            (Some(input_view), Some(views)) => {
                let t3 = Instant::now();
                let (to_orcl, checked) = utils.prediction_check_batch(&input_view, &views);
                tel.record("prediction_check", t3.elapsed());
                assert_eq!(
                    checked.len(),
                    gene.len(),
                    "prediction_check must return one entry per generator"
                );
                if oracle_enabled && !to_orcl.is_empty() {
                    tel.add("selected_for_oracle", to_orcl.len() as u64);
                    registry().add(Counter::SelectedForOracle, to_orcl.len() as u64);
                    ep.send(
                        topology::MANAGER,
                        TAG_ORCL_SELECT,
                        orcl_pack.pack_row_block(&to_orcl),
                    );
                }
                checked
            }
            _ => {
                // ragged traffic: legacy nested decode + check
                let mut preds_per_model = Vec::with_capacity(packed_preds.len());
                for p in &packed_preds {
                    match codec::unpack(p) {
                        Some(list) if list.len() == gene.len() => preds_per_model.push(list),
                        _ => {
                            tel.bump("malformed");
                            continue 'outer;
                        }
                    }
                }
                let nested_inputs = inputs.to_nested();
                let t3 = Instant::now();
                let (to_orcl, checked) = utils.prediction_check(&nested_inputs, &preds_per_model);
                tel.record("prediction_check", t3.elapsed());
                assert_eq!(
                    checked.len(),
                    gene.len(),
                    "prediction_check must return one entry per generator"
                );
                if oracle_enabled && !to_orcl.is_empty() {
                    tel.add("selected_for_oracle", to_orcl.len() as u64);
                    registry().add(Counter::SelectedForOracle, to_orcl.len() as u64);
                    ep.send(topology::MANAGER, TAG_ORCL_SELECT, codec::pack_vecs(&to_orcl));
                }
                RowBlock::from_rows(&checked)
            }
        };

        // scatter checked predictions back, ordered by generator rank —
        // each generator's row is a zero-copy slice of one shared payload
        // (one counted ingest copy for the whole block)
        let t4 = Instant::now();
        ep.note_ingest(checked.total_values());
        let shared = checked.into_shared();
        let payloads: Vec<Payload> = (0..gene.len()).map(|i| shared.row_payload(i)).collect();
        ep.scatter(&gene, TAG_GENE_IN, payloads);
        tel.record("scatter_gene", t4.elapsed());

        iterations += 1;
        tel.bump("iterations");
        registry().inc(Counter::AlIterations);
    }
    tel
}

// ---------------------------------------------------------------------------
// Batch scheduler (pure core: triggers, shard routing, backpressure)
// ---------------------------------------------------------------------------

/// One queued prediction request's metadata; the request's values live in
/// the scheduler's flat [`RowQueue`] at the same position.
#[derive(Debug)]
struct Pending {
    origin: usize,
    enqueued: Instant,
}

/// A batch the scheduler has routed to a shard, ready to send.
#[derive(Debug)]
pub struct DispatchedBatch {
    pub id: u64,
    pub shard: usize,
    /// Originating generator rank per item, aligned with `items`.
    pub origins: Vec<usize>,
    /// The batched rows, contiguous in one buffer (ordered like `origins`).
    pub items: RowBlock,
}

/// Size-/deadline-triggered micro-batching with shard routing and
/// per-shard backpressure — a facade over the shared
/// [`crate::coordinator::dispatch::DispatchCore`] state machine. Pure:
/// callers inject `now`, so the trigger semantics are unit-testable without
/// threads or sleeps.
///
/// The default static policy is round-robin with a least-outstanding
/// fallback (PR-1 semantics, with the cursor advancing past the shard
/// actually chosen); `sched_policy = "adaptive"` upgrades routing to the
/// EWMA least-estimated-completion-time policy with shard health/eviction
/// (see [`BatchScheduler::check_health`]).
///
/// The queue is flat: request values are staged contiguously in a
/// [`RowQueue`] (the generator buffer of the flat data plane), so enqueuing
/// a request copies its values once and allocates nothing per request in
/// steady state.
pub struct BatchScheduler {
    queue: VecDeque<Pending>,
    rows: RowQueue,
    core: DispatchCore<BuiltinPolicy>,
}

impl BatchScheduler {
    /// Static-policy scheduler (round-robin + least-outstanding fallback).
    pub fn new(batch: &BatchSetting, n_shards: usize) -> Self {
        Self::with_policy(batch, &SchedSetting::default(), n_shards)
    }

    /// Scheduler with the configured routing policy (`sched_*` knobs).
    pub fn with_policy(batch: &BatchSetting, sched: &SchedSetting, n_shards: usize) -> Self {
        let policy = match sched.policy {
            SchedPolicy::Static => BuiltinPolicy::round_robin(),
            SchedPolicy::Adaptive => BuiltinPolicy::adaptive(),
        };
        BatchScheduler {
            queue: VecDeque::new(),
            rows: RowQueue::new(),
            core: DispatchCore::new(DispatchConfig::new(batch, sched), policy, n_shards),
        }
    }

    /// Enqueue one request (FIFO). The row copies straight from the decoded
    /// payload into the flat staging buffer.
    pub fn push(&mut self, origin: usize, data: &[f32], now: Instant) {
        self.queue.push_back(Pending { origin, enqueued: now });
        self.rows.push_row(data);
    }

    /// Publish per-shard dispatch state (outstanding batches, EWMA) to the
    /// live metrics registry, labeling shard `i` as `ranks[i]` (the shard's
    /// lead rank). See
    /// [`crate::coordinator::dispatch::DispatchCore::observe_as`].
    pub fn observe_as(&mut self, ranks: Vec<usize>) {
        self.core.observe_as(ranks, DispatchLeg::Prediction);
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn outstanding(&self, shard: usize) -> usize {
        self.core.outstanding(shard)
    }

    pub fn in_flight(&self) -> usize {
        self.core.in_flight()
    }

    /// Form and route one batch if a trigger fired and a shard is free.
    /// Items leave the queue oldest-first (FIFO under backpressure); within
    /// a batch they are ordered by origin rank ("sorted by the rank of
    /// generator", SI) so downstream processing is arrival-order
    /// independent.
    pub fn try_dispatch(&mut self, now: Instant) -> Option<DispatchedBatch> {
        let head_since = self.queue.front().map(|p| p.enqueued);
        let d = self.core.try_dispatch(self.queue.len(), head_since, now, None)?;
        let n = d.take;
        // origin-sorted take order (stable: FIFO within an origin)
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| self.queue[i].origin);
        let total: usize = (0..n).map(|i| self.rows.row(i).len()).sum();
        let mut origins = Vec::with_capacity(n);
        let mut items = RowBlock::with_capacity(n, total);
        for &i in &order {
            origins.push(self.queue[i].origin);
            items.push_row(self.rows.row(i));
        }
        self.queue.drain(..n);
        self.rows.drop_front(n);
        Some(DispatchedBatch { id: d.id, shard: d.endpoint, origins, items })
    }

    /// Batch `id` completed its round-trip at `now`. Returns
    /// `(shard, items)`, or `None` for an orphan (unknown/duplicate id, or
    /// a batch already evicted and re-dispatched). The timestamp feeds the
    /// adaptive policy's EWMA.
    pub fn complete(&mut self, id: u64, now: Instant) -> Option<(usize, usize)> {
        self.core.complete(id, now).map(|c| (c.endpoint, c.items))
    }

    /// Evict unhealthy shards (adaptive policy only) and return their
    /// in-flight batches; the caller requeues each batch's items so they
    /// are re-served elsewhere. No-op under the static policy.
    pub fn check_health(&mut self, now: Instant) -> Vec<Eviction> {
        self.core.check_health(now)
    }

    /// A host in shard `shard` died (rank-down notice or failed send):
    /// permanently evict the whole shard — a committee gather can never
    /// complete with a member missing — under any policy, and return its
    /// in-flight batches for requeue. See
    /// [`crate::coordinator::dispatch::DispatchCore::mark_down`].
    pub fn mark_down(&mut self, shard: usize, now: Instant) -> Vec<Eviction> {
        self.core.mark_down(shard, now)
    }

    /// Whether `shard` has been permanently marked down.
    pub fn is_down(&self, shard: usize) -> bool {
        self.core.endpoint(shard).is_dead()
    }
}

// ---------------------------------------------------------------------------
// Batched relay host
// ---------------------------------------------------------------------------

/// One committee member's accepted reply. Both variants borrow the received
/// wire payload (refcount bump) — no reply is copied at ingest; ragged rows
/// materialize only if the legacy nested reduction actually runs.
#[derive(Debug, Clone)]
enum MemberReply {
    /// Uniform reply retained as a zero-copy slice of the received payload
    /// (the steady state): rows are read by stride straight off the wire
    /// buffer at reduction time.
    Flat(PayloadBatch),
    /// Ragged reply: per-row bounds over the same shared payload.
    Ragged(SharedRows),
}

/// A dispatched batch awaiting its committee replies.
struct InFlight {
    shard: usize,
    origins: Vec<usize>,
    items: RowBlock,
    /// One slot per committee member (well-formed replies only).
    replies: Vec<Option<MemberReply>>,
    n_replies: usize,
}

/// Reduce one completed batch. Flat path when the inputs are uniform and
/// every accepted reply is a uniform, equal-width payload batch — the
/// committee reduction then reads by stride straight off the received
/// payloads. Nested fallback otherwise (ragged traffic or mixed encoders).
/// Zero accepted replies yields empty checked rows so the generators never
/// stall.
fn reduce_batch(
    utils: &mut dyn Utils,
    items: &RowBlock,
    replies: Vec<MemberReply>,
) -> (RowBlock, RowBlock) {
    if replies.is_empty() {
        // every member reply was malformed; unblock the generators with
        // empty payloads rather than stalling the loop
        let mut checked = RowBlock::new();
        for _ in 0..items.len() {
            checked.push_row(&[]);
        }
        return (RowBlock::new(), checked);
    }
    if let Some(input_view) = items.as_view() {
        let mut views = Vec::with_capacity(replies.len());
        for r in &replies {
            match r {
                MemberReply::Flat(pb) => views.push(pb.view()),
                MemberReply::Ragged(_) => {
                    views.clear();
                    break;
                }
            }
        }
        if views.len() == replies.len()
            && views.windows(2).all(|w| w[0].width() == w[1].width())
        {
            return utils.prediction_check_batch(&input_view, &views);
        }
    }
    // ragged fallback: the legacy nested reduction is the one place rows
    // materialize — and only when it actually runs
    let preds_per_model: Vec<Vec<Vec<f32>>> = replies
        .into_iter()
        .map(|r| match r {
            MemberReply::Flat(pb) => pb.view().to_nested(),
            MemberReply::Ragged(rows) => rows.to_nested(),
        })
        .collect();
    let nested_inputs = items.to_nested();
    let (o, c) = utils.prediction_check(&nested_inputs, &preds_per_model);
    (RowBlock::from_rows(&o), RowBlock::from_rows(&c))
}

/// Permanently evict `shard` (a host in it died) and requeue every
/// in-flight batch it held so generators are re-served elsewhere. Returns
/// whether anything was requeued. Idempotent per shard.
fn evict_dead_shard(
    scheduler: &mut BatchScheduler,
    inflight: &mut HashMap<u64, InFlight>,
    tel: &mut KernelTelemetry,
    shard: usize,
    now: Instant,
) -> bool {
    if scheduler.is_down(shard) {
        return false;
    }
    tel.bump("shard_evictions");
    registry().inc(Counter::ShardEvictions);
    let mut requeued = false;
    for ev in scheduler.mark_down(shard, now) {
        if let Some(fl) = inflight.remove(&ev.id) {
            for (i, &origin) in fl.origins.iter().enumerate() {
                scheduler.push(origin, fl.items.row(i), now);
            }
            tel.add("requeued_items", fl.items.len() as u64);
            registry().add(Counter::RequeuedItems, fl.items.len() as u64);
            requeued = true;
        }
    }
    requeued
}

fn batched_host(
    mut ep: Endpoint,
    mut utils: Box<dyn Utils>,
    setting: &AlSetting,
    topo: &Topology,
    down: ShutdownFlag,
) -> KernelTelemetry {
    let mut tel = KernelTelemetry::new("exchange", ep.rank());
    let poll = setting.poll_interval;
    let committee = topo.committee.max(1);
    let shards = topo.shards();
    let oracle_enabled = !topo.orcl_ranks().is_empty();
    let mut scheduler = BatchScheduler::with_policy(&setting.batch, &setting.sched, shards.len());
    // live registry: label shard i by its lead rank (no-op publishes while
    // observability is disabled)
    scheduler.observe_as(shards.iter().filter_map(|s| s.first().copied()).collect());
    let mut inflight: HashMap<u64, InFlight> = HashMap::new();
    // reusable scratches: each dispatched batch is encoded in place and
    // converted once into a shared payload for the whole committee shard
    let mut frame_buf: Vec<f32> = Vec::new();
    let mut orcl_pack = codec::PackBuffer::new();
    let mut iterations: u64 = 0;
    let mut stop_forwarded = false;
    let t_start = Instant::now();

    loop {
        if is_down(&down) {
            break;
        }
        if let Some(max) = setting.stop.max_iterations {
            if iterations >= max {
                ep.send(topology::MANAGER, TAG_STOP, Payload::empty());
                tel.bump("stop_signals");
                break;
            }
        }
        if let Some(max_wall) = setting.stop.max_wall {
            if t_start.elapsed() >= max_wall {
                ep.send(topology::MANAGER, TAG_STOP, Payload::empty());
                tel.bump("stop_signals");
                break;
            }
        }

        let mut did_work = false;

        // --- control: rank-down notices from host supervisors — evict the
        // dead rank's shard immediately and requeue its in-flight items ---
        while let Some(m) = ep.try_recv(Src::Any, TAG_RANK_DOWN) {
            did_work = true;
            tel.bump("rank_down_notices");
            registry().inc(Counter::RankDownNotices);
            let Some(rank) = m.data.first().map(|&f| f as usize) else {
                continue;
            };
            if let Some(shard) = shards.iter().position(|s| s.contains(&rank)) {
                evict_dead_shard(&mut scheduler, &mut inflight, &mut tel, shard, Instant::now());
            }
        }

        // --- red flow in: drain generator requests into the queue ---
        while ep.try_recv(Src::Any, TAG_GEN_SIZE).is_some() {
            // batch frames are self-describing; size headers are consumed
            // and dropped (SI §S3 compatibility)
            tel.bump("size_headers");
            did_work = true;
        }
        while let Some(m) = ep.try_recv(Src::Any, TAG_GEN_TO_PRED) {
            if !topo.gene.contains(&m.src) {
                tel.bump("malformed");
                continue;
            }
            let (stop, data) = decode_gen(&m.data);
            if stop && !stop_forwarded {
                // a generator met its stop criterion; tell the Manager once
                ep.send(topology::MANAGER, TAG_STOP, Payload::empty());
                tel.bump("stop_signals");
                stop_forwarded = true;
            }
            // the request row copies once into the scheduler's flat queue
            scheduler.push(m.src, data, Instant::now());
            did_work = true;
        }

        // --- blue flow in: committee replies, one frame per member ---
        while let Some(m) = ep.try_recv(Src::Any, TAG_PRED_BATCH_RESULT) {
            did_work = true;
            // uniform and ragged replies are both retained as zero-copy
            // views of the received payload (a refcount bump each) — no
            // reply bytes are copied at ingest in either shape
            let (id, reply_rows, reply) =
                if let Some((id, pb)) = decode_predict_batch_result_shared(&m.data) {
                    (id, pb.rows(), MemberReply::Flat(pb))
                } else if let Some((id, rows)) = decode_predict_batch_result_shared_rows(&m.data) {
                    (id, rows.len(), MemberReply::Ragged(rows))
                } else {
                    tel.bump("malformed");
                    continue;
                };
            let Some(fl) = inflight.get_mut(&id) else {
                tel.bump("orphan_replies");
                continue;
            };
            let Some(member) = shards[fl.shard].iter().position(|&r| r == m.src) else {
                tel.bump("orphan_replies");
                continue;
            };
            if fl.replies[member].is_some() {
                tel.bump("duplicate_replies");
                continue;
            }
            fl.n_replies += 1;
            if reply_rows == fl.items.len() {
                fl.replies[member] = Some(reply);
            } else {
                tel.bump("malformed");
            }
            if fl.n_replies < committee {
                continue;
            }

            // batch complete: UQ check, forward selections, scatter results
            let fl = inflight.remove(&id).expect("present above");
            if scheduler.complete(id, Instant::now()).is_none() {
                tel.bump("orphan_completions");
            }
            let replies: Vec<MemberReply> = fl.replies.into_iter().flatten().collect();
            let t0 = Instant::now();
            let (to_orcl, checked) = reduce_batch(&mut *utils, &fl.items, replies);
            tel.record("prediction_check", t0.elapsed());
            assert_eq!(
                checked.len(),
                fl.items.len(),
                "prediction_check must return one entry per batched item"
            );
            if oracle_enabled && !to_orcl.is_empty() {
                tel.add("selected_for_oracle", to_orcl.len() as u64);
                registry().add(Counter::SelectedForOracle, to_orcl.len() as u64);
                ep.send(
                    topology::MANAGER,
                    TAG_ORCL_SELECT,
                    orcl_pack.pack_row_block(&to_orcl),
                );
            }
            // per-item results scatter as zero-copy row slices of one
            // shared result payload (one counted ingest copy per batch)
            ep.note_ingest(checked.total_values());
            let shared = checked.into_shared();
            for (i, &origin) in fl.origins.iter().enumerate() {
                ep.send(origin, TAG_GENE_IN, shared.row_payload(i));
            }
            iterations += 1;
            tel.bump("iterations");
            registry().inc(Counter::AlIterations);
            tel.add("batch_items", fl.items.len() as u64);
            if setting.stop.max_iterations.map_or(false, |max| iterations >= max) {
                // budget reached mid-drain: stop completing further batches
                // so the counter lands exactly on the limit; the outer loop
                // sends the stop signal
                break;
            }
        }

        // --- health: evict unresponsive/slow shards (adaptive policy
        // only; a no-op under the static default) and requeue their
        // in-flight items so generators are never stranded behind a dead
        // shard — late replies from the evicted batch become orphans ---
        for ev in scheduler.check_health(Instant::now()) {
            tel.bump("shard_evictions");
            registry().inc(Counter::ShardEvictions);
            if let Some(fl) = inflight.remove(&ev.id) {
                let now = Instant::now();
                for (i, &origin) in fl.origins.iter().enumerate() {
                    scheduler.push(origin, fl.items.row(i), now);
                }
                tel.add("requeued_items", fl.items.len() as u64);
                registry().add(Counter::RequeuedItems, fl.items.len() as u64);
                did_work = true;
            }
        }

        // --- dispatch: size/deadline triggers, shard routing, backpressure ---
        loop {
            if let Some(max) = setting.stop.max_iterations {
                // completed + in-flight batches must stay within the
                // iteration budget, or the drain pass overshoots it
                if iterations + inflight.len() as u64 >= max {
                    break;
                }
            }
            let Some(batch) = scheduler.try_dispatch(Instant::now()) else {
                break;
            };
            encode_predict_batch_block_into(batch.id, &batch.items, &mut frame_buf);
            let delivered = ep.bcast(&shards[batch.shard], TAG_PRED_BATCH, &frame_buf[..]);
            tel.bump("batches_dispatched");
            registry().inc(Counter::PredBatches);
            if batch.items.len() < setting.batch.max_size {
                tel.bump("partial_batches");
            }
            let shard = batch.shard;
            inflight.insert(
                batch.id,
                InFlight {
                    shard,
                    origins: batch.origins,
                    items: batch.items,
                    replies: vec![None; committee],
                    n_replies: 0,
                },
            );
            if delivered < shards[shard].len() && !is_down(&down) {
                // a committee member's endpoint is gone: the gather can
                // never complete — evict the shard now (requeues this
                // batch) instead of waiting for the rank-down notice
                tel.bump("dead_letter_dispatches");
                registry().inc(Counter::DeadLetterDispatches);
                evict_dead_shard(&mut scheduler, &mut inflight, &mut tel, shard, Instant::now());
            }
            did_work = true;
        }
        if scheduler.queue_len() > 0 && scheduler.in_flight() == shards.len() * setting.batch.max_outstanding {
            tel.bump("backpressure_polls");
        }

        // --- live gauges: overwritten once per loop pass (each a single
        // relaxed load + branch while observability is disabled) ---
        registry().gauge_set(Gauge::PredQueueDepth, scheduler.queue_len() as u64);
        registry().gauge_set(Gauge::PredInFlight, scheduler.in_flight() as u64);

        if !did_work {
            // bound the sleep by the deadline trigger so partial batches
            // are not delayed past batch.max_delay by the poll cadence
            std::thread::sleep(poll.min(setting.batch.max_delay).max(Duration::from_micros(50)));
        }
    }
    tel
}

#[cfg(test)]
mod tests {
    //! The batched relay is exercised end-to-end in
    //! `rust/tests/test_batched_exchange.rs`; here: the stop-criteria
    //! bookkeeping contract and the pure [`BatchScheduler`] trigger /
    //! backpressure semantics.
    use super::*;
    use crate::comm::World;
    use crate::config::AlSetting;
    use crate::coordinator::selection::CommitteeStdUtils;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn exchange_stops_at_zero_max_iterations() {
        let mut s = AlSetting::default();
        s.gene_process = 1;
        s.pred_process = 1;
        s.ml_process = 0;
        s.orcl_process = 0;
        s.stop.max_iterations = Some(0);
        let topo = Topology::new(&s);
        let mut world = World::new(topo.n_ranks());
        let manager_ep = world.endpoint(topology::MANAGER);
        let ex_ep = world.endpoint(topology::EXCHANGE);
        let down = Arc::new(AtomicBool::new(false));
        let tel = exchange_host(
            ex_ep,
            Box::new(CommitteeStdUtils::new(0.5, 4)),
            &s,
            &topo,
            down,
        );
        assert_eq!(tel.counter("iterations"), 0);
        assert_eq!(tel.counter("stop_signals"), 1);
        drop(manager_ep);
    }

    #[test]
    fn batched_exchange_stops_at_zero_max_iterations() {
        let mut s = AlSetting::default();
        s.gene_process = 1;
        s.pred_process = 1;
        s.ml_process = 0;
        s.orcl_process = 0;
        s.exchange_mode = ExchangeMode::Batched;
        s.stop.max_iterations = Some(0);
        let topo = Topology::new(&s);
        let mut world = World::new(topo.n_ranks());
        let manager_ep = world.endpoint(topology::MANAGER);
        let ex_ep = world.endpoint(topology::EXCHANGE);
        let down = Arc::new(AtomicBool::new(false));
        let tel = exchange_host(
            ex_ep,
            Box::new(CommitteeStdUtils::new(0.5, 4)),
            &s,
            &topo,
            down,
        );
        assert_eq!(tel.counter("iterations"), 0);
        assert_eq!(tel.counter("stop_signals"), 1);
        drop(manager_ep);
    }

    fn sched(max_size: usize, max_delay_ms: u64, max_outstanding: usize, shards: usize) -> BatchScheduler {
        BatchScheduler::new(
            &BatchSetting {
                max_size,
                max_delay: Duration::from_millis(max_delay_ms),
                max_outstanding,
            },
            shards,
        )
    }

    #[test]
    fn no_trigger_before_size_or_deadline() {
        let mut s = sched(4, 10, 2, 2);
        let t0 = Instant::now();
        s.push(8, &[1.0], t0);
        s.push(9, &[2.0], t0);
        // neither full nor old enough → nothing dispatches
        assert!(s.try_dispatch(t0 + Duration::from_millis(1)).is_none());
        assert_eq!(s.queue_len(), 2);
    }

    #[test]
    fn deadline_fires_with_partial_batch() {
        let mut s = sched(4, 10, 2, 2);
        let t0 = Instant::now();
        s.push(8, &[1.0], t0);
        s.push(9, &[2.0], t0 + Duration::from_millis(5));
        let b = s.try_dispatch(t0 + Duration::from_millis(10)).expect("deadline trigger");
        assert_eq!(b.items.len(), 2, "partial batch takes everything queued");
        assert_eq!(b.origins, vec![8, 9]);
        assert_eq!(s.queue_len(), 0);
    }

    #[test]
    fn size_trigger_preempts_deadline() {
        let mut s = sched(3, 1_000_000, 2, 2);
        let t0 = Instant::now();
        for origin in [10, 8, 9] {
            s.push(origin, &[origin as f32], t0);
        }
        // deadline is far away, but the queue hit max_size → dispatch now
        let b = s.try_dispatch(t0).expect("size trigger");
        assert_eq!(b.items.len(), 3);
        // items ordered by origin rank within the batch
        assert_eq!(b.origins, vec![8, 9, 10]);
        assert_eq!(b.items.to_nested(), vec![vec![8.0], vec![9.0], vec![10.0]]);
    }

    #[test]
    fn size_trigger_caps_batch_and_keeps_fifo_remainder() {
        let mut s = sched(2, 1_000_000, 4, 1);
        let t0 = Instant::now();
        for origin in [5, 6, 7] {
            s.push(origin, &[origin as f32], t0);
        }
        let b = s.try_dispatch(t0).unwrap();
        assert_eq!(b.origins, vec![5, 6], "oldest two leave first");
        assert_eq!(s.queue_len(), 1);
    }

    #[test]
    fn round_robin_rotates_shards() {
        let mut s = sched(1, 0, 2, 3);
        let t0 = Instant::now();
        for i in 0..3 {
            s.push(8, &[i as f32], t0);
        }
        let shards: Vec<usize> = (0..3).map(|_| s.try_dispatch(t0).unwrap().shard).collect();
        assert_eq!(shards, vec![0, 1, 2]);
    }

    #[test]
    fn saturated_preferred_shard_falls_back_to_least_outstanding() {
        let mut s = sched(1, 0, 1, 2);
        let t0 = Instant::now();
        for i in 0..3 {
            s.push(8, &[i as f32], t0);
        }
        let a = s.try_dispatch(t0).unwrap();
        assert_eq!(a.shard, 0);
        let b = s.try_dispatch(t0).unwrap();
        assert_eq!(b.shard, 1);
        // both saturated → backpressure
        assert!(s.try_dispatch(t0).is_none());
        // shard 1 frees; preferred cursor points at 0 (saturated) → fall
        // back to the least-outstanding shard 1
        assert_eq!(s.complete(b.id, t0), Some((1, 1)));
        let c = s.try_dispatch(t0).unwrap();
        assert_eq!(c.shard, 1);
    }

    #[test]
    fn rr_cursor_advances_past_chosen_shard_not_preferred() {
        // regression: the old scheduler advanced the cursor past the
        // *preferred* shard even when the fallback shard took the batch, so
        // a briefly-saturated shard was skipped on the next round despite
        // having received nothing
        let mut s = sched(1, 0, 1, 2);
        let t0 = Instant::now();
        for i in 0..2 {
            s.push(8, &[i as f32], t0);
        }
        let d1 = s.try_dispatch(t0).unwrap(); // preferred 0 → shard 0, cursor → 1
        let d2 = s.try_dispatch(t0).unwrap(); // preferred 1 → shard 1, cursor → 0
        assert_eq!((d1.shard, d2.shard), (0, 1));
        // shard 1 frees while 0 is busy: the fallback sends the next batch
        // to shard 1, and the cursor must advance past *shard 1*
        s.complete(d2.id, t0);
        s.push(8, &[2.0], t0);
        let d3 = s.try_dispatch(t0).unwrap();
        assert_eq!(d3.shard, 1, "fallback to the free shard");
        // everything frees: the preferred shard is now 0 — the
        // briefly-saturated shard that never got the fallback batch (the
        // old cursor logic would skip it and pick 1 again)
        s.complete(d1.id, t0);
        s.complete(d3.id, t0);
        s.push(8, &[3.0], t0);
        let d4 = s.try_dispatch(t0).unwrap();
        assert_eq!(d4.shard, 0, "shard 0 is next in rotation after the fallback chose 1");
        let shards: Vec<usize> = vec![d1.shard, d2.shard, d3.shard, d4.shard];
        assert_eq!(shards, vec![0, 1, 1, 0], "pinned dispatch sequence");
    }

    #[test]
    fn backpressure_releases_in_fifo_order() {
        let mut s = sched(1, 0, 1, 1);
        let t0 = Instant::now();
        s.push(8, &[0.0], t0);
        let first = s.try_dispatch(t0).unwrap();
        assert_eq!(first.items.to_nested(), vec![vec![0.0]]);
        // queue three more while the only shard is busy
        for i in 1..=3 {
            s.push(8, &[i as f32], t0);
        }
        assert!(s.try_dispatch(t0).is_none(), "shard saturated");
        assert_eq!(s.queue_len(), 3, "backpressure leaves the queue intact");
        // each completion releases exactly the oldest queued request
        let mut last = first.id;
        for i in 1..=3 {
            assert_eq!(s.complete(last, t0), Some((0, 1)));
            let b = s.try_dispatch(t0).unwrap();
            assert_eq!(b.items.to_nested(), vec![vec![i as f32]], "FIFO release");
            last = b.id;
        }
        assert_eq!(s.queue_len(), 0);
    }

    #[test]
    fn batch_ids_are_unique_and_sequential() {
        let mut s = sched(1, 0, 8, 2);
        let t0 = Instant::now();
        for i in 0..5 {
            s.push(8, &[i as f32], t0);
        }
        let ids: Vec<u64> = (0..5).map(|_| s.try_dispatch(t0).unwrap().id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }
}
