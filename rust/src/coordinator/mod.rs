//! The PAL controller: the paper's system contribution.
//!
//! Two controller sub-kernels (Fig. 2):
//!
//! * [`exchange`] — the dedicated high-frequency sub-kernel driving the
//!   generator ↔ prediction loop (gather inputs → broadcast to predictors →
//!   gather predictions → `prediction_check` → scatter back + forward
//!   selected samples to the Manager).
//! * [`manager`] — buffers (oracle input buffer, training data buffer),
//!   oracle dispatch to the first free oracle, retrain-threshold flushes to
//!   the training kernel, `dynamic_orcale_list` re-scoring, progress
//!   snapshots, and the shutdown fan-out.
//!
//! [`hosts`] holds the per-kernel host loops (prediction / training /
//! generator / oracle ranks) and [`workflow`] wires everything into threads
//! over a [`crate::comm::World`].

pub mod buffers;
pub mod exchange;
pub mod hosts;
pub mod manager;
pub mod selection;
pub mod workflow;
