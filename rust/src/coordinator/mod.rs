//! The PAL controller: the paper's system contribution.
//!
//! Two controller sub-kernels (Fig. 2):
//!
//! * [`exchange`] — the dedicated high-frequency sub-kernel driving the
//!   generator ↔ prediction loop. Two relay strategies
//!   ([`crate::config::ExchangeMode`]):
//!   - *lockstep* (paper Fig. 4): gather inputs → broadcast to predictors →
//!     gather predictions → `prediction_check` → scatter back + forward
//!     selected samples to the Manager;
//!   - *batched*: requests are coalesced into micro-batches (size trigger
//!     `batch.max_size`, deadline trigger `batch.max_delay`), routed to one
//!     committee shard per batch (round-robin, least-outstanding fallback,
//!     FIFO backpressure at `batch.max_outstanding` per shard), UQ-checked
//!     per batch, and scattered back per item.
//! * [`manager`] — buffers (oracle input buffer, training data buffer),
//!   oracle dispatch (per-label to the first free oracle, or micro-batched
//!   through the [`oracle_plane`] scheduler, optionally capped by the
//!   strict label budget), retrain-threshold flushes to the training
//!   kernel, `dynamic_orcale_list` re-scoring against one committee shard,
//!   progress snapshots, and the shutdown fan-out.
//!
//! [`oracle_plane`] is the green flow's exchange discipline: the
//! [`oracle_plane::OracleScheduler`] coalesces Manager-selected inputs into
//! size-/deadline-triggered micro-batches, routes each batch to the
//! least-loaded oracle (latency-aware under heterogeneous oracle costs),
//! and applies per-oracle backpressure — mirroring the prediction plane's
//! `BatchScheduler` on the labeling leg.
//!
//! Both batched planes share one dispatch discipline: [`dispatch`] holds
//! the extracted trigger/outstanding/backpressure state machine
//! ([`dispatch::DispatchCore`]) behind a routing [`dispatch::Policy`].
//! The static policies (least-outstanding for the oracle plane,
//! round-robin for the prediction exchange) reproduce the pre-extraction
//! schedulers bit-for-bit; the opt-in adaptive policy
//! ([`crate::config::SchedPolicy::Adaptive`]) adds per-endpoint EWMA
//! latency tracking, least-estimated-completion-time routing, adaptive
//! batch sizing, and health/eviction of stalled endpoints.
//!
//! [`hosts`] holds the per-kernel host loops (prediction / training /
//! generator / oracle ranks) and [`workflow`] wires everything into threads
//! over a [`crate::comm::World`].

pub mod buffers;
pub mod dispatch;
pub mod exchange;
pub mod hosts;
pub mod manager;
pub mod oracle_plane;
pub mod selection;
pub mod workflow;
