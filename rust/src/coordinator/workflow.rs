//! Workflow launcher: spawns one host thread per rank over a
//! [`crate::comm::World`] and aggregates the run report.
//!
//! Every spawned host runs under [`supervised`]: a panicking or
//! fault-killed host is caught at the thread boundary, announces itself to
//! the Manager and Exchange with a [`TAG_RANK_DOWN`] control message, and
//! returns a failed [`KernelTelemetry`] record instead of poisoning the
//! join. [`Workflow::run`] therefore completes with a *degraded*
//! [`RunReport`] — the `faults` section says who died and what the
//! coordinators recovered — rather than an `Err`. The one exception is the
//! Manager itself: it runs on the caller thread as the shutdown authority,
//! so its death is the run's death.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::comm::protocol::TAG_RANK_DOWN;
use crate::comm::transport::tcp::Bootstrap;
use crate::comm::{ControlHandle, FaultKill, FaultPlan, TransportKind, World};
use crate::config::{topology, AlSetting, Topology};
use crate::coordinator::{exchange, hosts, manager};
use crate::kernels::{KernelSet, Mode, OracleFactory};
use crate::telemetry::registry::{registry, Counter, RankKind, RankState};
use crate::telemetry::server::MetricsServer;
use crate::telemetry::{trace, FaultReport, KernelTelemetry, RunReport};

pub use crate::kernels::KernelSet as Kernels;

/// Run `body` on a host thread, catching panics at the boundary.
///
/// On a panic (genuine bug or injected [`FaultKill`]) the dead rank's own
/// endpoint is already gone — unwinding dropped it — so the rank-down
/// notice travels over the world's control plane instead, which outlives
/// every endpoint. Both coordinators are told: the Manager owns oracle
/// eviction and shutdown, the Exchange owns prediction shards.
fn supervised<F>(ctrl: ControlHandle, kernel: &'static str, rank: usize, body: F) -> KernelTelemetry
where
    F: FnOnce() -> KernelTelemetry,
{
    // live registry: the supervisor owns the rank's lifecycle row in
    // `/status` (no-op publishes while observability is disabled)
    registry().set_rank_kind(rank, RankKind::from_kernel(kernel));
    registry().set_rank_state(rank, RankState::Running);
    match catch_unwind(AssertUnwindSafe(body)) {
        Ok(tel) => {
            registry().set_rank_state(rank, RankState::Done);
            tel
        }
        Err(payload) => {
            let mut tel = KernelTelemetry::new(kernel, rank);
            tel.bump("failed");
            if payload.downcast_ref::<FaultKill>().is_some() {
                tel.bump("fault_injected");
            }
            registry().set_rank_state(rank, RankState::Failed);
            registry().inc(Counter::HostFailures);
            trace::sink().instant(rank, "rank_down", rank as u64);
            ctrl.send(topology::MANAGER, TAG_RANK_DOWN, vec![rank as f32]);
            if rank != topology::EXCHANGE {
                ctrl.send(topology::EXCHANGE, TAG_RANK_DOWN, vec![rank as f32]);
            }
            tel
        }
    }
}

/// A configured PAL workflow, ready to run a kernel set.
pub struct Workflow {
    setting: AlSetting,
    fault_plan: Option<FaultPlan>,
}

impl Workflow {
    pub fn new(setting: AlSetting) -> Self {
        Workflow { setting, fault_plan: None }
    }

    /// Install a deterministic fault plan for the next run (chaos testing).
    /// An empty plan is a no-op: the run stays bit-identical to a plain one.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        if !plan.is_empty() {
            self.fault_plan = Some(plan);
        }
        self
    }

    pub fn setting(&self) -> &AlSetting {
        &self.setting
    }

    /// Run the five-kernel workflow to completion. Blocks until every rank
    /// has drained and joined; returns the aggregated report.
    ///
    /// Serves the in-process transports (`channel`, `shm` — selected by
    /// `setting.transport`); a `tcp` setting is refused here because a
    /// socket world spans processes: use [`Workflow::run_tcp_leader`] in
    /// the process hosting the coordinators and
    /// [`Workflow::run_tcp_follower`] in each oracle process.
    pub fn run(&self, kernels: KernelSet) -> anyhow::Result<RunReport> {
        self.setting.validate()?;
        kernels.validate(&self.setting)?;
        if self.setting.transport == TransportKind::Tcp {
            anyhow::bail!(
                "transport \"tcp\" spans processes: run the coordinator side with \
                 Workflow::run_tcp_leader and each oracle process with \
                 Workflow::run_tcp_follower"
            );
        }
        let topo = Topology::new(&self.setting);
        let world =
            World::with_backend(topo.n_ranks(), self.setting.comm_latency, self.setting.transport);
        self.run_on(world, kernels, &topo)
    }

    /// Leader-side tcp run: this process homes every rank *except* the
    /// oracles (Manager, Exchange, predictors, trainers, generators) and
    /// blocks in accept until follower processes have advertised all
    /// oracle ranks — the paper's deployment shape, where the expensive
    /// oracle evaluations live on other nodes. `kernels.oracles` must be
    /// empty; the followers bring the oracles.
    pub fn run_tcp_leader(
        &self,
        kernels: KernelSet,
        bootstrap: Bootstrap,
    ) -> anyhow::Result<RunReport> {
        self.setting.validate()?;
        anyhow::ensure!(
            kernels.oracles.is_empty(),
            "tcp leader homes no oracle ranks; follower processes bring the oracles"
        );
        let topo = Topology::new(&self.setting);
        let orcl = topo.orcl_ranks();
        let local: Vec<usize> =
            (0..topo.n_ranks()).filter(|r| !orcl.contains(r)).collect();
        let (world, _monitor) =
            World::listen(bootstrap, topo.n_ranks(), &local, self.setting.comm_latency)
                .context("tcp leader bootstrap")?;
        self.run_on(world, kernels, &topo)
    }

    /// Follower-side tcp run: homes this process's oracle ranks, serves
    /// oracle requests until the leader hangs up (the cross-process
    /// shutdown signal — see [`crate::comm::transport::tcp::LinkMonitor`]),
    /// then drains and returns. `oracles` must staff *all* oracle ranks of
    /// the topology (single-follower deployment; multi-follower splits
    /// ride the same bootstrap with disjoint rank sets).
    pub fn run_tcp_follower(
        setting: &AlSetting,
        oracles: Vec<OracleFactory>,
        addr: &str,
        timeout: Duration,
    ) -> anyhow::Result<()> {
        setting.validate()?;
        let topo = Topology::new(setting);
        let orcl = topo.orcl_ranks();
        anyhow::ensure!(
            oracles.len() == orcl.len(),
            "follower staffs {} oracle ranks, got {} factories",
            orcl.len(),
            oracles.len()
        );
        let (mut world, monitor) =
            World::connect(addr, topo.n_ranks(), &orcl, setting.comm_latency, timeout)
                .context("tcp follower bootstrap")?;
        let down = Arc::new(AtomicBool::new(false));
        let done = Arc::new(AtomicBool::new(false));
        // Bridge "all peer sockets closed" onto the local shutdown flag:
        // the oracle hosts' request loop polls `down` between receives, so
        // the leader hanging up ends the follower like a local shutdown.
        let watcher = {
            let down = down.clone();
            let done = done.clone();
            let monitor = monitor.clone();
            std::thread::spawn(move || {
                while !done.load(Ordering::Acquire) && !monitor.all_peers_closed() {
                    std::thread::sleep(Duration::from_millis(20));
                }
                down.store(true, Ordering::Release);
            })
        };
        let mut handles = Vec::new();
        for (i, (rank, factory)) in orcl.into_iter().zip(oracles).enumerate() {
            let ep = world.endpoint(rank);
            let ctrl = world.control_handle(rank);
            let setting = setting.clone();
            let down = down.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-orcl-{i}"))
                    .spawn(move || {
                        supervised(ctrl, "oracle", rank, move || {
                            hosts::oracle_host(ep, factory(), &setting, down)
                        })
                    })
                    .context("spawning oracle")?,
            );
        }
        drop(world);
        for h in handles {
            let _ = h.join();
        }
        done.store(true, Ordering::Release);
        let _ = watcher.join();
        Ok(())
    }

    /// Shared body of every entry point: spawn a supervised host for each
    /// rank *homed in this world* (an in-process world homes all of them;
    /// a tcp world only its bootstrapped subset), run the Manager on the
    /// caller thread, and aggregate the report.
    fn run_on(
        &self,
        mut world: World,
        kernels: KernelSet,
        topo: &Topology,
    ) -> anyhow::Result<RunReport> {
        anyhow::ensure!(
            world.owns(topology::MANAGER) && world.owns(topology::EXCHANGE),
            "the coordinator ranks must be homed in this process"
        );
        if let Some(plan) = &self.fault_plan {
            // must precede endpoint handout: each endpoint compiles its
            // rank's slice of the plan when it is taken from the world
            world.set_fault_plan(plan.clone());
        }
        let world_stats = world.stats();
        // Observability plane: arm the live registry (and, if configured,
        // the HTTP surface and trace sink) before any kernel thread spawns
        // so no publish is lost. Everything below is a no-op for runs that
        // configure neither `metrics_addr` nor `trace_out`.
        let observing = self.setting.metrics_addr.is_some() || self.setting.trace_out.is_some();
        if observing {
            registry().reset_for_run(Some(world_stats.clone()));
            registry().set_enabled(true);
        }
        let metrics_server = match self.setting.metrics_addr.as_deref() {
            Some(addr) => Some(
                MetricsServer::start(addr)
                    .with_context(|| format!("binding metrics server on {addr}"))?,
            ),
            None => None,
        };
        if self.setting.trace_out.is_some() {
            trace::sink().begin();
        }
        let down = Arc::new(AtomicBool::new(false));
        let t0 = Instant::now();

        let KernelSet { generators, oracles, model, utils } = kernels;
        // Only oracle ranks may live in another process (tcp follower);
        // everything else must be spawnable right here.
        for r in topo.pred_ranks().into_iter().chain(topo.train_ranks()).chain(topo.gene_ranks()) {
            anyhow::ensure!(
                world.owns(r),
                "rank {r} must be homed in this process (only oracle ranks may be remote)"
            );
        }

        let mut tel_handles: Vec<std::thread::JoinHandle<KernelTelemetry>> = Vec::new();

        // Exchange controller (rank 1)
        {
            let ep = world.endpoint(topology::EXCHANGE);
            let ctrl = world.control_handle(topology::EXCHANGE);
            let setting = self.setting.clone();
            let topo = topo.clone();
            let down = down.clone();
            let utils_f = utils.clone();
            tel_handles.push(
                std::thread::Builder::new()
                    .name("pal-exchange".into())
                    .spawn(move || {
                        supervised(ctrl, "exchange", topology::EXCHANGE, move || {
                            exchange::exchange_host(ep, utils_f(), &setting, &topo, down)
                        })
                    })
                    .context("spawning exchange")?,
            );
        }

        // Prediction hosts. Rank `pred.start + i` hosts committee member
        // `i % committee`: with shards, replicas of the same member are
        // constructed identically so any shard answers any batch, and the
        // member's trainer keeps every replica in sync.
        for (i, rank) in topo.pred_ranks().into_iter().enumerate() {
            let ep = world.endpoint(rank);
            let ctrl = world.control_handle(rank);
            let setting = self.setting.clone();
            let down = down.clone();
            let factory = model.clone();
            let member = i % topo.committee.max(1);
            tel_handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-pred-{i}"))
                    .spawn(move || {
                        supervised(ctrl, "prediction", rank, move || {
                            let m = factory(Mode::Predict, member);
                            hosts::prediction_host(ep, m, &setting, down)
                        })
                    })
                    .context("spawning predictor")?,
            );
        }

        // Training hosts
        for (i, rank) in topo.train_ranks().into_iter().enumerate() {
            let ep = world.endpoint(rank);
            let ctrl = world.control_handle(rank);
            let setting = self.setting.clone();
            let topo2 = topo.clone();
            let down = down.clone();
            let factory = model.clone();
            tel_handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-train-{i}"))
                    .spawn(move || {
                        supervised(ctrl, "training", rank, move || {
                            let m = factory(Mode::Train, i);
                            hosts::training_host(ep, m, &setting, &topo2, down)
                        })
                    })
                    .context("spawning trainer")?,
            );
        }

        // Generator hosts
        for (i, (rank, factory)) in topo
            .gene_ranks()
            .into_iter()
            .zip(generators.into_iter())
            .enumerate()
        {
            let ep = world.endpoint(rank);
            let ctrl = world.control_handle(rank);
            let setting = self.setting.clone();
            let down = down.clone();
            tel_handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-gen-{i}"))
                    .spawn(move || {
                        supervised(ctrl, "generator", rank, move || {
                            hosts::generator_host(ep, factory(), &setting, down)
                        })
                    })
                    .context("spawning generator")?,
            );
        }

        // Oracle hosts (only those homed here — a tcp leader homes none)
        let owned_orcl: Vec<usize> =
            topo.orcl_ranks().into_iter().filter(|&r| world.owns(r)).collect();
        anyhow::ensure!(
            owned_orcl.len() == oracles.len(),
            "kernel set has {} oracles, this process homes {} oracle ranks",
            oracles.len(),
            owned_orcl.len()
        );
        for (i, (rank, factory)) in owned_orcl.into_iter().zip(oracles.into_iter()).enumerate() {
            let ep = world.endpoint(rank);
            let ctrl = world.control_handle(rank);
            let setting = self.setting.clone();
            let down = down.clone();
            tel_handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-orcl-{i}"))
                    .spawn(move || {
                        supervised(ctrl, "oracle", rank, move || {
                            hosts::oracle_host(ep, factory(), &setting, down)
                        })
                    })
                    .context("spawning oracle")?,
            );
        }

        // Manager runs on the caller thread (rank 0) — it is the shutdown
        // authority, so the workflow returns exactly when it decides. It is
        // not `supervised` (its death is the run's death), so its registry
        // lifecycle row is published here.
        registry().set_rank_kind(topology::MANAGER, RankKind::Manager);
        registry().set_rank_state(topology::MANAGER, RankState::Running);
        let manager_ep = world.endpoint(topology::MANAGER);
        drop(world); // release the spare sender clones held by World
        let (manager_tel, outcome) =
            manager::manager_host(manager_ep, utils(), &self.setting, topo, down);
        registry().set_rank_state(topology::MANAGER, RankState::Done);

        let mut report = RunReport {
            al_iterations: 0,
            oracle_labels: outcome.oracle_labels,
            retrain_rounds: outcome.retrain_rounds,
            final_losses: outcome.losses,
            wall: t0.elapsed(),
            kernels: vec![manager_tel],
            messages: world_stats.messages(),
            payload_bytes: world_stats.payload_bytes(),
            payload_clones: world_stats.payload_clones(),
            bytes_copied: world_stats.bytes_copied(),
            faults: FaultReport::default(),
        };
        // Supervised hosts catch their own panics and return a failed
        // telemetry record, so every join completes in spawn order — a dead
        // host can no longer abort this loop early and leave later handles
        // unjoined (the old `Err("kernel host panicked")` path). The
        // unwrap_or_else is a belt-and-braces backstop for a thread that
        // dies outside the catch (it cannot name its rank).
        for h in tel_handles {
            let tel = h.join().unwrap_or_else(|_| {
                let mut t = KernelTelemetry::new("unknown", usize::MAX);
                t.bump("failed");
                t
            });
            if tel.kernel == "exchange" {
                report.al_iterations = tel.counter("iterations");
            }
            report.kernels.push(tel);
        }
        // Trainers may finish their final round during shutdown, after the
        // Manager stopped counting — the trainer-side counter is the truth.
        let trainer_rounds: u64 =
            report.kernels.iter().filter(|k| k.kernel == "training").map(|k| k.counter("rounds")).sum();
        report.retrain_rounds = report.retrain_rounds.max(trainer_rounds);
        report.wall = t0.elapsed();
        report.messages = world_stats.messages();
        report.payload_bytes = world_stats.payload_bytes();
        report.payload_clones = world_stats.payload_clones();
        report.bytes_copied = world_stats.bytes_copied();
        // Fault section: aggregate the supervision and eviction counters
        // into one honest summary. `bad_frames`/`malformed` overlap inside
        // the Manager (bumped together on the paths that see both), so per
        // kernel the larger of the two is the frame-fault count.
        let mut faults = FaultReport::default();
        for k in &report.kernels {
            if k.counter("failed") > 0 {
                faults.failed_ranks.push(k.rank);
            }
            faults.bad_frames += k.counter("bad_frames").max(k.counter("malformed"));
            match k.kernel.as_str() {
                "manager" => {
                    faults.oracle_evictions += k.counter("oracle_evictions");
                    faults.requeued_inputs += k.counter("requeued_inputs");
                    faults.lost_inputs += k.counter("lost_inputs");
                }
                "exchange" => {
                    faults.shard_evictions += k.counter("shard_evictions");
                    faults.requeued_items += k.counter("requeued_items");
                }
                _ => {}
            }
        }
        faults.failed_ranks.sort_unstable();
        faults.dead_letters = world_stats.dead_letters();
        report.faults = faults;
        // Tear down the observability plane last, so a scraper that raced
        // the final joins still saw live (and now final) numbers. The
        // trace drains only after every host joined — lanes are complete.
        if let Some(server) = metrics_server {
            server.stop();
        }
        if let Some(path) = self.setting.trace_out.as_deref() {
            trace::sink().end();
            trace::sink()
                .drain_to_file(path)
                .with_context(|| format!("writing trace to {path}"))?;
        }
        if observing {
            registry().set_enabled(false);
        }
        Ok(report)
    }
}
