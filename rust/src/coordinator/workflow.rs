//! Workflow launcher: spawns one host thread per rank over a
//! [`crate::comm::World`] and aggregates the run report.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Context;

use crate::comm::World;
use crate::config::{topology, AlSetting, Topology};
use crate::coordinator::{exchange, hosts, manager};
use crate::kernels::{KernelSet, Mode};
use crate::telemetry::{KernelTelemetry, RunReport};

pub use crate::kernels::KernelSet as Kernels;

/// A configured PAL workflow, ready to run a kernel set.
pub struct Workflow {
    setting: AlSetting,
}

impl Workflow {
    pub fn new(setting: AlSetting) -> Self {
        Workflow { setting }
    }

    pub fn setting(&self) -> &AlSetting {
        &self.setting
    }

    /// Run the five-kernel workflow to completion. Blocks until every rank
    /// has drained and joined; returns the aggregated report.
    pub fn run(&self, kernels: KernelSet) -> anyhow::Result<RunReport> {
        self.setting.validate()?;
        kernels.validate(&self.setting)?;
        let topo = Topology::new(&self.setting);
        let mut world = World::with_latency(topo.n_ranks(), self.setting.comm_latency);
        let world_stats = world.stats();
        let down = Arc::new(AtomicBool::new(false));
        let t0 = Instant::now();

        let KernelSet { generators, oracles, model, utils } = kernels;

        let mut tel_handles: Vec<std::thread::JoinHandle<KernelTelemetry>> = Vec::new();

        // Exchange controller (rank 1)
        {
            let ep = world.endpoint(topology::EXCHANGE);
            let setting = self.setting.clone();
            let topo = topo.clone();
            let down = down.clone();
            let utils_f = utils.clone();
            tel_handles.push(
                std::thread::Builder::new()
                    .name("pal-exchange".into())
                    .spawn(move || exchange::exchange_host(ep, utils_f(), &setting, &topo, down))
                    .context("spawning exchange")?,
            );
        }

        // Prediction hosts. Rank `pred.start + i` hosts committee member
        // `i % committee`: with shards, replicas of the same member are
        // constructed identically so any shard answers any batch, and the
        // member's trainer keeps every replica in sync.
        for (i, rank) in topo.pred_ranks().into_iter().enumerate() {
            let ep = world.endpoint(rank);
            let setting = self.setting.clone();
            let down = down.clone();
            let factory = model.clone();
            let member = i % topo.committee.max(1);
            tel_handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-pred-{i}"))
                    .spawn(move || {
                        let m = factory(Mode::Predict, member);
                        hosts::prediction_host(ep, m, &setting, down)
                    })
                    .context("spawning predictor")?,
            );
        }

        // Training hosts
        for (i, rank) in topo.train_ranks().into_iter().enumerate() {
            let ep = world.endpoint(rank);
            let setting = self.setting.clone();
            let topo2 = topo.clone();
            let down = down.clone();
            let factory = model.clone();
            tel_handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-train-{i}"))
                    .spawn(move || {
                        let m = factory(Mode::Train, i);
                        hosts::training_host(ep, m, &setting, &topo2, down)
                    })
                    .context("spawning trainer")?,
            );
        }

        // Generator hosts
        for (i, (rank, factory)) in topo
            .gene_ranks()
            .into_iter()
            .zip(generators.into_iter())
            .enumerate()
        {
            let ep = world.endpoint(rank);
            let setting = self.setting.clone();
            let down = down.clone();
            tel_handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-gen-{i}"))
                    .spawn(move || hosts::generator_host(ep, factory(), &setting, down))
                    .context("spawning generator")?,
            );
        }

        // Oracle hosts
        for (i, (rank, factory)) in topo
            .orcl_ranks()
            .into_iter()
            .zip(oracles.into_iter())
            .enumerate()
        {
            let ep = world.endpoint(rank);
            let setting = self.setting.clone();
            let down = down.clone();
            tel_handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-orcl-{i}"))
                    .spawn(move || hosts::oracle_host(ep, factory(), &setting, down))
                    .context("spawning oracle")?,
            );
        }

        // Manager runs on the caller thread (rank 0) — it is the shutdown
        // authority, so the workflow returns exactly when it decides.
        let manager_ep = world.endpoint(topology::MANAGER);
        drop(world); // release the spare sender clones held by World
        let (manager_tel, outcome) =
            manager::manager_host(manager_ep, utils(), &self.setting, &topo, down);

        let mut report = RunReport {
            al_iterations: 0,
            oracle_labels: outcome.oracle_labels,
            retrain_rounds: outcome.retrain_rounds,
            final_losses: outcome.losses,
            wall: t0.elapsed(),
            kernels: vec![manager_tel],
            messages: world_stats.messages(),
            payload_bytes: world_stats.payload_bytes(),
            payload_clones: world_stats.payload_clones(),
            bytes_copied: world_stats.bytes_copied(),
        };
        for h in tel_handles {
            let tel = h.join().map_err(|_| anyhow::anyhow!("kernel host panicked"))?;
            if tel.kernel == "exchange" {
                report.al_iterations = tel.counter("iterations");
            }
            report.kernels.push(tel);
        }
        // Trainers may finish their final round during shutdown, after the
        // Manager stopped counting — the trainer-side counter is the truth.
        let trainer_rounds: u64 =
            report.kernels.iter().filter(|k| k.kernel == "training").map(|k| k.counter("rounds")).sum();
        report.retrain_rounds = report.retrain_rounds.max(trainer_rounds);
        report.wall = t0.elapsed();
        report.messages = world_stats.messages();
        report.payload_bytes = world_stats.payload_bytes();
        report.payload_clones = world_stats.payload_clones();
        report.bytes_copied = world_stats.bytes_copied();
        Ok(report)
    }
}
