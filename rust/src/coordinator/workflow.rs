//! Workflow launcher: spawns one host thread per rank over a
//! [`crate::comm::World`] and aggregates the run report.
//!
//! Every spawned host runs under [`supervised`]: a panicking or
//! fault-killed host is caught at the thread boundary, announces itself to
//! the Manager and Exchange with a [`TAG_RANK_DOWN`] control message, and
//! returns a failed [`KernelTelemetry`] record instead of poisoning the
//! join. [`Workflow::run`] therefore completes with a *degraded*
//! [`RunReport`] — the `faults` section says who died and what the
//! coordinators recovered — rather than an `Err`. The one exception is the
//! Manager itself: it runs on the caller thread as the shutdown authority,
//! so its death is the run's death.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Context;

use crate::comm::protocol::TAG_RANK_DOWN;
use crate::comm::{ControlHandle, FaultKill, FaultPlan, World};
use crate::config::{topology, AlSetting, Topology};
use crate::coordinator::{exchange, hosts, manager};
use crate::kernels::{KernelSet, Mode};
use crate::telemetry::{FaultReport, KernelTelemetry, RunReport};

pub use crate::kernels::KernelSet as Kernels;

/// Run `body` on a host thread, catching panics at the boundary.
///
/// On a panic (genuine bug or injected [`FaultKill`]) the dead rank's own
/// endpoint is already gone — unwinding dropped it — so the rank-down
/// notice travels over the world's control plane instead, which outlives
/// every endpoint. Both coordinators are told: the Manager owns oracle
/// eviction and shutdown, the Exchange owns prediction shards.
fn supervised<F>(ctrl: ControlHandle, kernel: &'static str, rank: usize, body: F) -> KernelTelemetry
where
    F: FnOnce() -> KernelTelemetry,
{
    match catch_unwind(AssertUnwindSafe(body)) {
        Ok(tel) => tel,
        Err(payload) => {
            let mut tel = KernelTelemetry::new(kernel, rank);
            tel.bump("failed");
            if payload.downcast_ref::<FaultKill>().is_some() {
                tel.bump("fault_injected");
            }
            ctrl.send(topology::MANAGER, TAG_RANK_DOWN, vec![rank as f32]);
            if rank != topology::EXCHANGE {
                ctrl.send(topology::EXCHANGE, TAG_RANK_DOWN, vec![rank as f32]);
            }
            tel
        }
    }
}

/// A configured PAL workflow, ready to run a kernel set.
pub struct Workflow {
    setting: AlSetting,
    fault_plan: Option<FaultPlan>,
}

impl Workflow {
    pub fn new(setting: AlSetting) -> Self {
        Workflow { setting, fault_plan: None }
    }

    /// Install a deterministic fault plan for the next run (chaos testing).
    /// An empty plan is a no-op: the run stays bit-identical to a plain one.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        if !plan.is_empty() {
            self.fault_plan = Some(plan);
        }
        self
    }

    pub fn setting(&self) -> &AlSetting {
        &self.setting
    }

    /// Run the five-kernel workflow to completion. Blocks until every rank
    /// has drained and joined; returns the aggregated report.
    pub fn run(&self, kernels: KernelSet) -> anyhow::Result<RunReport> {
        self.setting.validate()?;
        kernels.validate(&self.setting)?;
        let topo = Topology::new(&self.setting);
        let mut world = World::with_latency(topo.n_ranks(), self.setting.comm_latency);
        if let Some(plan) = &self.fault_plan {
            // must precede endpoint handout: each endpoint compiles its
            // rank's slice of the plan when it is taken from the world
            world.set_fault_plan(plan.clone());
        }
        let world_stats = world.stats();
        let down = Arc::new(AtomicBool::new(false));
        let t0 = Instant::now();

        let KernelSet { generators, oracles, model, utils } = kernels;

        let mut tel_handles: Vec<std::thread::JoinHandle<KernelTelemetry>> = Vec::new();

        // Exchange controller (rank 1)
        {
            let ep = world.endpoint(topology::EXCHANGE);
            let ctrl = world.control_handle(topology::EXCHANGE);
            let setting = self.setting.clone();
            let topo = topo.clone();
            let down = down.clone();
            let utils_f = utils.clone();
            tel_handles.push(
                std::thread::Builder::new()
                    .name("pal-exchange".into())
                    .spawn(move || {
                        supervised(ctrl, "exchange", topology::EXCHANGE, move || {
                            exchange::exchange_host(ep, utils_f(), &setting, &topo, down)
                        })
                    })
                    .context("spawning exchange")?,
            );
        }

        // Prediction hosts. Rank `pred.start + i` hosts committee member
        // `i % committee`: with shards, replicas of the same member are
        // constructed identically so any shard answers any batch, and the
        // member's trainer keeps every replica in sync.
        for (i, rank) in topo.pred_ranks().into_iter().enumerate() {
            let ep = world.endpoint(rank);
            let ctrl = world.control_handle(rank);
            let setting = self.setting.clone();
            let down = down.clone();
            let factory = model.clone();
            let member = i % topo.committee.max(1);
            tel_handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-pred-{i}"))
                    .spawn(move || {
                        supervised(ctrl, "prediction", rank, move || {
                            let m = factory(Mode::Predict, member);
                            hosts::prediction_host(ep, m, &setting, down)
                        })
                    })
                    .context("spawning predictor")?,
            );
        }

        // Training hosts
        for (i, rank) in topo.train_ranks().into_iter().enumerate() {
            let ep = world.endpoint(rank);
            let ctrl = world.control_handle(rank);
            let setting = self.setting.clone();
            let topo2 = topo.clone();
            let down = down.clone();
            let factory = model.clone();
            tel_handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-train-{i}"))
                    .spawn(move || {
                        supervised(ctrl, "training", rank, move || {
                            let m = factory(Mode::Train, i);
                            hosts::training_host(ep, m, &setting, &topo2, down)
                        })
                    })
                    .context("spawning trainer")?,
            );
        }

        // Generator hosts
        for (i, (rank, factory)) in topo
            .gene_ranks()
            .into_iter()
            .zip(generators.into_iter())
            .enumerate()
        {
            let ep = world.endpoint(rank);
            let ctrl = world.control_handle(rank);
            let setting = self.setting.clone();
            let down = down.clone();
            tel_handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-gen-{i}"))
                    .spawn(move || {
                        supervised(ctrl, "generator", rank, move || {
                            hosts::generator_host(ep, factory(), &setting, down)
                        })
                    })
                    .context("spawning generator")?,
            );
        }

        // Oracle hosts
        for (i, (rank, factory)) in topo
            .orcl_ranks()
            .into_iter()
            .zip(oracles.into_iter())
            .enumerate()
        {
            let ep = world.endpoint(rank);
            let ctrl = world.control_handle(rank);
            let setting = self.setting.clone();
            let down = down.clone();
            tel_handles.push(
                std::thread::Builder::new()
                    .name(format!("pal-orcl-{i}"))
                    .spawn(move || {
                        supervised(ctrl, "oracle", rank, move || {
                            hosts::oracle_host(ep, factory(), &setting, down)
                        })
                    })
                    .context("spawning oracle")?,
            );
        }

        // Manager runs on the caller thread (rank 0) — it is the shutdown
        // authority, so the workflow returns exactly when it decides.
        let manager_ep = world.endpoint(topology::MANAGER);
        drop(world); // release the spare sender clones held by World
        let (manager_tel, outcome) =
            manager::manager_host(manager_ep, utils(), &self.setting, &topo, down);

        let mut report = RunReport {
            al_iterations: 0,
            oracle_labels: outcome.oracle_labels,
            retrain_rounds: outcome.retrain_rounds,
            final_losses: outcome.losses,
            wall: t0.elapsed(),
            kernels: vec![manager_tel],
            messages: world_stats.messages(),
            payload_bytes: world_stats.payload_bytes(),
            payload_clones: world_stats.payload_clones(),
            bytes_copied: world_stats.bytes_copied(),
            faults: FaultReport::default(),
        };
        // Supervised hosts catch their own panics and return a failed
        // telemetry record, so every join completes in spawn order — a dead
        // host can no longer abort this loop early and leave later handles
        // unjoined (the old `Err("kernel host panicked")` path). The
        // unwrap_or_else is a belt-and-braces backstop for a thread that
        // dies outside the catch (it cannot name its rank).
        for h in tel_handles {
            let tel = h.join().unwrap_or_else(|_| {
                let mut t = KernelTelemetry::new("unknown", usize::MAX);
                t.bump("failed");
                t
            });
            if tel.kernel == "exchange" {
                report.al_iterations = tel.counter("iterations");
            }
            report.kernels.push(tel);
        }
        // Trainers may finish their final round during shutdown, after the
        // Manager stopped counting — the trainer-side counter is the truth.
        let trainer_rounds: u64 =
            report.kernels.iter().filter(|k| k.kernel == "training").map(|k| k.counter("rounds")).sum();
        report.retrain_rounds = report.retrain_rounds.max(trainer_rounds);
        report.wall = t0.elapsed();
        report.messages = world_stats.messages();
        report.payload_bytes = world_stats.payload_bytes();
        report.payload_clones = world_stats.payload_clones();
        report.bytes_copied = world_stats.bytes_copied();
        // Fault section: aggregate the supervision and eviction counters
        // into one honest summary. `bad_frames`/`malformed` overlap inside
        // the Manager (bumped together on the paths that see both), so per
        // kernel the larger of the two is the frame-fault count.
        let mut faults = FaultReport::default();
        for k in &report.kernels {
            if k.counter("failed") > 0 {
                faults.failed_ranks.push(k.rank);
            }
            faults.bad_frames += k.counter("bad_frames").max(k.counter("malformed"));
            match k.kernel.as_str() {
                "manager" => {
                    faults.oracle_evictions += k.counter("oracle_evictions");
                    faults.requeued_inputs += k.counter("requeued_inputs");
                    faults.lost_inputs += k.counter("lost_inputs");
                }
                "exchange" => {
                    faults.shard_evictions += k.counter("shard_evictions");
                    faults.requeued_items += k.counter("requeued_items");
                }
                _ => {}
            }
        }
        faults.failed_ranks.sort_unstable();
        faults.dead_letters = world_stats.dead_letters();
        report.faults = faults;
        Ok(report)
    }
}
