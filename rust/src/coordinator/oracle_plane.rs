//! Oracle plane: batched, latency-aware labeling dispatch (green flow).
//!
//! The paper's Manager ships one message per selected input and receives
//! one message per label — fine when every label costs a DFT hour, but the
//! dominant green-flow overhead once oracles are fast or plentiful. This
//! module gives labeling the same exchange discipline PR 1 gave prediction:
//!
//! * **Coalescing** — buffered inputs form micro-batches under
//!   `AlSetting::oracle_batch`: dispatch as soon as `max_size` inputs are
//!   queued, or when the queue head has waited `max_delay` (partial batch).
//! * **Latency-aware routing** — under the default static policy each
//!   batch goes to the oracle with the fewest batches in flight (ties
//!   break to the lowest rank index, which keeps single-oracle runs
//!   deterministic). Oracles have wildly heterogeneous latencies (DFT ≈
//!   1 h, xTB ≈ 10 s — SI §S2.2, modeled by
//!   [`crate::kernels::oracles::LatencyOracle`]); least-outstanding routing
//!   feeds fast oracles proportionally more work without any latency
//!   estimation. With `sched_policy = "adaptive"`
//!   ([`crate::config::SchedPolicy::Adaptive`]) the shared dispatch core
//!   ([`crate::coordinator::dispatch`]) upgrades this to EWMA
//!   least-estimated-completion-time routing with per-oracle batch sizing
//!   and health/eviction — see [`OracleScheduler::check_health`].
//! * **Backpressure** — at most `max_outstanding` batches in flight per
//!   oracle; beyond that, inputs wait in the
//!   [`crate::coordinator::buffers::OracleBuffer`] in FIFO order, where
//!   `dynamic_orcale_list` re-scoring can still reorder or prune them
//!   (rescore replacements route through the scheduler's queue clock via
//!   [`OracleScheduler::sync_queue`]).
//!
//! The scheduler is a thin facade over the shared
//! [`crate::coordinator::dispatch::DispatchCore`] state machine, keeping
//! the queue external (the Manager's `OracleBuffer` — selection staging and
//! scheduling share one row store, so nothing is copied between them):
//! callers inject `now` and the current queue length, making
//! trigger/backpressure semantics unit-testable without threads or sleeps.
//! Wire frames are `TAG_ORACLE_BATCH` out and labels-only
//! `TAG_ORACLE_LABELS` back ([`crate::comm::protocol`]; the Manager pairs
//! the labels with the input block it retained at dispatch, so inputs
//! never re-ship); the legacy per-label path
//! (`TAG_TO_ORACLE`/`TAG_ORACLE_RESULT`) is preserved bit-compatible and
//! remains the default ([`crate::config::OracleMode::PerLabel`]).

use std::time::{Duration, Instant};

use crate::config::{BatchSetting, SchedPolicy, SchedSetting};
use crate::coordinator::dispatch::{
    BuiltinPolicy, DispatchConfig, DispatchCore, DispatchLeg, Eviction,
};

/// A dispatch decision: send batch `id` with `take` queue-head inputs to
/// oracle index `oracle`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OracleDispatch {
    pub id: u64,
    /// Index into the oracle pool (not a rank).
    pub oracle: usize,
    /// How many rows to pop from the queue head (FIFO) into this batch.
    pub take: usize,
}

/// Size-/deadline-triggered micro-batching with policy-driven oracle
/// routing and per-oracle backpressure. See the module docs for semantics.
#[derive(Debug)]
pub struct OracleScheduler {
    core: DispatchCore<BuiltinPolicy>,
    /// Deadline clock: when the queue last became non-empty, or the last
    /// dispatch left a non-empty remainder — whichever is later. The
    /// deadline trigger fires `max_delay` after this instant, so a partial
    /// batch waits at most `max_delay` behind the batch dispatched before
    /// it.
    queued_since: Option<Instant>,
}

impl OracleScheduler {
    /// Static-policy scheduler (PR-5 semantics, bit-for-bit).
    pub fn new(batch: &BatchSetting, n_oracles: usize) -> Self {
        Self::with_policy(batch, &SchedSetting::default(), n_oracles)
    }

    /// Scheduler with the configured routing policy (`sched_*` knobs).
    pub fn with_policy(batch: &BatchSetting, sched: &SchedSetting, n_oracles: usize) -> Self {
        let policy = match sched.policy {
            SchedPolicy::Static => BuiltinPolicy::least_outstanding(),
            SchedPolicy::Adaptive => BuiltinPolicy::adaptive(),
        };
        OracleScheduler {
            core: DispatchCore::new(DispatchConfig::new(batch, sched), policy, n_oracles),
            queued_since: None,
        }
    }

    /// Publish per-oracle dispatch state (outstanding batches, EWMA) to the
    /// live metrics registry, labeling oracle index `i` as `ranks[i]`.
    /// See [`crate::coordinator::dispatch::DispatchCore::observe_as`].
    pub fn observe_as(&mut self, ranks: Vec<usize>) {
        self.core.observe_as(ranks, DispatchLeg::Oracle);
    }

    /// Inputs were appended to the (external) queue. Starts the deadline
    /// clock if the queue was empty.
    pub fn note_enqueued(&mut self, now: Instant) {
        if self.queued_since.is_none() {
            self.queued_since = Some(now);
        }
    }

    /// The external queue was mutated out-of-band (a `dynamic_orcale_list`
    /// rescore replaced its contents): resync the deadline clock. A queue
    /// that emptied stops the clock; one that stays non-empty keeps its
    /// original head-age (replacements are a permutation of queued rows,
    /// not new arrivals).
    pub fn sync_queue(&mut self, queue_len: usize, now: Instant) {
        if queue_len == 0 {
            self.queued_since = None;
        } else if self.queued_since.is_none() {
            self.queued_since = Some(now);
        }
    }

    /// Batches currently in flight across the pool.
    pub fn in_flight(&self) -> usize {
        self.core.in_flight()
    }

    /// Items currently in flight across the pool.
    pub fn in_flight_items(&self) -> usize {
        self.core.in_flight_items()
    }

    /// Decide one dispatch for a queue of `queue_len` rows, bounded by
    /// `budget` items (the strict label budget's remaining headroom;
    /// `None` = unbounded). On `Some`, the caller must pop exactly `take`
    /// rows from the queue head, encode them under `id`, and send to
    /// `oracle` — the scheduler has already recorded the batch as in
    /// flight and restarted the deadline clock for the remainder.
    pub fn try_dispatch(
        &mut self,
        queue_len: usize,
        now: Instant,
        budget: Option<u64>,
    ) -> Option<OracleDispatch> {
        let d = self.core.try_dispatch(queue_len, self.queued_since, now, budget)?;
        self.queued_since = if queue_len > d.take { Some(now) } else { None };
        Some(OracleDispatch { id: d.id, oracle: d.endpoint, take: d.take })
    }

    /// A batch's result frame arrived at `now`. Returns `(oracle, items)`
    /// of the completed batch, or `None` for an unknown id
    /// (orphan/duplicate, or an evicted-then-relabeled batch — the caller
    /// should still ingest the labels, they were paid for). The timestamp
    /// feeds the adaptive policy's EWMA and the drain bound's RTT window.
    pub fn complete(&mut self, id: u64, now: Instant) -> Option<(usize, usize)> {
        self.core.complete(id, now).map(|c| (c.endpoint, c.items))
    }

    /// Evict unhealthy oracles (timed-out or consecutively slow under the
    /// adaptive policy) and return their in-flight batches; the caller must
    /// requeue each eviction's inputs so they are relabeled elsewhere.
    /// No-op under the static policy.
    pub fn check_health(&mut self, now: Instant) -> Vec<Eviction> {
        self.core.check_health(now)
    }

    /// The host behind oracle index `oracle` died (rank-down notice or
    /// failed send): permanently evict it — under any policy — and return
    /// its in-flight batches for requeue. See
    /// [`crate::coordinator::dispatch::DispatchCore::mark_down`].
    pub fn mark_down(&mut self, oracle: usize, now: Instant) -> Vec<Eviction> {
        self.core.mark_down(oracle, now)
    }

    /// Whether `oracle` has been permanently marked down.
    pub fn is_down(&self, oracle: usize) -> bool {
        self.core.endpoint(oracle).is_dead()
    }

    /// Shutdown drain bound: `max(base, sched_drain_factor × p95 RTT)`.
    pub fn drain_bound(&self, base: Duration) -> Duration {
        self.core.drain_bound(base)
    }

    /// p95 of observed batch round-trips.
    pub fn rtt_p95(&self) -> Option<Duration> {
        self.core.rtt_p95()
    }
}

#[cfg(test)]
mod tests {
    //! Core trigger/routing semantics; the backpressure + budget properties
    //! live in `rust/tests/test_props.rs`, the static-policy equivalence
    //! with the pre-extraction scheduler in
    //! `rust/tests/test_dispatch_core.rs`, and the end-to-end behavior in
    //! `test_determinism.rs` / `comm_overhead`.
    use super::*;

    fn sched(
        max_size: usize,
        max_delay_ms: u64,
        max_outstanding: usize,
        oracles: usize,
    ) -> OracleScheduler {
        OracleScheduler::new(
            &BatchSetting {
                max_size,
                max_delay: Duration::from_millis(max_delay_ms),
                max_outstanding,
            },
            oracles,
        )
    }

    #[test]
    fn size_trigger_fires_and_caps_take() {
        let mut s = sched(4, 1_000_000, 2, 2);
        let t0 = Instant::now();
        s.note_enqueued(t0);
        assert!(s.try_dispatch(3, t0, None).is_none(), "below size, before deadline");
        let d = s.try_dispatch(6, t0, None).expect("size trigger");
        assert_eq!((d.id, d.oracle, d.take), (0, 0, 4));
        // remainder keeps the clock running: deadline fires max_delay later
        assert_eq!(s.in_flight(), 1);
    }

    #[test]
    fn deadline_trigger_flushes_partial_batch() {
        let mut s = sched(8, 10, 2, 1);
        let t0 = Instant::now();
        s.note_enqueued(t0);
        assert!(s.try_dispatch(2, t0 + Duration::from_millis(9), None).is_none());
        let d = s.try_dispatch(2, t0 + Duration::from_millis(10), None).expect("deadline");
        assert_eq!(d.take, 2, "partial batch takes everything queued");
        // queue drained → clock stops; new enqueue restarts it
        assert!(s.try_dispatch(0, t0 + Duration::from_secs(1), None).is_none());
    }

    #[test]
    fn least_outstanding_routing_is_deterministic() {
        let mut s = sched(1, 0, 2, 3);
        let t0 = Instant::now();
        s.note_enqueued(t0);
        // equal load → lowest index; then always the least-loaded oracle
        let picks: Vec<usize> =
            (0..4).map(|i| s.try_dispatch(4 - i, t0, None).unwrap().oracle).collect();
        assert_eq!(picks, vec![0, 1, 2, 0]);
        // oracle 1 frees first (it is faster): next batch routes to it
        let id = 1; // second dispatch went to oracle 1
        assert_eq!(s.complete(id, t0), Some((1, 1)));
        s.note_enqueued(t0);
        assert_eq!(s.try_dispatch(1, t0, None).unwrap().oracle, 1);
    }

    #[test]
    fn budget_caps_take_and_zero_budget_blocks() {
        let mut s = sched(8, 0, 2, 1);
        let t0 = Instant::now();
        s.note_enqueued(t0);
        assert!(s.try_dispatch(8, t0, Some(0)).is_none(), "budget exhausted");
        let d = s.try_dispatch(8, t0, Some(3)).unwrap();
        assert_eq!(d.take, 3, "budget bounds the batch");
    }

    #[test]
    fn completion_accounting_and_orphans() {
        let mut s = sched(2, 0, 1, 2);
        let t0 = Instant::now();
        s.note_enqueued(t0);
        let a = s.try_dispatch(4, t0, None).unwrap();
        let b = s.try_dispatch(2, t0, None).unwrap();
        assert_eq!(s.in_flight(), 2);
        assert_eq!(s.in_flight_items(), 4);
        // both oracles saturated at max_outstanding = 1
        s.note_enqueued(t0);
        assert!(s.try_dispatch(5, t0, None).is_none(), "backpressure");
        assert_eq!(s.complete(a.id, t0), Some((a.oracle, 2)));
        assert_eq!(s.complete(a.id, t0), None, "duplicate completion is an orphan");
        assert_eq!(s.complete(99, t0), None, "unknown id is an orphan");
        assert_eq!(s.complete(b.id, t0), Some((b.oracle, 2)));
        assert_eq!(s.in_flight(), 0);
        assert_eq!(s.in_flight_items(), 0);
    }

    #[test]
    fn sync_queue_resets_clock_only_when_emptied() {
        let mut s = sched(8, 10, 2, 1);
        let t0 = Instant::now();
        s.note_enqueued(t0);
        // rescore kept rows queued: head age is preserved
        s.sync_queue(3, t0 + Duration::from_millis(6));
        assert!(s.try_dispatch(3, t0 + Duration::from_millis(10), None).is_some());
        // rescore pruned everything: clock stops until a fresh enqueue
        s.sync_queue(0, t0 + Duration::from_millis(20));
        assert!(s.try_dispatch(2, t0 + Duration::from_secs(1), None).is_none());
    }

    #[test]
    fn static_policy_health_is_inert_and_drain_scales() {
        let mut s = sched(2, 0, 1, 2);
        let t0 = Instant::now();
        s.note_enqueued(t0);
        let d = s.try_dispatch(2, t0, None).unwrap();
        assert!(s.check_health(t0 + Duration::from_secs(10)).is_empty());
        assert_eq!(s.drain_bound(Duration::from_millis(300)), Duration::from_millis(300));
        // one slow round-trip stretches the drain bound past the base
        s.complete(d.id, t0 + Duration::from_millis(500));
        assert!(s.drain_bound(Duration::from_millis(300)) >= Duration::from_millis(1_400));
        assert!(s.rtt_p95().unwrap() >= Duration::from_millis(500));
    }
}
