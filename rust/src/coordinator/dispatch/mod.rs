//! Shared dispatch core: the trigger/outstanding/backpressure state machine
//! behind both exchange planes, generic over a routing [`Policy`].
//!
//! PR 1 (batched prediction exchange) and PR 5 (batched oracle plane) each
//! grew their own copy of the same micro-batching discipline — size/deadline
//! triggers, per-endpoint outstanding counts, backpressure at
//! `max_outstanding`, sequential batch ids. This module extracts that state
//! machine once and grows the latency-aware behavior PAL's heterogeneous
//! pools need (DFT hours next to xTB seconds — SI §S2.2) in a single place:
//!
//! * **Static policies** ([`policy::LeastOutstanding`],
//!   [`policy::RoundRobin`]) reproduce the old schedulers bit-for-bit —
//!   the wire- and determinism-default (`sched_policy = "static"`;
//!   equivalence pinned by `rust/tests/test_dispatch_core.rs`).
//! * **EWMA latency tracking** — [`DispatchCore::complete`] timestamps give
//!   a per-endpoint EWMA of per-item round-trip cost; the adaptive policy
//!   ([`policy::AdaptiveEwma`]) routes each batch to the endpoint with the
//!   least estimated completion time (deterministic lowest-index ties) and
//!   shrinks batches for slow endpoints (proportional to the fastest peer's
//!   EWMA) so a slow oracle chews small bites instead of parking a full
//!   batch behind one long calculation.
//! * **Health/eviction** — an endpoint whose in-flight batch exceeds
//!   `sched_timeout_ms`, or that delivers `sched_evict_after` consecutive
//!   slow completions (`> sched_slow_factor ×` the fastest peer), moves to
//!   a *rejected* set (the active/rejected endpoint-group idiom of
//!   agentgateway's load balancer). [`DispatchCore::check_health`] hands its
//!   in-flight work back to the caller for requeue/reroute; the endpoint
//!   rejoins after `sched_rejoin_ms`, or immediately when a late reply
//!   proves it recovered. The last active endpoint is never evicted.
//! * **Latency-scaled drain** — the core keeps a
//!   [`crate::telemetry::LatencyWindow`] of observed round-trips;
//!   [`DispatchCore::drain_bound`] scales the Manager's shutdown drain with
//!   p95 RTT instead of a fixed 300 ms, so labels already paid for are not
//!   discarded just because the oracle is slow.
//!
//! The core is clock-free and queue-free: callers inject `now`, the queue
//! length, and the queue-head age, so every trigger/eviction path is
//! unit-testable without threads or sleeps, and the two facades
//! ([`crate::coordinator::exchange::BatchScheduler`],
//! [`crate::coordinator::oracle_plane::OracleScheduler`]) keep owning their
//! queues (flat [`crate::data::batch::RowQueue`] / external `OracleBuffer`).

pub mod policy;

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::config::{BatchSetting, SchedPolicy, SchedSetting};
use crate::telemetry::registry::registry;
use crate::telemetry::LatencyWindow;

pub use policy::{AdaptiveEwma, BuiltinPolicy, LeastOutstanding, Policy, PoolView, RoundRobin};

/// Batching + adaptive knobs, flattened from [`BatchSetting`] and
/// [`SchedSetting`].
#[derive(Debug, Clone)]
pub struct DispatchConfig {
    pub max_size: usize,
    pub max_delay: Duration,
    pub max_outstanding: usize,
    /// Health tracking + eviction on (i.e. [`SchedPolicy::Adaptive`]).
    pub adaptive: bool,
    pub ewma_alpha: f64,
    pub slow_factor: f64,
    pub evict_after: u32,
    pub timeout: Option<Duration>,
    pub rejoin_backoff: Duration,
    pub drain_factor: f64,
}

impl DispatchConfig {
    pub fn new(batch: &BatchSetting, sched: &SchedSetting) -> Self {
        DispatchConfig {
            max_size: batch.max_size.max(1),
            max_delay: batch.max_delay,
            max_outstanding: batch.max_outstanding.max(1),
            adaptive: sched.policy == SchedPolicy::Adaptive,
            ewma_alpha: sched.ewma_alpha.clamp(f64::MIN_POSITIVE, 1.0),
            slow_factor: sched.slow_factor.max(1.0),
            evict_after: sched.evict_after.max(1),
            timeout: sched.timeout,
            rejoin_backoff: sched.rejoin_backoff,
            drain_factor: sched.drain_factor.max(1.0),
        }
    }
}

/// Per-endpoint load + health state, readable by policies through
/// [`PoolView`].
#[derive(Debug, Clone, Default)]
pub struct EndpointState {
    /// Batches in flight.
    pub outstanding: usize,
    /// Items in flight (the adaptive policy's cost unit).
    pub outstanding_items: usize,
    /// EWMA of per-item round-trip cost, ms (`None` until first completion).
    pub ewma_item_ms: Option<f64>,
    /// Consecutive completions slower than `slow_factor ×` the fastest peer.
    consecutive_slow: u32,
    /// Rejected until this instant (`None` = never evicted). A past instant
    /// means the endpoint is back on probation: routable again, but one
    /// more timeout/slow streak re-evicts it.
    rejected_until: Option<Instant>,
    /// Permanently down ([`DispatchCore::mark_down`]): its host died. Never
    /// routable again, and a late reply does *not* readmit it.
    dead: bool,
}

impl EndpointState {
    /// Routable at `now` (never evicted, or its backoff elapsed; dead
    /// endpoints are never routable).
    pub fn active(&self, now: Instant) -> bool {
        !self.dead && self.rejected_until.map_or(true, |t| now >= t)
    }

    /// Permanently down (its host died).
    pub fn is_dead(&self) -> bool {
        self.dead
    }

    fn is_rejected(&self, now: Instant) -> bool {
        !self.active(now)
    }
}

/// A dispatch decision: send batch `id` with `take` queue-head items to
/// `endpoint`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dispatch {
    pub id: u64,
    pub endpoint: usize,
    pub take: usize,
}

/// A completed round-trip (returned by [`DispatchCore::complete`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    pub endpoint: usize,
    pub items: usize,
    pub rtt: Duration,
}

/// In-flight work evicted from an unhealthy endpoint; the caller owns the
/// items and must requeue them (the core has already forgotten the batch —
/// a late reply under this `id` counts as an orphan *and* readmits the
/// endpoint).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Eviction {
    pub id: u64,
    pub endpoint: usize,
    pub items: usize,
}

#[derive(Debug, Clone, Copy)]
struct InFlightRec {
    endpoint: usize,
    items: usize,
    sent_at: Instant,
}

/// Which round-trip leg a core serves — selects the live-registry latency
/// histogram its completions feed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchLeg {
    /// Manager → oracle → Manager (labeling round-trips).
    Oracle,
    /// Exchange → prediction shard → Exchange (inference round-trips).
    Prediction,
}

/// The shared scheduler state machine. See the module docs for semantics.
#[derive(Debug)]
pub struct DispatchCore<P: Policy> {
    cfg: DispatchConfig,
    policy: P,
    eps: Vec<EndpointState>,
    inflight: HashMap<u64, InFlightRec>,
    /// Evicted batches by id: late replies are recognized as recovery
    /// evidence (and orphans) instead of unknown ids.
    evicted: HashMap<u64, InFlightRec>,
    next_id: u64,
    rtts: LatencyWindow,
    /// Live-registry publication map: endpoint index → world rank (for
    /// prediction shards, the shard's lead rank), plus the RTT leg. `None`
    /// (default, and every bare test core) publishes nothing.
    observe: Option<(Vec<usize>, DispatchLeg)>,
}

impl<P: Policy> DispatchCore<P> {
    pub fn new(cfg: DispatchConfig, policy: P, n_endpoints: usize) -> Self {
        DispatchCore {
            cfg,
            policy,
            eps: vec![EndpointState::default(); n_endpoints.max(1)],
            inflight: HashMap::new(),
            evicted: HashMap::new(),
            next_id: 0,
            rtts: LatencyWindow::default(),
            observe: None,
        }
    }

    /// Publish this core's per-endpoint state to the live metrics registry
    /// under the given rank labels (endpoint index order). The registry's
    /// enabled gate still applies — with observability off every publish
    /// is a single relaxed load.
    pub fn observe_as(&mut self, ranks: Vec<usize>, leg: DispatchLeg) {
        self.observe = Some((ranks, leg));
    }

    /// Rank label of endpoint `e` when observation is wired up.
    fn observed_rank(&self, e: usize) -> Option<usize> {
        self.observe.as_ref().and_then(|(ranks, _)| ranks.get(e).copied())
    }

    /// Push endpoint `e`'s outstanding counts to the registry.
    fn publish_endpoint(&self, e: usize) {
        if let Some(rank) = self.observed_rank(e) {
            registry().endpoint_outstanding(
                rank,
                self.eps[e].outstanding as u64,
                self.eps[e].outstanding_items as u64,
            );
        }
    }

    /// Push one completed round-trip (histogram + per-endpoint EWMA) and
    /// record the batch-lifecycle trace span (`oracle_batch`/`pred_batch`,
    /// `tid` = the serving endpoint's rank).
    fn publish_completion(&self, e: usize, id: u64, rtt: Duration, items: usize) {
        let Some((ranks, leg)) = &self.observe else {
            return;
        };
        let Some(&rank) = ranks.get(e) else {
            return;
        };
        let span_name = match leg {
            DispatchLeg::Oracle => {
                registry().observe_oracle_rtt(rtt);
                "oracle_batch"
            }
            DispatchLeg::Prediction => {
                registry().observe_pred_rtt(rtt);
                "pred_batch"
            }
        };
        // prefer the policy's EWMA; static policies don't keep one, so
        // fall back to the raw per-item cost of this completion
        let ms = self.eps[e]
            .ewma_item_ms
            .unwrap_or_else(|| rtt.as_secs_f64() * 1e3 / items.max(1) as f64);
        registry().endpoint_ewma_ms(rank, ms);
        let t0 = Instant::now().checked_sub(rtt).unwrap_or_else(Instant::now);
        crate::telemetry::trace::sink().span(rank, span_name, t0, id, items as u64);
    }

    pub fn config(&self) -> &DispatchConfig {
        &self.cfg
    }

    pub fn n_endpoints(&self) -> usize {
        self.eps.len()
    }

    pub fn endpoint(&self, e: usize) -> &EndpointState {
        &self.eps[e]
    }

    /// Batches in flight per endpoint.
    pub fn outstanding(&self, e: usize) -> usize {
        self.eps[e].outstanding
    }

    /// Batches in flight across the pool.
    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    /// Items in flight across the pool.
    pub fn in_flight_items(&self) -> usize {
        self.inflight.values().map(|f| f.items).sum()
    }

    /// Whether a dispatch trigger (size or deadline) has fired for a queue
    /// of `queue_len` rows whose head has been waiting since `head_since`.
    pub fn triggered(&self, queue_len: usize, head_since: Option<Instant>, now: Instant) -> bool {
        if queue_len == 0 {
            return false;
        }
        if queue_len >= self.cfg.max_size {
            return true; // size trigger preempts the deadline
        }
        head_since
            .map(|t| now.duration_since(t) >= self.cfg.max_delay)
            .unwrap_or(false)
    }

    /// Routable-endpoint mask. Safety net: if every endpoint is rejected
    /// (unreachable through [`DispatchCore::check_health`], which never
    /// evicts the last active one), the non-dead ones are treated as
    /// routable rather than deadlocking the queue. Dead endpoints are never
    /// resurrected — with every endpoint dead the mask stays all-false and
    /// dispatch stalls (the coordinator aborts the run instead).
    fn active_mask(&self, now: Instant) -> Vec<bool> {
        let mut mask: Vec<bool> = self.eps.iter().map(|e| e.active(now)).collect();
        if !mask.iter().any(|&a| a) {
            for (m, e) in mask.iter_mut().zip(&self.eps) {
                *m = !e.dead;
            }
        }
        mask
    }

    /// Decide one dispatch for a queue of `queue_len` rows, bounded by
    /// `budget` items (`None` = unbounded). On `Some`, the caller must pop
    /// exactly `take` rows from the queue head, encode them under `id`, and
    /// send to `endpoint` — the core has already recorded the batch as in
    /// flight.
    pub fn try_dispatch(
        &mut self,
        queue_len: usize,
        head_since: Option<Instant>,
        now: Instant,
        budget: Option<u64>,
    ) -> Option<Dispatch> {
        if budget == Some(0) {
            return None;
        }
        if !self.triggered(queue_len, head_since, now) {
            return None;
        }
        let active = self.active_mask(now);
        let view = PoolView {
            eps: &self.eps,
            active: &active,
            max_size: self.cfg.max_size,
            max_outstanding: self.cfg.max_outstanding,
        };
        let endpoint = self.policy.route(&view)?;
        let cap = self.policy.batch_cap(endpoint, &view).clamp(1, self.cfg.max_size);
        let mut take = queue_len.min(cap);
        if let Some(b) = budget {
            take = take.min(b as usize);
        }
        debug_assert!(take > 0);
        let id = self.next_id;
        self.next_id += 1;
        self.eps[endpoint].outstanding += 1;
        self.eps[endpoint].outstanding_items += take;
        self.inflight.insert(id, InFlightRec { endpoint, items: take, sent_at: now });
        self.publish_endpoint(endpoint);
        Some(Dispatch { id, endpoint, take })
    }

    /// A batch's result arrived. Returns the completed round-trip, or
    /// `None` for an orphan (unknown/duplicate id, or a batch already
    /// evicted and requeued — the caller should still ingest the labels,
    /// they were paid for). A late reply from an evicted batch readmits its
    /// endpoint immediately: the reply is proof of life.
    pub fn complete(&mut self, id: u64, now: Instant) -> Option<Completion> {
        if let Some(rec) = self.inflight.remove(&id) {
            let e = rec.endpoint;
            self.eps[e].outstanding = self.eps[e].outstanding.saturating_sub(1);
            self.eps[e].outstanding_items = self.eps[e].outstanding_items.saturating_sub(rec.items);
            let rtt = now.saturating_duration_since(rec.sent_at);
            self.rtts.record(rtt);
            if self.cfg.adaptive {
                self.observe(e, rtt, rec.items, now);
            }
            self.publish_endpoint(e);
            self.publish_completion(e, id, rtt, rec.items);
            return Some(Completion { endpoint: e, items: rec.items, rtt });
        }
        if let Some(rec) = self.evicted.remove(&id) {
            let e = rec.endpoint;
            let rtt = now.saturating_duration_since(rec.sent_at);
            self.rtts.record(rtt);
            if self.cfg.adaptive && !self.eps[e].dead {
                // recovery: rejoin the active group (probation), and feed
                // the observed cost into the EWMA so routing stays honest
                // about how slow the comeback actually was. A *dead*
                // endpoint is never readmitted — its host is gone, and a
                // reply it sent before dying is not proof of life.
                self.eps[e].rejected_until = None;
                self.eps[e].consecutive_slow = 0;
                self.update_ewma(e, rtt, rec.items);
            }
        }
        None
    }

    /// The host behind endpoint `e` died (rank-down notice or failed send):
    /// mark it permanently unroutable — under *any* policy, static
    /// included, since a dead host is not a tuning question — and hand its
    /// in-flight batches back for requeue (id-ordered, same contract as
    /// [`DispatchCore::check_health`]). Idempotent; out-of-range indices
    /// are ignored.
    pub fn mark_down(&mut self, e: usize, now: Instant) -> Vec<Eviction> {
        if e >= self.eps.len() || self.eps[e].dead {
            return Vec::new();
        }
        self.eps[e].dead = true;
        self.eps[e].rejected_until = Some(now + Duration::from_secs(86_400 * 365));
        self.eps[e].consecutive_slow = 0;
        let mut out: Vec<Eviction> = self
            .inflight
            .iter()
            .filter(|(_, r)| r.endpoint == e)
            .map(|(&id, r)| Eviction { id, endpoint: e, items: r.items })
            .collect();
        out.sort_by_key(|ev| ev.id);
        for ev in &out {
            let rec = self.inflight.remove(&ev.id).expect("collected above");
            self.eps[e].outstanding = self.eps[e].outstanding.saturating_sub(1);
            self.eps[e].outstanding_items = self.eps[e].outstanding_items.saturating_sub(rec.items);
            self.evicted.insert(ev.id, rec);
        }
        if let Some(rank) = self.observed_rank(e) {
            registry().endpoint_dead(rank, true);
            crate::telemetry::trace::sink().instant(rank, "evict", e as u64);
        }
        self.publish_endpoint(e);
        out
    }

    /// EWMA + slow-streak bookkeeping for one observed round-trip.
    fn observe(&mut self, e: usize, rtt: Duration, items: usize, now: Instant) {
        let per_item_ms = rtt.as_secs_f64() * 1e3 / items.max(1) as f64;
        // slow = markedly slower than the fastest *other* endpoint's EWMA
        let fastest_peer = self
            .eps
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != e)
            .filter_map(|(_, s)| s.ewma_item_ms)
            .fold(None::<f64>, |acc, x| Some(acc.map_or(x, |a| a.min(x))));
        match fastest_peer {
            Some(f) if f > 0.0 && per_item_ms > self.cfg.slow_factor * f => {
                self.eps[e].consecutive_slow += 1;
                if self.eps[e].consecutive_slow >= self.cfg.evict_after {
                    self.reject(e, now);
                }
            }
            Some(_) => self.eps[e].consecutive_slow = 0,
            None => {}
        }
        self.update_ewma(e, rtt, items);
    }

    fn update_ewma(&mut self, e: usize, rtt: Duration, items: usize) {
        let sample = rtt.as_secs_f64() * 1e3 / items.max(1) as f64;
        let a = self.cfg.ewma_alpha;
        self.eps[e].ewma_item_ms = Some(match self.eps[e].ewma_item_ms {
            Some(prev) => a * sample + (1.0 - a) * prev,
            None => sample,
        });
    }

    /// Move `e` to the rejected group for `rejoin_backoff` — unless it is
    /// the last active endpoint (someone has to serve the queue).
    fn reject(&mut self, e: usize, now: Instant) -> bool {
        let other_active = (0..self.eps.len()).any(|i| i != e && self.eps[i].active(now));
        if !other_active {
            return false;
        }
        self.eps[e].rejected_until = Some(now + self.cfg.rejoin_backoff);
        self.eps[e].consecutive_slow = 0;
        true
    }

    /// Timeout-evict endpoints with over-age in-flight batches and collect
    /// every in-flight batch parked on a rejected endpoint for requeue
    /// (id-ordered — deterministic requeue order). No-op under the static
    /// policy. The caller must re-enqueue each eviction's items; the core
    /// keeps the id so a late reply is recognized as recovery.
    pub fn check_health(&mut self, now: Instant) -> Vec<Eviction> {
        if !self.cfg.adaptive {
            return Vec::new();
        }
        if let Some(timeout) = self.cfg.timeout {
            let mut stale: Vec<usize> = self
                .inflight
                .values()
                .filter(|r| now.saturating_duration_since(r.sent_at) >= timeout)
                .map(|r| r.endpoint)
                .collect();
            // index order, not map order: deterministic when several
            // endpoints go stale at once (and the last-active guard then
            // spares the highest-indexed ones)
            stale.sort_unstable();
            stale.dedup();
            for e in stale {
                if self.eps[e].active(now) {
                    self.reject(e, now);
                }
            }
        }
        let mut out: Vec<Eviction> = self
            .inflight
            .iter()
            .filter(|(_, r)| self.eps[r.endpoint].is_rejected(now))
            .map(|(&id, r)| Eviction { id, endpoint: r.endpoint, items: r.items })
            .collect();
        out.sort_by_key(|ev| ev.id);
        for ev in &out {
            let rec = self.inflight.remove(&ev.id).expect("collected above");
            let e = rec.endpoint;
            self.eps[e].outstanding = self.eps[e].outstanding.saturating_sub(1);
            self.eps[e].outstanding_items = self.eps[e].outstanding_items.saturating_sub(rec.items);
            self.evicted.insert(ev.id, rec);
            self.publish_endpoint(e);
            if let Some(rank) = self.observed_rank(e) {
                crate::telemetry::trace::sink().instant(rank, "evict", ev.id);
            }
        }
        out
    }

    /// p95 of observed round-trips (completions, including late replies).
    pub fn rtt_p95(&self) -> Option<Duration> {
        self.rtts.p95()
    }

    /// Shutdown drain bound: `max(base, drain_factor × p95 RTT)`. The drain
    /// only ever waits *longer* than the fixed base, never ingests
    /// differently, so static-policy label streams are unchanged.
    pub fn drain_bound(&self, base: Duration) -> Duration {
        scaled_drain_bound(self.rtts.p95(), self.cfg.drain_factor, base)
    }
}

/// `max(base, factor × p95)` — shared by the batched core and the Manager's
/// per-label path (which tracks its own RTT window).
pub fn scaled_drain_bound(p95: Option<Duration>, factor: f64, base: Duration) -> Duration {
    match p95 {
        Some(p) => base.max(p.mul_f64(factor.max(1.0))),
        None => base,
    }
}

#[cfg(test)]
mod tests {
    //! Adaptive-policy semantics (EWMA routing, adaptive batch caps,
    //! eviction/recovery, drain scaling). Static-policy equivalence with
    //! the pre-extraction schedulers is pinned in
    //! `rust/tests/test_dispatch_core.rs`; the facades' trigger semantics
    //! in `exchange.rs` / `oracle_plane.rs`.
    use super::*;

    fn cfg(max_size: usize, max_outstanding: usize, sched: &SchedSetting) -> DispatchConfig {
        DispatchConfig::new(
            &BatchSetting {
                max_size,
                max_delay: Duration::from_millis(1),
                max_outstanding,
            },
            sched,
        )
    }

    fn adaptive() -> SchedSetting {
        SchedSetting { policy: SchedPolicy::Adaptive, ..Default::default() }
    }

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    /// Dispatch + complete one batch with a synthetic RTT.
    fn round_trip(
        core: &mut DispatchCore<BuiltinPolicy>,
        queue: usize,
        now: Instant,
        rtt: Duration,
    ) -> (Dispatch, Instant) {
        let d = core.try_dispatch(queue, Some(now), now, None).expect("dispatch");
        let done = now + rtt;
        core.complete(d.id, done).expect("completion");
        (d, done)
    }

    #[test]
    fn unexplored_endpoints_are_probed_first() {
        let mut core =
            DispatchCore::new(cfg(4, 2, &adaptive()), BuiltinPolicy::adaptive(), 3);
        let t0 = Instant::now();
        let picks: Vec<usize> = (0..3)
            .map(|_| core.try_dispatch(8, Some(t0), t0, None).unwrap().endpoint)
            .collect();
        assert_eq!(picks, vec![0, 1, 2], "probe every endpoint before trusting EWMAs");
    }

    #[test]
    fn ewma_routing_prefers_the_faster_endpoint() {
        let mut core =
            DispatchCore::new(cfg(4, 4, &adaptive()), BuiltinPolicy::adaptive(), 2);
        let t0 = Instant::now();
        // probe both: endpoint 0 is 8×, endpoint 1 is 1× per item
        let d0 = core.try_dispatch(4, Some(t0), t0, None).unwrap();
        let d1 = core.try_dispatch(4, Some(t0), t0, None).unwrap();
        assert_eq!((d0.endpoint, d1.endpoint), (0, 1));
        core.complete(d0.id, t0 + ms(32)).unwrap(); // 8 ms/item
        core.complete(d1.id, t0 + ms(4)).unwrap(); // 1 ms/item
        // both idle: the fast endpoint wins the next several batches
        let d = core.try_dispatch(4, Some(t0), t0 + ms(40), None).unwrap();
        assert_eq!(d.endpoint, 1);
        // pile work on the fast one until the slow one's ECT wins
        let mut routed_to_slow = false;
        for _ in 0..8 {
            let d = core.try_dispatch(4, Some(t0), t0 + ms(40), None).unwrap();
            if d.endpoint == 0 {
                routed_to_slow = true;
                break;
            }
        }
        assert!(routed_to_slow, "a loaded fast endpoint eventually loses to an idle slow one");
    }

    #[test]
    fn slow_endpoints_get_smaller_batches() {
        let mut core =
            DispatchCore::new(cfg(8, 4, &adaptive()), BuiltinPolicy::adaptive(), 2);
        let t0 = Instant::now();
        let d0 = core.try_dispatch(8, Some(t0), t0, None).unwrap();
        let d1 = core.try_dispatch(8, Some(t0), t0, None).unwrap();
        core.complete(d0.id, t0 + ms(8 * 8)).unwrap(); // endpoint 0: 8 ms/item
        core.complete(d1.id, t0 + ms(8)).unwrap(); // endpoint 1: 1 ms/item
        // force routing to the slow endpoint by saturating the fast one
        for _ in 0..4 {
            let d = core.try_dispatch(8, Some(t0), t0 + ms(70), None).unwrap();
            if d.endpoint == 0 {
                assert!(
                    d.take <= 2,
                    "4×-slower endpoint gets ≤ max_size × (1/4)-ish batches, got {}",
                    d.take
                );
                return;
            }
            assert_eq!(d.take, 8, "fast endpoint keeps full batches");
        }
        panic!("slow endpoint was never routed to");
    }

    #[test]
    fn timeout_evicts_and_requeues_in_flight_work() {
        let sched = SchedSetting {
            timeout: Some(ms(50)),
            rejoin_backoff: ms(1_000),
            ..adaptive()
        };
        let mut core = DispatchCore::new(cfg(4, 2, &sched), BuiltinPolicy::adaptive(), 2);
        let t0 = Instant::now();
        let d0 = core.try_dispatch(4, Some(t0), t0, None).unwrap();
        let d1 = core.try_dispatch(4, Some(t0), t0, None).unwrap();
        assert_eq!((d0.endpoint, d1.endpoint), (0, 1));
        core.complete(d1.id, t0 + ms(10)).unwrap();
        // endpoint 0's batch ages past the timeout → evicted with its work
        assert!(core.check_health(t0 + ms(49)).is_empty(), "not stale yet");
        let evs = core.check_health(t0 + ms(50));
        assert_eq!(evs, vec![Eviction { id: d0.id, endpoint: 0, items: 4 }]);
        assert_eq!(core.in_flight(), 0);
        assert_eq!(core.outstanding(0), 0, "evicted work no longer counts as outstanding");
        // rejected: routing skips endpoint 0 until the backoff elapses
        let d = core.try_dispatch(4, Some(t0), t0 + ms(60), None).unwrap();
        assert_eq!(d.endpoint, 1);
        // …then it rejoins on probation
        core.complete(d.id, t0 + ms(70)).unwrap();
        let d = core.try_dispatch(4, Some(t0), t0 + ms(1_100), None).unwrap();
        assert_eq!(d.endpoint, 0, "rejoined after backoff");
    }

    #[test]
    fn late_reply_from_evicted_batch_is_orphan_and_readmits() {
        let sched = SchedSetting {
            timeout: Some(ms(50)),
            rejoin_backoff: ms(60_000),
            ..adaptive()
        };
        let mut core = DispatchCore::new(cfg(4, 2, &sched), BuiltinPolicy::adaptive(), 2);
        let t0 = Instant::now();
        let d0 = core.try_dispatch(4, Some(t0), t0, None).unwrap();
        assert_eq!(core.check_health(t0 + ms(50)), vec![Eviction {
            id: d0.id,
            endpoint: 0,
            items: 4
        }]);
        // long backoff: still rejected…
        let d = core.try_dispatch(4, Some(t0), t0 + ms(100), None).unwrap();
        assert_eq!(d.endpoint, 1);
        // …until the late reply lands: orphan for accounting, but recovery
        assert_eq!(core.complete(d0.id, t0 + ms(200)), None);
        core.complete(d.id, t0 + ms(200)).unwrap();
        assert!(core.endpoint(0).active(t0 + ms(200)), "late reply readmits");
        assert_eq!(core.complete(d0.id, t0 + ms(201)), None, "evicted id drops after reuse");
    }

    #[test]
    fn last_active_endpoint_is_never_evicted() {
        let sched = SchedSetting { timeout: Some(ms(10)), ..adaptive() };
        let mut core = DispatchCore::new(cfg(4, 2, &sched), BuiltinPolicy::adaptive(), 2);
        let t0 = Instant::now();
        let d0 = core.try_dispatch(4, Some(t0), t0, None).unwrap();
        let d1 = core.try_dispatch(4, Some(t0), t0, None).unwrap();
        // both time out: only one may be evicted, and eviction scans
        // endpoints in index order, so endpoint 0 goes and 1 survives
        let evs = core.check_health(t0 + ms(20));
        assert_eq!(evs, vec![Eviction { id: d0.id, endpoint: 0, items: 4 }]);
        assert!(core.endpoint(1).active(t0 + ms(20)));
        assert_eq!(core.in_flight(), 1, "survivor keeps its batch");
        assert!(core.complete(d1.id, t0 + ms(30)).is_some());
    }

    #[test]
    fn mark_down_evicts_under_any_policy_and_is_permanent() {
        // static policy: rank-down eviction must work even though the
        // timeout/slow health plane is off
        let mut core = DispatchCore::new(
            cfg(4, 2, &SchedSetting::default()),
            BuiltinPolicy::least_outstanding(),
            2,
        );
        let t0 = Instant::now();
        let d0 = core.try_dispatch(4, Some(t0), t0, None).unwrap();
        let d1 = core.try_dispatch(4, Some(t0), t0, None).unwrap();
        assert_eq!((d0.endpoint, d1.endpoint), (0, 1));
        let evs = core.mark_down(0, t0 + ms(5));
        assert_eq!(evs, vec![Eviction { id: d0.id, endpoint: 0, items: 4 }]);
        assert!(core.endpoint(0).is_dead());
        assert_eq!(core.outstanding(0), 0);
        assert!(core.mark_down(0, t0 + ms(6)).is_empty(), "idempotent");
        assert!(core.mark_down(99, t0 + ms(6)).is_empty(), "out of range ignored");
        // routing skips the dead endpoint forever
        let d = core.try_dispatch(4, Some(t0), t0 + ms(10), None).unwrap();
        assert_eq!(d.endpoint, 1);
        // a late reply from the dead endpoint is an orphan and does NOT
        // readmit it
        assert_eq!(core.complete(d0.id, t0 + ms(20)), None);
        assert!(!core.endpoint(0).active(t0 + ms(20)), "dead endpoint stays down");
        core.complete(d1.id, t0 + ms(20)).unwrap();
        core.complete(d.id, t0 + ms(21)).unwrap();
        // both endpoints down → dispatch stalls instead of resurrecting
        let evs = core.mark_down(1, t0 + ms(30));
        assert!(evs.is_empty());
        assert!(core.try_dispatch(4, Some(t0), t0 + ms(40), None).is_none());
    }

    #[test]
    fn consecutive_slow_completions_evict() {
        let sched = SchedSetting {
            evict_after: 2,
            slow_factor: 4.0,
            rejoin_backoff: ms(1_000),
            ..adaptive()
        };
        let mut core = DispatchCore::new(cfg(1, 1, &sched), BuiltinPolicy::adaptive(), 2);
        let t0 = Instant::now();
        // establish baselines: endpoint 0 at 2 ms/item, endpoint 1 at 1
        let d0 = core.try_dispatch(2, Some(t0), t0, None).unwrap();
        assert_eq!(d0.endpoint, 0);
        core.complete(d0.id, t0 + ms(2)).unwrap();
        let d1 = core.try_dispatch(2, Some(t0), t0, None).unwrap();
        assert_eq!(d1.endpoint, 1, "unexplored endpoint probed next");
        core.complete(d1.id, t0 + ms(1)).unwrap();
        // endpoint 0 turns pathological: with the fast endpoint saturated
        // (max_outstanding = 1), overflow work lands on 0 and comes back
        // 10 ms/item — two consecutive slow completions (> 4 × 1 ms) evict
        let mut now = t0 + ms(3);
        for i in 0..2 {
            let fast = core.try_dispatch(2, Some(now), now, None).unwrap();
            assert_eq!(fast.endpoint, 1, "round {i}: lower-ECT endpoint preferred");
            let slow = core.try_dispatch(2, Some(now), now, None).unwrap();
            assert_eq!(slow.endpoint, 0, "round {i}: overflow routes to the slow endpoint");
            core.complete(fast.id, now + ms(1)).unwrap();
            core.complete(slow.id, now + ms(10)).unwrap();
            now += ms(11);
        }
        assert!(
            core.endpoint(0).is_rejected(now),
            "two consecutive slow completions evict (ewma0={:?})",
            core.endpoint(0).ewma_item_ms
        );
        assert!(core.endpoint(1).active(now));
    }

    #[test]
    fn static_policy_never_evicts_and_drain_bound_scales() {
        let mut core = DispatchCore::new(
            cfg(4, 1, &SchedSetting { timeout: Some(ms(1)), ..Default::default() }),
            BuiltinPolicy::least_outstanding(),
            2,
        );
        let t0 = Instant::now();
        let d = core.try_dispatch(4, Some(t0), t0, None).unwrap();
        assert!(core.check_health(t0 + ms(500)).is_empty(), "static policy: no health plane");
        assert_eq!(core.drain_bound(ms(300)), ms(300), "no samples yet → base bound");
        core.complete(d.id, t0 + ms(400)).unwrap();
        // p95 ≈ 400 ms, factor 3 → bound stretches to ~1.2 s
        assert!(core.drain_bound(ms(300)) >= ms(1_100));
        assert_eq!(scaled_drain_bound(Some(ms(10)), 3.0, ms(300)), ms(300), "base is a floor");
    }

    #[test]
    fn adaptive_take_respects_queue_and_budget() {
        let mut core =
            DispatchCore::new(cfg(8, 4, &adaptive()), BuiltinPolicy::adaptive(), 1);
        let t0 = Instant::now();
        assert!(core.try_dispatch(8, Some(t0), t0, Some(0)).is_none(), "budget exhausted");
        let d = core.try_dispatch(8, Some(t0), t0, Some(3)).unwrap();
        assert_eq!(d.take, 3, "budget caps the batch");
        let d = core.try_dispatch(2, Some(t0 - ms(10)), t0, None).unwrap();
        assert_eq!(d.take, 2, "queue length caps the batch");
    }
}
