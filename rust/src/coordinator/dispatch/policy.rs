//! Routing policies for the dispatch core.
//!
//! A [`Policy`] answers two questions per dispatch: *which* endpoint takes
//! the next batch ([`Policy::route`]) and *how large* that batch may be
//! ([`Policy::batch_cap`]). Policies are pure functions over the
//! [`PoolView`] (per-endpoint load/health snapshot) plus their own cursor
//! state, so routing sequences are deterministic and unit-testable.
//!
//! [`LeastOutstanding`] and [`RoundRobin`] reproduce the pre-extraction
//! oracle-plane and exchange schedulers; [`AdaptiveEwma`] adds
//! least-estimated-completion-time routing with adaptive batch sizing.

use super::EndpointState;

/// Read-only pool snapshot handed to policies at routing time. `active`
/// is the health mask (all-true under the static policies); a `false`
/// endpoint must not receive work.
#[derive(Debug)]
pub struct PoolView<'a> {
    pub eps: &'a [EndpointState],
    pub active: &'a [bool],
    pub max_size: usize,
    pub max_outstanding: usize,
}

impl PoolView<'_> {
    /// Routable: healthy and below the outstanding-batch cap.
    fn candidate(&self, e: usize) -> bool {
        self.active[e] && self.eps[e].outstanding < self.max_outstanding
    }

    /// Candidates in index order.
    fn candidates(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.eps.len()).filter(move |&e| self.candidate(e))
    }

    /// Least-outstanding candidate, lowest index on ties (`None` = every
    /// endpoint saturated or unhealthy: backpressure).
    fn least_outstanding(&self) -> Option<usize> {
        self.candidates().min_by_key(|&e| self.eps[e].outstanding)
    }
}

/// Endpoint choice + batch-size cap per dispatch.
pub trait Policy {
    /// Pick the endpoint for the next batch (`None` = backpressure).
    fn route(&mut self, view: &PoolView<'_>) -> Option<usize>;

    /// Upper bound on the next batch's size for `endpoint` (clamped by the
    /// core to `[1, max_size]`). Default: full batches.
    fn batch_cap(&self, endpoint: usize, view: &PoolView<'_>) -> usize {
        let _ = endpoint;
        view.max_size
    }
}

/// The oracle plane's static policy: fewest batches in flight, lowest
/// index on ties — deterministic, and heterogeneous-latency pools are fed
/// proportionally to their speed without any latency estimation.
#[derive(Debug, Default, Clone, Copy)]
pub struct LeastOutstanding;

impl Policy for LeastOutstanding {
    fn route(&mut self, view: &PoolView<'_>) -> Option<usize> {
        view.least_outstanding()
    }
}

/// The prediction exchange's static policy: round-robin across shards with
/// a least-outstanding fallback when the preferred shard is saturated. The
/// cursor advances past the shard *actually chosen* (not the preferred
/// one), so a briefly-saturated shard is not skipped on the next round
/// after its work went elsewhere.
#[derive(Debug, Default, Clone, Copy)]
pub struct RoundRobin {
    cursor: usize,
}

impl Policy for RoundRobin {
    fn route(&mut self, view: &PoolView<'_>) -> Option<usize> {
        let n = view.eps.len();
        let preferred = self.cursor % n;
        let chosen = if view.candidate(preferred) {
            preferred
        } else {
            view.least_outstanding()? // backpressure: cursor unchanged
        };
        self.cursor = (chosen + 1) % n;
        Some(chosen)
    }
}

/// Latency-aware routing: each batch goes to the candidate with the least
/// estimated completion time `ewma_item_ms × (outstanding_items +
/// planned_take)`, deterministic lowest-index ties. Endpoints without an
/// EWMA yet are probed first (least outstanding items, lowest index), so
/// every endpoint's cost gets measured before estimates are trusted. Batch
/// caps shrink proportionally to how much slower an endpoint is than the
/// fastest one, so a slow oracle receives small bites instead of parking a
/// full batch behind one long calculation.
#[derive(Debug, Default, Clone, Copy)]
pub struct AdaptiveEwma;

impl AdaptiveEwma {
    fn cap_for(&self, e: usize, view: &PoolView<'_>) -> usize {
        let Some(own) = view.eps[e].ewma_item_ms else {
            return view.max_size; // unexplored: probe at full size
        };
        let fastest = (0..view.eps.len())
            .filter(|&i| view.active[i])
            .filter_map(|i| view.eps[i].ewma_item_ms)
            .fold(own, f64::min);
        if own <= 0.0 || fastest <= 0.0 {
            return view.max_size;
        }
        let cap = (view.max_size as f64 * fastest / own).round() as usize;
        cap.clamp(1, view.max_size)
    }
}

impl Policy for AdaptiveEwma {
    fn route(&mut self, view: &PoolView<'_>) -> Option<usize> {
        // probe unexplored endpoints first (least items, lowest index)
        if let Some(e) = view
            .candidates()
            .filter(|&e| view.eps[e].ewma_item_ms.is_none())
            .min_by_key(|&e| view.eps[e].outstanding_items)
        {
            return Some(e);
        }
        // least estimated completion time, strict-improvement scan →
        // lowest index wins ties
        let mut best: Option<(usize, f64)> = None;
        for e in view.candidates() {
            let ewma = view.eps[e].ewma_item_ms.expect("unexplored handled above");
            let planned = view.eps[e].outstanding_items + self.cap_for(e, view);
            let ect = ewma * planned as f64;
            if best.map_or(true, |(_, b)| ect < b) {
                best = Some((e, ect));
            }
        }
        best.map(|(e, _)| e)
    }

    fn batch_cap(&self, endpoint: usize, view: &PoolView<'_>) -> usize {
        self.cap_for(endpoint, view)
    }
}

/// The concrete policy set the facades instantiate (an enum, so
/// `DispatchCore<BuiltinPolicy>` stays a single monomorphization per
/// facade while the `Policy` trait stays open for tests and extensions).
#[derive(Debug, Clone, Copy)]
pub enum BuiltinPolicy {
    LeastOutstanding(LeastOutstanding),
    RoundRobin(RoundRobin),
    Adaptive(AdaptiveEwma),
}

impl BuiltinPolicy {
    pub fn least_outstanding() -> Self {
        BuiltinPolicy::LeastOutstanding(LeastOutstanding)
    }

    pub fn round_robin() -> Self {
        BuiltinPolicy::RoundRobin(RoundRobin::default())
    }

    pub fn adaptive() -> Self {
        BuiltinPolicy::Adaptive(AdaptiveEwma)
    }
}

impl Policy for BuiltinPolicy {
    fn route(&mut self, view: &PoolView<'_>) -> Option<usize> {
        match self {
            BuiltinPolicy::LeastOutstanding(p) => p.route(view),
            BuiltinPolicy::RoundRobin(p) => p.route(view),
            BuiltinPolicy::Adaptive(p) => p.route(view),
        }
    }

    fn batch_cap(&self, endpoint: usize, view: &PoolView<'_>) -> usize {
        match self {
            BuiltinPolicy::LeastOutstanding(p) => p.batch_cap(endpoint, view),
            BuiltinPolicy::RoundRobin(p) => p.batch_cap(endpoint, view),
            BuiltinPolicy::Adaptive(p) => p.batch_cap(endpoint, view),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(outstanding: &[usize]) -> Vec<EndpointState> {
        outstanding
            .iter()
            .map(|&o| EndpointState { outstanding: o, outstanding_items: o, ..Default::default() })
            .collect()
    }

    fn view<'a>(
        eps: &'a [EndpointState],
        active: &'a [bool],
        max_outstanding: usize,
    ) -> PoolView<'a> {
        PoolView { eps, active, max_size: 8, max_outstanding }
    }

    #[test]
    fn least_outstanding_lowest_index_ties() {
        let eps = pool(&[1, 0, 0]);
        let active = [true; 3];
        let mut p = LeastOutstanding;
        assert_eq!(p.route(&view(&eps, &active, 2)), Some(1));
        let eps = pool(&[0, 0, 0]);
        assert_eq!(p.route(&view(&eps, &active, 2)), Some(0));
        let eps = pool(&[2, 2, 2]);
        assert_eq!(p.route(&view(&eps, &active, 2)), None, "saturated → backpressure");
    }

    #[test]
    fn round_robin_advances_past_chosen_not_preferred() {
        let mut eps = pool(&[0, 0]);
        let active = [true; 2];
        let mut p = RoundRobin::default();
        // 0 chosen, cursor → 1
        assert_eq!(p.route(&view(&eps, &active, 1)), Some(0));
        eps[0].outstanding = 1;
        // 1 chosen, cursor → 0
        assert_eq!(p.route(&view(&eps, &active, 1)), Some(1));
        eps[1].outstanding = 1;
        // saturated: no dispatch, cursor stays at 0
        assert_eq!(p.route(&view(&eps, &active, 1)), None);
        // shard 1 frees; preferred 0 still busy → fallback to 1, and the
        // cursor must advance past *1* (the chosen shard), back to 0
        eps[1].outstanding = 0;
        assert_eq!(p.route(&view(&eps, &active, 1)), Some(1));
        eps[1].outstanding = 1;
        // both free again: preferred is 0 — the briefly-saturated shard is
        // not skipped (the old scheduler would advance to 1 here)
        eps[0].outstanding = 0;
        eps[1].outstanding = 0;
        assert_eq!(p.route(&view(&eps, &active, 1)), Some(0));
    }

    #[test]
    fn rejected_endpoints_are_not_candidates() {
        let eps = pool(&[0, 5]);
        let active = [false, true];
        let mut lo = LeastOutstanding;
        assert_eq!(lo.route(&view(&eps, &active, 8)), Some(1), "idle-but-rejected skipped");
        let mut rr = RoundRobin::default();
        assert_eq!(rr.route(&view(&eps, &active, 8)), Some(1), "preferred-but-rejected skipped");
        let mut ad = AdaptiveEwma;
        assert_eq!(ad.route(&view(&eps, &active, 8)), Some(1));
    }

    #[test]
    fn adaptive_cap_scales_with_relative_speed() {
        let mut eps = pool(&[0, 0]);
        eps[0].ewma_item_ms = Some(8.0);
        eps[1].ewma_item_ms = Some(2.0);
        let active = [true; 2];
        let p = AdaptiveEwma;
        let v = view(&eps, &active, 4);
        assert_eq!(p.batch_cap(1, &v), 8, "fastest endpoint: full batches");
        assert_eq!(p.batch_cap(0, &v), 2, "4×-slower endpoint: quarter batches");
    }
}
