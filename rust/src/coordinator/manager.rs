//! Manager controller sub-kernel: buffers, oracle dispatch (per-label or
//! batched through the oracle plane), training flushes, dynamic oracle-list
//! adjustment, progress snapshots, shutdown.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use crate::comm::bus::{Endpoint, Payload, Src};
use crate::comm::codec;
use crate::comm::protocol::*;
use crate::config::{AlSetting, ExchangeMode, OracleMode, SchedPolicy, Topology};
use crate::coordinator::buffers::{OracleBuffer, TrainBuffer};
use crate::coordinator::dispatch::scaled_drain_bound;
use crate::coordinator::hosts::ShutdownFlag;
use crate::coordinator::oracle_plane::OracleScheduler;
use crate::data::batch::RowBlock;
use crate::json::{obj, Value};
use crate::kernels::Utils;
use crate::telemetry::registry::{registry, Counter, Gauge};
use crate::telemetry::{KernelTelemetry, LatencyWindow};

/// Outcome counters the workflow report needs from the Manager.
#[derive(Debug, Default, Clone)]
pub struct ManagerOutcome {
    pub oracle_labels: u64,
    pub retrain_rounds: u64,
    pub losses: Vec<f32>,
}

/// Ingest one legacy interleaved `TAG_ORACLE_BATCH_RESULT` frame (current
/// oracle hosts reply labels-only — see [`ingest_oracle_labels`] — but
/// mixed-version runs and the per-frame compatibility tests still produce
/// the old layout): free the scheduler's
/// in-flight slot (the arrival timestamp feeds the RTT window and, under
/// the adaptive policy, the EWMA), stage every `(input, label)` pair into
/// the train buffer (borrowed views — constant allocations per batch, zero
/// per label), and keep the accounting identical between the main loop and
/// the shutdown drain. Undecodable frames are counted (`malformed` +
/// `bad_frames`), never silently dropped.
#[allow(clippy::too_many_arguments)]
fn ingest_oracle_batch_result(
    data: &Payload,
    now: Instant,
    sched: &mut OracleScheduler,
    inflight_rows: &mut HashMap<u64, RowBlock>,
    train_buffer: &mut TrainBuffer,
    out: &mut ManagerOutcome,
    tel: &mut KernelTelemetry,
    drained: bool,
) {
    match decode_oracle_batch_result_views(data) {
        Some((id, pairs)) => {
            if sched.complete(id, now).is_none() {
                // duplicate, or a late reply from an evicted batch whose
                // inputs were already requeued — the labels are still paid
                // for, so they are ingested either way
                tel.bump("orphan_results");
            }
            inflight_rows.remove(&id);
            out.oracle_labels += pairs.len() as u64;
            tel.add("labels", pairs.len() as u64);
            registry().add(Counter::Labels, pairs.len() as u64);
            tel.bump("oracle_batch_results");
            if drained {
                tel.add("drained_labels", pairs.len() as u64);
            }
            for (x, y) in pairs.iter() {
                train_buffer.push_pair(x, y);
            }
        }
        None => {
            tel.bump("malformed");
            tel.bump("bad_frames");
            registry().inc(Counter::BadFrames);
        }
    }
}

/// Return a retained input block to the dispatch pool: cleared in place so
/// the next batched dispatch refills it without a fresh allocation. The pool
/// is bounded — blocks past the cap (more in-flight batches than the pool
/// ever needs to recycle at once) simply drop.
fn recycle_block(pool: &mut Vec<RowBlock>, mut block: RowBlock) {
    const POOL_CAP: usize = 16;
    block.clear();
    if pool.len() < POOL_CAP {
        pool.push(block);
    }
}

/// Ingest one labels-only `TAG_ORACLE_LABELS` frame: free the scheduler's
/// in-flight slot, then pair label row `i` with row `i` of the input block
/// retained at dispatch — the inputs never travel back over the wire, which
/// is what halves batched green-flow result bytes. The emptied block returns
/// to the dispatch pool. Labels whose batch was already evicted (inputs
/// requeued) are orphans: paid for but unpairable, so they are counted and
/// dropped — the requeued inputs will be relabeled. A label count that does
/// not match the retained batch means the pairing is untrustworthy; the
/// frame is rejected as malformed with the slot still freed.
#[allow(clippy::too_many_arguments)]
fn ingest_oracle_labels(
    data: &Payload,
    now: Instant,
    sched: &mut OracleScheduler,
    inflight_rows: &mut HashMap<u64, RowBlock>,
    block_pool: &mut Vec<RowBlock>,
    train_buffer: &mut TrainBuffer,
    out: &mut ManagerOutcome,
    tel: &mut KernelTelemetry,
    drained: bool,
) {
    match decode_oracle_labels_views(data) {
        Some((id, labels)) => {
            if sched.complete(id, now).is_none() {
                tel.bump("orphan_results");
            }
            match inflight_rows.remove(&id) {
                Some(inputs) if inputs.len() == labels.len() => {
                    out.oracle_labels += labels.len() as u64;
                    tel.add("labels", labels.len() as u64);
                    registry().add(Counter::Labels, labels.len() as u64);
                    tel.bump("oracle_batch_results");
                    if drained {
                        tel.add("drained_labels", labels.len() as u64);
                    }
                    for (i, y) in labels.iter().enumerate() {
                        train_buffer.push_pair(inputs.row(i), y);
                    }
                    recycle_block(block_pool, inputs);
                }
                Some(inputs) => {
                    tel.bump("malformed");
                    tel.bump("bad_frames");
                    registry().inc(Counter::BadFrames);
                    tel.add("lost_inputs", inputs.len() as u64);
                    registry().add(Counter::LostInputs, inputs.len() as u64);
                    recycle_block(block_pool, inputs);
                }
                None => {
                    tel.add("orphan_labels", labels.len() as u64);
                }
            }
        }
        None => {
            tel.bump("malformed");
            tel.bump("bad_frames");
            registry().inc(Counter::BadFrames);
        }
    }
}

/// Permanently evict batched-mode oracle `i` (its host died — rank-down
/// notice or failed send) and requeue its in-flight batches. Retained rows
/// go back to the buffer with their budget headroom released and the emptied
/// block returns to the dispatch pool; a batch with no retained block (its
/// labels already landed between the send failure and this eviction) is
/// recorded as lost, releasing the headroom so the budget can still be met
/// by the survivors. Idempotent per oracle.
#[allow(clippy::too_many_arguments)]
fn evict_dead_oracle(
    orcl_sched: &mut OracleScheduler,
    inflight_rows: &mut HashMap<u64, RowBlock>,
    block_pool: &mut Vec<RowBlock>,
    orcl_buffer: &mut OracleBuffer,
    dispatched_total: &mut u64,
    tel: &mut KernelTelemetry,
    i: usize,
    now: Instant,
) {
    if orcl_sched.is_down(i) {
        return;
    }
    tel.bump("oracle_evictions");
    registry().inc(Counter::OracleEvictions);
    for ev in orcl_sched.mark_down(i, now) {
        if let Some(rows) = inflight_rows.remove(&ev.id) {
            for r in 0..rows.len() {
                orcl_buffer.push_row(rows.row(r));
            }
            orcl_sched.note_enqueued(now);
            *dispatched_total = dispatched_total.saturating_sub(rows.len() as u64);
            tel.add("requeued_inputs", rows.len() as u64);
            registry().add(Counter::RequeuedInputs, rows.len() as u64);
            recycle_block(block_pool, rows);
        } else {
            *dispatched_total = dispatched_total.saturating_sub(ev.items as u64);
            tel.add("lost_inputs", ev.items as u64);
            registry().add(Counter::LostInputs, ev.items as u64);
        }
    }
}

/// Ingest one per-label `TAG_ORACLE_RESULT` frame — the single ingest path
/// shared by the main loop and the shutdown drain, so busy-flag, RTT, and
/// label accounting cannot diverge between them (the old drain silently
/// discarded malformed results and left no trace of unknown-rank senders).
/// The decoded `(input, label)` views copy straight into the train buffer's
/// contiguous block — no per-sample boxing.
#[allow(clippy::too_many_arguments)]
fn ingest_oracle_result(
    src: usize,
    data: &Payload,
    now: Instant,
    orcl: &[usize],
    oracle_busy: &mut [bool],
    busy_since: &mut [Option<Instant>],
    oracle_retry_until: &mut [Option<Instant>],
    inflight_input: &mut [Option<Payload>],
    label_rtts: &mut LatencyWindow,
    train_buffer: &mut TrainBuffer,
    out: &mut ManagerOutcome,
    tel: &mut KernelTelemetry,
    drained: bool,
) {
    match orcl.iter().position(|&r| r == src) {
        Some(i) => {
            oracle_busy[i] = false;
            // the retained in-flight input (fault/adaptive retention) is
            // answered; a reply from a timeout-evicted oracle is proof of
            // life and readmits it (dead oracles are gated separately)
            inflight_input[i] = None;
            oracle_retry_until[i] = None;
            if let Some(sent) = busy_since[i].take() {
                let rtt = now.saturating_duration_since(sent);
                label_rtts.record(rtt);
                registry().observe_oracle_rtt(rtt);
            }
        }
        // a result from a rank that is not an oracle: no busy flag to
        // clear, but the protocol breakage is counted, not ignored
        None => {
            tel.bump("bad_frames");
            registry().inc(Counter::BadFrames);
        }
    }
    match codec::unpack_views(data) {
        Some(parts) if parts.len() == 2 => {
            out.oracle_labels += 1;
            tel.bump("labels");
            registry().inc(Counter::Labels);
            if drained {
                tel.bump("drained_labels");
            }
            train_buffer.push_pair(parts[0], parts[1]);
        }
        // malformed or wrong arity: the label is lost on the wire, but the
        // loss is visible in telemetry instead of silent
        _ => {
            tel.bump("malformed");
            tel.bump("bad_frames");
            registry().inc(Counter::BadFrames);
        }
    }
}

/// Run the Manager until a stop request or a stop criterion fires, then
/// fan out shutdown.
pub fn manager_host(
    mut ep: Endpoint,
    mut utils: Box<dyn Utils>,
    setting: &AlSetting,
    topo: &Topology,
    down: ShutdownFlag,
) -> (KernelTelemetry, ManagerOutcome) {
    let mut tel = KernelTelemetry::new("manager", ep.rank());
    let mut out = ManagerOutcome::default();
    let orcl = topo.orcl_ranks();
    // re-scoring needs one full committee; the first shard suffices (other
    // shards hold replicas of the same members)
    let rescore = topo.rescore_ranks();
    let train = topo.train_ranks();
    let mut oracle_busy = vec![false; orcl.len()];
    // per-label dispatch timestamps → RTT window: the shutdown drain bound
    // scales with the observed p95 label latency instead of assuming a
    // fixed 300 ms covers every oracle pool
    let mut busy_since: Vec<Option<Instant>> = vec![None; orcl.len()];
    let mut label_rtts = LatencyWindow::default();
    // strict label budget: never dispatch beyond stop.max_labels — oracle
    // hours past the stop criterion are wasted work, and a bounded dispatch
    // count makes the final label tally exact (the deterministic e2e test
    // relies on this)
    let label_budget = if setting.strict_label_budget { setting.stop.max_labels } else { None };
    let mut dispatched_total: u64 = 0;
    let mut orcl_buffer = OracleBuffer::new(Some(4096));
    let mut train_buffer = TrainBuffer::new(setting.retrain_size);
    // oracle plane (batched oracle mode): micro-batch scheduler over the
    // oracle buffer, plus reusable staging/encode scratches — a steady-state
    // batch dispatch moves rows buffer → scratch → frame with no fresh
    // allocations
    let oracle_batched = setting.oracle_mode == OracleMode::Batched && !orcl.is_empty();
    let adaptive = setting.sched.policy == SchedPolicy::Adaptive;
    let mut orcl_sched =
        OracleScheduler::with_policy(&setting.oracle_batch, &setting.sched, orcl.len());
    // live registry: label oracle index i as world rank orcl[i] (no-op
    // publishes while observability is disabled)
    orcl_sched.observe_as(orcl.clone());
    // Per-label in-flight input retention, so an evicted/dead oracle's
    // input can be requeued and relabeled elsewhere (one clone per
    // dispatch); on under the adaptive policy and whenever a fault plan is
    // installed. Batched mode always retains: oracle replies are
    // labels-only (`TAG_ORACLE_LABELS`), so the dispatched block is the
    // only copy of the inputs — retention is what the ingest pairs labels
    // against and what eviction requeues.
    let retain_inflight = adaptive || ep.fault_active();
    let mut inflight_rows: HashMap<u64, RowBlock> = HashMap::new();
    // recycled input blocks: a batched dispatch moves a pooled block into
    // `inflight_rows`; ingest and eviction clear it and hand it back —
    // steady-state retention allocates nothing per batch
    let mut block_pool: Vec<RowBlock> = Vec::new();
    // per-label fault/eviction state: dead oracles (never dispatched to
    // again), timeout-evicted oracles on rejoin backoff, and the retained
    // in-flight input per oracle
    let mut oracle_down = vec![false; orcl.len()];
    let mut oracle_retry_until: Vec<Option<Instant>> = vec![None; orcl.len()];
    let mut inflight_input: Vec<Option<Payload>> = vec![None; orcl.len()];
    let mut exchange_down = false;
    let mut orcl_frame: Vec<f32> = Vec::new();
    // reusable flush-encode scratch (steady-state flushes allocate nothing)
    let mut train_pack = codec::PackBuffer::new();
    let mut last_save = Instant::now();
    let t_start = Instant::now();
    let mut losses_latest: Vec<f32> = vec![f32::NAN; train.len()];
    let mut total_epochs: u64 = 0;
    let mut stop_requested = false;
    let mut evict_noted = false;

    loop {
        let mut did_work = false;

        // --- control: rank-down notices from host supervisors — evict the
        // dead rank immediately, requeue its in-flight inputs, and note a
        // dead Exchange (no further selections will arrive) ---
        while let Some(m) = ep.try_recv(Src::Any, TAG_RANK_DOWN) {
            did_work = true;
            tel.bump("rank_down_notices");
            registry().inc(Counter::RankDownNotices);
            let Some(rank) = m.data.first().map(|&f| f as usize) else {
                continue;
            };
            if rank == crate::config::topology::EXCHANGE {
                exchange_down = true;
            } else if let Some(i) = orcl.iter().position(|&r| r == rank) {
                if oracle_batched {
                    evict_dead_oracle(
                        &mut orcl_sched,
                        &mut inflight_rows,
                        &mut block_pool,
                        &mut orcl_buffer,
                        &mut dispatched_total,
                        &mut tel,
                        i,
                        Instant::now(),
                    );
                } else if !oracle_down[i] {
                    tel.bump("oracle_evictions");
                    registry().inc(Counter::OracleEvictions);
                    crate::telemetry::trace::sink().instant(ep.rank(), "evict", rank as u64);
                    oracle_down[i] = true;
                    let was_busy = std::mem::replace(&mut oracle_busy[i], false);
                    busy_since[i] = None;
                    oracle_retry_until[i] = None;
                    if let Some(p) = inflight_input[i].take() {
                        orcl_buffer.push_row(&p);
                        dispatched_total = dispatched_total.saturating_sub(1);
                        tel.bump("requeued_inputs");
                        registry().inc(Counter::RequeuedInputs);
                    } else if was_busy {
                        // input was not retained: lost with the host —
                        // release its budget headroom, record the loss
                        dispatched_total = dispatched_total.saturating_sub(1);
                        tel.bump("lost_inputs");
                        registry().inc(Counter::LostInputs);
                    }
                }
            } else if setting.exchange_mode == ExchangeMode::Lockstep
                && (topo.gene_ranks().contains(&rank) || topo.pred_ranks().contains(&rank))
            {
                // lockstep rounds gather from every generator and every
                // prediction rank; the Exchange aborts on its own notice,
                // but if it is already blocked mid-gather on the dead peer
                // only the Manager can break the cycle — initiate shutdown
                stop_requested = true;
                tel.bump("lockstep_abort_stops");
            }
            // otherwise (trainers; batched-mode generators): nothing for
            // the Manager to evict — the Exchange owns prediction shards,
            // a dead generator just stops contributing to the red flow,
            // and flushes to a dead trainer become counted dead letters
        }

        // --- selected inputs from the Exchange (green flow in) ---
        while let Some(m) = ep.try_recv(Src::Rank(crate::config::topology::EXCHANGE), TAG_ORCL_SELECT) {
            // flat ingest: decoded row views copy straight into the oracle
            // buffer's contiguous staging storage — no per-row boxing
            if let Some(rows) = codec::unpack_views(&m.data) {
                tel.add("selected_in", rows.len() as u64);
                let any = !rows.is_empty();
                for row in rows {
                    orcl_buffer.push_row(row);
                }
                if oracle_batched && any {
                    orcl_sched.note_enqueued(Instant::now());
                }
            } else {
                tel.bump("malformed");
            }
            did_work = true;
        }

        // --- completed oracle labels (green flow back) ---
        while let Some(m) = ep.try_recv(Src::Any, TAG_ORACLE_RESULT) {
            ingest_oracle_result(
                m.src,
                &m.data,
                Instant::now(),
                &orcl,
                &mut oracle_busy,
                &mut busy_since,
                &mut oracle_retry_until,
                &mut inflight_input,
                &mut label_rtts,
                &mut train_buffer,
                &mut out,
                &mut tel,
                false,
            );
            did_work = true;
        }

        // --- completed oracle batches (green flow back, batched mode):
        // labels-only frames pair with the retained input blocks; the legacy
        // interleaved layout is still ingested for mixed-version runs ---
        while let Some(m) = ep.try_recv(Src::Any, TAG_ORACLE_LABELS) {
            ingest_oracle_labels(
                &m.data,
                Instant::now(),
                &mut orcl_sched,
                &mut inflight_rows,
                &mut block_pool,
                &mut train_buffer,
                &mut out,
                &mut tel,
                false,
            );
            did_work = true;
        }
        while let Some(m) = ep.try_recv(Src::Any, TAG_ORACLE_BATCH_RESULT) {
            ingest_oracle_batch_result(
                &m.data,
                Instant::now(),
                &mut orcl_sched,
                &mut inflight_rows,
                &mut train_buffer,
                &mut out,
                &mut tel,
                false,
            );
            did_work = true;
        }

        // --- retrain notifications ---
        while let Some(m) = ep.try_recv(Src::Any, TAG_RETRAIN_DONE) {
            out.retrain_rounds += 1;
            tel.bump("retrain_rounds");
            registry().inc(Counter::RetrainRounds);
            if let Some(i) = train.iter().position(|&r| r == m.src) {
                if let Some(&loss) = m.data.first() {
                    losses_latest[i] = loss;
                }
            }
            if let Some(&epochs) = m.data.get(1) {
                total_epochs += epochs as u64;
                tel.add("train_epochs", epochs as u64);
            }
            did_work = true;
            // dynamic oracle-list adjustment with the freshly-synced models
            if setting.dynamic_oracle_list && !orcl_buffer.is_empty() && !rescore.is_empty() {
                adjust_oracle_buffer(&mut ep, &mut *utils, &mut orcl_buffer, &rescore, setting, &mut tel);
                if oracle_batched {
                    // rescore replacements route through the scheduler: only
                    // still-queued rows were re-scored (in-flight batches are
                    // already paid for), and the dispatch clock follows the
                    // adjusted queue
                    orcl_sched.sync_queue(orcl_buffer.len(), Instant::now());
                }
            }
        }

        // --- health sweep: runs every loop pass (not just on dispatch),
        // so an idle Manager still notices a stalled or dead oracle.
        // Batched mode: evict stalled oracles (adaptive policy; a no-op
        // under static) and requeue their in-flight inputs — inputs
        // already dispatched are never lost to a dead oracle, and their
        // budget headroom is released for the re-dispatch. Per-label mode:
        // the same timeout eviction, extended to the paper-faithful path —
        // a busy oracle past `sched_timeout_ms` frees its slot, its
        // retained input requeues, and the oracle backs off for
        // `sched_rejoin_ms` (a later reply readmits it) ---
        let now = Instant::now();
        if oracle_batched {
            for ev in orcl_sched.check_health(now) {
                tel.bump("oracle_evictions");
                registry().inc(Counter::OracleEvictions);
                if let Some(rows) = inflight_rows.remove(&ev.id) {
                    for i in 0..rows.len() {
                        orcl_buffer.push_row(rows.row(i));
                    }
                    orcl_sched.note_enqueued(now);
                    dispatched_total = dispatched_total.saturating_sub(rows.len() as u64);
                    tel.add("requeued_inputs", rows.len() as u64);
                    registry().add(Counter::RequeuedInputs, rows.len() as u64);
                    recycle_block(&mut block_pool, rows);
                    did_work = true;
                }
            }
        } else if adaptive {
            if let Some(timeout) = setting.sched.timeout {
                for i in 0..orcl.len() {
                    if !oracle_busy[i] || oracle_down[i] {
                        continue;
                    }
                    let stale = busy_since[i]
                        .map_or(false, |t| now.saturating_duration_since(t) >= timeout);
                    if !stale {
                        continue;
                    }
                    tel.bump("oracle_evictions");
                    registry().inc(Counter::OracleEvictions);
                    crate::telemetry::trace::sink().instant(ep.rank(), "evict", orcl[i] as u64);
                    oracle_busy[i] = false;
                    busy_since[i] = None;
                    oracle_retry_until[i] = Some(now + setting.sched.rejoin_backoff);
                    dispatched_total = dispatched_total.saturating_sub(1);
                    if let Some(p) = inflight_input[i].take() {
                        orcl_buffer.push_row(&p);
                        tel.bump("requeued_inputs");
                        registry().inc(Counter::RequeuedInputs);
                    } else {
                        tel.bump("lost_inputs");
                        registry().inc(Counter::LostInputs);
                    }
                    did_work = true;
                }
            }
        }

        // --- dispatch buffered inputs (green flow out), bounded by the
        //     label budget when one is set ---
        if oracle_batched {
            let now = Instant::now();
            // oracle plane: coalesce queue-head rows into micro-batches,
            // routed by the configured policy (triggers/backpressure in
            // the scheduler; `dispatched` counts items in both modes)
            loop {
                let budget = label_budget.map(|max| max.saturating_sub(dispatched_total));
                if budget == Some(0) {
                    if !orcl_buffer.is_empty() {
                        tel.bump("budget_gated");
                    }
                    break;
                }
                let Some(d) = orcl_sched.try_dispatch(orcl_buffer.len(), now, budget) else {
                    break;
                };
                // fill a pooled block (moved into `inflight_rows` below —
                // no per-dispatch clone): the labels-only reply pairs
                // against these rows, so retention is unconditional
                let mut block = block_pool.pop().unwrap_or_else(RowBlock::new);
                for _ in 0..d.take {
                    let row = orcl_buffer.pop_row().expect("scheduler take within queue");
                    block.push_row(row);
                }
                encode_oracle_batch_block_into(d.id, &block, &mut orcl_frame);
                let delivered = ep.send(orcl[d.oracle], TAG_ORACLE_BATCH, &orcl_frame[..]);
                inflight_rows.insert(d.id, block);
                dispatched_total += d.take as u64;
                tel.add("dispatched", d.take as u64);
                registry().add(Counter::Dispatched, d.take as u64);
                tel.bump("oracle_batches");
                registry().inc(Counter::OracleBatches);
                if d.take < setting.oracle_batch.max_size {
                    tel.bump("oracle_partial_batches");
                }
                if !delivered {
                    // dead letter: the oracle's endpoint is gone — evict it
                    // now (requeues this batch and any others it held)
                    // instead of waiting for the rank-down notice
                    tel.bump("dead_letter_dispatches");
                    registry().inc(Counter::DeadLetterDispatches);
                    evict_dead_oracle(
                        &mut orcl_sched,
                        &mut inflight_rows,
                        &mut block_pool,
                        &mut orcl_buffer,
                        &mut dispatched_total,
                        &mut tel,
                        d.oracle,
                        now,
                    );
                }
                did_work = true;
            }
        } else {
            // per-label path (paper-faithful): one input to the first free
            // oracle, one message per label. Dead oracles never dispatch
            // again; timeout-evicted ones sit out their rejoin backoff.
            let now = Instant::now();
            for (i, &rank) in orcl.iter().enumerate() {
                if oracle_busy[i] || oracle_down[i] {
                    continue;
                }
                if oracle_retry_until[i].map_or(false, |t| now < t) {
                    continue;
                }
                if let Some(max) = label_budget {
                    if dispatched_total >= max {
                        tel.bump("budget_gated");
                        break;
                    }
                }
                if let Some(input) = orcl_buffer.pop_row() {
                    let sent = if retain_inflight {
                        // ingest once into a shared payload the Manager
                        // keeps a handle on, so a dying oracle's input can
                        // be requeued (same single copy as the plain send)
                        let p: Payload = input.to_vec().into();
                        ep.note_ingest(p.len());
                        let ok = ep.send(rank, TAG_TO_ORACLE, &p);
                        if ok {
                            inflight_input[i] = Some(p);
                        } else {
                            orcl_buffer.push_row(&p);
                            tel.bump("requeued_inputs");
                            registry().inc(Counter::RequeuedInputs);
                        }
                        ok
                    } else {
                        // borrowed row out of the flat buffer; the send
                        // ingests it into a shared payload (the one
                        // unavoidable copy). A failed send loses the input:
                        // counted, and headroom stays released.
                        let ok = ep.send(rank, TAG_TO_ORACLE, input);
                        if !ok {
                            tel.bump("lost_inputs");
                            registry().inc(Counter::LostInputs);
                        }
                        ok
                    };
                    if !sent {
                        // dead letter: the oracle's endpoint is gone
                        tel.bump("dead_letter_dispatches");
                        registry().inc(Counter::DeadLetterDispatches);
                        if !oracle_down[i] {
                            tel.bump("oracle_evictions");
                            registry().inc(Counter::OracleEvictions);
                            oracle_down[i] = true;
                        }
                        did_work = true;
                        continue;
                    }
                    oracle_busy[i] = true;
                    busy_since[i] = Some(Instant::now());
                    dispatched_total += 1;
                    tel.bump("dispatched");
                    registry().inc(Counter::Dispatched);
                    did_work = true;
                } else {
                    break;
                }
            }
        }

        // --- flush labeled batch to every trainer (one shared payload; the
        // flat block encodes into the reusable scratch with zero
        // steady-state allocations, wire bytes identical to the nested
        // encoder) ---
        if !train.is_empty() {
            if let Some(batch) = train_buffer.flush() {
                ep.bcast(&train, TAG_TRAIN_DATA, train_pack.pack_train_block(&batch));
                tel.bump("train_flushes");
                tel.add("train_points", batch.len() as u64);
                did_work = true;
            }
        }

        // --- live gauges: overwritten once per loop pass (each a single
        // relaxed load + branch while observability is disabled) ---
        registry().gauge_set(Gauge::OracleQueueDepth, orcl_buffer.len() as u64);
        registry().gauge_set(Gauge::TrainBufferDepth, train_buffer.len() as u64);
        registry().gauge_set(Gauge::OracleInFlight, orcl_sched.in_flight() as u64);
        registry().gauge_set(Gauge::OracleInFlightItems, orcl_sched.in_flight_items() as u64);

        // --- progress snapshot ---
        if last_save.elapsed() >= setting.progress_save_interval {
            save_progress(setting, &tel, &out, orcl_buffer.len(), train_buffer.len());
            last_save = Instant::now();
        }

        // --- stop requests from any kernel (checked after dispatch so the
        // final round of selected inputs reaches the oracles; their results
        // are collected by the bounded drain below) ---
        if ep.try_recv(Src::Any, TAG_STOP).is_some() {
            tel.bump("stop_requests");
            stop_requested = true;
        }
        if exchange_down {
            // no further selections can arrive; everything already queued
            // was dispatched above, in-flight labels are collected by the
            // bounded drain — finish degraded instead of polling forever
            stop_requested = true;
            tel.bump("exchange_down_stops");
        }
        if !orcl.is_empty() {
            let all_down = if oracle_batched {
                (0..orcl.len()).all(|i| orcl_sched.is_down(i))
            } else {
                oracle_down.iter().all(|&d| d)
            };
            if all_down {
                // nobody left to label: the budget is unreachable — finish
                // degraded with the labels already earned
                stop_requested = true;
                tel.bump("all_oracles_down_stops");
            }
        }
        if let Some(max) = setting.stop.max_labels {
            if out.oracle_labels >= max
                && out.retrain_rounds >= setting.stop.min_retrain_rounds
                && total_epochs >= setting.stop.min_train_epochs
            {
                stop_requested = true;
            }
        }
        if let Some(max_wall) = setting.stop.max_wall {
            // grace factor: the Exchange enforces its own wall limit; the
            // Manager is the backstop in case Exchange is blocked
            if t_start.elapsed() >= max_wall + Duration::from_secs(5) {
                stop_requested = true;
                tel.bump("wall_backstop");
            }
        }
        // time-to-evict for the fault bench: run start → first oracle
        // eviction, whichever path detected it (notice, dead letter, health)
        if !evict_noted && tel.counter("oracle_evictions") > 0 {
            tel.record("time_to_first_evict", t_start.elapsed());
            evict_noted = true;
        }
        if stop_requested {
            break;
        }

        if !did_work {
            std::thread::sleep(setting.poll_interval);
        }
    }

    // --- bounded drain: don't discard labels already paid for (a DFT hour
    // that finished during shutdown must land in the training buffer). The
    // bound scales with the observed p95 oracle latency (`sched_drain_factor
    // × p95`, floored at 300 ms) instead of assuming a fixed 300 ms covers
    // every pool; per-label mode waits on busy oracles, batched mode on
    // in-flight batches ---
    let drain_base = Duration::from_millis(300);
    let drain_bound = if oracle_batched {
        orcl_sched.drain_bound(drain_base)
    } else {
        scaled_drain_bound(label_rtts.p95(), setting.sched.drain_factor, drain_base)
    };
    drain_oracle_results(
        &mut ep,
        &orcl,
        &mut oracle_busy,
        &mut busy_since,
        &mut oracle_retry_until,
        &mut inflight_input,
        &mut label_rtts,
        &mut orcl_sched,
        &mut inflight_rows,
        &mut block_pool,
        &mut train_buffer,
        &mut out,
        &mut tel,
        oracle_batched,
        drain_bound,
        setting.poll_interval,
    );
    // flush what we can so trainers see the drained labels before exiting
    if !train.is_empty() {
        if let Some(batch) = train_buffer.flush() {
            ep.bcast(&train, TAG_TRAIN_DATA, train_pack.pack_train_block(&batch));
            tel.bump("train_flushes");
            tel.add("train_points", batch.len() as u64);
        }
    }

    // --- shutdown fan-out: flag first (the truth), then wake every rank.
    // The empty control payload is the OnceLock-cached singleton: the whole
    // fan-out allocates nothing ---
    down.store(true, Ordering::Release);
    for r in 0..ep.world_size() {
        if r != ep.rank() {
            ep.send(r, TAG_SHUTDOWN, Payload::empty());
        }
    }
    // final drain: labels already computed should not be lost — push any
    // complete batch out before trainers exit (they poll until down)
    let rest = train_buffer.flush_all();
    if !rest.is_empty() && !train.is_empty() {
        tel.add("train_points_dropped", rest.len() as u64);
    }
    save_progress(setting, &tel, &out, orcl_buffer.len(), 0);

    out.losses = losses_latest;
    (tel, out)
}

/// Shutdown drain: ingest oracle results still in flight, bounded by
/// `bound`. The receive is *vectored* — every ready frame lands per pass
/// ([`Endpoint::recv_ready_all`]), so a burst of completions arriving
/// together is fully ingested before the wait condition is re-checked. The
/// old loop took at most one frame per tag per pass with a sleep in
/// between, so clearing the last busy flag ended the drain with ready
/// results still parked in the mailbox — labels paid for and thrown away.
#[allow(clippy::too_many_arguments)]
fn drain_oracle_results(
    ep: &mut Endpoint,
    orcl: &[usize],
    oracle_busy: &mut [bool],
    busy_since: &mut [Option<Instant>],
    oracle_retry_until: &mut [Option<Instant>],
    inflight_input: &mut [Option<Payload>],
    label_rtts: &mut LatencyWindow,
    orcl_sched: &mut OracleScheduler,
    inflight_rows: &mut HashMap<u64, RowBlock>,
    block_pool: &mut Vec<RowBlock>,
    train_buffer: &mut TrainBuffer,
    out: &mut ManagerOutcome,
    tel: &mut KernelTelemetry,
    oracle_batched: bool,
    bound: Duration,
    poll: Duration,
) {
    let deadline = Instant::now() + bound;
    loop {
        let waiting = if oracle_batched {
            orcl_sched.in_flight() > 0
        } else {
            oracle_busy.iter().any(|&b| b)
        };
        if !waiting || Instant::now() >= deadline {
            break;
        }
        // a rank-down notice mid-drain frees the dead host's slots so the
        // drain is not pinned open waiting on replies that can never come
        while let Some(m) = ep.try_recv(Src::Any, TAG_RANK_DOWN) {
            tel.bump("rank_down_notices");
            registry().inc(Counter::RankDownNotices);
            let Some(rank) = m.data.first().map(|&f| f as usize) else {
                continue;
            };
            if let Some(i) = orcl.iter().position(|&r| r == rank) {
                if oracle_batched {
                    for ev in orcl_sched.mark_down(i, Instant::now()) {
                        tel.bump("oracle_evictions");
                        registry().inc(Counter::OracleEvictions);
                        // the run is ending: nothing re-dispatches, so the
                        // dead host's in-flight inputs are honestly lost
                        if let Some(rows) = inflight_rows.remove(&ev.id) {
                            recycle_block(block_pool, rows);
                        }
                        tel.add("lost_inputs", ev.items as u64);
                        registry().add(Counter::LostInputs, ev.items as u64);
                    }
                } else {
                    oracle_busy[i] = false;
                    busy_since[i] = None;
                    if inflight_input[i].take().is_some() {
                        tel.bump("lost_inputs");
                        registry().inc(Counter::LostInputs);
                    }
                }
            }
        }
        let mut got = false;
        for m in ep.recv_ready_all(Src::Any, TAG_ORACLE_RESULT) {
            ingest_oracle_result(
                m.src,
                &m.data,
                Instant::now(),
                orcl,
                oracle_busy,
                busy_since,
                oracle_retry_until,
                inflight_input,
                label_rtts,
                train_buffer,
                out,
                tel,
                true,
            );
            got = true;
        }
        for m in ep.recv_ready_all(Src::Any, TAG_ORACLE_LABELS) {
            ingest_oracle_labels(
                &m.data,
                Instant::now(),
                orcl_sched,
                inflight_rows,
                block_pool,
                train_buffer,
                out,
                tel,
                true,
            );
            got = true;
        }
        for m in ep.recv_ready_all(Src::Any, TAG_ORACLE_BATCH_RESULT) {
            ingest_oracle_batch_result(
                &m.data,
                Instant::now(),
                orcl_sched,
                inflight_rows,
                train_buffer,
                out,
                tel,
                true,
            );
            got = true;
        }
        if !got {
            std::thread::sleep(poll);
        }
    }
}

/// Re-score the oracle buffer with the prediction committee and let the
/// user's `adjust_input_for_oracle` reorder/prune it (SI Utilities,
/// `dynamic_orcale_list`).
///
/// Flat path: the buffer drains into one contiguous
/// [`crate::data::batch::RowBlock`], the
/// request packs with a single `memcpy`, and when every committee reply
/// decodes as a uniform strided view the batch-typed
/// `adjust_input_for_oracle_batch` hook re-scores without materializing a
/// nested `Vec` anywhere; the adjusted block refills the buffer row by
/// row. Ragged traffic (or a custom nested-only `Utils`: the default batch
/// hook shims through the nested one, behaving identically) falls back to
/// the legacy nested reduction.
fn adjust_oracle_buffer(
    ep: &mut Endpoint,
    utils: &mut dyn Utils,
    buffer: &mut OracleBuffer,
    pred: &[usize],
    setting: &AlSetting,
    tel: &mut KernelTelemetry,
) {
    let inputs = buffer.drain_block();
    // one shared request payload for the whole committee
    let mut pack = codec::PackBuffer::new();
    ep.bcast(pred, TAG_RESCORE_REQ, pack.pack_row_block(&inputs));
    // bounded wait: predictors are serving the hot loop; if they cannot
    // answer quickly, skip the adjustment rather than stall labeling
    let deadline = Duration::from_millis(500).max(setting.poll_interval * 50);
    let packed_preds = match ep.gather(pred, TAG_RESCORE_RESP, deadline) {
        Ok(p) => p,
        Err(_) => {
            tel.bump("adjust_timeouts");
            buffer.replace_block(&inputs);
            return;
        }
    };
    // flat fast path: uniform input block + uniform equal-width replies
    // re-score as strided views straight over the received payloads
    if let Some(input_view) = inputs.as_view() {
        let mut views = Vec::with_capacity(packed_preds.len());
        let mut flat_ok = true;
        for p in &packed_preds {
            match codec::unpack_batch_view(p) {
                Some(v) if v.rows() == inputs.len() => views.push(v),
                _ => {
                    flat_ok = false;
                    break;
                }
            }
        }
        flat_ok = flat_ok && views.windows(2).all(|w| w[0].width() == w[1].width());
        if flat_ok {
            let before = inputs.len();
            let adjusted = utils.adjust_input_for_oracle_batch(&input_view, &views);
            tel.add("adjusted_dropped", (before - adjusted.len().min(before)) as u64);
            tel.bump("adjustments");
            buffer.replace_block(&adjusted);
            return;
        }
    }
    // ragged fallback: legacy nested decode + adjustment
    let nested_inputs = inputs.to_nested();
    let mut preds_per_model = Vec::with_capacity(packed_preds.len());
    for p in &packed_preds {
        match codec::unpack(p) {
            Some(list) if list.len() == nested_inputs.len() => preds_per_model.push(list),
            _ => {
                tel.bump("malformed");
                buffer.replace_block(&inputs);
                return;
            }
        }
    }
    let before = nested_inputs.len();
    let adjusted = utils.adjust_input_for_oracle(nested_inputs, &preds_per_model);
    tel.add("adjusted_dropped", (before - adjusted.len().min(before)) as u64);
    tel.bump("adjustments");
    buffer.replace(adjusted);
}

fn save_progress(
    setting: &AlSetting,
    tel: &KernelTelemetry,
    out: &ManagerOutcome,
    orcl_buffered: usize,
    train_buffered: usize,
) {
    let dir = std::path::Path::new(&setting.result_dir);
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let snapshot = obj(vec![
        ("oracle_labels", Value::Num(out.oracle_labels as f64)),
        ("retrain_rounds", Value::Num(out.retrain_rounds as f64)),
        ("oracle_buffered", Value::Num(orcl_buffered as f64)),
        ("train_buffered", Value::Num(train_buffered as f64)),
        ("manager", tel.to_json()),
        ("setting", setting.to_json()),
    ]);
    let _ = std::fs::write(dir.join("progress.json"), crate::json::to_string(&snapshot));
}

#[cfg(test)]
mod tests {
    //! Shutdown-drain pins: the vectored drain must ingest every parked
    //! result (the old one-frame-per-pass loop could exit with paid-for
    //! labels still in the mailbox) and account for bad frames instead of
    //! silently discarding them.
    use super::*;
    use crate::comm::bus::World;
    use crate::config::BatchSetting;

    #[test]
    fn drain_ingests_all_parked_results_and_counts_bad_frames() {
        let mut world = World::new(4);
        let mut eps = world.endpoints();
        let mut other = eps.pop().unwrap(); // rank 3: not an oracle
        let mut orcl2 = eps.pop().unwrap(); // rank 2
        let mut orcl1 = eps.pop().unwrap(); // rank 1
        let mut mgr = eps.pop().unwrap(); // rank 0: the Manager
        let orcl = vec![1usize, 2];
        // park 4 good results (2 per oracle), one malformed frame, and one
        // well-formed frame from a non-oracle rank — all ready before the
        // drain starts
        for (k, ep) in [&mut orcl1, &mut orcl2].into_iter().enumerate() {
            for v in [1.0f32, 2.0] {
                let x = v + k as f32 * 10.0;
                let (input, label) = ([x, x], [x * 10.0]);
                ep.send(0, TAG_ORACLE_RESULT, codec::pack(&[&input[..], &label[..]]));
            }
        }
        orcl1.send(0, TAG_ORACLE_RESULT, [3.0f32, 9.9].as_slice()); // truncated header
        other.send(0, TAG_ORACLE_RESULT, codec::pack(&[&[7.0f32, 7.0][..], &[70.0f32][..]]));

        let t0 = Instant::now();
        let mut oracle_busy = vec![true, true];
        let mut busy_since = vec![Some(t0), Some(t0)];
        let mut oracle_retry_until = vec![None, None];
        let mut inflight_input = vec![None, None];
        let mut label_rtts = LatencyWindow::default();
        let mut orcl_sched = OracleScheduler::new(&BatchSetting::default(), orcl.len());
        let mut inflight_rows = HashMap::new();
        let mut block_pool = Vec::new();
        let mut train_buffer = TrainBuffer::new(100);
        let mut out = ManagerOutcome::default();
        let mut tel = KernelTelemetry::new("manager", 0);
        drain_oracle_results(
            &mut mgr,
            &orcl,
            &mut oracle_busy,
            &mut busy_since,
            &mut oracle_retry_until,
            &mut inflight_input,
            &mut label_rtts,
            &mut orcl_sched,
            &mut inflight_rows,
            &mut block_pool,
            &mut train_buffer,
            &mut out,
            &mut tel,
            false,
            Duration::from_millis(300),
            Duration::from_millis(1),
        );
        // every parked label lands — including the unknown-rank one (it was
        // paid for) — even though the first pass clears both busy flags
        assert_eq!(train_buffer.len(), 5, "all parked labels staged, none starved");
        assert_eq!(out.oracle_labels, 5);
        assert_eq!(tel.counter("drained_labels"), 5);
        assert_eq!(tel.counter("malformed"), 1);
        assert_eq!(tel.counter("bad_frames"), 2, "1 malformed + 1 unknown-rank sender");
        assert!(oracle_busy.iter().all(|&b| !b), "busy flags cleared");
        assert_eq!(label_rtts.len(), 2, "one RTT per oracle's first drained result");
    }

    #[test]
    fn drain_frees_batched_slots_and_stages_pairs() {
        let mut world = World::new(2);
        let mut eps = world.endpoints();
        let mut orcl1 = eps.pop().unwrap();
        let mut mgr = eps.pop().unwrap();
        let batch = BatchSetting { max_size: 2, ..Default::default() };
        let mut orcl_sched = OracleScheduler::new(&batch, 1);
        let t0 = Instant::now();
        orcl_sched.note_enqueued(t0);
        let d = orcl_sched.try_dispatch(2, t0, None).expect("size trigger");
        assert_eq!(d.take, 2);
        // the oracle's reply is already parked when the drain starts
        let inputs: [&[f32]; 2] = [&[1.0, 2.0], &[3.0, 4.0]];
        let mut labels = RowBlock::new();
        labels.push_row(&[10.0]);
        labels.push_row(&[30.0]);
        let mut frame = Vec::new();
        encode_oracle_batch_result_into(d.id, &inputs, &labels, &mut frame);
        orcl1.send(0, TAG_ORACLE_BATCH_RESULT, frame);

        let mut oracle_busy = vec![false];
        let mut busy_since = vec![None];
        let mut oracle_retry_until = vec![None];
        let mut inflight_input = vec![None];
        let mut label_rtts = LatencyWindow::default();
        let mut inflight_rows = HashMap::new();
        let mut block_pool = Vec::new();
        let mut train_buffer = TrainBuffer::new(100);
        let mut out = ManagerOutcome::default();
        let mut tel = KernelTelemetry::new("manager", 0);
        drain_oracle_results(
            &mut mgr,
            &[1],
            &mut oracle_busy,
            &mut busy_since,
            &mut oracle_retry_until,
            &mut inflight_input,
            &mut label_rtts,
            &mut orcl_sched,
            &mut inflight_rows,
            &mut block_pool,
            &mut train_buffer,
            &mut out,
            &mut tel,
            true,
            Duration::from_millis(300),
            Duration::from_millis(1),
        );
        assert_eq!(orcl_sched.in_flight(), 0, "slot freed by the drained result");
        assert_eq!(train_buffer.len(), 2);
        assert_eq!(out.oracle_labels, 2);
        assert_eq!(tel.counter("drained_labels"), 2);
        assert!(orcl_sched.rtt_p95().is_some(), "drained completion feeds the RTT window");
    }

    #[test]
    fn drain_pairs_labels_only_results_with_retained_inputs() {
        let mut world = World::new(2);
        let mut eps = world.endpoints();
        let mut orcl1 = eps.pop().unwrap();
        let mut mgr = eps.pop().unwrap();
        let batch = BatchSetting { max_size: 2, ..Default::default() };
        let mut orcl_sched = OracleScheduler::new(&batch, 1);
        let t0 = Instant::now();
        orcl_sched.note_enqueued(t0);
        let d = orcl_sched.try_dispatch(2, t0, None).expect("size trigger");
        assert_eq!(d.take, 2);
        // the Manager retained the dispatched inputs; the oracle's
        // labels-only reply is already parked when the drain starts
        let mut retained = RowBlock::new();
        retained.push_row(&[1.0, 2.0]);
        retained.push_row(&[3.0, 4.0]);
        let mut inflight_rows = HashMap::new();
        inflight_rows.insert(d.id, retained);
        let mut labels = RowBlock::new();
        labels.push_row(&[10.0]);
        labels.push_row(&[30.0]);
        let mut frame = Vec::new();
        encode_oracle_labels_into(d.id, &labels, &mut frame);
        orcl1.send(0, TAG_ORACLE_LABELS, frame);
        // labels for an unknown batch id are orphans: counted, not paired
        let mut stray = Vec::new();
        encode_oracle_labels_into(d.id + 999, &labels, &mut stray);
        orcl1.send(0, TAG_ORACLE_LABELS, stray);

        let mut oracle_busy = vec![false];
        let mut busy_since = vec![None];
        let mut oracle_retry_until = vec![None];
        let mut inflight_input = vec![None];
        let mut label_rtts = LatencyWindow::default();
        let mut block_pool = Vec::new();
        let mut train_buffer = TrainBuffer::new(100);
        let mut out = ManagerOutcome::default();
        let mut tel = KernelTelemetry::new("manager", 0);
        drain_oracle_results(
            &mut mgr,
            &[1],
            &mut oracle_busy,
            &mut busy_since,
            &mut oracle_retry_until,
            &mut inflight_input,
            &mut label_rtts,
            &mut orcl_sched,
            &mut inflight_rows,
            &mut block_pool,
            &mut train_buffer,
            &mut out,
            &mut tel,
            true,
            Duration::from_millis(300),
            Duration::from_millis(1),
        );
        assert_eq!(orcl_sched.in_flight(), 0, "slot freed by the drained result");
        assert_eq!(train_buffer.len(), 2, "labels paired with the retained inputs");
        assert_eq!(out.oracle_labels, 2);
        assert_eq!(tel.counter("drained_labels"), 2);
        assert_eq!(tel.counter("orphan_labels"), 2, "stray-id labels counted, not staged");
        assert_eq!(tel.counter("orphan_results"), 1, "stray id had no in-flight slot");
        assert!(inflight_rows.is_empty(), "retained block released on ingest");
        assert_eq!(block_pool.len(), 1, "emptied block returned to the dispatch pool");
        let staged = train_buffer.flush_all();
        assert_eq!(staged.pair(0), (&[1.0f32, 2.0][..], &[10.0f32][..]), "row i pairs label i");
        assert_eq!(staged.pair(1), (&[3.0f32, 4.0][..], &[30.0f32][..]));
    }
}
