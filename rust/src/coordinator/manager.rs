//! Manager controller sub-kernel: buffers, oracle dispatch (per-label or
//! batched through the oracle plane), training flushes, dynamic oracle-list
//! adjustment, progress snapshots, shutdown.

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use crate::comm::bus::{Endpoint, Payload, Src};
use crate::comm::codec;
use crate::comm::protocol::*;
use crate::config::{AlSetting, OracleMode, Topology};
use crate::coordinator::buffers::{OracleBuffer, TrainBuffer};
use crate::coordinator::hosts::ShutdownFlag;
use crate::coordinator::oracle_plane::OracleScheduler;
use crate::data::batch::RowBlock;
use crate::json::{obj, Value};
use crate::kernels::Utils;
use crate::telemetry::KernelTelemetry;

/// Outcome counters the workflow report needs from the Manager.
#[derive(Debug, Default, Clone)]
pub struct ManagerOutcome {
    pub oracle_labels: u64,
    pub retrain_rounds: u64,
    pub losses: Vec<f32>,
}

/// Ingest one `TAG_ORACLE_BATCH_RESULT` frame: free the scheduler's
/// in-flight slot, stage every `(input, label)` pair into the train buffer
/// (borrowed views — constant allocations per batch, zero per label), and
/// keep the accounting identical between the main loop and the shutdown
/// drain.
fn ingest_oracle_batch_result(
    data: &Payload,
    sched: &mut OracleScheduler,
    train_buffer: &mut TrainBuffer,
    out: &mut ManagerOutcome,
    tel: &mut KernelTelemetry,
    drained: bool,
) {
    match decode_oracle_batch_result_views(data) {
        Some((id, pairs)) => {
            if sched.complete(id).is_none() {
                tel.bump("orphan_results");
            }
            out.oracle_labels += pairs.len() as u64;
            tel.add("labels", pairs.len() as u64);
            tel.bump("oracle_batch_results");
            if drained {
                tel.add("drained_labels", pairs.len() as u64);
            }
            for (x, y) in pairs.iter() {
                train_buffer.push_pair(x, y);
            }
        }
        None => tel.bump("malformed"),
    }
}

/// Run the Manager until a stop request or a stop criterion fires, then
/// fan out shutdown.
pub fn manager_host(
    mut ep: Endpoint,
    mut utils: Box<dyn Utils>,
    setting: &AlSetting,
    topo: &Topology,
    down: ShutdownFlag,
) -> (KernelTelemetry, ManagerOutcome) {
    let mut tel = KernelTelemetry::new("manager", ep.rank());
    let mut out = ManagerOutcome::default();
    let orcl = topo.orcl_ranks();
    // re-scoring needs one full committee; the first shard suffices (other
    // shards hold replicas of the same members)
    let rescore = topo.rescore_ranks();
    let train = topo.train_ranks();
    let mut oracle_busy = vec![false; orcl.len()];
    // strict label budget: never dispatch beyond stop.max_labels — oracle
    // hours past the stop criterion are wasted work, and a bounded dispatch
    // count makes the final label tally exact (the deterministic e2e test
    // relies on this)
    let label_budget = if setting.strict_label_budget { setting.stop.max_labels } else { None };
    let mut dispatched_total: u64 = 0;
    let mut orcl_buffer = OracleBuffer::new(Some(4096));
    let mut train_buffer = TrainBuffer::new(setting.retrain_size);
    // oracle plane (batched oracle mode): micro-batch scheduler over the
    // oracle buffer, plus reusable staging/encode scratches — a steady-state
    // batch dispatch moves rows buffer → scratch → frame with no fresh
    // allocations
    let oracle_batched = setting.oracle_mode == OracleMode::Batched && !orcl.is_empty();
    let mut orcl_sched = OracleScheduler::new(&setting.oracle_batch, orcl.len());
    let mut batch_scratch = RowBlock::new();
    let mut orcl_frame: Vec<f32> = Vec::new();
    // reusable flush-encode scratch (steady-state flushes allocate nothing)
    let mut train_pack = codec::PackBuffer::new();
    let mut last_save = Instant::now();
    let t_start = Instant::now();
    let mut losses_latest: Vec<f32> = vec![f32::NAN; train.len()];
    let mut total_epochs: u64 = 0;
    let mut stop_requested = false;

    loop {
        let mut did_work = false;

        // --- selected inputs from the Exchange (green flow in) ---
        while let Some(m) = ep.try_recv(Src::Rank(crate::config::topology::EXCHANGE), TAG_ORCL_SELECT) {
            // flat ingest: decoded row views copy straight into the oracle
            // buffer's contiguous staging storage — no per-row boxing
            if let Some(rows) = codec::unpack_views(&m.data) {
                tel.add("selected_in", rows.len() as u64);
                let any = !rows.is_empty();
                for row in rows {
                    orcl_buffer.push_row(row);
                }
                if oracle_batched && any {
                    orcl_sched.note_enqueued(Instant::now());
                }
            } else {
                tel.bump("malformed");
            }
            did_work = true;
        }

        // --- completed oracle labels (green flow back) ---
        while let Some(m) = ep.try_recv(Src::Any, TAG_ORACLE_RESULT) {
            if let Some(i) = orcl.iter().position(|&r| r == m.src) {
                oracle_busy[i] = false;
            }
            // flat ingest: the (input, label) views copy straight from the
            // decoded payload into the train buffer's contiguous block —
            // no per-sample (Vec, Vec) boxing
            match codec::unpack_views(&m.data) {
                Some(parts) if parts.len() == 2 => {
                    out.oracle_labels += 1;
                    tel.bump("labels");
                    train_buffer.push_pair(parts[0], parts[1]);
                }
                _ => tel.bump("malformed"),
            }
            did_work = true;
        }

        // --- completed oracle batches (green flow back, batched mode) ---
        while let Some(m) = ep.try_recv(Src::Any, TAG_ORACLE_BATCH_RESULT) {
            ingest_oracle_batch_result(
                &m.data,
                &mut orcl_sched,
                &mut train_buffer,
                &mut out,
                &mut tel,
                false,
            );
            did_work = true;
        }

        // --- retrain notifications ---
        while let Some(m) = ep.try_recv(Src::Any, TAG_RETRAIN_DONE) {
            out.retrain_rounds += 1;
            tel.bump("retrain_rounds");
            if let Some(i) = train.iter().position(|&r| r == m.src) {
                if let Some(&loss) = m.data.first() {
                    losses_latest[i] = loss;
                }
            }
            if let Some(&epochs) = m.data.get(1) {
                total_epochs += epochs as u64;
                tel.add("train_epochs", epochs as u64);
            }
            did_work = true;
            // dynamic oracle-list adjustment with the freshly-synced models
            if setting.dynamic_oracle_list && !orcl_buffer.is_empty() && !rescore.is_empty() {
                adjust_oracle_buffer(&mut ep, &mut *utils, &mut orcl_buffer, &rescore, setting, &mut tel);
                if oracle_batched {
                    // rescore replacements route through the scheduler: only
                    // still-queued rows were re-scored (in-flight batches are
                    // already paid for), and the dispatch clock follows the
                    // adjusted queue
                    orcl_sched.sync_queue(orcl_buffer.len(), Instant::now());
                }
            }
        }

        // --- dispatch buffered inputs (green flow out), bounded by the
        //     label budget when one is set ---
        if oracle_batched {
            // oracle plane: coalesce queue-head rows into micro-batches,
            // routed to the least-loaded oracle (triggers/backpressure in
            // the scheduler; `dispatched` counts items in both modes)
            let now = Instant::now();
            loop {
                let budget = label_budget.map(|max| max.saturating_sub(dispatched_total));
                if budget == Some(0) {
                    if !orcl_buffer.is_empty() {
                        tel.bump("budget_gated");
                    }
                    break;
                }
                let Some(d) = orcl_sched.try_dispatch(orcl_buffer.len(), now, budget) else {
                    break;
                };
                batch_scratch.clear();
                for _ in 0..d.take {
                    let row = orcl_buffer.pop_row().expect("scheduler take within queue");
                    batch_scratch.push_row(row);
                }
                encode_oracle_batch_block_into(d.id, &batch_scratch, &mut orcl_frame);
                ep.send(orcl[d.oracle], TAG_ORACLE_BATCH, &orcl_frame[..]);
                dispatched_total += d.take as u64;
                tel.add("dispatched", d.take as u64);
                tel.bump("oracle_batches");
                if d.take < setting.oracle_batch.max_size {
                    tel.bump("oracle_partial_batches");
                }
                did_work = true;
            }
        } else {
            // per-label path (paper-faithful): one input to the first free
            // oracle, one message per label
            for (i, &rank) in orcl.iter().enumerate() {
                if oracle_busy[i] {
                    continue;
                }
                if let Some(max) = label_budget {
                    if dispatched_total >= max {
                        tel.bump("budget_gated");
                        break;
                    }
                }
                if let Some(input) = orcl_buffer.pop_row() {
                    // borrowed row out of the flat buffer; the send ingests
                    // it into a shared payload (the one unavoidable copy)
                    ep.send(rank, TAG_TO_ORACLE, input);
                    oracle_busy[i] = true;
                    dispatched_total += 1;
                    tel.bump("dispatched");
                    did_work = true;
                } else {
                    break;
                }
            }
        }

        // --- flush labeled batch to every trainer (one shared payload; the
        // flat block encodes into the reusable scratch with zero
        // steady-state allocations, wire bytes identical to the nested
        // encoder) ---
        if !train.is_empty() {
            if let Some(batch) = train_buffer.flush() {
                ep.bcast(&train, TAG_TRAIN_DATA, train_pack.pack_train_block(&batch));
                tel.bump("train_flushes");
                tel.add("train_points", batch.len() as u64);
                did_work = true;
            }
        }

        // --- progress snapshot ---
        if last_save.elapsed() >= setting.progress_save_interval {
            save_progress(setting, &tel, &out, orcl_buffer.len(), train_buffer.len());
            last_save = Instant::now();
        }

        // --- stop requests from any kernel (checked after dispatch so the
        // final round of selected inputs reaches the oracles; their results
        // are collected by the bounded drain below) ---
        if ep.try_recv(Src::Any, TAG_STOP).is_some() {
            tel.bump("stop_requests");
            stop_requested = true;
        }
        if let Some(max) = setting.stop.max_labels {
            if out.oracle_labels >= max
                && out.retrain_rounds >= setting.stop.min_retrain_rounds
                && total_epochs >= setting.stop.min_train_epochs
            {
                stop_requested = true;
            }
        }
        if let Some(max_wall) = setting.stop.max_wall {
            // grace factor: the Exchange enforces its own wall limit; the
            // Manager is the backstop in case Exchange is blocked
            if t_start.elapsed() >= max_wall + Duration::from_secs(5) {
                stop_requested = true;
                tel.bump("wall_backstop");
            }
        }
        if stop_requested {
            break;
        }

        if !did_work {
            std::thread::sleep(setting.poll_interval);
        }
    }

    // --- bounded drain: don't discard labels already paid for (a DFT hour
    // that finished during shutdown must land in the training buffer).
    // Per-label mode waits on busy oracles; batched mode on in-flight
    // batches ---
    let drain_deadline = Instant::now() + Duration::from_millis(300);
    loop {
        let waiting = if oracle_batched {
            orcl_sched.in_flight() > 0
        } else {
            oracle_busy.iter().any(|&b| b)
        };
        if !waiting || Instant::now() >= drain_deadline {
            break;
        }
        let mut got = false;
        if let Some(m) = ep.try_recv(Src::Any, TAG_ORACLE_RESULT) {
            if let Some(i) = orcl.iter().position(|&r| r == m.src) {
                oracle_busy[i] = false;
            }
            if let Some(parts) = codec::unpack_views(&m.data) {
                if parts.len() == 2 {
                    out.oracle_labels += 1;
                    tel.bump("labels");
                    tel.bump("drained_labels");
                    train_buffer.push_pair(parts[0], parts[1]);
                }
            }
            got = true;
        }
        if let Some(m) = ep.try_recv(Src::Any, TAG_ORACLE_BATCH_RESULT) {
            ingest_oracle_batch_result(
                &m.data,
                &mut orcl_sched,
                &mut train_buffer,
                &mut out,
                &mut tel,
                true,
            );
            got = true;
        }
        if !got {
            std::thread::sleep(setting.poll_interval);
        }
    }
    // flush what we can so trainers see the drained labels before exiting
    if !train.is_empty() {
        if let Some(batch) = train_buffer.flush() {
            ep.bcast(&train, TAG_TRAIN_DATA, train_pack.pack_train_block(&batch));
            tel.bump("train_flushes");
            tel.add("train_points", batch.len() as u64);
        }
    }

    // --- shutdown fan-out: flag first (the truth), then wake every rank.
    // The empty control payload is the OnceLock-cached singleton: the whole
    // fan-out allocates nothing ---
    down.store(true, Ordering::Release);
    for r in 0..ep.world_size() {
        if r != ep.rank() {
            ep.send(r, TAG_SHUTDOWN, Payload::empty());
        }
    }
    // final drain: labels already computed should not be lost — push any
    // complete batch out before trainers exit (they poll until down)
    let rest = train_buffer.flush_all();
    if !rest.is_empty() && !train.is_empty() {
        tel.add("train_points_dropped", rest.len() as u64);
    }
    save_progress(setting, &tel, &out, orcl_buffer.len(), 0);

    out.losses = losses_latest;
    (tel, out)
}

/// Re-score the oracle buffer with the prediction committee and let the
/// user's `adjust_input_for_oracle` reorder/prune it (SI Utilities,
/// `dynamic_orcale_list`).
///
/// Flat path: the buffer drains into one contiguous
/// [`crate::data::batch::RowBlock`], the
/// request packs with a single `memcpy`, and when every committee reply
/// decodes as a uniform strided view the batch-typed
/// `adjust_input_for_oracle_batch` hook re-scores without materializing a
/// nested `Vec` anywhere; the adjusted block refills the buffer row by
/// row. Ragged traffic (or a custom nested-only `Utils`: the default batch
/// hook shims through the nested one, behaving identically) falls back to
/// the legacy nested reduction.
fn adjust_oracle_buffer(
    ep: &mut Endpoint,
    utils: &mut dyn Utils,
    buffer: &mut OracleBuffer,
    pred: &[usize],
    setting: &AlSetting,
    tel: &mut KernelTelemetry,
) {
    let inputs = buffer.drain_block();
    // one shared request payload for the whole committee
    let mut pack = codec::PackBuffer::new();
    ep.bcast(pred, TAG_RESCORE_REQ, pack.pack_row_block(&inputs));
    // bounded wait: predictors are serving the hot loop; if they cannot
    // answer quickly, skip the adjustment rather than stall labeling
    let deadline = Duration::from_millis(500).max(setting.poll_interval * 50);
    let packed_preds = match ep.gather(pred, TAG_RESCORE_RESP, deadline) {
        Ok(p) => p,
        Err(_) => {
            tel.bump("adjust_timeouts");
            buffer.replace_block(&inputs);
            return;
        }
    };
    // flat fast path: uniform input block + uniform equal-width replies
    // re-score as strided views straight over the received payloads
    if let Some(input_view) = inputs.as_view() {
        let mut views = Vec::with_capacity(packed_preds.len());
        let mut flat_ok = true;
        for p in &packed_preds {
            match codec::unpack_batch_view(p) {
                Some(v) if v.rows() == inputs.len() => views.push(v),
                _ => {
                    flat_ok = false;
                    break;
                }
            }
        }
        flat_ok = flat_ok && views.windows(2).all(|w| w[0].width() == w[1].width());
        if flat_ok {
            let before = inputs.len();
            let adjusted = utils.adjust_input_for_oracle_batch(&input_view, &views);
            tel.add("adjusted_dropped", (before - adjusted.len().min(before)) as u64);
            tel.bump("adjustments");
            buffer.replace_block(&adjusted);
            return;
        }
    }
    // ragged fallback: legacy nested decode + adjustment
    let nested_inputs = inputs.to_nested();
    let mut preds_per_model = Vec::with_capacity(packed_preds.len());
    for p in &packed_preds {
        match codec::unpack(p) {
            Some(list) if list.len() == nested_inputs.len() => preds_per_model.push(list),
            _ => {
                tel.bump("malformed");
                buffer.replace_block(&inputs);
                return;
            }
        }
    }
    let before = nested_inputs.len();
    let adjusted = utils.adjust_input_for_oracle(nested_inputs, &preds_per_model);
    tel.add("adjusted_dropped", (before - adjusted.len().min(before)) as u64);
    tel.bump("adjustments");
    buffer.replace(adjusted);
}

fn save_progress(
    setting: &AlSetting,
    tel: &KernelTelemetry,
    out: &ManagerOutcome,
    orcl_buffered: usize,
    train_buffered: usize,
) {
    let dir = std::path::Path::new(&setting.result_dir);
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let snapshot = obj(vec![
        ("oracle_labels", Value::Num(out.oracle_labels as f64)),
        ("retrain_rounds", Value::Num(out.retrain_rounds as f64)),
        ("oracle_buffered", Value::Num(orcl_buffered as f64)),
        ("train_buffered", Value::Num(train_buffered as f64)),
        ("manager", tel.to_json()),
        ("setting", setting.to_json()),
    ]);
    let _ = std::fs::write(dir.join("progress.json"), crate::json::to_string(&snapshot));
}
