//! Kernel host loops: one per rank, each owning its kernel object and its
//! [`crate::comm::Endpoint`]. All blocking waits poll the shared shutdown
//! flag so the drain discipline can never deadlock.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::comm::bus::{Endpoint, Message, Payload, Src};
use crate::comm::codec::{self, PackBuffer};
use crate::comm::protocol::*;
use crate::config::{AlSetting, Topology};
use crate::kernels::{Generator, Mode, Model, Oracle};
use crate::telemetry::KernelTelemetry;

/// Shared run flag; `true` once the Manager initiates shutdown.
pub type ShutdownFlag = Arc<AtomicBool>;

pub fn is_down(f: &ShutdownFlag) -> bool {
    f.load(Ordering::Acquire)
}

/// Blocking receive that polls the shutdown flag. `None` = shutting down.
pub fn recv_poll(
    ep: &mut Endpoint,
    src: Src,
    tag: u32,
    down: &ShutdownFlag,
    poll: Duration,
) -> Option<Message> {
    loop {
        if is_down(down) {
            return None;
        }
        match ep.recv_timeout(src, tag, poll) {
            Ok(m) => return Some(m),
            Err(crate::comm::RecvError::Timeout) => continue,
            Err(crate::comm::RecvError::Disconnected) => return None,
        }
    }
}

/// Ordered gather (one message per `srcs` entry) polling shutdown.
/// Payloads come back shared (zero-copy), ordered like `srcs`.
pub fn gather_poll(
    ep: &mut Endpoint,
    srcs: &[usize],
    tag: u32,
    down: &ShutdownFlag,
    poll: Duration,
) -> Option<Vec<Payload>> {
    let mut slots: Vec<Option<Payload>> = vec![None; srcs.len()];
    let mut remaining = srcs.len();
    while remaining > 0 {
        let m = recv_poll(ep, Src::Any, tag, down, poll)?;
        if let Some(i) = srcs.iter().position(|&s| s == m.src) {
            if slots[i].is_none() {
                slots[i] = Some(m.data);
                remaining -= 1;
            }
        }
    }
    Some(slots.into_iter().map(|s| s.unwrap()).collect())
}

// ---------------------------------------------------------------------------
// Generator host (SI §S6)
// ---------------------------------------------------------------------------

/// Drive one generator process: `generate_new_data(None)` first, then a
/// lockstep loop of send-to-Exchange / receive-checked-prediction.
pub fn generator_host(
    mut ep: Endpoint,
    mut gen: Box<dyn Generator>,
    setting: &AlSetting,
    down: ShutdownFlag,
) -> KernelTelemetry {
    let mut tel = KernelTelemetry::new("generator", ep.rank());
    let poll = setting.poll_interval;
    // checked predictions arrive as shared payloads; hold the Arc instead of
    // copying it out — the generator reads through `as_deref`
    let mut data_to_gene: Option<Payload> = None;
    // reusable frame scratch: steady-state encoding allocates nothing
    let mut frame = Vec::new();
    loop {
        if is_down(&down) {
            break;
        }
        let (stop, data_to_pred) = tel.time("generate", || {
            gen.generate_new_data(data_to_gene.as_deref())
        });
        tel.bump("steps");
        encode_gen_into(stop, &data_to_pred, &mut frame);
        if !setting.fixed_size_data {
            // SI §S3 fixed_size_data=False: a size header precedes every
            // payload so the receiver can size its MPI buffer
            ep.send(
                crate::config::topology::EXCHANGE,
                TAG_GEN_SIZE,
                vec![frame.len() as f32],
            );
        }
        ep.send(crate::config::topology::EXCHANGE, TAG_GEN_TO_PRED, &frame[..]);
        if stop {
            tel.bump("stop_signals");
            // Exchange forwards the stop to the Manager; keep looping until
            // the shutdown flag lands so in-flight scatters drain.
        }
        match recv_poll(&mut ep, Src::Rank(crate::config::topology::EXCHANGE), TAG_GENE_IN, &down, poll) {
            Some(m) => data_to_gene = Some(m.data),
            None => break,
        }
    }
    gen.stop_run();
    tel
}

// ---------------------------------------------------------------------------
// Oracle host (SI §S7)
// ---------------------------------------------------------------------------

/// Drive one oracle process: receive inputs from the Manager, label, reply.
pub fn oracle_host(
    mut ep: Endpoint,
    mut oracle: Box<dyn Oracle>,
    setting: &AlSetting,
    down: ShutdownFlag,
) -> KernelTelemetry {
    let mut tel = KernelTelemetry::new("oracle", ep.rank());
    let poll = setting.poll_interval;
    let mut reply = PackBuffer::new();
    loop {
        let m = match recv_poll(&mut ep, Src::Rank(crate::config::topology::MANAGER), TAG_TO_ORACLE, &down, poll) {
            Some(m) => m,
            None => break,
        };
        let label = tel.time("run_calc", || oracle.run_calc(&m.data));
        tel.bump("labels");
        ep.send(
            crate::config::topology::MANAGER,
            TAG_ORACLE_RESULT,
            reply.pack(&[m.data.as_slice(), label.as_slice()]),
        );
    }
    oracle.stop_run();
    tel
}

// ---------------------------------------------------------------------------
// Prediction host (SI §S4)
// ---------------------------------------------------------------------------

/// Drive one prediction process: serve Exchange traffic (lockstep
/// broadcasts *and* batched `PredictBatch` frames — models take stacked
/// input lists either way), absorb weight pushes from the paired trainer,
/// serve Manager re-scoring requests.
pub fn prediction_host(
    mut ep: Endpoint,
    mut model: Box<dyn Model>,
    setting: &AlSetting,
    down: ShutdownFlag,
) -> KernelTelemetry {
    let mut tel = KernelTelemetry::new("prediction", ep.rank());
    let poll = setting.poll_interval;
    // reusable reply scratches (lockstep pack + batch frame encode)
    let mut reply = PackBuffer::new();
    let mut frame = Vec::new();
    loop {
        if is_down(&down) {
            break;
        }
        // newest weights win; stale updates are discarded (paper §2.1:
        // models "updated periodically by replicating weights")
        if let Some(m) = ep.recv_latest(Src::Any, TAG_WEIGHTS) {
            tel.time("update", || model.update(&m.data));
            tel.bump("weight_updates");
        }
        // manager re-scoring for dynamic_orcale_list
        if let Some(m) = ep.try_recv(Src::Rank(crate::config::topology::MANAGER), TAG_RESCORE_REQ) {
            if let Some(view) = codec::unpack_batch_view(&m.data) {
                // flat path: strided view over the request payload in,
                // contiguous rows out, packed with one memcpy
                let preds = tel.time("rescore", || model.predict_batch(&view));
                tel.bump("rescores");
                ep.send(
                    crate::config::topology::MANAGER,
                    TAG_RESCORE_RESP,
                    reply.pack_row_block(&preds),
                );
            } else if let Some(inputs) = codec::unpack(&m.data) {
                // ragged request: legacy nested path
                let preds = tel.time("rescore", || model.predict(&inputs));
                tel.bump("rescores");
                ep.send(
                    crate::config::topology::MANAGER,
                    TAG_RESCORE_RESP,
                    reply.pack(&preds),
                );
            }
        }
        // the hot path: stacked generator inputs from Exchange, as either a
        // lockstep broadcast or a sharded batch frame. Uniform-width frames
        // (the steady state) decode to a strided view with zero per-row
        // allocations and feed `predict_batch`; ragged frames fall back to
        // the nested decode + `predict`.
        match ep.recv_timeout_tags(
            Src::Rank(crate::config::topology::EXCHANGE),
            &[TAG_PRED_IN, TAG_PRED_BATCH],
            poll,
        ) {
            Ok(m) if m.tag == TAG_PRED_BATCH => {
                if let Some((id, view)) = decode_predict_batch_rows(&m.data) {
                    let preds = tel.time("predict", || model.predict_batch(&view));
                    debug_assert_eq!(preds.len(), view.rows());
                    tel.bump("batches");
                    tel.add("samples", view.rows() as u64);
                    encode_predict_batch_result_block_into(id, &preds, &mut frame);
                    ep.send(
                        crate::config::topology::EXCHANGE,
                        TAG_PRED_BATCH_RESULT,
                        &frame[..],
                    );
                } else if let Some((id, items)) = decode_predict_batch(&m.data) {
                    let preds = tel.time("predict", || model.predict(&items));
                    debug_assert_eq!(preds.len(), items.len());
                    tel.bump("batches");
                    tel.add("samples", items.len() as u64);
                    encode_predict_batch_result_into(id, &preds, &mut frame);
                    ep.send(
                        crate::config::topology::EXCHANGE,
                        TAG_PRED_BATCH_RESULT,
                        &frame[..],
                    );
                } else {
                    tel.bump("malformed");
                }
            }
            Ok(m) => {
                if let Some(view) = codec::unpack_batch_view(&m.data) {
                    let preds = tel.time("predict", || model.predict_batch(&view));
                    debug_assert_eq!(preds.len(), view.rows());
                    tel.bump("batches");
                    tel.add("samples", view.rows() as u64);
                    ep.send(
                        crate::config::topology::EXCHANGE,
                        TAG_PRED_OUT,
                        reply.pack_row_block(&preds),
                    );
                } else if let Some(inputs) = codec::unpack(&m.data) {
                    let preds = tel.time("predict", || model.predict(&inputs));
                    debug_assert_eq!(preds.len(), inputs.len());
                    tel.bump("batches");
                    tel.add("samples", inputs.len() as u64);
                    ep.send(
                        crate::config::topology::EXCHANGE,
                        TAG_PRED_OUT,
                        reply.pack(&preds),
                    );
                } else {
                    tel.bump("malformed");
                }
            }
            Err(crate::comm::RecvError::Timeout) => continue,
            Err(crate::comm::RecvError::Disconnected) => break,
        }
    }
    model.stop_run();
    tel
}

// ---------------------------------------------------------------------------
// Training host (SI §S5)
// ---------------------------------------------------------------------------

/// Drive one training process: wait for labeled batches, retrain until new
/// data or shutdown interrupts, then push weights to the paired predictor.
pub fn training_host(
    mut ep: Endpoint,
    mut model: Box<dyn Model>,
    setting: &AlSetting,
    topology: &Topology,
    down: ShutdownFlag,
) -> KernelTelemetry {
    let mut tel = KernelTelemetry::new("training", ep.rank());
    let poll = setting.poll_interval;
    // this member's replica in every prediction shard (one shard = the
    // paper's 1:1 trainer→predictor pairing; sharded mode fans out so all
    // shards serve the same committee)
    let replicas = topology.replicas_for_trainer(ep.rank());
    // initial weight sync so predictors start from the same replica; the
    // weight vector converts to shared storage once and fans out by
    // refcount — replica count does not multiply copies
    ep.bcast(&replicas, TAG_WEIGHTS, model.get_weight());
    loop {
        let m = match recv_poll(&mut ep, Src::Rank(crate::config::topology::MANAGER), TAG_TRAIN_DATA, &down, poll) {
            Some(m) => m,
            None => break,
        };
        let Some(points) = codec::unpack_datapoints(&m.data) else {
            tel.bump("malformed");
            continue;
        };
        tel.add("datapoints", points.len() as u64);
        model.add_trainingset(&points);
        // retrain, interruptible by new data / shutdown (paper §S5:
        // "checking req_data.Test() at every training epoch")
        let stop = {
            let down2 = down.clone();
            let probe_ep_interrupt = |ep: &mut Endpoint| {
                is_down(&down2) || ep.probe(Src::Rank(crate::config::topology::MANAGER), TAG_TRAIN_DATA)
            };
            let t0 = std::time::Instant::now();
            // split borrow: retrain takes the model; the closure needs the
            // endpoint. Endpoint probing is cheap and lock-free.
            let stop = model.retrain(&mut || probe_ep_interrupt(&mut ep));
            tel.record("retrain", t0.elapsed());
            stop
        };
        tel.bump("rounds");
        // one shared weight payload for every shard replica (zero-copy fan-out)
        ep.bcast(&replicas, TAG_WEIGHTS, model.get_weight());
        let loss = model.last_loss().unwrap_or(f32::NAN);
        let epochs = model.last_round_epochs() as f32;
        tel.add("epochs", epochs as u64);
        ep.send(
            crate::config::topology::MANAGER,
            TAG_RETRAIN_DONE,
            vec![loss, epochs],
        );
        model.save_progress();
        if stop {
            tel.bump("stop_signals");
            ep.send(crate::config::topology::MANAGER, TAG_STOP, Payload::empty());
        }
    }
    model.stop_run();
    tel
}

/// Construct the model for a host thread.
pub fn build_model(
    factory: &crate::kernels::ModelFactory,
    mode: Mode,
    replica: usize,
) -> Box<dyn Model> {
    factory(mode, replica)
}
