//! Kernel host loops: one per rank, each owning its kernel object and its
//! [`crate::comm::Endpoint`]. All blocking waits poll the shared shutdown
//! flag so the drain discipline can never deadlock.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::bus::{fill_gather_slots, Endpoint, Message, Payload, Src};
use crate::comm::codec::{self, PackBuffer};
use crate::comm::protocol::*;
use crate::config::{AlSetting, Topology};
use crate::kernels::{Generator, Mode, Model, Oracle};
use crate::telemetry::registry::{registry, Counter};
use crate::telemetry::{trace, KernelTelemetry};

/// Fold a model's upload-cache statistics (if its backend keeps any) into
/// the host's telemetry counters, so `RunReport::to_json` can aggregate
/// engine-level cache efficiency across kernels.
fn record_upload_stats(tel: &mut KernelTelemetry, model: &dyn Model) {
    if let Some(us) = model.upload_stats() {
        tel.add("upload_cache_hits", us.hits);
        tel.add("upload_cache_misses", us.misses);
        tel.add("upload_cache_bytes_uploaded", us.bytes_uploaded);
        tel.add("upload_cache_bytes_reused", us.bytes_reused);
    }
}

/// Shared run flag; `true` once the Manager initiates shutdown.
pub type ShutdownFlag = Arc<AtomicBool>;

pub fn is_down(f: &ShutdownFlag) -> bool {
    f.load(Ordering::Acquire)
}

/// Blocking receive that polls the shutdown flag. `None` = shutting down.
pub fn recv_poll(
    ep: &mut Endpoint,
    src: Src,
    tag: u32,
    down: &ShutdownFlag,
    poll: Duration,
) -> Option<Message> {
    loop {
        if is_down(down) {
            return None;
        }
        match ep.recv_timeout(src, tag, poll) {
            Ok(m) => return Some(m),
            Err(crate::comm::RecvError::Timeout) => continue,
            Err(crate::comm::RecvError::Disconnected) => return None,
        }
    }
}

/// Ordered gather (one message per `srcs` entry) polling shutdown.
/// Payloads come back shared (zero-copy), ordered like `srcs`.
///
/// The receive is *vectored* ([`Endpoint::recv_ready_all`]): each pass
/// drains the whole per-tag mailbox once, so a lockstep round in which
/// every generator has already sent costs one mailbox scan instead of one
/// wake-up per generator. Early next-round messages from an already-filled
/// source are deferred and reinjected at the front of the mailbox when the
/// gather completes, preserving per-(src, tag) FIFO.
///
/// An *aborted* gather (shutdown flag, world disconnect) requeues
/// everything it consumed — the filled current-round messages ahead of the
/// deferred next-round ones — so the mailbox never restarts mid-stream
/// with an early next-round message interleaved in place of a consumed
/// round (pinned by `gather_poll_requeues_consumed_round_on_shutdown`).
pub fn gather_poll(
    ep: &mut Endpoint,
    srcs: &[usize],
    tag: u32,
    down: &ShutdownFlag,
    poll: Duration,
) -> Option<Vec<Payload>> {
    let mut slots: Vec<Option<Message>> = vec![None; srcs.len()];
    let mut remaining = srcs.len();
    let mut deferred: Vec<Message> = Vec::new();
    let abort = |ep: &mut Endpoint, slots: Vec<Option<Message>>, deferred: Vec<Message>| {
        // per-(src, tag) FIFO: each source's filled round precedes its
        // deferred next rounds, which are already in arrival order
        let mut msgs: Vec<Message> = slots.into_iter().flatten().collect();
        msgs.extend(deferred);
        ep.requeue_front(tag, msgs);
    };
    while remaining > 0 {
        if is_down(down) {
            abort(ep, slots, deferred);
            return None;
        }
        let mut batch = ep.recv_ready_all(Src::Any, tag);
        if batch.is_empty() {
            match ep.recv_timeout(Src::Any, tag, poll) {
                Ok(m) => batch.push(m),
                Err(crate::comm::RecvError::Timeout) => continue,
                Err(crate::comm::RecvError::Disconnected) => {
                    abort(ep, slots, deferred);
                    return None;
                }
            }
        }
        remaining -= fill_gather_slots(batch, srcs, &mut slots, &mut deferred);
    }
    ep.requeue_front(tag, deferred);
    Some(slots.into_iter().map(|s| s.unwrap().data).collect())
}

// ---------------------------------------------------------------------------
// Generator host (SI §S6)
// ---------------------------------------------------------------------------

/// Drive one generator process: `generate_new_data(None)` first, then a
/// lockstep loop of send-to-Exchange / receive-checked-prediction.
pub fn generator_host(
    mut ep: Endpoint,
    mut gen: Box<dyn Generator>,
    setting: &AlSetting,
    down: ShutdownFlag,
) -> KernelTelemetry {
    let mut tel = KernelTelemetry::new("generator", ep.rank());
    let poll = setting.poll_interval;
    // checked predictions arrive as shared payloads; hold the Arc instead of
    // copying it out — the generator reads through `as_deref`
    let mut data_to_gene: Option<Payload> = None;
    // reusable frame scratch: steady-state encoding allocates nothing
    let mut frame = Vec::new();
    loop {
        if is_down(&down) {
            break;
        }
        let (stop, data_to_pred) = tel.time("generate", || {
            gen.generate_new_data(data_to_gene.as_deref())
        });
        tel.bump("steps");
        encode_gen_into(stop, &data_to_pred, &mut frame);
        if !setting.fixed_size_data {
            // SI §S3 fixed_size_data=False: a size header precedes every
            // payload so the receiver can size its MPI buffer
            ep.send(
                crate::config::topology::EXCHANGE,
                TAG_GEN_SIZE,
                vec![frame.len() as f32],
            );
        }
        ep.send(crate::config::topology::EXCHANGE, TAG_GEN_TO_PRED, &frame[..]);
        if stop {
            tel.bump("stop_signals");
            // Exchange forwards the stop to the Manager; keep looping until
            // the shutdown flag lands so in-flight scatters drain.
        }
        match recv_poll(&mut ep, Src::Rank(crate::config::topology::EXCHANGE), TAG_GENE_IN, &down, poll) {
            Some(m) => data_to_gene = Some(m.data),
            None => break,
        }
    }
    gen.stop_run();
    tel
}

// ---------------------------------------------------------------------------
// Oracle host (SI §S7)
// ---------------------------------------------------------------------------

/// Drive one oracle process: receive inputs from the Manager, label, reply.
///
/// Serves both green-flow dispatch legs on one loop: legacy per-label
/// messages (`TAG_TO_ORACLE` → `TAG_ORACLE_RESULT`, wire bytes unchanged)
/// and oracle-plane batch frames (`TAG_ORACLE_BATCH` →
/// `TAG_ORACLE_LABELS`, one labels-only frame per micro-batch through
/// [`Oracle::run_calc_batch`] — the Manager retained the dispatched
/// inputs, so echoing them back would be pure wire waste; result bytes
/// drop to the labels alone). The receive is *vectored*: one wake-up
/// drains every request already queued ([`Endpoint::recv_ready_all`]) and
/// processes them strictly in dispatch order; if shutdown fires mid-drain,
/// the unprocessed tail is requeued at the mailbox front — never dropped or
/// reordered — so per-(src, tag) FIFO holds for whoever drains next.
///
/// Eviction safety: the host never needs to know it was evicted by the
/// adaptive scheduler's health plane. A reply to an already-evicted batch
/// id is ingested by the Manager as an orphan (the labels were paid for)
/// and doubles as proof of life — the dispatch core readmits the oracle —
/// while the evicted inputs were requeued and relabeled elsewhere, so a
/// stalled oracle costs at most duplicate labels, never lost ones.
pub fn oracle_host(
    mut ep: Endpoint,
    mut oracle: Box<dyn Oracle>,
    setting: &AlSetting,
    down: ShutdownFlag,
) -> KernelTelemetry {
    use crate::data::batch::RowBlock;

    const MANAGER: usize = crate::config::topology::MANAGER;
    const REQ_TAGS: [u32; 2] = [TAG_TO_ORACLE, TAG_ORACLE_BATCH];
    let mut tel = KernelTelemetry::new("oracle", ep.rank());
    let poll = setting.poll_interval;
    let mut reply = PackBuffer::new();
    // reusable batch-frame scratch (steady-state replies allocate only the
    // label staging the oracle itself produces)
    let mut frame: Vec<f32> = Vec::new();
    'outer: loop {
        if is_down(&down) {
            break;
        }
        let first = match ep.recv_timeout_tags(Src::Rank(MANAGER), &REQ_TAGS, poll) {
            Ok(m) => m,
            Err(crate::comm::RecvError::Timeout) => continue,
            Err(crate::comm::RecvError::Disconnected) => break,
        };
        // vectored drain of this round's backlog (each mode uses one tag
        // per run, so per-tag draining preserves dispatch order)
        let mut backlog = std::collections::VecDeque::with_capacity(4);
        backlog.push_back(first);
        for tag in REQ_TAGS {
            backlog.extend(ep.recv_ready_all(Src::Rank(MANAGER), tag));
        }
        while let Some(m) = backlog.pop_front() {
            if is_down(&down) {
                // shutdown mid-drain: requeue the unprocessed tail in order
                backlog.push_front(m);
                for tag in REQ_TAGS {
                    let rest: Vec<Message> =
                        backlog.iter().filter(|x| x.tag == tag).cloned().collect();
                    ep.requeue_front(tag, rest);
                }
                break 'outer;
            }
            if m.tag == TAG_ORACLE_BATCH {
                // oracle plane: label the whole micro-batch, reply with one
                // labels-only frame echoing the batch id — row i answers
                // input i, which the Manager retained at dispatch
                if let Some((id, view)) = decode_oracle_batch_rows(&m.data) {
                    let t0 = Instant::now();
                    let labels = tel.time("run_calc", || oracle.run_calc_batch(&view));
                    debug_assert_eq!(labels.len(), view.rows());
                    tel.bump("batches");
                    tel.add("labels", view.rows() as u64);
                    trace::sink().span(ep.rank(), "oracle_calc", t0, id, view.rows() as u64);
                    encode_oracle_labels_into(id, &labels, &mut frame);
                    ep.send(MANAGER, TAG_ORACLE_LABELS, &frame[..]);
                } else if let Some((id, views)) = decode_oracle_batch_views(&m.data) {
                    // ragged batch: per-row labeling into a contiguous block
                    let t0 = Instant::now();
                    let labels = tel.time("run_calc", || {
                        let mut out = RowBlock::new();
                        for row in &views {
                            out.push_row(&oracle.run_calc(row));
                        }
                        out
                    });
                    tel.bump("batches");
                    tel.add("labels", views.len() as u64);
                    trace::sink().span(ep.rank(), "oracle_calc", t0, id, views.len() as u64);
                    encode_oracle_labels_into(id, &labels, &mut frame);
                    ep.send(MANAGER, TAG_ORACLE_LABELS, &frame[..]);
                } else if let Some(id) = decode_oracle_batch_id(&m.data) {
                    // undecodable item section: echo an *empty* result so
                    // the Manager frees this batch's in-flight slot — a bad
                    // frame costs its labels, never green-flow liveness
                    tel.bump("malformed");
                    encode_oracle_labels_into(id, &RowBlock::new(), &mut frame);
                    ep.send(MANAGER, TAG_ORACLE_LABELS, &frame[..]);
                } else {
                    tel.bump("malformed");
                }
            } else {
                // legacy per-label leg (wire bytes unchanged)
                let label = tel.time("run_calc", || oracle.run_calc(&m.data));
                tel.bump("labels");
                ep.send(
                    MANAGER,
                    TAG_ORACLE_RESULT,
                    reply.pack(&[m.data.as_slice(), label.as_slice()]),
                );
            }
        }
    }
    oracle.stop_run();
    tel
}

// ---------------------------------------------------------------------------
// Prediction host (SI §S4)
// ---------------------------------------------------------------------------

/// Drive one prediction process: serve Exchange traffic (lockstep
/// broadcasts *and* batched `PredictBatch` frames — models take stacked
/// input lists either way), absorb weight pushes from the paired trainer,
/// serve Manager re-scoring requests.
pub fn prediction_host(
    mut ep: Endpoint,
    mut model: Box<dyn Model>,
    setting: &AlSetting,
    down: ShutdownFlag,
) -> KernelTelemetry {
    let mut tel = KernelTelemetry::new("prediction", ep.rank());
    let poll = setting.poll_interval;
    // reusable reply scratches (lockstep pack + batch frame encode)
    let mut reply = PackBuffer::new();
    let mut frame = Vec::new();
    loop {
        if is_down(&down) {
            break;
        }
        // newest weights win; stale updates are discarded (paper §2.1:
        // models "updated periodically by replicating weights"). The
        // payload-typed update lets the replica *adopt* the shared buffer
        // the trainer materialized once — no per-replica weight copy.
        if let Some(m) = ep.recv_latest(Src::Any, TAG_WEIGHTS) {
            tel.time("update", || model.update_from(&m.data));
            tel.bump("weight_updates");
        }
        // manager re-scoring for dynamic_orcale_list
        if let Some(m) = ep.try_recv(Src::Rank(crate::config::topology::MANAGER), TAG_RESCORE_REQ) {
            if let Some(view) = codec::unpack_batch_view(&m.data) {
                // flat path: strided view over the request payload in,
                // contiguous rows out, packed with one memcpy
                let preds = tel.time("rescore", || model.predict_batch(&view));
                tel.bump("rescores");
                ep.send(
                    crate::config::topology::MANAGER,
                    TAG_RESCORE_RESP,
                    reply.pack_row_block(&preds),
                );
            } else if let Some(inputs) = codec::unpack(&m.data) {
                // ragged request: legacy nested path
                let preds = tel.time("rescore", || model.predict(&inputs));
                tel.bump("rescores");
                ep.send(
                    crate::config::topology::MANAGER,
                    TAG_RESCORE_RESP,
                    reply.pack(&preds),
                );
            }
        }
        // the hot path: stacked generator inputs from Exchange, as either a
        // lockstep broadcast or a sharded batch frame. Uniform-width frames
        // (the steady state) decode to a strided view with zero per-row
        // allocations and feed `predict_batch`; ragged frames fall back to
        // the nested decode + `predict`.
        match ep.recv_timeout_tags(
            Src::Rank(crate::config::topology::EXCHANGE),
            &[TAG_PRED_IN, TAG_PRED_BATCH],
            poll,
        ) {
            Ok(m) if m.tag == TAG_PRED_BATCH => {
                if let Some((id, view)) = decode_predict_batch_rows(&m.data) {
                    let t0 = Instant::now();
                    let preds = tel.time("predict", || model.predict_batch(&view));
                    debug_assert_eq!(preds.len(), view.rows());
                    tel.bump("batches");
                    tel.add("samples", view.rows() as u64);
                    trace::sink().span(ep.rank(), "predict", t0, id, view.rows() as u64);
                    encode_predict_batch_result_block_into(id, &preds, &mut frame);
                    ep.send(
                        crate::config::topology::EXCHANGE,
                        TAG_PRED_BATCH_RESULT,
                        &frame[..],
                    );
                } else if let Some((id, items)) = decode_predict_batch(&m.data) {
                    let t0 = Instant::now();
                    let preds = tel.time("predict", || model.predict(&items));
                    debug_assert_eq!(preds.len(), items.len());
                    tel.bump("batches");
                    tel.add("samples", items.len() as u64);
                    trace::sink().span(ep.rank(), "predict", t0, id, items.len() as u64);
                    encode_predict_batch_result_into(id, &preds, &mut frame);
                    ep.send(
                        crate::config::topology::EXCHANGE,
                        TAG_PRED_BATCH_RESULT,
                        &frame[..],
                    );
                } else {
                    tel.bump("malformed");
                }
            }
            Ok(m) => {
                if let Some(view) = codec::unpack_batch_view(&m.data) {
                    let t0 = Instant::now();
                    let preds = tel.time("predict", || model.predict_batch(&view));
                    debug_assert_eq!(preds.len(), view.rows());
                    tel.bump("batches");
                    tel.add("samples", view.rows() as u64);
                    trace::sink().span(ep.rank(), "predict", t0, u64::MAX, view.rows() as u64);
                    ep.send(
                        crate::config::topology::EXCHANGE,
                        TAG_PRED_OUT,
                        reply.pack_row_block(&preds),
                    );
                } else if let Some(inputs) = codec::unpack(&m.data) {
                    let t0 = Instant::now();
                    let preds = tel.time("predict", || model.predict(&inputs));
                    debug_assert_eq!(preds.len(), inputs.len());
                    tel.bump("batches");
                    tel.add("samples", inputs.len() as u64);
                    trace::sink().span(ep.rank(), "predict", t0, u64::MAX, inputs.len() as u64);
                    ep.send(
                        crate::config::topology::EXCHANGE,
                        TAG_PRED_OUT,
                        reply.pack(&preds),
                    );
                } else {
                    tel.bump("malformed");
                }
            }
            Err(crate::comm::RecvError::Timeout) => continue,
            Err(crate::comm::RecvError::Disconnected) => break,
        }
    }
    record_upload_stats(&mut tel, &*model);
    model.stop_run();
    tel
}

// ---------------------------------------------------------------------------
// Training host (SI §S5)
// ---------------------------------------------------------------------------

/// One trainer → replica weight sync: materialize the weights as a shared
/// payload at most once (the single physical copy, charged to the world
/// stats via [`Endpoint::note_ingest`]) and fan it out by refcount —
/// per-destination cost is a pointer bump regardless of the shard count.
///
/// A freshly materialized export holds the only handle on its buffer; a
/// cached re-export (a model holding adopted shared weights) arrives
/// already shared and is *not* charged — no bytes moved for it.
pub fn sync_weights(ep: &Endpoint, replicas: &[usize], model: &dyn Model) {
    if replicas.is_empty() {
        return;
    }
    let w = model.get_weight_payload();
    if w.shared_handles() <= 1 {
        ep.note_ingest(w.len());
    }
    ep.bcast(replicas, TAG_WEIGHTS, &w);
}

/// Drive one training process: wait for labeled batches, retrain until new
/// data or shutdown interrupts, then push weights to the paired predictor.
pub fn training_host(
    mut ep: Endpoint,
    mut model: Box<dyn Model>,
    setting: &AlSetting,
    topology: &Topology,
    down: ShutdownFlag,
) -> KernelTelemetry {
    let mut tel = KernelTelemetry::new("training", ep.rank());
    let poll = setting.poll_interval;
    // this member's replica in every prediction shard (one shard = the
    // paper's 1:1 trainer→predictor pairing; sharded mode fans out so all
    // shards serve the same committee)
    let replicas = topology.replicas_for_trainer(ep.rank());
    // initial weight sync so predictors start from the same replica; one
    // shared payload fans out by refcount — replica count does not
    // multiply copies
    let mut rounds: u64 = 0;
    if !replicas.is_empty() {
        let t0 = Instant::now();
        sync_weights(&ep, &replicas, &*model);
        tel.bump("weight_syncs");
        registry().inc(Counter::WeightSyncs);
        trace::sink().span(ep.rank(), "weight_sync", t0, rounds, replicas.len() as u64);
    }
    loop {
        let m = match recv_poll(&mut ep, Src::Rank(crate::config::topology::MANAGER), TAG_TRAIN_DATA, &down, poll) {
            Some(m) => m,
            None => break,
        };
        // flat ingest: the labeled pairs are read as borrowed views over
        // the received payload and staged contiguously by the model — no
        // (Vec, Vec) boxing between the wire and the training set
        let Some(points) = codec::decode_train_block_views(&m.data) else {
            tel.bump("malformed");
            continue;
        };
        tel.add("datapoints", points.len() as u64);
        model.add_trainingset_batch(&points);
        // retrain, interruptible by new data / shutdown (paper §S5:
        // "checking req_data.Test() at every training epoch")
        let stop = {
            let down2 = down.clone();
            let probe_ep_interrupt = |ep: &mut Endpoint| {
                is_down(&down2) || ep.probe(Src::Rank(crate::config::topology::MANAGER), TAG_TRAIN_DATA)
            };
            let t0 = std::time::Instant::now();
            // split borrow: retrain takes the model; the closure needs the
            // endpoint. Endpoint probing is cheap and lock-free.
            let stop = model.retrain(&mut || probe_ep_interrupt(&mut ep));
            tel.record("retrain", t0.elapsed());
            trace::sink().span(ep.rank(), "retrain", t0, rounds, points.len() as u64);
            stop
        };
        tel.bump("rounds");
        rounds += 1;
        // one shared weight payload for every shard replica (zero-copy fan-out)
        if !replicas.is_empty() {
            let t0 = Instant::now();
            sync_weights(&ep, &replicas, &*model);
            tel.bump("weight_syncs");
            registry().inc(Counter::WeightSyncs);
            trace::sink().span(ep.rank(), "weight_sync", t0, rounds, replicas.len() as u64);
        }
        let loss = model.last_loss().unwrap_or(f32::NAN);
        let epochs = model.last_round_epochs() as f32;
        tel.add("epochs", epochs as u64);
        ep.send(
            crate::config::topology::MANAGER,
            TAG_RETRAIN_DONE,
            vec![loss, epochs],
        );
        model.save_progress();
        if stop {
            tel.bump("stop_signals");
            ep.send(crate::config::topology::MANAGER, TAG_STOP, Payload::empty());
        }
    }
    record_upload_stats(&mut tel, &*model);
    model.stop_run();
    tel
}

/// Construct the model for a host thread.
pub fn build_model(
    factory: &crate::kernels::ModelFactory,
    mode: Mode,
    replica: usize,
) -> Box<dyn Model> {
    factory(mode, replica)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;

    fn flag() -> ShutdownFlag {
        Arc::new(AtomicBool::new(false))
    }

    #[test]
    fn vectored_gather_poll_orders_by_src_list() {
        let mut w = World::new(4);
        let mut eps = w.endpoints();
        let e3 = eps.pop().unwrap();
        let e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e3.send(0, 9, vec![3.0]);
        e1.send(0, 9, vec![1.0]);
        e2.send(0, 9, vec![2.0]);
        let got = gather_poll(&mut e0, &[1, 2, 3], 9, &flag(), Duration::from_millis(2)).unwrap();
        assert_eq!(got, vec![vec![1.0], vec![2.0], vec![3.0]]);
    }

    #[test]
    fn vectored_gather_poll_defers_early_rounds_in_fifo_order() {
        // the satellite's ordering pin: one generator races two rounds
        // ahead; the vectored drain must not reorder its backlog
        let mut w = World::new(3);
        let mut eps = w.endpoints();
        let e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let down = flag();
        let poll = Duration::from_millis(2);
        e1.send(0, 9, vec![1.0]); // round 1
        e1.send(0, 9, vec![10.0]); // round 2, early
        e1.send(0, 9, vec![100.0]); // round 3, early
        e2.send(0, 9, vec![2.0]); // round 1
        let r1 = gather_poll(&mut e0, &[1, 2], 9, &down, poll).unwrap();
        assert_eq!(r1, vec![vec![1.0], vec![2.0]]);
        e2.send(0, 9, vec![20.0]);
        let r2 = gather_poll(&mut e0, &[1, 2], 9, &down, poll).unwrap();
        assert_eq!(r2, vec![vec![10.0], vec![20.0]]);
        e2.send(0, 9, vec![200.0]);
        let r3 = gather_poll(&mut e0, &[1, 2], 9, &down, poll).unwrap();
        assert_eq!(r3, vec![vec![100.0], vec![200.0]]);
    }

    #[test]
    fn gather_poll_requeues_consumed_round_on_shutdown() {
        let mut w = World::new(3);
        let mut eps = w.endpoints();
        let _e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let down = flag();
        e1.send(0, 9, vec![1.0]); // round 1 — filled, then requeued on abort
        e1.send(0, 9, vec![10.0]); // round 2, early — deferred, requeued
        // rank 2 never sends; shut down mid-gather from another thread
        let down2 = down.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            down2.store(true, Ordering::Release);
        });
        assert!(gather_poll(&mut e0, &[1, 2], 9, &down, Duration::from_millis(2)).is_none());
        h.join().unwrap();
        // the aborted gather put *everything* back, in FIFO order: the
        // consumed round-1 message first, the early round-2 one behind it —
        // never round 2 interleaved in place of round 1
        assert_eq!(e0.try_recv(Src::Rank(1), 9).unwrap().data, vec![1.0]);
        assert_eq!(e0.try_recv(Src::Rank(1), 9).unwrap().data, vec![10.0]);
        assert!(e0.try_recv(Src::Rank(1), 9).is_none());
    }

    #[test]
    fn oracle_host_replies_to_queued_batches_in_dispatch_order() {
        use crate::comm::protocol::{
            decode_oracle_labels_views, encode_oracle_batch_block_into, TAG_ORACLE_BATCH,
            TAG_ORACLE_LABELS,
        };
        use crate::data::batch::RowBlock;

        struct Echo;
        impl crate::kernels::Oracle for Echo {
            fn run_calc(&mut self, input: &[f32]) -> Vec<f32> {
                input.iter().map(|v| v + 100.0).collect()
            }
        }

        let mut w = World::new(2); // rank 0 = Manager, rank 1 = oracle
        let mut manager = w.endpoint(0);
        let orcl_ep = w.endpoint(1);
        let setting = crate::config::AlSetting::default();
        let down = flag();

        // two batch frames queued back to back (max_outstanding > 1): the
        // host must serve them strictly in dispatch order
        let mut frame = Vec::new();
        let dispatched = [vec![vec![1.0f32], vec![2.0]], vec![vec![3.0f32]]];
        encode_oracle_batch_block_into(7, &RowBlock::from_rows(&dispatched[0]), &mut frame);
        manager.send(1, TAG_ORACLE_BATCH, &frame[..]);
        encode_oracle_batch_block_into(8, &RowBlock::from_rows(&dispatched[1]), &mut frame);
        manager.send(1, TAG_ORACLE_BATCH, &frame[..]);
        // a frame with a readable id but an undecodable item section must
        // come back as an *empty* result (the Manager frees its slot)
        manager.send(1, TAG_ORACLE_BATCH, vec![0.0, 9.0, 1.0]);

        let down2 = down.clone();
        let h = std::thread::spawn(move || {
            oracle_host(orcl_ep, Box::new(Echo), &setting, down2)
        });
        let mut ids = Vec::new();
        let mut label_counts = Vec::new();
        for round in 0..3 {
            let m = manager
                .recv_timeout(Src::Rank(1), TAG_ORACLE_LABELS, Duration::from_secs(5))
                .unwrap();
            let (id, labels) = decode_oracle_labels_views(&m.data).unwrap();
            if let Some(inputs) = dispatched.get(round) {
                // labels-only contract: label row i answers dispatched
                // input row i of the same batch
                assert_eq!(labels.len(), inputs.len());
                for (x, y) in inputs.iter().zip(&labels) {
                    assert_eq!(y[0], x[0] + 100.0, "label pairs with its own input");
                }
            }
            ids.push(id);
            label_counts.push(labels.len());
        }
        assert_eq!(ids, vec![7, 8, 9], "batches answered in dispatch order");
        assert_eq!(label_counts, vec![2, 1, 0], "malformed batch echoes empty");
        down.store(true, Ordering::Release);
        let tel = h.join().unwrap();
        assert_eq!(tel.counter("batches"), 2);
        assert_eq!(tel.counter("labels"), 3);
        assert_eq!(tel.counter("malformed"), 1);
    }
}
