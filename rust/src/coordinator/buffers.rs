//! Manager-side buffers: oracle input buffer + training data buffer
//! (the "metadata storage" of §2.5).

use crate::data::batch::{DatapointBlock, RowBlock, RowQueue};
use crate::data::Datapoint;

/// FIFO of inputs awaiting oracle labeling, with optional capacity bound
/// (backpressure: when full, the oldest *lowest-priority* entries are
/// dropped — the controller decided they were stale).
///
/// Storage is a flat [`RowQueue`]: staged inputs live contiguously in one
/// buffer, so enqueuing a decoded selection row ([`OracleBuffer::push_row`])
/// and handing a row to a free oracle ([`OracleBuffer::pop_row`]) never
/// allocate per row. The nested-`Vec` API (`push_all` / `pop` / `drain`)
/// remains for the cold re-scoring path and compatibility.
#[derive(Debug, Default)]
pub struct OracleBuffer {
    queue: RowQueue,
    /// Hard cap; None = unbounded.
    pub capacity: Option<usize>,
    /// Total samples ever enqueued / dropped (telemetry).
    pub enqueued: u64,
    pub dropped: u64,
}

impl OracleBuffer {
    pub fn new(capacity: Option<usize>) -> Self {
        OracleBuffer { capacity, ..Default::default() }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    fn evict_over_cap(&mut self) {
        if let Some(cap) = self.capacity {
            while self.queue.len() > cap {
                self.queue.drop_back();
                self.dropped += 1;
            }
        }
    }

    /// Enqueue one input row (hot path: values copy straight from the
    /// decoded payload into the flat staging buffer; no boxing). Drops from
    /// the *back* (newest beyond cap) under pressure — `prediction_check`
    /// orders each selection batch by priority, and
    /// `adjust_input_for_oracle` re-fronts the most uncertain entries on
    /// every rescore (exactly for the next dispatch window; the tail is
    /// kept but only approximately ordered).
    pub fn push_row(&mut self, row: &[f32]) {
        self.enqueued += 1;
        self.queue.push_row(row);
        self.evict_over_cap();
    }

    /// Enqueue owned inputs (legacy API; same eviction semantics).
    pub fn push_all(&mut self, inputs: Vec<Vec<f32>>) {
        for x in &inputs {
            self.enqueued += 1;
            self.queue.push_row(x);
        }
        self.evict_over_cap();
    }

    /// Next input for a free oracle, borrowed from the flat buffer (valid
    /// until the next mutation). No allocation.
    pub fn pop_row(&mut self) -> Option<&[f32]> {
        self.queue.pop_front_row()
    }

    /// Next input for a free oracle, owned (legacy API).
    pub fn pop(&mut self) -> Option<Vec<f32>> {
        self.queue.pop_front_row().map(|r| r.to_vec())
    }

    /// Drain all buffered inputs into one contiguous [`RowBlock`] (the
    /// `adjust_input_for_oracle_batch` re-scoring path): rows copy straight
    /// from the flat queue into the flat block, nothing is boxed per row.
    pub fn drain_block(&mut self) -> RowBlock {
        let values: usize = self.queue.iter().map(|r| r.len()).sum();
        let mut out = RowBlock::with_capacity(self.queue.len(), values);
        for row in self.queue.iter() {
            out.push_row(row);
        }
        self.queue = RowQueue::new();
        out
    }

    /// Drain all buffered inputs (legacy nested API; routed through
    /// [`OracleBuffer::drain_block`]'s contiguous staging).
    pub fn drain(&mut self) -> Vec<Vec<f32>> {
        self.drain_block().to_nested()
    }

    /// Replace contents from a contiguous block (after user adjustment).
    /// The adjusted rows must be a sub-multiset of the drained ones —
    /// validated by the caller in debug builds.
    pub fn replace_block(&mut self, rows: &RowBlock) {
        self.fill_from_rows(rows.iter());
    }

    /// Replace contents (legacy nested API; same internals as
    /// [`OracleBuffer::replace_block`] — rows move into the flat queue
    /// without any intermediate re-boxing).
    pub fn replace(&mut self, inputs: Vec<Vec<f32>>) {
        self.fill_from_rows(inputs.iter().map(|v| v.as_slice()));
    }

    fn fill_from_rows<'a>(&mut self, rows: impl Iterator<Item = &'a [f32]>) {
        self.queue = RowQueue::new();
        for row in rows {
            self.queue.push_row(row);
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = &[f32]> {
        self.queue.iter()
    }
}

/// Labeled data accumulating toward a retraining broadcast (§2.5:
/// "distributed to the ML models in the training kernel once the buffer
/// size reaches a user-defined threshold").
///
/// Storage is a flat [`DatapointBlock`]: each oracle result's `(input,
/// label)` views copy straight from the decoded payload into two
/// contiguous buffers ([`TrainBuffer::push_pair`]), and a flush hands the
/// whole block to the wire encoder — no `(Vec, Vec)` boxing anywhere
/// between the oracle and the trainers.
#[derive(Debug, Default)]
pub struct TrainBuffer {
    buf: DatapointBlock,
    pub threshold: usize,
    /// Total datapoints ever flushed (telemetry).
    pub flushed: u64,
}

impl TrainBuffer {
    pub fn new(threshold: usize) -> Self {
        TrainBuffer { buf: DatapointBlock::new(), threshold: threshold.max(1), flushed: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Stage one labeled sample from borrowed slices (hot path: the values
    /// copy once, into the flat block).
    pub fn push_pair(&mut self, input: &[f32], label: &[f32]) {
        self.buf.push(input, label);
    }

    /// Stage one owned sample (legacy API; same flat staging).
    pub fn push(&mut self, point: Datapoint) {
        self.push_pair(&point.0, &point.1);
    }

    pub fn ready(&self) -> bool {
        self.buf.len() >= self.threshold
    }

    /// Take the accumulated batch if the threshold is met. The replacement
    /// staging block is pre-sized to the flushed batch's shape, so a
    /// steady-state flush cycle costs a fixed handful of allocations (the
    /// replacement buffers) and the per-label `push_pair`s between flushes
    /// allocate nothing — pinned by `test_oracle_plane`.
    pub fn flush(&mut self) -> Option<DatapointBlock> {
        if !self.ready() {
            return None;
        }
        self.flushed += self.buf.len() as u64;
        let fresh = DatapointBlock::with_capacity(
            self.buf.len(),
            self.buf.total_input_values(),
            self.buf.total_label_values(),
        );
        Some(std::mem::replace(&mut self.buf, fresh))
    }

    /// Unconditional drain (shutdown path: don't lose labeled data).
    pub fn flush_all(&mut self) -> DatapointBlock {
        self.flushed += self.buf.len() as u64;
        std::mem::take(&mut self.buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_buffer_fifo() {
        let mut b = OracleBuffer::new(None);
        b.push_all(vec![vec![1.0], vec![2.0]]);
        assert_eq!(b.pop().unwrap(), vec![1.0]);
        assert_eq!(b.pop().unwrap(), vec![2.0]);
        assert!(b.pop().is_none());
    }

    #[test]
    fn oracle_buffer_caps_dropping_newest() {
        let mut b = OracleBuffer::new(Some(2));
        b.push_all(vec![vec![1.0], vec![2.0], vec![3.0]]);
        assert_eq!(b.len(), 2);
        assert_eq!(b.dropped, 1);
        assert_eq!(b.pop().unwrap(), vec![1.0]); // priority head kept
    }

    #[test]
    fn oracle_buffer_drain_replace() {
        let mut b = OracleBuffer::new(None);
        b.push_all(vec![vec![1.0], vec![2.0], vec![3.0]]);
        let drained = b.drain();
        assert_eq!(drained.len(), 3);
        assert!(b.is_empty());
        b.replace(vec![drained[2].clone(), drained[0].clone()]);
        assert_eq!(b.pop().unwrap(), vec![3.0]);
    }

    #[test]
    fn oracle_buffer_flat_rows_roundtrip() {
        let mut b = OracleBuffer::new(Some(2));
        b.push_row(&[1.0, 2.0]);
        b.push_row(&[3.0, 4.0]);
        b.push_row(&[5.0, 6.0]); // over cap: newest dropped
        assert_eq!((b.len(), b.dropped, b.enqueued), (2, 1, 3));
        assert_eq!(b.pop_row().unwrap(), &[1.0, 2.0]);
        assert_eq!(b.pop_row().unwrap(), &[3.0, 4.0]);
        assert!(b.pop_row().is_none());
    }

    #[test]
    fn oracle_buffer_drain_replace_block_roundtrip() {
        let mut b = OracleBuffer::new(None);
        b.push_row(&[1.0, 2.0]);
        b.push_row(&[3.0, 4.0]);
        b.push_row(&[5.0, 6.0]);
        let drained = b.drain_block();
        assert_eq!(drained.len(), 3);
        assert!(b.is_empty());
        // keep rows 2 and 0, in that order (a typical adjustment)
        let mut adjusted = RowBlock::new();
        adjusted.push_row(drained.row(2));
        adjusted.push_row(drained.row(0));
        b.replace_block(&adjusted);
        assert_eq!(b.len(), 2);
        assert_eq!(b.pop_row().unwrap(), &[5.0, 6.0]);
        assert_eq!(b.pop_row().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn train_buffer_push_pair_matches_owned_push() {
        let mut a = TrainBuffer::new(2);
        let mut b = TrainBuffer::new(2);
        a.push_pair(&[1.0, 2.0], &[0.5]);
        a.push_pair(&[3.0], &[0.25, 0.75]);
        b.push((vec![1.0, 2.0], vec![0.5]));
        b.push((vec![3.0], vec![0.25, 0.75]));
        let fa = a.flush().unwrap();
        let fb = b.flush().unwrap();
        assert_eq!(fa, fb);
        assert_eq!(fa.pair(1), (&[3.0f32][..], &[0.25f32, 0.75][..]));
    }

    #[test]
    fn train_buffer_threshold() {
        let mut t = TrainBuffer::new(3);
        t.push((vec![1.0], vec![0.0]));
        t.push((vec![2.0], vec![0.0]));
        assert!(t.flush().is_none());
        t.push((vec![3.0], vec![0.0]));
        let batch = t.flush().unwrap();
        assert_eq!(batch.len(), 3);
        assert!(t.is_empty());
        assert_eq!(t.flushed, 3);
    }

    #[test]
    fn train_buffer_flush_all_ignores_threshold() {
        let mut t = TrainBuffer::new(100);
        t.push((vec![1.0], vec![0.0]));
        assert_eq!(t.flush_all().len(), 1);
        assert_eq!(t.flushed, 1);
    }

    #[test]
    fn zero_threshold_clamped() {
        let t = TrainBuffer::new(0);
        assert_eq!(t.threshold, 1);
    }
}
