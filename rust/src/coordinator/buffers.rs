//! Manager-side buffers: oracle input buffer + training data buffer
//! (the "metadata storage" of §2.5).

use crate::data::batch::RowQueue;
use crate::data::Datapoint;

/// FIFO of inputs awaiting oracle labeling, with optional capacity bound
/// (backpressure: when full, the oldest *lowest-priority* entries are
/// dropped — the controller decided they were stale).
///
/// Storage is a flat [`RowQueue`]: staged inputs live contiguously in one
/// buffer, so enqueuing a decoded selection row ([`OracleBuffer::push_row`])
/// and handing a row to a free oracle ([`OracleBuffer::pop_row`]) never
/// allocate per row. The nested-`Vec` API (`push_all` / `pop` / `drain`)
/// remains for the cold re-scoring path and compatibility.
#[derive(Debug, Default)]
pub struct OracleBuffer {
    queue: RowQueue,
    /// Hard cap; None = unbounded.
    pub capacity: Option<usize>,
    /// Total samples ever enqueued / dropped (telemetry).
    pub enqueued: u64,
    pub dropped: u64,
}

impl OracleBuffer {
    pub fn new(capacity: Option<usize>) -> Self {
        OracleBuffer { capacity, ..Default::default() }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    fn evict_over_cap(&mut self) {
        if let Some(cap) = self.capacity {
            while self.queue.len() > cap {
                self.queue.drop_back();
                self.dropped += 1;
            }
        }
    }

    /// Enqueue one input row (hot path: values copy straight from the
    /// decoded payload into the flat staging buffer; no boxing). Drops from
    /// the *back* (newest beyond cap) under pressure — `prediction_check`
    /// orders each selection batch by priority, and
    /// `adjust_input_for_oracle` re-fronts the most uncertain entries on
    /// every rescore (exactly for the next dispatch window; the tail is
    /// kept but only approximately ordered).
    pub fn push_row(&mut self, row: &[f32]) {
        self.enqueued += 1;
        self.queue.push_row(row);
        self.evict_over_cap();
    }

    /// Enqueue owned inputs (legacy API; same eviction semantics).
    pub fn push_all(&mut self, inputs: Vec<Vec<f32>>) {
        for x in &inputs {
            self.enqueued += 1;
            self.queue.push_row(x);
        }
        self.evict_over_cap();
    }

    /// Next input for a free oracle, borrowed from the flat buffer (valid
    /// until the next mutation). No allocation.
    pub fn pop_row(&mut self) -> Option<&[f32]> {
        self.queue.pop_front_row()
    }

    /// Next input for a free oracle, owned (legacy API).
    pub fn pop(&mut self) -> Option<Vec<f32>> {
        self.queue.pop_front_row().map(|r| r.to_vec())
    }

    /// Drain all buffered inputs (for `adjust_input_for_oracle` re-scoring;
    /// cold path, so the nested materialization is fine).
    pub fn drain(&mut self) -> Vec<Vec<f32>> {
        let out: Vec<Vec<f32>> = self.queue.iter().map(|r| r.to_vec()).collect();
        self.queue = RowQueue::new();
        out
    }

    /// Replace contents (after user adjustment). The adjusted list must be
    /// a sub-multiset of the drained one — validated by the caller in
    /// debug builds.
    pub fn replace(&mut self, inputs: Vec<Vec<f32>>) {
        self.queue = RowQueue::new();
        for x in &inputs {
            self.queue.push_row(x);
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = &[f32]> {
        self.queue.iter()
    }
}

/// Labeled data accumulating toward a retraining broadcast (§2.5:
/// "distributed to the ML models in the training kernel once the buffer
/// size reaches a user-defined threshold").
#[derive(Debug, Default)]
pub struct TrainBuffer {
    buf: Vec<Datapoint>,
    pub threshold: usize,
    /// Total datapoints ever flushed (telemetry).
    pub flushed: u64,
}

impl TrainBuffer {
    pub fn new(threshold: usize) -> Self {
        TrainBuffer { buf: vec![], threshold: threshold.max(1), flushed: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn push(&mut self, point: Datapoint) {
        self.buf.push(point);
    }

    pub fn ready(&self) -> bool {
        self.buf.len() >= self.threshold
    }

    /// Take the accumulated batch if the threshold is met.
    pub fn flush(&mut self) -> Option<Vec<Datapoint>> {
        if !self.ready() {
            return None;
        }
        self.flushed += self.buf.len() as u64;
        Some(std::mem::take(&mut self.buf))
    }

    /// Unconditional drain (shutdown path: don't lose labeled data).
    pub fn flush_all(&mut self) -> Vec<Datapoint> {
        self.flushed += self.buf.len() as u64;
        std::mem::take(&mut self.buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_buffer_fifo() {
        let mut b = OracleBuffer::new(None);
        b.push_all(vec![vec![1.0], vec![2.0]]);
        assert_eq!(b.pop().unwrap(), vec![1.0]);
        assert_eq!(b.pop().unwrap(), vec![2.0]);
        assert!(b.pop().is_none());
    }

    #[test]
    fn oracle_buffer_caps_dropping_newest() {
        let mut b = OracleBuffer::new(Some(2));
        b.push_all(vec![vec![1.0], vec![2.0], vec![3.0]]);
        assert_eq!(b.len(), 2);
        assert_eq!(b.dropped, 1);
        assert_eq!(b.pop().unwrap(), vec![1.0]); // priority head kept
    }

    #[test]
    fn oracle_buffer_drain_replace() {
        let mut b = OracleBuffer::new(None);
        b.push_all(vec![vec![1.0], vec![2.0], vec![3.0]]);
        let drained = b.drain();
        assert_eq!(drained.len(), 3);
        assert!(b.is_empty());
        b.replace(vec![drained[2].clone(), drained[0].clone()]);
        assert_eq!(b.pop().unwrap(), vec![3.0]);
    }

    #[test]
    fn oracle_buffer_flat_rows_roundtrip() {
        let mut b = OracleBuffer::new(Some(2));
        b.push_row(&[1.0, 2.0]);
        b.push_row(&[3.0, 4.0]);
        b.push_row(&[5.0, 6.0]); // over cap: newest dropped
        assert_eq!((b.len(), b.dropped, b.enqueued), (2, 1, 3));
        assert_eq!(b.pop_row().unwrap(), &[1.0, 2.0]);
        assert_eq!(b.pop_row().unwrap(), &[3.0, 4.0]);
        assert!(b.pop_row().is_none());
    }

    #[test]
    fn train_buffer_threshold() {
        let mut t = TrainBuffer::new(3);
        t.push((vec![1.0], vec![0.0]));
        t.push((vec![2.0], vec![0.0]));
        assert!(t.flush().is_none());
        t.push((vec![3.0], vec![0.0]));
        let batch = t.flush().unwrap();
        assert_eq!(batch.len(), 3);
        assert!(t.is_empty());
        assert_eq!(t.flushed, 3);
    }

    #[test]
    fn train_buffer_flush_all_ignores_threshold() {
        let mut t = TrainBuffer::new(100);
        t.push((vec![1.0], vec![0.0]));
        assert_eq!(t.flush_all().len(), 1);
        assert_eq!(t.flushed, 1);
    }

    #[test]
    fn zero_threshold_clamped() {
        let t = TrainBuffer::new(0);
        assert_eq!(t.threshold, 1);
    }
}
