//! Manager-side buffers: oracle input buffer + training data buffer
//! (the "metadata storage" of §2.5).

use std::collections::VecDeque;

use crate::data::Datapoint;

/// FIFO of inputs awaiting oracle labeling, with optional capacity bound
/// (backpressure: when full, the oldest *lowest-priority* entries are
/// dropped — the controller decided they were stale).
#[derive(Debug, Default)]
pub struct OracleBuffer {
    queue: VecDeque<Vec<f32>>,
    /// Hard cap; None = unbounded.
    pub capacity: Option<usize>,
    /// Total samples ever enqueued / dropped (telemetry).
    pub enqueued: u64,
    pub dropped: u64,
}

impl OracleBuffer {
    pub fn new(capacity: Option<usize>) -> Self {
        OracleBuffer { capacity, ..Default::default() }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Enqueue inputs; drops from the *back* (newest beyond cap) under
    /// pressure — entries already ordered by priority by `prediction_check`
    /// / `adjust_input_for_oracle`.
    pub fn push_all(&mut self, inputs: Vec<Vec<f32>>) {
        for x in inputs {
            self.enqueued += 1;
            self.queue.push_back(x);
        }
        if let Some(cap) = self.capacity {
            while self.queue.len() > cap {
                self.queue.pop_back();
                self.dropped += 1;
            }
        }
    }

    /// Next input for a free oracle.
    pub fn pop(&mut self) -> Option<Vec<f32>> {
        self.queue.pop_front()
    }

    /// Drain all buffered inputs (for `adjust_input_for_oracle` re-scoring).
    pub fn drain(&mut self) -> Vec<Vec<f32>> {
        self.queue.drain(..).collect()
    }

    /// Replace contents (after user adjustment). The adjusted list must be
    /// a sub-multiset of the drained one — validated by the caller in
    /// debug builds.
    pub fn replace(&mut self, inputs: Vec<Vec<f32>>) {
        self.queue = inputs.into();
    }

    pub fn iter(&self) -> impl Iterator<Item = &Vec<f32>> {
        self.queue.iter()
    }
}

/// Labeled data accumulating toward a retraining broadcast (§2.5:
/// "distributed to the ML models in the training kernel once the buffer
/// size reaches a user-defined threshold").
#[derive(Debug, Default)]
pub struct TrainBuffer {
    buf: Vec<Datapoint>,
    pub threshold: usize,
    /// Total datapoints ever flushed (telemetry).
    pub flushed: u64,
}

impl TrainBuffer {
    pub fn new(threshold: usize) -> Self {
        TrainBuffer { buf: vec![], threshold: threshold.max(1), flushed: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn push(&mut self, point: Datapoint) {
        self.buf.push(point);
    }

    pub fn ready(&self) -> bool {
        self.buf.len() >= self.threshold
    }

    /// Take the accumulated batch if the threshold is met.
    pub fn flush(&mut self) -> Option<Vec<Datapoint>> {
        if !self.ready() {
            return None;
        }
        self.flushed += self.buf.len() as u64;
        Some(std::mem::take(&mut self.buf))
    }

    /// Unconditional drain (shutdown path: don't lose labeled data).
    pub fn flush_all(&mut self) -> Vec<Datapoint> {
        self.flushed += self.buf.len() as u64;
        std::mem::take(&mut self.buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_buffer_fifo() {
        let mut b = OracleBuffer::new(None);
        b.push_all(vec![vec![1.0], vec![2.0]]);
        assert_eq!(b.pop().unwrap(), vec![1.0]);
        assert_eq!(b.pop().unwrap(), vec![2.0]);
        assert!(b.pop().is_none());
    }

    #[test]
    fn oracle_buffer_caps_dropping_newest() {
        let mut b = OracleBuffer::new(Some(2));
        b.push_all(vec![vec![1.0], vec![2.0], vec![3.0]]);
        assert_eq!(b.len(), 2);
        assert_eq!(b.dropped, 1);
        assert_eq!(b.pop().unwrap(), vec![1.0]); // priority head kept
    }

    #[test]
    fn oracle_buffer_drain_replace() {
        let mut b = OracleBuffer::new(None);
        b.push_all(vec![vec![1.0], vec![2.0], vec![3.0]]);
        let drained = b.drain();
        assert_eq!(drained.len(), 3);
        assert!(b.is_empty());
        b.replace(vec![drained[2].clone(), drained[0].clone()]);
        assert_eq!(b.pop().unwrap(), vec![3.0]);
    }

    #[test]
    fn train_buffer_threshold() {
        let mut t = TrainBuffer::new(3);
        t.push((vec![1.0], vec![0.0]));
        t.push((vec![2.0], vec![0.0]));
        assert!(t.flush().is_none());
        t.push((vec![3.0], vec![0.0]));
        let batch = t.flush().unwrap();
        assert_eq!(batch.len(), 3);
        assert!(t.is_empty());
        assert_eq!(t.flushed, 3);
    }

    #[test]
    fn train_buffer_flush_all_ignores_threshold() {
        let mut t = TrainBuffer::new(100);
        t.push((vec![1.0], vec![0.0]));
        assert_eq!(t.flush_all().len(), 1);
        assert_eq!(t.flushed, 1);
    }

    #[test]
    fn zero_threshold_clamped() {
        let t = TrainBuffer::new(0);
        assert_eq!(t.threshold, 1);
    }
}
