//! Labeled-dataset store: train/val split, rolling window (SI use case 2).

use crate::rng::Rng;

pub mod batch;

pub use batch::{
    Batch, BatchView, DatapointBlock, DatapointView, PayloadBatch, RowBlock, RowQueue, SharedRows,
};

/// One labeled sample: `(input, label)` flat arrays (paper wire format).
pub type Datapoint = (Vec<f32>, Vec<f32>);

/// Training/validation store with optional rolling window.
///
/// The rolling window implements the SI use-case-2 recommendation: "newly
/// incoming xTB-labeled samples are added after every single training epoch,
/// and old samples are removed to keep the training set size constant".
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x_train: Vec<Vec<f32>>,
    pub y_train: Vec<Vec<f32>>,
    pub x_val: Vec<Vec<f32>>,
    pub y_val: Vec<Vec<f32>>,
    /// Fraction of incoming data routed to validation.
    pub val_split: f64,
    /// If set, training set is capped at this size (oldest dropped first).
    pub rolling_window: Option<usize>,
    rng: Rng,
    total_added: u64,
}

impl Dataset {
    pub fn new(val_split: f64, seed: u64) -> Self {
        Dataset {
            x_train: vec![],
            y_train: vec![],
            x_val: vec![],
            y_val: vec![],
            val_split,
            rolling_window: None,
            rng: Rng::new(seed),
            total_added: 0,
        }
    }

    pub fn with_rolling_window(mut self, cap: usize) -> Self {
        self.rolling_window = Some(cap);
        self
    }

    /// Add labeled datapoints, assigning each to train or val
    /// (paper SI §S5 `add_trainingset`).
    pub fn add(&mut self, points: &[Datapoint]) {
        for (x, y) in points {
            self.add_one(x, y);
        }
        self.apply_window();
    }

    /// Flat-training-plane twin of [`Dataset::add`]: pairs stream in as
    /// borrowed views (typically straight over a decoded `TAG_TRAIN_DATA`
    /// payload), so no intermediate nested pair list is materialized. The
    /// per-point split logic — and therefore the RNG stream — is shared
    /// with [`Dataset::add`], so both paths produce identical datasets.
    pub fn add_view(&mut self, points: &DatapointView<'_>) {
        for (x, y) in points.iter() {
            self.add_one(x, y);
        }
        self.apply_window();
    }

    fn add_one(&mut self, x: &[f32], y: &[f32]) {
        self.total_added += 1;
        if self.rng.f64() < self.val_split && !self.x_train.is_empty() {
            self.x_val.push(x.to_vec());
            self.y_val.push(y.to_vec());
        } else {
            self.x_train.push(x.to_vec());
            self.y_train.push(y.to_vec());
        }
    }

    fn apply_window(&mut self) {
        if let Some(cap) = self.rolling_window {
            while self.x_train.len() > cap {
                self.x_train.remove(0);
                self.y_train.remove(0);
            }
            // keep validation bounded too (half the window)
            while self.x_val.len() > cap / 2 + 1 {
                self.x_val.remove(0);
                self.y_val.remove(0);
            }
        }
    }

    pub fn n_train(&self) -> usize {
        self.x_train.len()
    }

    pub fn n_val(&self) -> usize {
        self.x_val.len()
    }

    pub fn total_added(&self) -> u64 {
        self.total_added
    }

    pub fn is_empty(&self) -> bool {
        self.x_train.is_empty()
    }

    /// Sample a training minibatch of exactly `batch` rows (with
    /// replacement if the set is smaller — the fixed-shape HLO train step
    /// needs full batches).
    pub fn minibatch(&mut self, batch: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(!self.x_train.is_empty(), "minibatch from empty dataset");
        let xw = self.x_train[0].len();
        let yw = self.y_train[0].len();
        let mut xs = Vec::with_capacity(batch * xw);
        let mut ys = Vec::with_capacity(batch * yw);
        for _ in 0..batch {
            let i = self.rng.below(self.x_train.len());
            xs.extend_from_slice(&self.x_train[i]);
            ys.extend_from_slice(&self.y_train[i]);
        }
        (xs, ys)
    }

    /// Flattened validation set (or train set if no val yet), padded by
    /// cycling to exactly `batch` rows. Returns (x, y, real_rows).
    pub fn val_batch(&self, batch: usize) -> (Vec<f32>, Vec<f32>, usize) {
        let (xs_src, ys_src) = if self.x_val.is_empty() {
            (&self.x_train, &self.y_train)
        } else {
            (&self.x_val, &self.y_val)
        };
        let n = xs_src.len().min(batch);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..batch {
            let idx = i % xs_src.len();
            xs.extend_from_slice(&xs_src[idx]);
            ys.extend_from_slice(&ys_src[idx]);
        }
        (xs, ys, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(n: usize) -> Vec<Datapoint> {
        (0..n).map(|i| (vec![i as f32; 3], vec![i as f32])).collect()
    }

    #[test]
    fn add_splits_train_val() {
        let mut d = Dataset::new(0.25, 0);
        d.add(&pts(200));
        assert_eq!(d.n_train() + d.n_val(), 200);
        assert!(d.n_val() > 20 && d.n_val() < 80, "val {}", d.n_val());
        assert_eq!(d.total_added(), 200);
    }

    #[test]
    fn first_sample_goes_to_train() {
        let mut d = Dataset::new(0.99, 0);
        d.add(&pts(1));
        assert_eq!(d.n_train(), 1);
    }

    #[test]
    fn rolling_window_caps_and_drops_oldest() {
        let mut d = Dataset::new(0.0, 0).with_rolling_window(10);
        d.add(&pts(25));
        assert_eq!(d.n_train(), 10);
        // oldest dropped: first remaining input should be from the tail
        assert!(d.x_train[0][0] >= 15.0);
    }

    #[test]
    fn minibatch_shapes() {
        let mut d = Dataset::new(0.0, 0);
        d.add(&pts(5));
        let (xs, ys) = d.minibatch(8);
        assert_eq!(xs.len(), 8 * 3);
        assert_eq!(ys.len(), 8);
    }

    #[test]
    fn val_batch_pads_by_cycling() {
        let mut d = Dataset::new(0.0, 0);
        d.add(&pts(3));
        let (xs, _ys, real) = d.val_batch(7);
        assert_eq!(xs.len(), 7 * 3);
        assert_eq!(real, 3);
    }

    #[test]
    fn add_view_identical_to_add() {
        let points = pts(60);
        let mut nested = Dataset::new(0.3, 7).with_rolling_window(25);
        nested.add(&points);
        let mut flat = Dataset::new(0.3, 7).with_rolling_window(25);
        let block = batch::DatapointBlock::from_pairs(&points);
        flat.add_view(&block.view());
        assert_eq!(flat.x_train, nested.x_train);
        assert_eq!(flat.y_train, nested.y_train);
        assert_eq!(flat.x_val, nested.x_val);
        assert_eq!(flat.y_val, nested.y_val);
        assert_eq!(flat.total_added(), nested.total_added());
    }

    #[test]
    fn deterministic_under_seed() {
        let mut a = Dataset::new(0.3, 42);
        let mut b = Dataset::new(0.3, 42);
        a.add(&pts(50));
        b.add(&pts(50));
        assert_eq!(a.n_train(), b.n_train());
    }
}
