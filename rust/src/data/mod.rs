//! Labeled-dataset store: train/val split, rolling window (SI use case 2).

use crate::rng::Rng;

pub mod batch;

pub use batch::{
    Batch, BatchView, DatapointBlock, DatapointView, PayloadBatch, RowBlock, RowQueue, SharedRows,
};

use batch::RowQueue as Split;

/// One labeled sample: `(input, label)` flat arrays (paper wire format).
pub type Datapoint = (Vec<f32>, Vec<f32>);

/// Training/validation store with optional rolling window.
///
/// Storage is flat: each split is a [`RowQueue`] — one contiguous `f32`
/// buffer plus per-row bounds — so adding a sample appends values instead
/// of boxing a `Vec` per row, and the rolling window drops index entries
/// (lazy buffer compaction) instead of `remove(0)`-shifting every row.
/// [`Dataset::minibatch`] gathers sampled rows into a reused scratch
/// buffer, so steady-state training allocates nothing regardless of the
/// window size.
///
/// The rolling window implements the SI use-case-2 recommendation: "newly
/// incoming xTB-labeled samples are added after every single training epoch,
/// and old samples are removed to keep the training set size constant".
#[derive(Debug, Clone)]
pub struct Dataset {
    x_train: Split,
    y_train: Split,
    x_val: Split,
    y_val: Split,
    /// Fraction of incoming data routed to validation.
    pub val_split: f64,
    /// If set, training set is capped at this size (oldest dropped first).
    pub rolling_window: Option<usize>,
    rng: Rng,
    total_added: u64,
    /// Minibatch gather scratch, reused across calls.
    mb_x: Vec<f32>,
    mb_y: Vec<f32>,
}

impl Dataset {
    pub fn new(val_split: f64, seed: u64) -> Self {
        Dataset {
            x_train: Split::new(),
            y_train: Split::new(),
            x_val: Split::new(),
            y_val: Split::new(),
            val_split,
            rolling_window: None,
            rng: Rng::new(seed),
            total_added: 0,
            mb_x: Vec::new(),
            mb_y: Vec::new(),
        }
    }

    pub fn with_rolling_window(mut self, cap: usize) -> Self {
        self.rolling_window = Some(cap);
        self
    }

    /// Add labeled datapoints, assigning each to train or val
    /// (paper SI §S5 `add_trainingset`).
    pub fn add(&mut self, points: &[Datapoint]) {
        for (x, y) in points {
            self.add_one(x, y);
        }
        self.apply_window();
    }

    /// Flat-training-plane twin of [`Dataset::add`]: pairs stream in as
    /// borrowed views (typically straight over a decoded `TAG_TRAIN_DATA`
    /// payload), so no intermediate nested pair list is materialized. The
    /// per-point split logic — and therefore the RNG stream — is shared
    /// with [`Dataset::add`], so both paths produce identical datasets.
    pub fn add_view(&mut self, points: &DatapointView<'_>) {
        for (x, y) in points.iter() {
            self.add_one(x, y);
        }
        self.apply_window();
    }

    fn add_one(&mut self, x: &[f32], y: &[f32]) {
        self.total_added += 1;
        if self.rng.f64() < self.val_split && !self.x_train.is_empty() {
            self.x_val.push_row(x);
            self.y_val.push_row(y);
        } else {
            self.x_train.push_row(x);
            self.y_train.push_row(y);
        }
    }

    fn apply_window(&mut self) {
        if let Some(cap) = self.rolling_window {
            let over = self.x_train.len().saturating_sub(cap);
            self.x_train.drop_front(over);
            self.y_train.drop_front(over);
            // keep validation bounded too (half the window)
            let over = self.x_val.len().saturating_sub(cap / 2 + 1);
            self.x_val.drop_front(over);
            self.y_val.drop_front(over);
        }
    }

    pub fn n_train(&self) -> usize {
        self.x_train.len()
    }

    pub fn n_val(&self) -> usize {
        self.x_val.len()
    }

    pub fn total_added(&self) -> u64 {
        self.total_added
    }

    pub fn is_empty(&self) -> bool {
        self.x_train.is_empty()
    }

    /// Training input row `i` (0 = oldest retained).
    pub fn train_input(&self, i: usize) -> &[f32] {
        self.x_train.row(i)
    }

    /// Training label row `i` (0 = oldest retained).
    pub fn train_label(&self, i: usize) -> &[f32] {
        self.y_train.row(i)
    }

    /// Iterate the retained training inputs oldest-first (checkpoint I/O).
    pub fn train_inputs(&self) -> impl Iterator<Item = &[f32]> {
        self.x_train.iter()
    }

    /// Iterate the retained training labels oldest-first (checkpoint I/O).
    pub fn train_labels(&self) -> impl Iterator<Item = &[f32]> {
        self.y_train.iter()
    }

    /// Sample a training minibatch of exactly `batch` rows (with
    /// replacement if the set is smaller — the fixed-shape HLO train step
    /// needs full batches). The returned slices borrow the dataset's
    /// reused gather scratch: valid until the next `&mut self` call,
    /// zero allocations in steady state.
    pub fn minibatch(&mut self, batch: usize) -> (&[f32], &[f32]) {
        assert!(!self.x_train.is_empty(), "minibatch from empty dataset");
        let n = self.x_train.len();
        self.mb_x.clear();
        self.mb_y.clear();
        for _ in 0..batch {
            let i = self.rng.below(n);
            self.mb_x.extend_from_slice(self.x_train.row(i));
            self.mb_y.extend_from_slice(self.y_train.row(i));
        }
        (&self.mb_x, &self.mb_y)
    }

    /// Flattened validation set (or train set if no val yet), padded by
    /// cycling to exactly `batch` rows. Returns (x, y, real_rows).
    pub fn val_batch(&self, batch: usize) -> (Vec<f32>, Vec<f32>, usize) {
        let (xs_src, ys_src) = if self.x_val.is_empty() {
            (&self.x_train, &self.y_train)
        } else {
            (&self.x_val, &self.y_val)
        };
        let n = xs_src.len().min(batch);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..batch {
            let idx = i % xs_src.len();
            xs.extend_from_slice(xs_src.row(idx));
            ys.extend_from_slice(ys_src.row(idx));
        }
        (xs, ys, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(n: usize) -> Vec<Datapoint> {
        (0..n).map(|i| (vec![i as f32; 3], vec![i as f32])).collect()
    }

    fn nested(d: &Dataset) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        (
            d.train_inputs().map(|x| x.to_vec()).collect(),
            d.train_labels().map(|y| y.to_vec()).collect(),
        )
    }

    #[test]
    fn add_splits_train_val() {
        let mut d = Dataset::new(0.25, 0);
        d.add(&pts(200));
        assert_eq!(d.n_train() + d.n_val(), 200);
        assert!(d.n_val() > 20 && d.n_val() < 80, "val {}", d.n_val());
        assert_eq!(d.total_added(), 200);
    }

    #[test]
    fn first_sample_goes_to_train() {
        let mut d = Dataset::new(0.99, 0);
        d.add(&pts(1));
        assert_eq!(d.n_train(), 1);
    }

    #[test]
    fn rolling_window_caps_and_drops_oldest() {
        let mut d = Dataset::new(0.0, 0).with_rolling_window(10);
        d.add(&pts(25));
        assert_eq!(d.n_train(), 10);
        // oldest dropped: first remaining input should be from the tail
        assert!(d.train_input(0)[0] >= 15.0);
    }

    #[test]
    fn minibatch_shapes() {
        let mut d = Dataset::new(0.0, 0);
        d.add(&pts(5));
        let (xs, ys) = d.minibatch(8);
        assert_eq!(xs.len(), 8 * 3);
        assert_eq!(ys.len(), 8);
    }

    /// The flat store must not perturb the sampling stream: the RNG draw
    /// sequence (one split draw per added point, one index draw per
    /// minibatch row) matches a reference nested implementation exactly.
    #[test]
    fn minibatch_rng_stream_matches_nested_reference() {
        let mut d = Dataset::new(0.3, 11).with_rolling_window(16);
        // reference: the pre-flat nested implementation, inlined
        let mut rng = Rng::new(11);
        let mut rx: Vec<Vec<f32>> = vec![];
        let mut ry: Vec<Vec<f32>> = vec![];
        for (x, y) in pts(40) {
            d.add(&[(x.clone(), y.clone())]);
            if rng.f64() < 0.3 && !rx.is_empty() {
                // val row: the flat store consumes the same single draw
            } else {
                rx.push(x);
                ry.push(y);
            }
            while rx.len() > 16 {
                rx.remove(0);
                ry.remove(0);
            }
        }
        assert_eq!(d.n_train(), rx.len());
        for round in 0..5 {
            let (xs, ys) = d.minibatch(6);
            let mut ex = Vec::new();
            let mut ey = Vec::new();
            for _ in 0..6 {
                let i = rng.below(rx.len());
                ex.extend_from_slice(&rx[i]);
                ey.extend_from_slice(&ry[i]);
            }
            assert_eq!(xs, ex.as_slice(), "round {round} inputs diverge");
            assert_eq!(ys, ey.as_slice(), "round {round} labels diverge");
        }
    }

    #[test]
    fn val_batch_pads_by_cycling() {
        let mut d = Dataset::new(0.0, 0);
        d.add(&pts(3));
        let (xs, _ys, real) = d.val_batch(7);
        assert_eq!(xs.len(), 7 * 3);
        assert_eq!(real, 3);
    }

    #[test]
    fn add_view_identical_to_add() {
        let points = pts(60);
        let mut a = Dataset::new(0.3, 7).with_rolling_window(25);
        a.add(&points);
        let mut b = Dataset::new(0.3, 7).with_rolling_window(25);
        let block = batch::DatapointBlock::from_pairs(&points);
        b.add_view(&block.view());
        assert_eq!(nested(&a), nested(&b));
        assert_eq!(a.n_val(), b.n_val());
        assert_eq!(a.total_added(), b.total_added());
    }

    #[test]
    fn deterministic_under_seed() {
        let mut a = Dataset::new(0.3, 42);
        let mut b = Dataset::new(0.3, 42);
        a.add(&pts(50));
        b.add(&pts(50));
        assert_eq!(a.n_train(), b.n_train());
    }
}
