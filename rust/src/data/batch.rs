//! Contiguous batch storage: the crate's flat data plane.
//!
//! PR 1 batched the prediction traffic and PR 2 made the transport
//! zero-copy, but between the two the in-memory representation was still
//! `Vec<Vec<f32>>` — one heap allocation per row on every decode, predict,
//! and reduce. This module provides the contiguous replacements:
//!
//! * [`Batch`] — owned `rows × width` over one flat `Vec<f32>`; what
//!   [`crate::kernels::Model::predict_batch`] returns.
//! * [`BatchView`] — borrowed strided view (over a decoded frame, a
//!   [`Payload`], or a [`Batch`]); row access is pointer arithmetic, never
//!   an allocation.
//! * [`RowBlock`] — owned contiguous rows with per-row bounds (tolerates
//!   ragged rows); the staging form for selection outputs and dispatched
//!   micro-batches.
//! * [`RowQueue`] — flat FIFO of rows (generator request queue, oracle
//!   staging buffer): push/pop move `f32`s within one growing buffer
//!   instead of boxing each row.
//! * [`SharedRows`] / [`PayloadBatch`] — payload-backed rows: the backing
//!   buffer is a shared [`Payload`], so each row can be shipped to a
//!   different destination as a zero-copy [`Payload::slice`].
//! * [`DatapointBlock`] / [`DatapointView`] — the *training* plane's
//!   staging form: paired input/label [`RowBlock`]s (owned, contiguous)
//!   and the borrowed per-pair view over either a block or a decoded
//!   `TAG_TRAIN_DATA` payload. They replace boxed `Vec<(Vec, Vec)>`
//!   datapoint lists between the oracle result and `Model::add_trainingset`.
//!
//! The uniform-width types reject ragged input (`Option` constructors);
//! ragged data stays on the legacy nested-`Vec` paths, which every consumer
//! keeps as a fallback.

use std::collections::VecDeque;

use crate::comm::bus::Payload;

// ---------------------------------------------------------------------------
// Batch (owned, uniform width)
// ---------------------------------------------------------------------------

/// Owned contiguous batch: `rows × width` values in one flat `Vec<f32>`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Batch {
    data: Vec<f32>,
    rows: usize,
    width: usize,
}

impl Batch {
    /// An empty batch (0 rows) that will adopt the width of the first
    /// pushed row.
    pub fn new() -> Self {
        Batch::default()
    }

    /// A zero-filled `rows × width` batch.
    pub fn zeros(rows: usize, width: usize) -> Self {
        Batch { data: vec![0.0; rows * width], rows, width }
    }

    /// An empty batch with reserved capacity for `rows × width` values.
    pub fn with_capacity(rows: usize, width: usize) -> Self {
        Batch { data: Vec::with_capacity(rows * width), rows: 0, width }
    }

    /// Wrap an existing flat buffer. `None` unless `data.len() == rows * width`.
    pub fn from_flat(data: Vec<f32>, rows: usize, width: usize) -> Option<Self> {
        if data.len() != rows.checked_mul(width)? {
            return None;
        }
        Some(Batch { data, rows, width })
    }

    /// Stack equal-width rows into a batch. `None` if the rows are ragged.
    pub fn from_rows<S: AsRef<[f32]>>(rows: &[S]) -> Option<Self> {
        let width = rows.first().map(|r| r.as_ref().len()).unwrap_or(0);
        let mut data = Vec::with_capacity(rows.len() * width);
        for r in rows {
            if r.as_ref().len() != width {
                return None;
            }
            data.extend_from_slice(r.as_ref());
        }
        Some(Batch { data, rows: rows.len(), width })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Append one row. An empty batch adopts the row's width; afterwards
    /// widths must match (panics otherwise — callers stay uniform).
    pub fn push_row(&mut self, row: &[f32]) {
        if self.rows == 0 {
            self.width = row.len();
        }
        assert_eq!(row.len(), self.width, "ragged row pushed into Batch");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Append one row assembled from consecutive parts (e.g. an energy
    /// block followed by a force block) without a temporary row buffer.
    pub fn push_row_concat(&mut self, parts: &[&[f32]]) {
        let len: usize = parts.iter().map(|p| p.len()).sum();
        if self.rows == 0 {
            self.width = len;
        }
        assert_eq!(len, self.width, "ragged row pushed into Batch");
        for p in parts {
            self.data.extend_from_slice(p);
        }
        self.rows += 1;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.width..(i + 1) * self.width]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.width..(i + 1) * self.width]
    }

    /// The whole `rows × width` backing buffer.
    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    pub fn view(&self) -> BatchView<'_> {
        BatchView { data: &self.data, rows: self.rows, width: self.width }
    }

    pub fn iter(&self) -> impl ExactSizeIterator<Item = &[f32]> {
        (0..self.rows).map(move |i| self.row(i))
    }

    /// Materialize nested rows (legacy-API shim).
    pub fn to_nested(&self) -> Vec<Vec<f32>> {
        (0..self.rows).map(|i| self.row(i).to_vec()).collect()
    }

    /// Reinterpret as a (trivially uniform) [`RowBlock`].
    pub fn into_row_block(self) -> RowBlock {
        let ends = (1..=self.rows).map(|i| i * self.width).collect();
        RowBlock { data: self.data, ends }
    }
}

// ---------------------------------------------------------------------------
// BatchView (borrowed, uniform width)
// ---------------------------------------------------------------------------

/// Borrowed strided view of `rows × width` values in one contiguous slice.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchView<'a> {
    data: &'a [f32],
    rows: usize,
    width: usize,
}

impl<'a> BatchView<'a> {
    /// Wrap a flat slice. `None` unless `data.len() == rows * width`.
    pub fn from_parts(data: &'a [f32], rows: usize, width: usize) -> Option<Self> {
        if data.len() != rows.checked_mul(width)? {
            return None;
        }
        Some(BatchView { data, rows, width })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.width..(i + 1) * self.width]
    }

    pub fn flat(&self) -> &'a [f32] {
        self.data
    }

    pub fn iter(&self) -> impl ExactSizeIterator<Item = &'a [f32]> + 'a {
        let v = *self;
        (0..v.rows).map(move |i| v.row(i))
    }

    pub fn to_batch(&self) -> Batch {
        Batch { data: self.data.to_vec(), rows: self.rows, width: self.width }
    }

    /// Materialize an owned (trivially uniform) [`RowBlock`] — one flat
    /// copy, no per-row boxing.
    pub fn to_row_block(&self) -> RowBlock {
        let ends = (1..=self.rows).map(|i| i * self.width).collect();
        RowBlock { data: self.data.to_vec(), ends }
    }

    /// Materialize nested rows (legacy-API shim).
    pub fn to_nested(&self) -> Vec<Vec<f32>> {
        (0..self.rows).map(|i| self.row(i).to_vec()).collect()
    }
}

// ---------------------------------------------------------------------------
// RowBlock (owned, contiguous, possibly ragged)
// ---------------------------------------------------------------------------

/// Owned contiguous rows with per-row end offsets. Unlike [`Batch`] the rows
/// may be ragged, so it can stage anything the nested-`Vec` APIs could —
/// while still storing every value in one buffer and allocating nothing per
/// row in steady state.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RowBlock {
    data: Vec<f32>,
    /// `ends[i]` = end offset of row `i`; row `i` starts at `ends[i-1]` (0
    /// for the first).
    ends: Vec<usize>,
}

impl RowBlock {
    pub fn new() -> Self {
        RowBlock::default()
    }

    pub fn with_capacity(rows: usize, values: usize) -> Self {
        RowBlock { data: Vec::with_capacity(values), ends: Vec::with_capacity(rows) }
    }

    pub fn from_rows<S: AsRef<[f32]>>(rows: &[S]) -> Self {
        let total = rows.iter().map(|r| r.as_ref().len()).sum();
        let mut out = RowBlock::with_capacity(rows.len(), total);
        for r in rows {
            out.push_row(r.as_ref());
        }
        out
    }

    pub fn len(&self) -> usize {
        self.ends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ends.is_empty()
    }

    /// Total stored values across all rows.
    pub fn total_values(&self) -> usize {
        self.data.len()
    }

    pub fn push_row(&mut self, row: &[f32]) {
        self.data.extend_from_slice(row);
        self.ends.push(self.data.len());
    }

    /// Append one row assembled from consecutive parts (e.g. an energy
    /// block followed by a force block) without a temporary row buffer —
    /// the ragged twin of [`Batch::push_row_concat`].
    pub fn push_row_concat(&mut self, parts: &[&[f32]]) {
        for p in parts {
            self.data.extend_from_slice(p);
        }
        self.ends.push(self.data.len());
    }

    /// Reserve space for `rows` more rows totalling `values` more values,
    /// so a following run of [`RowBlock::push_row`]s performs at most one
    /// (re)allocation per backing buffer regardless of the row count.
    pub fn reserve(&mut self, rows: usize, values: usize) {
        self.data.reserve(values);
        self.ends.reserve(rows);
    }

    /// `(start, end)` bounds of row `i` in [`RowBlock::flat`].
    pub fn bounds(&self, i: usize) -> (usize, usize) {
        let start = if i == 0 { 0 } else { self.ends[i - 1] };
        (start, self.ends[i])
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let (s, e) = self.bounds(i);
        &self.data[s..e]
    }

    pub fn iter(&self) -> impl ExactSizeIterator<Item = &[f32]> {
        (0..self.len()).map(move |i| self.row(i))
    }

    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    pub fn clear(&mut self) {
        self.data.clear();
        self.ends.clear();
    }

    /// All rows share one width (an empty block is uniform with width 0).
    pub fn as_view(&self) -> Option<BatchView<'_>> {
        let rows = self.len();
        if rows == 0 {
            return Some(BatchView { data: &[], rows: 0, width: 0 });
        }
        let width = self.ends[0];
        for i in 1..rows {
            if self.ends[i] - self.ends[i - 1] != width {
                return None;
            }
        }
        Some(BatchView { data: &self.data, rows, width })
    }

    /// Materialize nested rows (legacy-API shim).
    pub fn to_nested(&self) -> Vec<Vec<f32>> {
        (0..self.len()).map(|i| self.row(i).to_vec()).collect()
    }

    /// Move the backing buffer into a shared [`Payload`] so each row can be
    /// scattered as a zero-copy payload slice. One ingest copy total,
    /// regardless of row count.
    pub fn into_shared(self) -> SharedRows {
        SharedRows { payload: Payload::from(self.data), ends: self.ends }
    }
}

// ---------------------------------------------------------------------------
// DatapointBlock / DatapointView (flat training plane)
// ---------------------------------------------------------------------------

/// Contiguous labeled-data staging: paired input/label [`RowBlock`]s.
///
/// This is the training plane's twin of [`RowBlock`]: every input value
/// lives in one flat buffer and every label value in another, so
/// accumulating oracle results toward a retraining flush
/// (`coordinator::buffers::TrainBuffer`), encoding the flush
/// (`codec::encode_train_block_into`) and staging a model's training set
/// all move `f32`s without boxing a `(Vec, Vec)` pair per sample.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DatapointBlock {
    inputs: RowBlock,
    labels: RowBlock,
}

impl DatapointBlock {
    pub fn new() -> Self {
        DatapointBlock::default()
    }

    pub fn with_capacity(points: usize, input_values: usize, label_values: usize) -> Self {
        DatapointBlock {
            inputs: RowBlock::with_capacity(points, input_values),
            labels: RowBlock::with_capacity(points, label_values),
        }
    }

    /// Build from nested `(input, label)` pairs (legacy-API shim).
    pub fn from_pairs<X: AsRef<[f32]>, Y: AsRef<[f32]>>(pairs: &[(X, Y)]) -> Self {
        let xv: usize = pairs.iter().map(|(x, _)| x.as_ref().len()).sum();
        let yv: usize = pairs.iter().map(|(_, y)| y.as_ref().len()).sum();
        let mut out = DatapointBlock::with_capacity(pairs.len(), xv, yv);
        for (x, y) in pairs {
            out.push(x.as_ref(), y.as_ref());
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }

    /// Append one labeled sample; both slices copy into the flat buffers.
    pub fn push(&mut self, input: &[f32], label: &[f32]) {
        self.inputs.push_row(input);
        self.labels.push_row(label);
    }

    pub fn input(&self, i: usize) -> &[f32] {
        self.inputs.row(i)
    }

    pub fn label(&self, i: usize) -> &[f32] {
        self.labels.row(i)
    }

    pub fn pair(&self, i: usize) -> (&[f32], &[f32]) {
        (self.inputs.row(i), self.labels.row(i))
    }

    pub fn iter(&self) -> impl ExactSizeIterator<Item = (&[f32], &[f32])> {
        (0..self.len()).map(move |i| self.pair(i))
    }

    pub fn total_input_values(&self) -> usize {
        self.inputs.total_values()
    }

    pub fn total_label_values(&self) -> usize {
        self.labels.total_values()
    }

    pub fn clear(&mut self) {
        self.inputs.clear();
        self.labels.clear();
    }

    /// Borrow the whole block as a [`DatapointView`] (one bounds-list
    /// allocation, independent of the point count).
    pub fn view(&self) -> DatapointView<'_> {
        let mut bounds = Vec::with_capacity(self.len());
        for i in 0..self.len() {
            let (xs, xe) = self.inputs.bounds(i);
            let (ys, ye) = self.labels.bounds(i);
            bounds.push((xs, xe, ys, ye));
        }
        DatapointView { xs: self.inputs.flat(), ys: self.labels.flat(), bounds }
    }

    /// Append every pair of `v`, reserving exactly once per backing buffer
    /// first — the whole extension performs O(1) allocations regardless of
    /// how many points the view carries.
    pub fn extend_from_view(&mut self, v: &DatapointView<'_>) {
        self.inputs.reserve(v.len(), v.total_input_values());
        self.labels.reserve(v.len(), v.total_label_values());
        for (x, y) in v.iter() {
            self.inputs.push_row(x);
            self.labels.push_row(y);
        }
    }

    /// Materialize nested pairs (legacy-API shim).
    pub fn to_nested(&self) -> Vec<(Vec<f32>, Vec<f32>)> {
        (0..self.len())
            .map(|i| (self.inputs.row(i).to_vec(), self.labels.row(i).to_vec()))
            .collect()
    }
}

/// Borrowed labeled samples: per-pair `(input, label)` subslices into up to
/// two backing buffers.
///
/// Two producers share this one consumer-facing type: a
/// [`DatapointBlock::view`] points `xs`/`ys` at the block's separate
/// input/label buffers, while `codec::decode_train_block_views` points both
/// at the *same* decoded wire payload (whose layout interleaves
/// `x0 y0 x1 y1 ...`). Either way, reading a pair is pointer arithmetic —
/// never an allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct DatapointView<'a> {
    xs: &'a [f32],
    ys: &'a [f32],
    /// Per-point `(x_start, x_end, y_start, y_end)`; `x` bounds index into
    /// `xs`, `y` bounds into `ys`.
    bounds: Vec<(usize, usize, usize, usize)>,
}

impl<'a> DatapointView<'a> {
    /// Wrap backing buffers + bounds. `None` if any bound is out of range.
    pub fn from_bounds(
        xs: &'a [f32],
        ys: &'a [f32],
        bounds: Vec<(usize, usize, usize, usize)>,
    ) -> Option<Self> {
        for &(xs_, xe, ys_, ye) in &bounds {
            if xs_ > xe || xe > xs.len() || ys_ > ye || ye > ys.len() {
                return None;
            }
        }
        Some(DatapointView { xs, ys, bounds })
    }

    pub fn len(&self) -> usize {
        self.bounds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bounds.is_empty()
    }

    pub fn input(&self, i: usize) -> &'a [f32] {
        let (s, e, _, _) = self.bounds[i];
        &self.xs[s..e]
    }

    pub fn label(&self, i: usize) -> &'a [f32] {
        let (_, _, s, e) = self.bounds[i];
        &self.ys[s..e]
    }

    pub fn pair(&self, i: usize) -> (&'a [f32], &'a [f32]) {
        (self.input(i), self.label(i))
    }

    pub fn iter(&self) -> impl ExactSizeIterator<Item = (&'a [f32], &'a [f32])> + '_ {
        (0..self.len()).map(move |i| self.pair(i))
    }

    /// Total input values across all points (no allocation).
    pub fn total_input_values(&self) -> usize {
        self.bounds.iter().map(|&(s, e, _, _)| e - s).sum()
    }

    /// Total label values across all points (no allocation).
    pub fn total_label_values(&self) -> usize {
        self.bounds.iter().map(|&(_, _, s, e)| e - s).sum()
    }

    /// Materialize an owned [`DatapointBlock`] (one flat copy per buffer).
    pub fn to_block(&self) -> DatapointBlock {
        let mut out = DatapointBlock::new();
        out.extend_from_view(self);
        out
    }

    /// Materialize nested pairs (legacy-API shim).
    pub fn to_nested(&self) -> Vec<(Vec<f32>, Vec<f32>)> {
        (0..self.len())
            .map(|i| (self.input(i).to_vec(), self.label(i).to_vec()))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// SharedRows / PayloadBatch (payload-backed)
// ---------------------------------------------------------------------------

/// Rows backed by one shared [`Payload`]: per-row access yields payload
/// slices (refcount bumps), so scattering n rows to n destinations costs
/// zero copies.
#[derive(Debug, Clone)]
pub struct SharedRows {
    payload: Payload,
    ends: Vec<usize>,
}

impl SharedRows {
    /// Wrap a payload with explicit row bounds: row `i` spans
    /// `ends[i-1]..ends[i]` (row 0 starts at 0). `None` unless the bounds
    /// are monotonically non-decreasing and stay inside the payload — the
    /// validated entry point for rows decoded straight off a wire frame.
    pub fn from_payload_ends(payload: Payload, ends: Vec<usize>) -> Option<Self> {
        let mut prev = 0usize;
        for &e in &ends {
            if e < prev || e > payload.len() {
                return None;
            }
            prev = e;
        }
        Some(SharedRows { payload, ends })
    }

    pub fn len(&self) -> usize {
        self.ends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ends.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let start = if i == 0 { 0 } else { self.ends[i - 1] };
        &self.payload[start..self.ends[i]]
    }

    /// Row `i` as a zero-copy slice of the shared payload.
    pub fn row_payload(&self, i: usize) -> Payload {
        let start = if i == 0 { 0 } else { self.ends[i - 1] };
        self.payload.slice(start..self.ends[i])
    }

    pub fn iter(&self) -> impl ExactSizeIterator<Item = &[f32]> {
        (0..self.len()).map(move |i| self.row(i))
    }

    /// Materialize as nested rows — the legacy-`Utils` boundary only;
    /// everything upstream of the reduction stays payload-backed.
    pub fn to_nested(&self) -> Vec<Vec<f32>> {
        self.iter().map(|r| r.to_vec()).collect()
    }
}

/// A uniform `rows × width` batch stored inside a shared [`Payload`] —
/// typically the rows region of a received `PredictBatchResult` frame, held
/// alive by refcount instead of being re-boxed into nested `Vec`s.
#[derive(Debug, Clone)]
pub struct PayloadBatch {
    payload: Payload,
    rows: usize,
    width: usize,
}

impl PayloadBatch {
    /// Wrap a payload. `None` unless `payload.len() == rows * width`.
    pub fn from_payload(payload: Payload, rows: usize, width: usize) -> Option<Self> {
        if payload.len() != rows.checked_mul(width)? {
            return None;
        }
        Some(PayloadBatch { payload, rows, width })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn view(&self) -> BatchView<'_> {
        BatchView { data: self.payload.as_slice(), rows: self.rows, width: self.width }
    }
}

// ---------------------------------------------------------------------------
// RowQueue (flat FIFO)
// ---------------------------------------------------------------------------

/// Flat FIFO of rows: one growing `f32` buffer plus per-row `(start, len)`
/// metadata. Push appends to the buffer; pop returns a borrowed row and
/// advances the head. The buffer compacts lazily once at least half of it
/// is dead space in front of the head, so steady-state traffic moves values
/// without per-row heap allocations.
#[derive(Debug, Clone, Default)]
pub struct RowQueue {
    data: Vec<f32>,
    rows: VecDeque<(usize, usize)>,
    /// Dead values in `data` before the first live row.
    front_waste: usize,
}

impl RowQueue {
    pub fn new() -> Self {
        RowQueue::default()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn maybe_compact(&mut self) {
        if self.rows.is_empty() {
            self.data.clear();
            self.front_waste = 0;
            return;
        }
        if self.front_waste < 1024 || self.front_waste < self.data.len() / 2 {
            return;
        }
        let shift = self.front_waste;
        self.data.drain(..shift);
        for (start, _) in self.rows.iter_mut() {
            *start -= shift;
        }
        self.front_waste = 0;
    }

    pub fn push_row(&mut self, row: &[f32]) {
        self.maybe_compact();
        let start = self.data.len();
        self.data.extend_from_slice(row);
        self.rows.push_back((start, row.len()));
    }

    /// Borrow row `i` (0 = front) without removing it.
    pub fn row(&self, i: usize) -> &[f32] {
        let (start, len) = self.rows[i];
        &self.data[start..start + len]
    }

    /// Pop the front row, returning a borrow of its values (valid until the
    /// next `&mut` call). No allocation, no copy.
    pub fn pop_front_row(&mut self) -> Option<&[f32]> {
        let (start, len) = self.rows.pop_front()?;
        self.front_waste = start + len;
        Some(&self.data[start..start + len])
    }

    /// Drop the front `n` rows (already consumed via [`RowQueue::row`]).
    pub fn drop_front(&mut self, n: usize) {
        for _ in 0..n {
            if let Some((start, len)) = self.rows.pop_front() {
                self.front_waste = start + len;
            }
        }
    }

    /// Drop the newest row (capacity eviction). Reclaims its values when
    /// they sit at the buffer's tail (they always do under push/pop usage).
    pub fn drop_back(&mut self) -> bool {
        match self.rows.pop_back() {
            Some((start, len)) => {
                if start + len == self.data.len() {
                    self.data.truncate(start);
                }
                true
            }
            None => false,
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = &[f32]> {
        self.rows.iter().map(move |&(start, len)| &self.data[start..start + len])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_push_and_index() {
        let mut b = Batch::new();
        b.push_row(&[1.0, 2.0]);
        b.push_row(&[3.0, 4.0]);
        assert_eq!((b.rows(), b.width()), (2, 2));
        assert_eq!(b.row(1), &[3.0, 4.0]);
        assert_eq!(b.flat(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(b.to_nested(), vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(b.view().row(0), &[1.0, 2.0]);
    }

    #[test]
    fn batch_from_rows_rejects_ragged() {
        assert!(Batch::from_rows(&[vec![1.0], vec![2.0, 3.0]]).is_none());
        let b = Batch::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(b.rows(), 2);
        let empty = Batch::from_rows::<Vec<f32>>(&[]).unwrap();
        assert_eq!((empty.rows(), empty.width()), (0, 0));
    }

    #[test]
    fn batch_zero_width_rows() {
        let b = Batch::from_rows(&[vec![], Vec::<f32>::new()]).unwrap();
        assert_eq!((b.rows(), b.width()), (2, 0));
        assert_eq!(b.row(1), &[] as &[f32]);
        assert_eq!(b.iter().count(), 2);
    }

    #[test]
    fn view_from_parts_checks_shape() {
        let d = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let v = BatchView::from_parts(&d, 2, 3).unwrap();
        assert_eq!(v.row(1), &[4.0, 5.0, 6.0]);
        assert!(BatchView::from_parts(&d, 2, 2).is_none());
        assert!(BatchView::from_parts(&[], 0, 0).is_some());
    }

    #[test]
    fn row_block_ragged_and_uniform() {
        let mut rb = RowBlock::new();
        rb.push_row(&[1.0, 2.0]);
        rb.push_row(&[3.0]);
        assert_eq!(rb.len(), 2);
        assert_eq!(rb.row(1), &[3.0]);
        assert!(rb.as_view().is_none(), "ragged block has no uniform view");
        let rb2 = RowBlock::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let v = rb2.as_view().unwrap();
        assert_eq!((v.rows(), v.width()), (2, 2));
        assert_eq!(v.row(1), &[3.0, 4.0]);
        assert_eq!(RowBlock::new().as_view().unwrap().rows(), 0);
    }

    #[test]
    fn row_block_push_row_concat_matches_push_row() {
        let mut a = RowBlock::new();
        a.push_row_concat(&[&[1.0, 2.0], &[], &[3.0]]);
        a.push_row_concat(&[&[4.0]]);
        let mut b = RowBlock::new();
        b.push_row(&[1.0, 2.0, 3.0]);
        b.push_row(&[4.0]);
        assert_eq!(a, b);
    }

    #[test]
    fn row_block_into_shared_slices() {
        let rb = RowBlock::from_rows(&[vec![1.0, 2.0], vec![3.0], vec![]]);
        let shared = rb.into_shared();
        assert_eq!(shared.len(), 3);
        assert_eq!(shared.row(0), &[1.0, 2.0]);
        let p = shared.row_payload(1);
        assert_eq!(p.as_slice(), &[3.0]);
        assert_eq!(shared.row_payload(2).len(), 0);
        // row payloads share the block's backing buffer
        assert!(p.shared_handles() >= 2);
    }

    #[test]
    fn shared_rows_from_payload_ends_validates_bounds() {
        let p = Payload::from(vec![1.0, 2.0, 3.0, 4.0]);
        let s = SharedRows::from_payload_ends(p.clone(), vec![2, 2, 4]).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s.row(0), &[1.0, 2.0]);
        assert_eq!(s.row(1), &[] as &[f32]);
        assert_eq!(s.row(2), &[3.0, 4.0]);
        assert_eq!(s.to_nested(), vec![vec![1.0, 2.0], vec![], vec![3.0, 4.0]]);
        // decreasing or out-of-range bounds are rejected
        assert!(SharedRows::from_payload_ends(p.clone(), vec![3, 2]).is_none());
        assert!(SharedRows::from_payload_ends(p, vec![5]).is_none());
    }

    #[test]
    fn datapoint_block_pairs_roundtrip() {
        let pairs = vec![
            (vec![1.0f32, 2.0], vec![0.5f32]),
            (vec![3.0], vec![0.25, 0.75]),
            (vec![], vec![]),
        ];
        let block = DatapointBlock::from_pairs(&pairs);
        assert_eq!(block.len(), 3);
        assert_eq!(block.pair(1), (&[3.0f32][..], &[0.25f32, 0.75][..]));
        assert_eq!(block.to_nested(), pairs);
        assert_eq!(block.total_input_values(), 3);
        assert_eq!(block.total_label_values(), 3);
        let view = block.view();
        assert_eq!(view.len(), 3);
        assert_eq!(view.to_nested(), pairs);
        assert_eq!(view.pair(0), (&[1.0f32, 2.0][..], &[0.5f32][..]));
        assert_eq!(view.total_input_values(), 3);
        // extend_from_view appends a copy of every pair
        let mut grown = block.clone();
        grown.extend_from_view(&view);
        assert_eq!(grown.len(), 6);
        assert_eq!(grown.pair(4), block.pair(1));
        assert_eq!(view.to_block(), block);
    }

    #[test]
    fn datapoint_view_from_bounds_checks_ranges() {
        let xs = [1.0f32, 2.0, 3.0];
        let ys = [4.0f32];
        let v = DatapointView::from_bounds(&xs, &ys, vec![(0, 2, 0, 1)]).unwrap();
        assert_eq!(v.pair(0), (&[1.0f32, 2.0][..], &[4.0f32][..]));
        assert!(DatapointView::from_bounds(&xs, &ys, vec![(0, 4, 0, 1)]).is_none());
        assert!(DatapointView::from_bounds(&xs, &ys, vec![(2, 1, 0, 1)]).is_none());
        assert!(DatapointView::from_bounds(&xs, &ys, vec![(0, 1, 0, 2)]).is_none());
    }

    #[test]
    fn batch_view_to_row_block_matches_nested() {
        let b = Batch::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let rb = b.view().to_row_block();
        assert_eq!(rb.to_nested(), b.to_nested());
        assert_eq!(rb.as_view().unwrap().width(), 2);
    }

    #[test]
    fn batch_into_row_block_roundtrip() {
        let b = Batch::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let rb = b.clone().into_row_block();
        assert_eq!(rb.to_nested(), b.to_nested());
        assert_eq!(rb.as_view().unwrap().width(), 2);
    }

    #[test]
    fn payload_batch_views_payload() {
        let p = Payload::from(vec![1.0, 2.0, 3.0, 4.0]);
        let pb = PayloadBatch::from_payload(p.clone(), 2, 2).unwrap();
        assert_eq!(pb.view().row(1), &[3.0, 4.0]);
        assert!(PayloadBatch::from_payload(p, 3, 2).is_none());
    }

    #[test]
    fn row_queue_fifo_against_model() {
        let mut q = RowQueue::new();
        let mut model: VecDeque<Vec<f32>> = VecDeque::new();
        let mut k = 0u32;
        for step in 0..500u32 {
            if step % 3 == 2 {
                let got = q.pop_front_row().map(|r| r.to_vec());
                assert_eq!(got, model.pop_front());
            } else {
                let row: Vec<f32> = (0..(step % 7)).map(|j| (k + j) as f32).collect();
                k += 7;
                q.push_row(&row);
                model.push_back(row);
            }
            assert_eq!(q.len(), model.len());
        }
        while let Some(want) = model.pop_front() {
            assert_eq!(q.pop_front_row().unwrap(), want.as_slice());
        }
        assert!(q.pop_front_row().is_none());
    }

    #[test]
    fn row_queue_compacts_dead_space() {
        let mut q = RowQueue::new();
        for i in 0..2000 {
            q.push_row(&[i as f32; 4]);
            if i % 2 == 1 {
                q.pop_front_row();
            }
        }
        // half the pushed values were popped; compaction must keep the
        // buffer within a small factor of the live data
        assert!(q.data.len() <= 4 * (q.len() * 4).max(1024), "buffer never compacts");
        assert_eq!(q.row(0), q.iter().next().unwrap());
    }

    #[test]
    fn row_queue_drop_back_reclaims_tail() {
        let mut q = RowQueue::new();
        q.push_row(&[1.0]);
        q.push_row(&[2.0, 3.0]);
        assert!(q.drop_back());
        assert_eq!(q.data.len(), 1);
        assert_eq!(q.pop_front_row().unwrap(), &[1.0]);
        assert!(!q.drop_back());
    }
}
