//! Serial active-learning baseline (the paper's Fig. 1a).
//!
//! Runs the *same* kernel objects as the parallel workflow, but strictly
//! sequentially per iteration: (1) explore — `k` generation/prediction
//! steps; (2) label — the selected samples through the oracles (the only
//! parallelism the paper grants the serial baseline: `P` oracle workers,
//! eq. (1)'s `N/P` term); (3) train to completion. This is the comparator
//! for the Fig-1/S2 speedup benches.

use std::time::{Duration, Instant};

use crate::data::batch::{BatchView, DatapointBlock, RowBlock};
use crate::kernels::{Generator, Model, Oracle, Utils};
use crate::telemetry::KernelTelemetry;

/// Phase timings + counters of one serial run.
#[derive(Debug, Default, Clone)]
pub struct SerialReport {
    pub iterations: u64,
    pub oracle_labels: u64,
    pub wall: Duration,
    pub gen_time: Duration,
    pub oracle_time: Duration,
    pub train_time: Duration,
    pub final_loss: Option<f32>,
    pub telemetry: KernelTelemetry,
}

/// Serial workflow over user kernels.
pub struct SerialWorkflow {
    pub generators: Vec<Box<dyn Generator>>,
    pub oracles: Vec<Box<dyn Oracle>>,
    /// One model per committee member (predict + train roles fused —
    /// serial AL retrains the same weights it predicts with).
    pub models: Vec<Box<dyn Model>>,
    pub utils: Box<dyn Utils>,
    /// generation/prediction steps per AL iteration
    pub steps_per_iter: usize,
    /// AL iterations to run
    pub iterations: u64,
}

impl SerialWorkflow {
    pub fn run(&mut self) -> SerialReport {
        let mut report = SerialReport::default();
        let mut tel = KernelTelemetry::new("serial", 0);
        let t_start = Instant::now();
        // flat data plane: checked predictions (one row per generator),
        // stacked inputs and the selection staging all live in contiguous
        // row blocks reused across steps
        let mut last_checked: Option<RowBlock> = None;
        let mut inputs = RowBlock::new();
        let mut selected = RowBlock::new();

        for _ in 0..self.iterations {
            // ---- phase 1: explore (generation + prediction, sequential) ----
            let t0 = Instant::now();
            selected.clear();
            for _ in 0..self.steps_per_iter {
                inputs.clear();
                for (g, gen) in self.generators.iter_mut().enumerate() {
                    // guard against a utils impl returning fewer checked
                    // rows than generators (e.g. an empty committee)
                    let prev = last_checked
                        .as_ref()
                        .and_then(|c| (g < c.len()).then(|| c.row(g)));
                    let (_stop, data) = gen.generate_new_data(prev);
                    inputs.push_row(&data);
                }
                let (to_orcl, checked) = match inputs.as_view() {
                    Some(view) => {
                        // flat path: each committee member predicts the
                        // whole stacked batch into one contiguous buffer
                        let preds: Vec<RowBlock> =
                            self.models.iter_mut().map(|m| m.predict_batch(&view)).collect();
                        let views: Option<Vec<BatchView<'_>>> =
                            preds.iter().map(|b| b.as_view()).collect();
                        match views {
                            Some(views) => self.utils.prediction_check_batch(&view, &views),
                            None => {
                                // a model produced ragged rows: reduce on
                                // the legacy nested path
                                let nested = inputs.to_nested();
                                let preds_per_model: Vec<Vec<Vec<f32>>> =
                                    preds.iter().map(|b| b.to_nested()).collect();
                                let (o, c) =
                                    self.utils.prediction_check(&nested, &preds_per_model);
                                (RowBlock::from_rows(&o), RowBlock::from_rows(&c))
                            }
                        }
                    }
                    None => {
                        // ragged generators: legacy nested path
                        let nested = inputs.to_nested();
                        let preds_per_model: Vec<Vec<Vec<f32>>> =
                            self.models.iter_mut().map(|m| m.predict(&nested)).collect();
                        let (o, c) = self.utils.prediction_check(&nested, &preds_per_model);
                        (RowBlock::from_rows(&o), RowBlock::from_rows(&c))
                    }
                };
                for i in 0..to_orcl.len() {
                    selected.push_row(to_orcl.row(i));
                }
                last_checked = Some(checked);
            }
            report.gen_time += t0.elapsed();
            tel.record("generate", t0.elapsed());

            // ---- phase 2: label (P-parallel oracles — eq. (1)'s N/P) ----
            let t1 = Instant::now();
            let labeled = label_parallel(&mut self.oracles, &selected);
            report.oracle_labels += labeled.len() as u64;
            report.oracle_time += t1.elapsed();
            tel.record("label", t1.elapsed());

            // ---- phase 3: train to completion (flat: every model reads
            // the same borrowed view over the contiguous labeled block) ----
            let t2 = Instant::now();
            if !labeled.is_empty() {
                let view = labeled.view();
                for m in self.models.iter_mut() {
                    m.add_trainingset_batch(&view);
                    m.retrain(&mut || false);
                    report.final_loss = m.last_loss().or(report.final_loss);
                }
            }
            report.train_time += t2.elapsed();
            tel.record("train", t2.elapsed());

            report.iterations += 1;
        }
        report.wall = t_start.elapsed();
        report.telemetry = tel;
        report
    }
}

/// Label `inputs` over `P` oracle workers run on scoped threads — the
/// serial workflow's only concurrency (the paper assumes "only
/// parallelization of the oracles", eq. (1)).
///
/// Work splits into contiguous shard ranges, so a uniform selection block
/// is consumed as zero-copy strided sub-views of the shared flat buffer
/// and each worker labels its whole shard with **one**
/// [`Oracle::run_calc_batch`] call — the serial baseline rides the oracle
/// plane too (labels bit-identical to per-row `run_calc`, which remains
/// the fallback for ragged selections). Inputs and labels are copied
/// exactly once, into the returned contiguous [`DatapointBlock`].
fn label_parallel(oracles: &mut [Box<dyn Oracle>], inputs: &RowBlock) -> DatapointBlock {
    if inputs.is_empty() || oracles.is_empty() {
        return DatapointBlock::new();
    }
    let p = oracles.len();
    let n = inputs.len();
    // worker w labels rows [lo_w, hi_w) — contiguous, so the uniform fast
    // path is pointer arithmetic over the shared block
    let bounds: Vec<(usize, usize)> = (0..p).map(|w| (w * n / p, (w + 1) * n / p)).collect();
    let uniform = inputs.as_view();
    // Scoped threads: oracle objects are borrowed mutably, one per thread.
    // Oracle is not Sync, so each worker gets exactly one oracle by value
    // of the mutable borrow.
    let shard_results: Vec<RowBlock> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        for (oracle, &(lo, hi)) in oracles.iter_mut().zip(&bounds) {
            handles.push(scope.spawn(move || {
                if lo == hi {
                    return RowBlock::new();
                }
                match uniform {
                    Some(view) => {
                        let width = view.width();
                        let sub = BatchView::from_parts(
                            &view.flat()[lo * width..hi * width],
                            hi - lo,
                            width,
                        )
                        .expect("contiguous shard view");
                        oracle.run_calc_batch(&sub)
                    }
                    None => {
                        // ragged selections: per-row labeling, still into
                        // one contiguous block per shard
                        let mut out = RowBlock::new();
                        for i in lo..hi {
                            out.push_row(&oracle.run_calc(inputs.row(i)));
                        }
                        out
                    }
                }
            }));
        }
        handles.into_iter().map(|h| h.join().expect("oracle worker panicked")).collect()
    });
    let label_values: usize = shard_results.iter().map(|b| b.total_values()).sum();
    let mut out = DatapointBlock::with_capacity(n, inputs.total_values(), label_values);
    for (block, &(lo, _)) in shard_results.iter().zip(&bounds) {
        for (j, y) in block.iter().enumerate() {
            out.push(inputs.row(lo + j), y);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::selection::SelectAllUtils;
    use crate::kernels::Mode;
    use crate::sim::workload::{SyntheticGenerator, SyntheticModel, SyntheticOracle};
    use std::time::Duration;

    fn workflow(n_oracles: usize, label_cost: Duration) -> SerialWorkflow {
        SerialWorkflow {
            generators: (0..4)
                .map(|i| {
                    Box::new(SyntheticGenerator::new(4, Duration::ZERO, u64::MAX, i as u64))
                        as Box<dyn Generator>
                })
                .collect(),
            oracles: (0..n_oracles)
                .map(|_| {
                    Box::new(SyntheticOracle { label_cost, out_dim: 2 }) as Box<dyn Oracle>
                })
                .collect(),
            models: (0..2)
                .map(|_| {
                    Box::new(SyntheticModel::new(
                        4,
                        2,
                        Duration::ZERO,
                        Duration::ZERO,
                        4,
                        Mode::Train,
                    )) as Box<dyn Model>
                })
                .collect(),
            utils: Box::new(SelectAllUtils { max_per_iter: 4 }),
            steps_per_iter: 2,
            iterations: 3,
        }
    }

    #[test]
    fn serial_runs_and_labels() {
        let mut w = workflow(2, Duration::ZERO);
        let r = w.run();
        assert_eq!(r.iterations, 3);
        // 3 iters × 2 steps × 4 selected per step
        assert_eq!(r.oracle_labels, 24);
        assert!(r.final_loss.is_some());
    }

    #[test]
    fn oracle_parallelism_scales_labeling() {
        let cost = Duration::from_millis(8);
        let mut w1 = workflow(1, cost);
        let r1 = w1.run();
        let mut w4 = workflow(4, cost);
        let r4 = w4.run();
        assert_eq!(r1.oracle_labels, r4.oracle_labels);
        // 4 workers should label ≥2x faster than 1
        assert!(
            r4.oracle_time < r1.oracle_time / 2,
            "1 worker {:?}, 4 workers {:?}",
            r1.oracle_time,
            r4.oracle_time
        );
    }

    #[test]
    fn phases_sum_to_wall_approximately() {
        let mut w = workflow(2, Duration::from_millis(2));
        let r = w.run();
        let phases = r.gen_time + r.oracle_time + r.train_time;
        assert!(phases <= r.wall + Duration::from_millis(5));
        assert!(phases >= r.wall / 2, "phases {phases:?} wall {:?}", r.wall);
    }
}
