//! Tiny CLI argument layer (offline `clap` substitute) + the `pal`
//! subcommand implementations used by `main.rs`.

use std::collections::BTreeMap;

/// Parsed command line: positional args + `--key value` / `--flag` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value | --key value | --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("run --config cfg.json --iters 10 extra");
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.get("config"), Some("cfg.json"));
        assert_eq!(a.get_usize("iters", 0), 10);
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("bench --n=5 --verbose");
        assert_eq!(a.get_usize("n", 0), 5);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b v");
        assert!(a.has_flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
