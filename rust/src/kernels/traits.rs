//! User-facing kernel interfaces, mirroring the paper's SI §S4–S7 APIs.
//!
//! Construction model: kernel *factories* (closures) are `Send` and move
//! into the host threads, where they build the actual kernel objects.
//! The objects themselves need not be `Send` — important because the
//! HLO-backed models own thread-affine PJRT handles, exactly like the
//! paper's per-MPI-rank model replicas.

use crate::comm::bus::Payload;
use crate::data::batch::{BatchView, DatapointView, RowBlock};

/// Whether a [`Model`] instance serves the prediction or the training kernel
/// (the paper's `mode` flag in `UserModel.__init__`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Predict,
    Train,
}

/// Generator kernel (SI §S6): explores the input space.
pub trait Generator {
    /// One generation step. `data_to_gene` is `None` on the first call and
    /// the checked prediction thereafter (zeroed when the controller flagged
    /// the previous step as unreliable). Returns `(stop_run, data_to_pred)`.
    fn generate_new_data(&mut self, data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>);

    /// Persist state; called every `progress_save_interval`.
    fn save_progress(&mut self) {}

    /// Called once before the process terminates at workflow shutdown.
    fn stop_run(&mut self) {}
}

/// Oracle kernel (SI §S7): produces ground-truth labels.
///
/// `Send` is required (unlike [`Generator`]/[`Model`]) because the serial
/// baseline labels through scoped worker threads (eq. (1)'s `N/P`); all
/// oracle implementations are plain computation + sleep, so this costs
/// nothing.
pub trait Oracle: Send {
    /// Label one input (blocking; this is where DFT/CFD wall time lives).
    fn run_calc(&mut self, input_for_orcl: &[f32]) -> Vec<f32>;

    /// Oracle-plane twin of [`Oracle::run_calc`]: label a whole micro-batch
    /// of inputs (a strided view straight over the decoded
    /// `TAG_ORACLE_BATCH` payload) into one contiguous [`RowBlock`] — one
    /// label row per input row, in order, with no per-label boxing.
    ///
    /// The default implementation loops [`Oracle::run_calc`] in row order,
    /// so labels are **bit-identical** to the per-label path for any
    /// existing oracle; the built-in CFD, latency, and PES oracles override
    /// it with native batch implementations (same labels, no intermediate
    /// `Vec` per row).
    fn run_calc_batch(&mut self, inputs: &BatchView<'_>) -> RowBlock {
        let mut out = RowBlock::new();
        for row in inputs.iter() {
            out.push_row(&self.run_calc(row));
        }
        out
    }

    fn stop_run(&mut self) {}
}

/// Prediction + training kernel (SI §S4/§S5). One implementation serves
/// both kernels; instances are constructed with [`Mode::Predict`] or
/// [`Mode::Train`] (the paper's single `UserModel` class with a mode flag).
pub trait Model {
    /// Predict for every generator's input; must return one output per
    /// input, in order (SI: "size and order should match processes in
    /// Generator kernel").
    fn predict(&mut self, list_data_to_pred: &[Vec<f32>]) -> Vec<Vec<f32>>;

    /// Flat-data-plane twin of [`Model::predict`]: inputs arrive as a
    /// contiguous `rows × width` view (typically a strided view straight
    /// over the decoded wire payload) and outputs return as one contiguous
    /// [`RowBlock`] — no per-row boxing in either direction. Real models
    /// produce uniform rows (committee reduction needs them, and the
    /// built-in implementations build a uniform
    /// [`Batch`](crate::data::batch::Batch) internally), but
    /// the block form also carries per-row-width outputs losslessly, so a
    /// legacy kernel that returns ragged predictions keeps working through
    /// the shim exactly as it did on the nested path.
    ///
    /// The default implementation shims through the nested-`Vec`
    /// [`Model::predict`], so existing kernels keep working and migrate
    /// incrementally; the built-in HLO and synthetic models override it
    /// with native strided implementations. The block must contain one
    /// output row per input row, in order.
    fn predict_batch(&mut self, batch: &BatchView<'_>) -> RowBlock {
        let nested = self.predict(&batch.to_nested());
        debug_assert_eq!(nested.len(), batch.rows());
        RowBlock::from_rows(&nested)
    }

    /// Replace model weights from a flat array (prediction side).
    fn update(&mut self, weight_array: &[f32]);

    /// Flat-training-plane twin of [`Model::update`]: adopt weights from a
    /// shared wire [`Payload`]. The built-in models override this to *hold*
    /// the payload (a refcount bump — the replica then reads weights
    /// through the same buffer the trainer materialized once), so a
    /// trainer → n-replica sync costs one physical copy total, end to end.
    ///
    /// The default implementation shims through [`Model::update`], so
    /// existing kernels keep working unchanged.
    fn update_from(&mut self, weights: &Payload) {
        self.update(weights.as_slice());
    }

    /// Current weights as a flat array (training side).
    fn get_weight(&self) -> Vec<f32>;

    /// Flat-training-plane twin of [`Model::get_weight`]: the current
    /// weights as a shared [`Payload`], ready to broadcast to every shard
    /// replica by refcount. Bit-identical to [`Model::get_weight`]
    /// (property-tested). The default shim pays the nested path's extra
    /// copy (`get_weight` clone + shared-storage ingest); native overrides
    /// materialize shared storage directly — or, when the weights already
    /// live in an adopted payload, just bump its refcount.
    fn get_weight_payload(&self) -> Payload {
        Payload::from(self.get_weight())
    }

    /// Size of the flat weight array (SI: exchanged once at startup so MPI
    /// knows message sizes).
    fn get_weight_size(&self) -> usize;

    /// Extend the training set with labeled datapoints (training side).
    fn add_trainingset(&mut self, datapoints: &[(Vec<f32>, Vec<f32>)]);

    /// Flat-training-plane twin of [`Model::add_trainingset`]: labeled
    /// samples arrive as a borrowed [`DatapointView`] — typically straight
    /// over the decoded `TAG_TRAIN_DATA` payload — so a native
    /// implementation stages them contiguously without boxing a
    /// `(Vec, Vec)` pair per sample. The default implementation shims
    /// through the nested [`Model::add_trainingset`]; the built-in
    /// synthetic and HLO models override it.
    fn add_trainingset_batch(&mut self, datapoints: &DatapointView<'_>) {
        self.add_trainingset(&datapoints.to_nested());
    }

    /// Run (re)training until `interrupt()` turns true (new data arrived /
    /// shutdown) or an internal criterion stops the round. Returns
    /// `stop_run`: `true` asks the controller to shut the workflow down.
    fn retrain(&mut self, interrupt: &mut dyn FnMut() -> bool) -> bool;

    /// Most recent training loss (telemetry; `None` before first round).
    fn last_loss(&self) -> Option<f32> {
        None
    }

    /// Epochs actually executed in the most recent `retrain` round
    /// (interrupts truncate rounds; the Manager sums these for the
    /// equal-work stop criterion).
    fn last_round_epochs(&self) -> u64 {
        0
    }

    fn save_progress(&mut self) {}

    fn stop_run(&mut self) {}

    /// Device upload-cache statistics, if this model's backend keeps any
    /// (observability hook). The hosts fold the returned snapshot into
    /// their [`KernelTelemetry`](crate::telemetry::KernelTelemetry) at
    /// join, so `RunReport::to_json` can report engine-level cache
    /// efficiency; models without a device engine keep the `None` default.
    fn upload_stats(&self) -> Option<crate::runtime::UploadStats> {
        None
    }
}

/// Controller customization points (SI "Utilities").
pub trait Utils {
    /// The paper's `prediction_check`: given every generator's input and
    /// every prediction-model's outputs (outer index = model, inner =
    /// generator), select inputs for oracle labeling and produce the checked
    /// per-generator payloads.
    ///
    /// Returns `(list_input_to_orcl, list_data_to_gene_checked)`; the second
    /// list must have exactly one entry per generator, in order.
    fn prediction_check(
        &mut self,
        list_data_to_pred: &[Vec<f32>],
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>);

    /// Flat-data-plane twin of [`Utils::prediction_check`]: inputs and the
    /// per-model committee outputs arrive as strided views (the latter
    /// usually directly over the received result payloads), and both
    /// returned row sets are contiguous [`RowBlock`]s — the controller
    /// scatters the checked rows as zero-copy payload slices.
    ///
    /// The default implementation shims through the nested-`Vec`
    /// [`Utils::prediction_check`]; the built-in committee-std utilities
    /// override it with single-pass strided reductions. The checked block
    /// must contain exactly one row per input row, in order.
    fn prediction_check_batch(
        &mut self,
        inputs: &BatchView<'_>,
        preds_per_model: &[BatchView<'_>],
    ) -> (RowBlock, RowBlock) {
        let nested_inputs = inputs.to_nested();
        let nested_preds: Vec<Vec<Vec<f32>>> =
            preds_per_model.iter().map(|v| v.to_nested()).collect();
        let (to_orcl, checked) = self.prediction_check(&nested_inputs, &nested_preds);
        (RowBlock::from_rows(&to_orcl), RowBlock::from_rows(&checked))
    }

    /// The paper's `adjust_input_for_oracle`: re-order / prune the oracle
    /// buffer given fresh per-model predictions for each buffered input
    /// (outer index = model). Must return a subset (permutation allowed) of
    /// `buffer`. Only called when `dynamic_orcale_list` is set.
    fn adjust_input_for_oracle(
        &mut self,
        buffer: Vec<Vec<f32>>,
        preds_per_model: &[Vec<Vec<f32>>],
    ) -> Vec<Vec<f32>> {
        let _ = preds_per_model;
        buffer
    }

    /// Flat-data-plane twin of [`Utils::adjust_input_for_oracle`]: the
    /// drained oracle buffer arrives as one strided view over its
    /// contiguous staging storage and the per-model rescore replies as
    /// strided views over the received payloads; the adjusted subset
    /// returns as one contiguous [`RowBlock`], ready to refill the buffer
    /// without boxing a `Vec` per row. Must return a sub-multiset
    /// (permutation allowed) of `buffer`'s rows, like the nested hook.
    ///
    /// The default implementation shims through the nested
    /// [`Utils::adjust_input_for_oracle`]; the built-in committee-std
    /// utilities override it with a strided reduction.
    fn adjust_input_for_oracle_batch(
        &mut self,
        buffer: &BatchView<'_>,
        preds_per_model: &[BatchView<'_>],
    ) -> RowBlock {
        let nested: Vec<Vec<Vec<f32>>> =
            preds_per_model.iter().map(|v| v.to_nested()).collect();
        let adjusted = self.adjust_input_for_oracle(buffer.to_nested(), &nested);
        RowBlock::from_rows(&adjusted)
    }
}

/// Factory closures moved into host threads. `Model` factories take the
/// [`Mode`] so prediction and training construct independent replicas.
/// `Utils` factories are shared: both controller sub-kernels (Exchange for
/// `prediction_check`, Manager for `adjust_input_for_oracle`) build one.
pub type GeneratorFactory = Box<dyn FnOnce() -> Box<dyn Generator> + Send>;
pub type OracleFactory = Box<dyn FnOnce() -> Box<dyn Oracle> + Send>;
pub type ModelFactory = std::sync::Arc<dyn Fn(Mode, usize) -> Box<dyn Model> + Send + Sync>;
pub type UtilsFactory = std::sync::Arc<dyn Fn() -> Box<dyn Utils> + Send + Sync>;

/// Everything the workflow needs to staff its kernels.
pub struct KernelSet {
    pub generators: Vec<GeneratorFactory>,
    pub oracles: Vec<OracleFactory>,
    /// One factory shared by prediction and training hosts; called with
    /// `(mode, replica_index)`.
    pub model: ModelFactory,
    pub utils: UtilsFactory,
}

impl KernelSet {
    /// Sanity-check against a setting before spawning.
    pub fn validate(&self, s: &crate::config::AlSetting) -> anyhow::Result<()> {
        if self.generators.len() != s.gene_process {
            anyhow::bail!(
                "kernel set has {} generators, setting wants {}",
                self.generators.len(),
                s.gene_process
            );
        }
        if self.oracles.len() != s.orcl_process {
            anyhow::bail!(
                "kernel set has {} oracles, setting wants {}",
                self.oracles.len(),
                s.orcl_process
            );
        }
        Ok(())
    }
}
