//! Kernel interfaces + built-in implementations.
//!
//! [`traits`] defines the four user-facing kernel interfaces exactly as the
//! paper's SI does (`UserGene.generate_new_data`, `UserOracle.run_calc`,
//! `UserModel.{predict, update, get_weight, add_trainingset, retrain}`,
//! plus the `Utils` pair `prediction_check` / `adjust_input_for_oracle`).
//! The submodules provide the implementations used by the four application
//! studies (Table 1) and the benches.

pub mod generators;
pub mod models;
pub mod oracles;
pub mod traits;

pub use traits::{
    Generator, GeneratorFactory, KernelSet, Mode, Model, ModelFactory, Oracle, OracleFactory,
    Utils,
};
