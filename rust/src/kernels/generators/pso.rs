//! Particle-swarm generator for the thermo-fluid application (§3.4):
//! optimizes eddy-promoter layouts against the *predicted* objective.
//!
//! Wire contract with the CNN surrogate model:
//! `data_to_pred = flattened occupancy grid (H*W)`,
//! `data_to_gene = [C_f, St] committee mean` (zeroed when uncertain).
//! The PSO minimizes `C_f − weight·St` (low drag, high heat transfer).

use crate::kernels::Generator;
use crate::rng::Rng;

/// One PSO particle per generator process; the swarm lives across processes
/// and shares information *through the surrogate* (each particle refines
/// the model that all particles query — the paper's coupling).
pub struct PsoGenerator {
    pub grid: usize,
    /// number of eddy promoters to place
    pub n_promoters: usize,
    /// trade-off weight in the objective
    pub st_weight: f32,
    /// inertia / cognitive / social-ish coefficients
    pub inertia: f32,
    pub cognitive: f32,
    pub max_steps: Option<u64>,

    /// promoter center positions in [0, grid)² (continuous; rasterized per
    /// query)
    pos: Vec<f32>,
    vel: Vec<f32>,
    best_pos: Vec<f32>,
    best_obj: f32,
    last_obj: Option<f32>,
    steps: u64,
    rng: Rng,
}

impl PsoGenerator {
    pub fn new(grid: usize, n_promoters: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let pos: Vec<f32> =
            (0..2 * n_promoters).map(|_| rng.range(1.0, (grid - 1) as f64) as f32).collect();
        PsoGenerator {
            grid,
            n_promoters,
            st_weight: 0.5,
            inertia: 0.6,
            cognitive: 0.4,
            max_steps: None,
            vel: vec![0.0; 2 * n_promoters],
            best_pos: pos.clone(),
            pos,
            best_obj: f32::INFINITY,
            last_obj: None,
            steps: 0,
            rng,
        }
    }

    pub fn with_max_steps(mut self, n: u64) -> Self {
        self.max_steps = Some(n);
        self
    }

    /// Rasterize promoter centers into the occupancy grid the CNN consumes.
    pub fn rasterize(&self) -> Vec<f32> {
        let g = self.grid;
        let mut grid = vec![0.0f32; g * g];
        for p in 0..self.n_promoters {
            let cx = self.pos[2 * p].clamp(0.0, (g - 1) as f32);
            let cy = self.pos[2 * p + 1].clamp(0.0, (g - 1) as f32);
            // 2x2 soft stamp
            let (ix, iy) = (cx as usize, cy as usize);
            for (dx, dy) in [(0usize, 0usize), (1, 0), (0, 1), (1, 1)] {
                let (x, y) = ((ix + dx).min(g - 1), (iy + dy).min(g - 1));
                grid[y * g + x] = 1.0;
            }
        }
        grid
    }

    fn objective(&self, cf_st: &[f32]) -> f32 {
        cf_st[0] - self.st_weight * cf_st.get(1).copied().unwrap_or(0.0)
    }

    pub fn best_objective(&self) -> f32 {
        self.best_obj
    }

    fn move_particle(&mut self) {
        for i in 0..self.pos.len() {
            let r = self.rng.f32();
            self.vel[i] = self.inertia * self.vel[i]
                + self.cognitive * r * (self.best_pos[i] - self.pos[i])
                + 0.3 * (self.rng.normal() as f32);
            self.pos[i] = (self.pos[i] + self.vel[i]).clamp(0.0, (self.grid - 1) as f32);
        }
    }
}

impl Generator for PsoGenerator {
    fn generate_new_data(&mut self, data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>) {
        match data_to_gene {
            None => {}
            Some(pred) if pred.iter().all(|&p| p == 0.0) => {
                // surrogate uncertain here: exploit elsewhere while the
                // oracle labels this region — random kick
                for i in 0..self.pos.len() {
                    self.pos[i] = (self.pos[i] + (self.rng.normal() as f32) * 2.0)
                        .clamp(0.0, (self.grid - 1) as f32);
                }
            }
            Some(pred) => {
                let obj = self.objective(pred);
                self.last_obj = Some(obj);
                if obj < self.best_obj {
                    self.best_obj = obj;
                    self.best_pos.copy_from_slice(&self.pos);
                }
                self.move_particle();
            }
        }
        self.steps += 1;
        let stop = self.max_steps.map(|m| self.steps >= m).unwrap_or(false);
        (stop, self.rasterize())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rasterized_grid_shape_and_occupancy() {
        let g = PsoGenerator::new(16, 3, 0);
        let grid = g.rasterize();
        assert_eq!(grid.len(), 256);
        let occ: f32 = grid.iter().sum();
        assert!(occ >= 3.0 && occ <= 12.0, "occupancy {occ}");
    }

    #[test]
    fn improving_objective_updates_best() {
        let mut g = PsoGenerator::new(16, 2, 1);
        g.generate_new_data(None);
        g.generate_new_data(Some(&[1.0, 0.0])); // obj 1.0
        assert!((g.best_objective() - 1.0).abs() < 1e-6);
        g.generate_new_data(Some(&[0.5, 0.2])); // obj 0.4
        assert!((g.best_objective() - 0.4).abs() < 1e-6);
        g.generate_new_data(Some(&[2.0, 0.0])); // worse: best unchanged
        assert!((g.best_objective() - 0.4).abs() < 1e-6);
    }

    #[test]
    fn zeroed_prediction_kicks_particle() {
        let mut g = PsoGenerator::new(16, 2, 2);
        g.generate_new_data(None);
        let before = g.pos.clone();
        g.generate_new_data(Some(&[0.0, 0.0]));
        assert_ne!(before, g.pos);
    }

    #[test]
    fn stops_at_max_steps() {
        let mut g = PsoGenerator::new(8, 1, 3).with_max_steps(2);
        assert!(!g.generate_new_data(None).0);
        assert!(g.generate_new_data(Some(&[1.0, 1.0])).0);
    }

    #[test]
    fn positions_stay_in_bounds() {
        let mut g = PsoGenerator::new(8, 2, 4);
        g.generate_new_data(None);
        for _ in 0..100 {
            g.generate_new_data(Some(&[1.0, 0.5]));
            for &p in &g.pos {
                assert!((0.0..=7.0).contains(&p));
            }
        }
    }
}
