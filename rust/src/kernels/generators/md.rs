//! MD generator: velocity-Verlet propagation on the *predicted* PES, with
//! the paper's uncertainty-patience / trajectory-restart policy (§2.2).
//!
//! Wire contract with the HLO committee model:
//! `data_to_pred = [x (n_atoms*3), g (n_globals), s (n_states one-hot)]`
//! `data_to_gene = [e (n_states), f (n_atoms*3)]` — after the controller's
//! `prediction_check` this is the committee mean (or zeros when uncertain).
//! A zeroed `data_to_gene` means the controller flagged the step as
//! unreliable (paper: "send 0 instead to generator").

use crate::kernels::Generator;
use crate::rng::Rng;

/// Geometry/feature layout shared between MD generators and the committee
/// model (kept in sync through the artifact manifest metadata).
#[derive(Debug, Clone, Copy)]
pub struct MdLayout {
    pub n_atoms: usize,
    pub n_globals: usize,
    pub n_states: usize,
}

impl MdLayout {
    pub fn x_len(&self) -> usize {
        self.n_atoms * 3
    }
    pub fn input_len(&self) -> usize {
        self.x_len() + self.n_globals + self.n_states
    }
    pub fn output_len(&self) -> usize {
        self.n_states + self.x_len()
    }
}

/// Velocity-Verlet MD over ML-predicted forces.
pub struct MdGenerator {
    layout: MdLayout,
    /// timestep
    pub dt: f32,
    /// friction for a crude Langevin thermostat (0 = NVE)
    pub friction: f32,
    /// thermal noise amplitude
    pub temperature: f32,
    /// allowed consecutive uncertain steps before restart (paper's
    /// 'patience')
    pub patience: u32,
    /// stop after this many steps (None = run until the workflow stops)
    pub max_steps: Option<u64>,
    /// global features (e.g. charge), fixed per trajectory
    pub globals: Vec<f32>,
    /// active PES one-hot (photodynamics: current surface)
    pub state_weights: Vec<f32>,

    x: Vec<f32>,
    v: Vec<f32>,
    restart_geometry: Vec<f32>,
    uncertain_streak: u32,
    steps: u64,
    restarts: u64,
    rng: Rng,
}

impl MdGenerator {
    pub fn new(layout: MdLayout, x0: Vec<f32>, seed: u64) -> Self {
        assert_eq!(x0.len(), layout.x_len());
        let mut state_weights = vec![0.0; layout.n_states];
        state_weights[0] = 1.0;
        MdGenerator {
            layout,
            dt: 0.05,
            friction: 0.02,
            temperature: 0.05,
            patience: 5,
            max_steps: None,
            globals: vec![0.0; layout.n_globals],
            state_weights,
            v: vec![0.0; x0.len()],
            restart_geometry: x0.clone(),
            x: x0,
            uncertain_streak: 0,
            steps: 0,
            restarts: 0,
            rng: Rng::new(seed),
        }
    }

    pub fn with_dt(mut self, dt: f32) -> Self {
        self.dt = dt;
        self
    }

    pub fn with_patience(mut self, patience: u32) -> Self {
        self.patience = patience;
        self
    }

    pub fn with_max_steps(mut self, n: u64) -> Self {
        self.max_steps = Some(n);
        self
    }

    pub fn with_globals(mut self, g: Vec<f32>) -> Self {
        assert_eq!(g.len(), self.layout.n_globals);
        self.globals = g;
        self
    }

    /// Set the active PES (photodynamics surface hopping).
    pub fn set_state(&mut self, state: usize) {
        self.state_weights.iter_mut().for_each(|w| *w = 0.0);
        self.state_weights[state] = 1.0;
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn restarts(&self) -> u64 {
        self.restarts
    }

    fn assemble_input(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.layout.input_len());
        out.extend_from_slice(&self.x);
        out.extend_from_slice(&self.globals);
        out.extend_from_slice(&self.state_weights);
        out
    }

    fn restart(&mut self) {
        self.restarts += 1;
        self.uncertain_streak = 0;
        // restart from the reference geometry with fresh thermal jitter
        // (paper: "whether to restart trajectories")
        for (x, &x0) in self.x.iter_mut().zip(&self.restart_geometry) {
            *x = x0 + (self.rng.normal() * 0.05) as f32;
        }
        self.v.iter_mut().for_each(|v| *v = 0.0);
    }

    fn step(&mut self, forces: &[f32]) {
        let dt = self.dt;
        for i in 0..self.x.len() {
            // Langevin-ish velocity update (unit masses)
            self.v[i] = (1.0 - self.friction) * self.v[i]
                + forces[i] * dt
                + self.temperature * (self.rng.normal() as f32) * dt.sqrt();
            self.x[i] += self.v[i] * dt;
        }
    }
}

impl Generator for MdGenerator {
    fn generate_new_data(&mut self, data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>) {
        match data_to_gene {
            None => {} // first call: just emit the initial geometry
            Some(pred) if pred.len() != self.layout.output_len() => {
                // malformed prediction — treat as uncertain
                self.uncertain_streak += 1;
                if self.uncertain_streak > self.patience {
                    self.restart();
                }
            }
            Some(pred) => {
                let zeroed = pred.iter().all(|&p| p == 0.0);
                if zeroed {
                    // controller flagged high uncertainty: keep exploring on
                    // the last velocities for up to `patience` steps, then
                    // restart the trajectory (paper §2.2)
                    self.uncertain_streak += 1;
                    if self.uncertain_streak > self.patience {
                        self.restart();
                    } else {
                        let zero_f = vec![0.0; self.layout.x_len()];
                        self.step(&zero_f);
                    }
                } else {
                    self.uncertain_streak = 0;
                    let f_off = self.layout.n_states;
                    let forces = &pred[f_off..f_off + self.layout.x_len()].to_vec();
                    self.step(forces);
                }
            }
        }
        self.steps += 1;
        let stop = self.max_steps.map(|m| self.steps >= m).unwrap_or(false);
        (stop, self.assemble_input())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> MdLayout {
        MdLayout { n_atoms: 2, n_globals: 1, n_states: 1 }
    }

    fn pred(e: f32, f: [f32; 6]) -> Vec<f32> {
        let mut p = vec![e];
        p.extend_from_slice(&f);
        p
    }

    #[test]
    fn first_call_emits_initial_geometry() {
        let x0 = vec![0.0, 0.0, 0.0, 1.4, 0.0, 0.0];
        let mut g = MdGenerator::new(layout(), x0.clone(), 0);
        let (stop, out) = g.generate_new_data(None);
        assert!(!stop);
        assert_eq!(out.len(), layout().input_len());
        assert_eq!(&out[..6], &x0[..]);
        assert_eq!(out[6], 0.0); // global
        assert_eq!(out[7], 1.0); // state one-hot
    }

    #[test]
    fn forces_move_the_geometry() {
        let x0 = vec![0.0; 6];
        let mut g = MdGenerator::new(layout(), x0, 0);
        g.temperature = 0.0;
        let (_, before) = g.generate_new_data(None);
        let (_, after) = g.generate_new_data(Some(&pred(0.0, [1.0, 0.0, 0.0, -1.0, 0.0, 0.0])));
        assert!(after[0] > before[0]);
        assert!(after[3] < before[3]);
    }

    #[test]
    fn patience_then_restart_on_zeroed_predictions() {
        let x0 = vec![0.0, 0.0, 0.0, 1.4, 0.0, 0.0];
        let mut g = MdGenerator::new(layout(), x0, 0).with_patience(3);
        g.generate_new_data(None);
        let zero = vec![0.0; layout().output_len()];
        for _ in 0..3 {
            g.generate_new_data(Some(&zero));
            assert_eq!(g.restarts(), 0);
        }
        g.generate_new_data(Some(&zero)); // patience exceeded
        assert_eq!(g.restarts(), 1);
    }

    #[test]
    fn certainty_resets_streak() {
        let mut g = MdGenerator::new(layout(), vec![0.0; 6], 0).with_patience(2);
        g.generate_new_data(None);
        let zero = vec![0.0; layout().output_len()];
        g.generate_new_data(Some(&zero));
        g.generate_new_data(Some(&pred(-1.0, [0.1; 6]))); // confident
        g.generate_new_data(Some(&zero));
        g.generate_new_data(Some(&zero));
        assert_eq!(g.restarts(), 0); // streak was reset in between
    }

    #[test]
    fn stops_at_max_steps() {
        let mut g = MdGenerator::new(layout(), vec![0.0; 6], 0).with_max_steps(2);
        assert!(!g.generate_new_data(None).0);
        assert!(g.generate_new_data(Some(&pred(1.0, [0.0; 6]))).0);
    }

    #[test]
    fn state_switch_changes_onehot() {
        let lay = MdLayout { n_atoms: 2, n_globals: 1, n_states: 3 };
        let mut g = MdGenerator::new(lay, vec![0.0; 6], 0);
        g.set_state(2);
        let (_, out) = g.generate_new_data(None);
        assert_eq!(&out[7..10], &[0.0, 0.0, 1.0]);
    }
}
