//! Built-in generator kernels: the exploration algorithms of Table 1.

mod md;
mod pso;
mod random;
mod sampler;

pub use md::{MdGenerator, MdLayout};
pub use pso::PsoGenerator;
pub use random::RandomGenerator;
pub use sampler::BiasedSampler;
