//! Biased reaction-path sampler for the HAT application (§3.2): generates
//! geometries along randomized interpolation paths between minima —
//! "randomized sampling of relevant geometries; transition state search"
//! (Table 1), producing an infinite stream of diverse unlabeled samples.

use crate::kernels::Generator;
use crate::potential::MullerBrown;
use crate::rng::Rng;

/// Minima of the Müller-Brown surface used as path endpoints.
pub mod mb {
    pub use crate::potential::muller_brown::MINIMA;
}

/// Walks interpolation paths between randomly chosen basin pairs with
/// transverse noise — concentrating samples near reaction paths and
/// transition regions, where the HAT models need data.
pub struct BiasedSampler {
    pub layout_len: usize,
    pub n_states: usize,
    pub n_globals: usize,
    pub path_steps: u32,
    pub noise: f32,
    pub max_steps: Option<u64>,

    #[allow(dead_code)]
    surface: MullerBrown,
    from: (f64, f64),
    to: (f64, f64),
    t: f32,
    steps: u64,
    rng: Rng,
}

impl BiasedSampler {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let (from, to) = Self::pick_pair(&mut rng);
        BiasedSampler {
            layout_len: 3,
            n_states: 1,
            n_globals: 1,
            path_steps: 20,
            noise: 0.08,
            max_steps: None,
            surface: MullerBrown::default(),
            from,
            to,
            t: 0.0,
            steps: 0,
            rng,
        }
    }

    pub fn with_max_steps(mut self, n: u64) -> Self {
        self.max_steps = Some(n);
        self
    }

    fn pick_pair(rng: &mut Rng) -> ((f64, f64), (f64, f64)) {
        let i = rng.below(3);
        let mut j = rng.below(3);
        if j == i {
            j = (j + 1) % 3;
        }
        (mb::MINIMA[i], mb::MINIMA[j])
    }

    fn current_point(&mut self) -> (f32, f32) {
        let t = self.t as f64;
        let x = self.from.0 + t * (self.to.0 - self.from.0);
        let y = self.from.1 + t * (self.to.1 - self.from.1);
        (
            x as f32 + (self.rng.normal() as f32) * self.noise,
            y as f32 + (self.rng.normal() as f32) * self.noise,
        )
    }
}

impl Generator for BiasedSampler {
    fn generate_new_data(&mut self, _data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>) {
        // This generator streams diverse samples regardless of predictions
        // (the paper's HAT case: "an infinite stream of diverse unlabeled
        // samples"); predictions are still received (and used for UQ by the
        // controller) but do not steer the path walk.
        let (x, y) = self.current_point();
        self.t += 1.0 / self.path_steps as f32;
        if self.t >= 1.0 {
            self.t = 0.0;
            let (f, t2) = Self::pick_pair(&mut self.rng);
            self.from = f;
            self.to = t2;
        }
        self.steps += 1;
        // layout: [x, y, z=0, globals..., state one-hot]
        let mut out = vec![x, y, 0.0];
        out.extend(std::iter::repeat(0.0).take(self.n_globals));
        out.push(1.0);
        out.extend(std::iter::repeat(0.0).take(self.n_states - 1));
        let stop = self.max_steps.map(|m| self.steps >= m).unwrap_or(false);
        (stop, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_have_expected_layout() {
        let mut s = BiasedSampler::new(0);
        let (_, d) = s.generate_new_data(None);
        assert_eq!(d.len(), 3 + 1 + 1); // xyz + global + 1 state
        assert_eq!(d[2], 0.0);
        assert_eq!(d[4], 1.0);
    }

    #[test]
    fn path_cycles_between_minima() {
        let mut s = BiasedSampler::new(1);
        s.noise = 0.0;
        let first = s.generate_new_data(None).1;
        for _ in 0..s.path_steps {
            s.generate_new_data(None);
        }
        let later = s.generate_new_data(None).1;
        // after a full path the sampler starts a new pair — samples differ
        assert!((first[0] - later[0]).abs() + (first[1] - later[1]).abs() > 1e-3);
    }

    #[test]
    fn samples_cover_transition_region() {
        // noise-free midpoints must leave the basins (x between minima)
        let mut s = BiasedSampler::new(2);
        s.noise = 0.0;
        let mut saw_midpath = false;
        for _ in 0..200 {
            let (_, d) = s.generate_new_data(None);
            let near_minimum = mb::MINIMA.iter().any(|&(mx, my)| {
                ((d[0] as f64 - mx).powi(2) + (d[1] as f64 - my).powi(2)).sqrt() < 0.15
            });
            if !near_minimum {
                saw_midpath = true;
            }
        }
        assert!(saw_midpath, "sampler never left the basins");
    }

    #[test]
    fn stops_at_max_steps() {
        let mut s = BiasedSampler::new(3).with_max_steps(2);
        assert!(!s.generate_new_data(None).0);
        assert!(s.generate_new_data(None).0);
    }
}
