//! Random-number generator kernel — the SI toy example (§S6), used by the
//! quickstart and the protocol tests.

use crate::kernels::Generator;
use crate::rng::Rng;

/// Mirrors the SI toy: emits random vectors; when the prediction is valid
/// it multiplies its hidden state by it, when zeroed it resamples; signals
/// stop after `limit` iterations.
pub struct RandomGenerator {
    pub dim: usize,
    pub limit: u64,
    counter: u64,
    state: Vec<f32>,
    rng: Rng,
}

impl RandomGenerator {
    pub fn new(dim: usize, limit: u64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let state = rng.normal_vec(dim);
        RandomGenerator { dim, limit, counter: 0, state, rng }
    }
}

impl Generator for RandomGenerator {
    fn generate_new_data(&mut self, data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>) {
        let data_to_pred = match data_to_gene {
            None => self.rng.normal_vec(self.dim),
            Some(pred) if pred.iter().any(|&p| p == 0.0) => self.rng.normal_vec(self.dim),
            Some(pred) => {
                // state * prediction (the SI example's update rule)
                self.state.iter().zip(pred).map(|(s, p)| s * p).collect()
            }
        };
        self.counter += 1;
        (self.counter > self.limit, data_to_pred)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_fixed_width() {
        let mut g = RandomGenerator::new(4, 100, 0);
        let (_, d) = g.generate_new_data(None);
        assert_eq!(d.len(), 4);
    }

    #[test]
    fn stop_after_limit() {
        let mut g = RandomGenerator::new(4, 2, 0);
        assert!(!g.generate_new_data(None).0);
        assert!(!g.generate_new_data(None).0);
        assert!(g.generate_new_data(None).0);
    }

    #[test]
    fn multiplies_state_by_valid_prediction() {
        let mut g = RandomGenerator::new(2, 10, 1);
        g.state = vec![2.0, 3.0];
        let (_, d) = g.generate_new_data(Some(&[4.0, 5.0]));
        assert_eq!(d, vec![8.0, 15.0]);
    }

    #[test]
    fn resamples_on_zeroed_prediction() {
        let mut g = RandomGenerator::new(2, 10, 2);
        g.state = vec![2.0, 3.0];
        let (_, d) = g.generate_new_data(Some(&[0.0, 5.0]));
        assert_ne!(d, vec![0.0, 15.0]);
    }
}
