//! Latency wrapper: makes any oracle cost what the paper's oracles cost.
//!
//! The AL *dynamics* depend on the oracle's wall time (DFT ≈ 1 h, xTB ≈
//! 10 s, CFD ≈ 10 min — SI §S2.2); this wrapper injects that cost (at a
//! benchable scale) around an analytic labeler, optionally with
//! multiplicative jitter so dispatch order gets exercised.

use std::time::Duration;

use crate::data::batch::{BatchView, RowBlock};
use crate::kernels::Oracle;
use crate::rng::Rng;

/// Wraps an oracle with simulated compute latency.
pub struct LatencyOracle<O: Oracle> {
    pub inner: O,
    pub latency: Duration,
    /// Uniform multiplicative jitter in `[1-j, 1+j]` (0 = deterministic).
    pub jitter: f64,
    rng: Rng,
}

impl<O: Oracle> LatencyOracle<O> {
    pub fn new(inner: O, latency: Duration) -> Self {
        LatencyOracle { inner, latency, jitter: 0.0, rng: Rng::new(0x0A11) }
    }

    pub fn with_jitter(mut self, jitter: f64, seed: u64) -> Self {
        self.jitter = jitter.clamp(0.0, 0.99);
        self.rng = Rng::new(seed);
        self
    }
}

impl<O: Oracle> LatencyOracle<O> {
    /// One jittered per-item wait (advances the jitter RNG exactly once).
    fn sample_wait(&mut self) -> Duration {
        let scale = 1.0 + self.jitter * (2.0 * self.rng.f64() - 1.0);
        self.latency.mul_f64(scale.max(0.0))
    }
}

impl<O: Oracle> Oracle for LatencyOracle<O> {
    fn run_calc(&mut self, input: &[f32]) -> Vec<f32> {
        let wait = self.sample_wait();
        if wait > Duration::ZERO {
            std::thread::sleep(wait);
        }
        self.inner.run_calc(input)
    }

    /// Native batch labeling: the per-item waits are sampled exactly as the
    /// per-label path would (one jitter draw per item, same RNG stream, so
    /// labels and total simulated cost are identical) but slept **once** as
    /// their sum — a batch of n costs one syscall instead of n. The inner
    /// oracle labels the whole batch through its own `run_calc_batch`.
    fn run_calc_batch(&mut self, inputs: &BatchView<'_>) -> RowBlock {
        let mut wait = Duration::ZERO;
        for _ in 0..inputs.rows() {
            wait += self.sample_wait();
        }
        if wait > Duration::ZERO {
            std::thread::sleep(wait);
        }
        self.inner.run_calc_batch(inputs)
    }

    fn stop_run(&mut self) {
        self.inner.stop_run();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl Oracle for Echo {
        fn run_calc(&mut self, input: &[f32]) -> Vec<f32> {
            input.to_vec()
        }
    }

    #[test]
    fn latency_is_applied() {
        let mut o = LatencyOracle::new(Echo, Duration::from_millis(30));
        let t0 = std::time::Instant::now();
        let out = o.run_calc(&[1.0, 2.0]);
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert_eq!(out, vec![1.0, 2.0]);
    }

    #[test]
    fn zero_latency_is_fast() {
        let mut o = LatencyOracle::new(Echo, Duration::ZERO);
        let t0 = std::time::Instant::now();
        o.run_calc(&[1.0]);
        assert!(t0.elapsed() < Duration::from_millis(10));
    }

    #[test]
    fn batch_labels_and_rng_stream_match_per_label_path() {
        use crate::data::batch::Batch;
        let rows = vec![vec![1.0f32, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let mut a = LatencyOracle::new(Echo, Duration::ZERO).with_jitter(0.5, 42);
        let want: Vec<Vec<f32>> = rows.iter().map(|r| a.run_calc(r)).collect();
        let mut b = LatencyOracle::new(Echo, Duration::ZERO).with_jitter(0.5, 42);
        let batch = Batch::from_rows(&rows).unwrap();
        let got = b.run_calc_batch(&batch.view());
        assert_eq!(got.to_nested(), want);
        // the jitter streams advanced identically: the next draw matches
        assert_eq!(a.rng.f64().to_bits(), b.rng.f64().to_bits());
    }

    #[test]
    fn batch_sleeps_the_summed_latency_once() {
        use crate::data::batch::Batch;
        let mut o = LatencyOracle::new(Echo, Duration::from_millis(10));
        let batch = Batch::from_rows(&[vec![1.0f32], vec![2.0], vec![3.0]]).unwrap();
        let t0 = std::time::Instant::now();
        let out = o.run_calc_batch(&batch.view());
        assert!(t0.elapsed() >= Duration::from_millis(25), "summed latency applied");
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn jitter_bounds_wait() {
        let mut o = LatencyOracle::new(Echo, Duration::from_millis(10)).with_jitter(0.5, 1);
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            o.run_calc(&[1.0]);
            let dt = t0.elapsed();
            assert!(dt >= Duration::from_millis(4) && dt < Duration::from_millis(60), "{dt:?}");
        }
    }
}
