//! PES-backed oracles: label geometries with analytic energy + forces.
//!
//! Wire contract (matches the HLO committee model's training layout):
//! input  = `[x (n_atoms*3), g (n_globals), s (n_states)]`
//! label  = `[e (n_states), f (n_atoms*3)]`
//! where `f` are the forces on the state-weighted PES.

use crate::data::batch::{BatchView, RowBlock};
use crate::kernels::Oracle;
use crate::potential::{MultiState, Pes};

/// Ground-state oracle over any [`Pes`]. The global features are passed
/// through to a user hook so charge-dependent PES (e.g. Gupta) can use them.
pub struct PesOracle<P: Pes> {
    pes_for: Box<dyn Fn(&[f32]) -> P + Send>,
    pub n_atoms: usize,
    pub n_globals: usize,
    pub n_states: usize,
    labels: u64,
}

impl<P: Pes> PesOracle<P> {
    /// Fixed-PES oracle (globals ignored).
    pub fn fixed(pes: P, n_globals: usize) -> Self
    where
        P: Clone + Send + 'static,
    {
        let n_atoms = pes.n_atoms();
        PesOracle {
            pes_for: Box::new(move |_| pes.clone()),
            n_atoms,
            n_globals,
            n_states: 1,
            labels: 0,
        }
    }

    /// Globals-dependent oracle (e.g. charge → Gupta parameters).
    pub fn from_globals(n_atoms: usize, n_globals: usize, f: impl Fn(&[f32]) -> P + Send + 'static) -> Self {
        PesOracle { pes_for: Box::new(f), n_atoms, n_globals, n_states: 1, labels: 0 }
    }

    pub fn labels(&self) -> u64 {
        self.labels
    }
}

impl<P: Pes> Oracle for PesOracle<P> {
    fn run_calc(&mut self, input: &[f32]) -> Vec<f32> {
        let n3 = self.n_atoms * 3;
        let x = &input[..n3];
        let g = &input[n3..n3 + self.n_globals];
        let pes = (self.pes_for)(g);
        let e = pes.energy(x) as f32;
        let f = pes.forces(x);
        self.labels += 1;
        let mut out = Vec::with_capacity(self.n_states + n3);
        out.push(e);
        out.extend(std::iter::repeat(0.0).take(self.n_states - 1));
        out.extend_from_slice(&f);
        out
    }

    /// Native batch labeling: each `[e, 0.., f]` row is concatenated
    /// straight into the contiguous output block. Energies and forces are
    /// computed by the same per-row evaluation as [`Oracle::run_calc`], so
    /// labels are bit-identical to the per-label path.
    fn run_calc_batch(&mut self, inputs: &BatchView<'_>) -> RowBlock {
        let n3 = self.n_atoms * 3;
        let pad = vec![0.0f32; self.n_states - 1];
        let mut out = RowBlock::with_capacity(inputs.rows(), inputs.rows() * (self.n_states + n3));
        for row in inputs.iter() {
            let x = &row[..n3];
            let g = &row[n3..n3 + self.n_globals];
            let pes = (self.pes_for)(g);
            let e = pes.energy(x) as f32;
            let f = pes.forces(x);
            self.labels += 1;
            out.push_row_concat(&[&[e], &pad, &f]);
        }
        out
    }
}

/// Excited-state oracle over [`MultiState`] (the TDDFT stand-in, §3.1):
/// labels all state energies plus forces on the active (one-hot) state.
pub struct MultiStateOracle {
    pub pes: MultiState,
    pub n_globals: usize,
    labels: u64,
}

impl MultiStateOracle {
    pub fn new(pes: MultiState, n_globals: usize) -> Self {
        MultiStateOracle { pes, n_globals, labels: 0 }
    }

    pub fn labels(&self) -> u64 {
        self.labels
    }
}

impl MultiStateOracle {
    /// `(energies, forces)` of one input row — shared by both label paths
    /// so they stay bit-identical.
    fn label_row(&self, input: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let n3 = self.pes.n_atoms * 3;
        let s_off = n3 + self.n_globals;
        let x = &input[..n3];
        let s = &input[s_off..s_off + self.pes.n_states];
        // energies of every state
        let energies: Vec<f32> = self.pes.energies(x).iter().map(|&e| e as f32).collect();
        // forces on the state-weighted PES
        let active = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0);
        let f = self.pes.state_forces(x, active);
        (energies, f)
    }
}

impl Oracle for MultiStateOracle {
    fn run_calc(&mut self, input: &[f32]) -> Vec<f32> {
        let (energies, f) = self.label_row(input);
        self.labels += 1;
        let mut out = energies;
        out.extend_from_slice(&f);
        out
    }

    /// Native batch labeling: energy + force blocks concatenate straight
    /// into the contiguous output block, one row per input in order.
    fn run_calc_batch(&mut self, inputs: &BatchView<'_>) -> RowBlock {
        let width = self.pes.n_states + self.pes.n_atoms * 3;
        let mut out = RowBlock::with_capacity(inputs.rows(), inputs.rows() * width);
        for row in inputs.iter() {
            let (energies, f) = self.label_row(row);
            self.labels += 1;
            out.push_row_concat(&[&energies, &f]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::potential::{Gupta, Morse};

    #[test]
    fn ground_state_label_layout() {
        let mut o = PesOracle::fixed(Morse::dimer(), 1);
        let input = [0.0, 0.0, 0.0, 1.4, 0.0, 0.0, /*g*/ 0.0, /*s*/ 1.0];
        let label = o.run_calc(&input);
        assert_eq!(label.len(), 1 + 6);
        assert!((label[0] - (-1.0)).abs() < 1e-5); // Morse minimum
        assert_eq!(o.labels(), 1);
    }

    #[test]
    fn globals_change_the_label() {
        let mut o = PesOracle::from_globals(2, 1, |g| Gupta::bismuth(2, g[0] as f64));
        let mut input = vec![0.0, 0.0, 0.0, 3.0, 0.0, 0.0, 0.0, 1.0];
        let neutral = o.run_calc(&input);
        input[6] = 1.0; // charge +1
        let cation = o.run_calc(&input);
        assert!((neutral[0] - cation[0]).abs() > 1e-7);
    }

    #[test]
    fn batch_labels_bit_identical_to_per_label_path() {
        use crate::data::batch::Batch;
        let rows = vec![
            vec![0.0, 0.0, 0.0, 1.4, 0.0, 0.0, 0.0, 1.0],
            vec![0.0, 0.0, 0.0, 1.1, 0.0, 0.0, 0.0, 1.0],
            vec![0.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, 1.0],
        ];
        let mut per_label = PesOracle::fixed(Morse::dimer(), 1);
        let want: Vec<Vec<f32>> = rows.iter().map(|r| per_label.run_calc(r)).collect();
        let mut batched = PesOracle::fixed(Morse::dimer(), 1);
        let batch = Batch::from_rows(&rows).unwrap();
        let got = batched.run_calc_batch(&batch.view());
        assert_eq!(got.to_nested(), want, "batch labels must be bit-identical");
        assert_eq!(batched.labels(), 3);

        // multi-state twin
        let pes = MultiState::photo(2, 3);
        let ms_rows = vec![
            vec![0.0, 0.0, 0.0, 1.5, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0],
            vec![0.0, 0.0, 0.0, 1.2, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0],
        ];
        let mut ms_a = MultiStateOracle::new(pes.clone(), 1);
        let ms_want: Vec<Vec<f32>> = ms_rows.iter().map(|r| ms_a.run_calc(r)).collect();
        let mut ms_b = MultiStateOracle::new(pes, 1);
        let ms_batch = Batch::from_rows(&ms_rows).unwrap();
        assert_eq!(ms_b.run_calc_batch(&ms_batch.view()).to_nested(), ms_want);
    }

    #[test]
    fn multistate_label_layout_and_active_state_forces() {
        let pes = MultiState::photo(2, 3);
        let mut o = MultiStateOracle::new(pes.clone(), 1);
        // active state 1
        let input = [0.0, 0.0, 0.0, 1.5, 0.0, 0.0, /*g*/ 0.0, /*s*/ 0.0, 1.0, 0.0];
        let label = o.run_calc(&input);
        assert_eq!(label.len(), 3 + 6);
        // energies sorted by state index at this geometry
        assert!(label[0] < label[1] && label[1] < label[2]);
        // forces match state 1 directly
        let f1 = pes.state_forces(&input[..6], 1);
        for (a, b) in label[3..].iter().zip(&f1) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
