//! PES-backed oracles: label geometries with analytic energy + forces.
//!
//! Wire contract (matches the HLO committee model's training layout):
//! input  = `[x (n_atoms*3), g (n_globals), s (n_states)]`
//! label  = `[e (n_states), f (n_atoms*3)]`
//! where `f` are the forces on the state-weighted PES.

use crate::kernels::Oracle;
use crate::potential::{MultiState, Pes};

/// Ground-state oracle over any [`Pes`]. The global features are passed
/// through to a user hook so charge-dependent PES (e.g. Gupta) can use them.
pub struct PesOracle<P: Pes> {
    pes_for: Box<dyn Fn(&[f32]) -> P + Send>,
    pub n_atoms: usize,
    pub n_globals: usize,
    pub n_states: usize,
    labels: u64,
}

impl<P: Pes> PesOracle<P> {
    /// Fixed-PES oracle (globals ignored).
    pub fn fixed(pes: P, n_globals: usize) -> Self
    where
        P: Clone + Send + 'static,
    {
        let n_atoms = pes.n_atoms();
        PesOracle {
            pes_for: Box::new(move |_| pes.clone()),
            n_atoms,
            n_globals,
            n_states: 1,
            labels: 0,
        }
    }

    /// Globals-dependent oracle (e.g. charge → Gupta parameters).
    pub fn from_globals(n_atoms: usize, n_globals: usize, f: impl Fn(&[f32]) -> P + Send + 'static) -> Self {
        PesOracle { pes_for: Box::new(f), n_atoms, n_globals, n_states: 1, labels: 0 }
    }

    pub fn labels(&self) -> u64 {
        self.labels
    }
}

impl<P: Pes> Oracle for PesOracle<P> {
    fn run_calc(&mut self, input: &[f32]) -> Vec<f32> {
        let n3 = self.n_atoms * 3;
        let x = &input[..n3];
        let g = &input[n3..n3 + self.n_globals];
        let pes = (self.pes_for)(g);
        let e = pes.energy(x) as f32;
        let f = pes.forces(x);
        self.labels += 1;
        let mut out = Vec::with_capacity(self.n_states + n3);
        out.push(e);
        out.extend(std::iter::repeat(0.0).take(self.n_states - 1));
        out.extend_from_slice(&f);
        out
    }
}

/// Excited-state oracle over [`MultiState`] (the TDDFT stand-in, §3.1):
/// labels all state energies plus forces on the active (one-hot) state.
pub struct MultiStateOracle {
    pub pes: MultiState,
    pub n_globals: usize,
    labels: u64,
}

impl MultiStateOracle {
    pub fn new(pes: MultiState, n_globals: usize) -> Self {
        MultiStateOracle { pes, n_globals, labels: 0 }
    }

    pub fn labels(&self) -> u64 {
        self.labels
    }
}

impl Oracle for MultiStateOracle {
    fn run_calc(&mut self, input: &[f32]) -> Vec<f32> {
        let n3 = self.pes.n_atoms * 3;
        let s_off = n3 + self.n_globals;
        let x = &input[..n3];
        let s = &input[s_off..s_off + self.pes.n_states];
        // energies of every state
        let energies: Vec<f32> = self.pes.energies(x).iter().map(|&e| e as f32).collect();
        // forces on the state-weighted PES
        let active = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0);
        let f = self.pes.state_forces(x, active);
        self.labels += 1;
        let mut out = energies;
        out.extend_from_slice(&f);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::potential::{Gupta, Morse};

    #[test]
    fn ground_state_label_layout() {
        let mut o = PesOracle::fixed(Morse::dimer(), 1);
        let input = [0.0, 0.0, 0.0, 1.4, 0.0, 0.0, /*g*/ 0.0, /*s*/ 1.0];
        let label = o.run_calc(&input);
        assert_eq!(label.len(), 1 + 6);
        assert!((label[0] - (-1.0)).abs() < 1e-5); // Morse minimum
        assert_eq!(o.labels(), 1);
    }

    #[test]
    fn globals_change_the_label() {
        let mut o = PesOracle::from_globals(2, 1, |g| Gupta::bismuth(2, g[0] as f64));
        let mut input = vec![0.0, 0.0, 0.0, 3.0, 0.0, 0.0, 0.0, 1.0];
        let neutral = o.run_calc(&input);
        input[6] = 1.0; // charge +1
        let cation = o.run_calc(&input);
        assert!((neutral[0] - cation[0]).abs() > 1e-7);
    }

    #[test]
    fn multistate_label_layout_and_active_state_forces() {
        let pes = MultiState::photo(2, 3);
        let mut o = MultiStateOracle::new(pes.clone(), 1);
        // active state 1
        let input = [0.0, 0.0, 0.0, 1.5, 0.0, 0.0, /*g*/ 0.0, /*s*/ 0.0, 1.0, 0.0];
        let label = o.run_calc(&input);
        assert_eq!(label.len(), 3 + 6);
        // energies sorted by state index at this geometry
        assert!(label[0] < label[1] && label[1] < label[2]);
        // forces match state 1 directly
        let f1 = pes.state_forces(&input[..6], 1);
        for (a, b) in label[3..].iter().zip(&f1) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
