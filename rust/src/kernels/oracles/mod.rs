//! Built-in oracle kernels: ground-truth labelers of Table 1 (analytic
//! stand-ins for TDDFT/DFT/xTB/CFD — see DESIGN.md §3).

mod cfd;
mod latency;
mod pes_oracle;

pub use cfd::ChannelFlowOracle;
pub use latency::LatencyOracle;
pub use pes_oracle::{MultiStateOracle, PesOracle};
