//! Reduced-order channel-flow "CFD" oracle for the thermo-fluid
//! application (§3.4) — the OpenFOAM stand-in.
//!
//! Computes drag coefficient `C_f` and Stanton number `St` for a 2-D
//! laminar channel with eddy promoters, using a deterministic reduced-order
//! model: promoters add blockage drag (∝ projected area with wake-shadowing
//! between streamwise neighbours) and enhance heat transfer (mixing ∝
//! promoter count and wall proximity, with diminishing returns). The exact
//! coefficients are not physical truth — what matters for the AL loop is a
//! smooth, nontrivial geometry→(C_f, St) map with realistic trade-off
//! structure (more promoters → more drag *and* more heat transfer), which
//! gives the PSO a meaningful Pareto landscape.

use crate::data::batch::{BatchView, RowBlock};
use crate::kernels::Oracle;

/// Baseline fully-developed laminar values (dimensionless toy units).
const CF0: f32 = 0.085;
const ST0: f32 = 0.021;

/// Reduced-order 2-D channel flow labeled `[C_f, St]`.
pub struct ChannelFlowOracle {
    pub grid: usize,
    labels: u64,
}

impl ChannelFlowOracle {
    pub fn new(grid: usize) -> Self {
        ChannelFlowOracle { grid, labels: 0 }
    }

    pub fn labels(&self) -> u64 {
        self.labels
    }

    /// Evaluate the ROM on an occupancy grid (row-major, H = W = grid).
    pub fn evaluate(&self, grid: &[f32]) -> (f32, f32) {
        let g = self.grid;
        debug_assert_eq!(grid.len(), g * g);
        let occ = |x: usize, y: usize| grid[y * g + x] > 0.5;

        // column blockage: fraction of each streamwise column occupied
        let mut drag = 0.0f32;
        let mut shadow = vec![false; g]; // wake shadowing per row
        for x in 0..g {
            let mut col_block = 0.0f32;
            for y in 0..g {
                if occ(x, y) {
                    // a promoter in the wake of an upstream one adds less drag
                    col_block += if shadow[y] { 0.25 } else { 1.0 };
                    shadow[y] = true;
                } else {
                    // wake decays
                    if shadow[y] && (x % 3 == 0) {
                        shadow[y] = false;
                    }
                }
            }
            drag += col_block / g as f32;
        }
        drag /= g as f32;

        // mixing: promoters near the channel centerline mix best; wall-
        // adjacent ones disturb the boundary layer directly
        let mut mixing = 0.0f32;
        let mut wall_disturb = 0.0f32;
        for y in 0..g {
            let yn = (y as f32 + 0.5) / g as f32; // 0..1 across channel
            let center_w = 1.0 - (2.0 * yn - 1.0).abs(); // 1 at center
            let wall_w = 1.0 - center_w;
            for x in 0..g {
                if occ(x, y) {
                    mixing += center_w;
                    wall_disturb += wall_w;
                }
            }
        }
        let n_occ: f32 = grid.iter().filter(|&&v| v > 0.5).count() as f32;
        let norm = (g * g) as f32;

        // diminishing returns on heat-transfer enhancement
        let enhancement = 1.0 + 2.5 * (1.0 - (-(3.0 * mixing / norm + 1.5 * wall_disturb / norm)).exp());
        let cf = CF0 * (1.0 + 9.0 * drag + 0.8 * n_occ / norm);
        let st = ST0 * enhancement;
        (cf, st)
    }
}

impl Oracle for ChannelFlowOracle {
    fn run_calc(&mut self, input: &[f32]) -> Vec<f32> {
        self.labels += 1;
        let (cf, st) = self.evaluate(input);
        vec![cf, st]
    }

    /// Native batch labeling: each `[C_f, St]` row writes straight into the
    /// contiguous output block — no `Vec` per label, same values as the
    /// per-label path.
    fn run_calc_batch(&mut self, inputs: &BatchView<'_>) -> RowBlock {
        let mut out = RowBlock::with_capacity(inputs.rows(), inputs.rows() * 2);
        for row in inputs.iter() {
            self.labels += 1;
            let (cf, st) = self.evaluate(row);
            out.push_row(&[cf, st]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty(g: usize) -> Vec<f32> {
        vec![0.0; g * g]
    }

    #[test]
    fn empty_channel_is_baseline() {
        let o = ChannelFlowOracle::new(16);
        let (cf, st) = o.evaluate(&empty(16));
        assert!((cf - CF0).abs() < 1e-6);
        assert!((st - ST0).abs() < 1e-6);
    }

    #[test]
    fn promoters_increase_both_cf_and_st() {
        let o = ChannelFlowOracle::new(16);
        let mut grid = empty(16);
        for (x, y) in [(4usize, 8usize), (8, 4), (12, 10)] {
            grid[y * 16 + x] = 1.0;
        }
        let (cf, st) = o.evaluate(&grid);
        assert!(cf > CF0, "cf {cf}");
        assert!(st > ST0, "st {st}");
    }

    #[test]
    fn centerline_promoter_mixes_more_than_wall() {
        let o = ChannelFlowOracle::new(16);
        let mut center = empty(16);
        center[8 * 16 + 8] = 1.0;
        let mut wall = empty(16);
        wall[15 * 16 + 8] = 1.0; // same column, near wall
        let (_, st_c) = o.evaluate(&center);
        let (_, st_w) = o.evaluate(&wall);
        assert!(st_c > st_w, "center {st_c} vs wall {st_w}");
    }

    #[test]
    fn wake_shadowing_discounts_downstream_drag() {
        let o = ChannelFlowOracle::new(16);
        // two promoters in the same row, adjacent columns (shadowed)
        let mut tandem = empty(16);
        tandem[8 * 16 + 4] = 1.0;
        tandem[8 * 16 + 5] = 1.0;
        // two promoters in different rows (both exposed)
        let mut spread = empty(16);
        spread[4 * 16 + 4] = 1.0;
        spread[12 * 16 + 10] = 1.0;
        let (cf_t, _) = o.evaluate(&tandem);
        let (cf_s, _) = o.evaluate(&spread);
        assert!(cf_t < cf_s, "tandem {cf_t} should draft below spread {cf_s}");
    }

    #[test]
    fn oracle_interface_counts_labels() {
        let mut o = ChannelFlowOracle::new(8);
        let out = o.run_calc(&empty(8));
        assert_eq!(out.len(), 2);
        assert_eq!(o.labels(), 1);
    }

    #[test]
    fn batch_labels_match_per_label_path() {
        use crate::data::batch::Batch;
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..64).map(|k| if (i * 7 + k) % 9 == 0 { 1.0 } else { 0.0 }).collect())
            .collect();
        let mut per_label = ChannelFlowOracle::new(8);
        let want: Vec<Vec<f32>> = rows.iter().map(|r| per_label.run_calc(r)).collect();
        let mut batched = ChannelFlowOracle::new(8);
        let batch = Batch::from_rows(&rows).unwrap();
        let got = batched.run_calc_batch(&batch.view());
        assert_eq!(got.to_nested(), want);
        assert_eq!(batched.labels(), per_label.labels());
    }

    #[test]
    fn st_saturates() {
        let o = ChannelFlowOracle::new(8);
        let full: Vec<f32> = vec![1.0; 64];
        let (_, st_full) = o.evaluate(&full);
        assert!(st_full < ST0 * 4.0, "diminishing returns violated: {st_full}");
    }
}
