//! Model kernels backed by AOT artifacts (PJRT) — the deployed ML models.
//!
//! Each prediction/training rank owns **one committee member**, exactly like
//! the paper's one-MPI-process-per-model layout; the controller aggregates
//! across ranks (query-by-committee). The `*1` artifact variants
//! (`potential_ground1_*`, `surrogate1_*`) are single-member lowerings used
//! here; the fused multi-member variants back the fused-committee benches.

mod hlo_potential;
mod hlo_surrogate;
mod hlo_toy;
pub(crate) mod util;

pub use hlo_potential::{HloPotentialModel, TrainOptions};
pub use hlo_surrogate::HloSurrogateModel;
pub use hlo_toy::HloToyModel;
